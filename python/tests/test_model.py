"""Layer-2 model correctness: Pallas-backed graphs vs pure-jnp reference
gradients (jax.grad of ref losses), plus the decomposition property the
coding layer relies on (shard gradients sum to the full gradient)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def data(seed, m, d, c=None):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (m, d), dtype=jnp.float32) / np.sqrt(d)
    if c is None:
        y = jax.random.normal(k2, (m, 1), dtype=jnp.float32)
        return x, y
    labels = jax.random.randint(k2, (m,), 0, c)
    y = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    return x, y


# ------------------------------------------------------------- linreg


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 64), d=st.integers(1, 96), seed=st.integers(0, 10**6))
def test_linreg_grad_matches_ref(m, d, seed):
    x, y = data(seed, m, d)
    theta = jax.random.normal(jax.random.PRNGKey(seed + 1), (d,), dtype=jnp.float32)
    got = model.linreg_grad(theta, x, y)
    want = ref.linreg_grad_ref(theta, x, y)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
    # And the closed-form grad equals autodiff of the Pallas loss.
    auto = jax.grad(model.linreg_loss)(theta, x, y)
    assert_allclose(np.asarray(got), np.asarray(auto), rtol=1e-4, atol=1e-4)


def test_linreg_loss_matches_ref():
    x, y = data(7, 32, 16)
    theta = jax.random.normal(jax.random.PRNGKey(8), (16,), dtype=jnp.float32)
    assert_allclose(
        float(model.linreg_loss(theta, x, y)),
        float(ref.linreg_loss_ref(theta, x, y)),
        rtol=1e-5,
    )


# ------------------------------------------------------------------ mlp


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 32),
    d=st.integers(2, 24),
    h=st.integers(2, 48),
    c=st.integers(2, 8),
    seed=st.integers(0, 10**6),
)
def test_mlp_grad_matches_ref(m, d, h, c, seed):
    x, y = data(seed, m, d, c)
    dim = ref.mlp_dim(d, h, c)
    theta = 0.3 * jax.random.normal(jax.random.PRNGKey(seed + 1), (dim,), dtype=jnp.float32)
    got = model.mlp_grad(theta, x, y, hidden=h)
    want = ref.mlp_grad_ref(theta, x, y, hidden=h)
    assert got.shape == (dim,)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_mlp_loss_matches_ref():
    x, y = data(3, 16, 8, 5)
    dim = ref.mlp_dim(8, 12, 5)
    theta = 0.3 * jax.random.normal(jax.random.PRNGKey(4), (dim,), dtype=jnp.float32)
    assert_allclose(
        float(model.mlp_loss(theta, x, y, hidden=12)),
        float(ref.mlp_loss_ref(theta, x, y, hidden=12)),
        rtol=1e-5,
    )


def test_shard_grads_sum_to_full_gradient():
    """The decomposition property gradient coding relies on."""
    d, h, c, m, shards = 6, 10, 3, 24, 4
    x, y = data(11, m, d, c)
    dim = ref.mlp_dim(d, h, c)
    theta = 0.3 * jax.random.normal(jax.random.PRNGKey(12), (dim,), dtype=jnp.float32)
    per = m // shards
    total = jnp.zeros(dim)
    for s in range(shards):
        xs = x[s * per : (s + 1) * per]
        ys = y[s * per : (s + 1) * per]
        total = total + model.mlp_grad(theta, xs, ys, hidden=h)
    full = model.mlp_grad(theta, x, y, hidden=h)
    assert_allclose(np.asarray(total), np.asarray(full), rtol=1e-3, atol=1e-3)


def test_coded_grad_fuses_encode():
    d, h, c, m, k = 5, 8, 3, 6, 3
    dim = ref.mlp_dim(d, h, c)
    key = jax.random.PRNGKey(21)
    ks = jax.random.split(key, 4)
    theta = 0.3 * jax.random.normal(ks[0], (dim,), dtype=jnp.float32)
    xs = jax.random.normal(ks[1], (k, m, d), dtype=jnp.float32)
    labels = jax.random.randint(ks[2], (k, m), 0, c)
    ys = jax.nn.one_hot(labels, c, dtype=jnp.float32)
    coeffs = jax.random.normal(ks[3], (k,), dtype=jnp.float32)
    got = model.coded_grad(theta, xs, ys, coeffs, hidden=h)
    want = sum(
        coeffs[i] * ref.mlp_grad_ref(theta, xs[i], ys[i], hidden=h) for i in range(k)
    )
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_mlp_gd_reduces_loss():
    """A few full-batch GD steps on the Pallas-backed model must descend."""
    d, h, c, m = 8, 16, 4, 64
    x, y = data(31, m, d, c)
    dim = ref.mlp_dim(d, h, c)
    theta = 0.1 * jax.random.normal(jax.random.PRNGKey(32), (dim,), dtype=jnp.float32)
    loss0 = float(model.mlp_loss(theta, x, y, hidden=h))
    grad = functools.partial(model.mlp_grad, hidden=h)
    for _ in range(40):
        theta = theta - 0.02 * grad(theta, x, y)
    loss1 = float(model.mlp_loss(theta, x, y, hidden=h))
    assert loss1 < 0.8 * loss0, (loss0, loss1)
