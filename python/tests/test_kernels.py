"""Layer-1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes (divisible and ragged vs the tile sizes) and the
values' scale; assert_allclose against ref.py is the core signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import ref
from compile.kernels.encode import pl_encode
from compile.kernels.matmul import pl_matmul, vmem_footprint_bytes

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


# ------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_hypothesis(m, k, n, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(k1, m, k)
    y = rand(k2, k, n)
    got = pl_matmul(x, y)
    want = ref.matmul_ref(x, y)
    assert got.shape == want.shape
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (128, 128, 128),  # exactly one tile
        (256, 384, 128),  # multi-tile, divisible
        (1, 1, 1),        # degenerate
        (127, 129, 3),    # ragged on every axis
        (130, 64, 200),
    ],
)
def test_matmul_shapes(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m * 7 + k * 3 + n))
    x = rand(k1, m, k)
    y = rand(k2, k, n)
    # Tiled accumulation reorders the f32 sums vs XLA's dot — allow the
    # corresponding rounding slack (grows with k).
    assert_allclose(
        np.asarray(pl_matmul(x, y)),
        np.asarray(ref.matmul_ref(x, y)),
        rtol=1e-4,
        atol=1e-3,
    )


def test_matmul_large_scale_values():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = rand(k1, 64, 64, scale=100.0)
    y = rand(k2, 64, 64, scale=100.0)
    assert_allclose(
        np.asarray(pl_matmul(x, y)),
        np.asarray(ref.matmul_ref(x, y)),
        rtol=1e-4,
        atol=1e-2,
    )


def test_matmul_gradient_flows_through_custom_vjp():
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    x = rand(k1, 17, 9)
    y = rand(k2, 9, 5)

    def f(x, y):
        return jnp.sum(pl_matmul(x, y) ** 2)

    def f_ref(x, y):
        return jnp.sum(ref.matmul_ref(x, y) ** 2)

    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    gx_ref, gy_ref = jax.grad(f_ref, argnums=(0, 1))(x, y)
    assert_allclose(np.asarray(gx), np.asarray(gx_ref), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(gy), np.asarray(gy_ref), rtol=1e-4, atol=1e-4)


def test_vmem_footprint_within_budget():
    # Default tiles must fit comfortably in 16 MiB VMEM.
    assert vmem_footprint_bytes() <= 16 * 1024 * 1024 // 4


# -------------------------------------------------------------- encode


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 8),
    l=st.integers(1, 2000),
    seed=st.integers(0, 2**31 - 1),
)
def test_encode_matches_ref_hypothesis(k, l, seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    coeffs = rand(k1, k)
    grads = rand(k2, k, l)
    got = pl_encode(coeffs, grads)
    want = ref.encode_ref(coeffs, grads)
    assert got.shape == (l,)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_encode_exact_tile_boundary():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    coeffs = rand(k1, 3)
    grads = rand(k2, 3, 1024)  # exactly two 512-tiles
    assert_allclose(
        np.asarray(pl_encode(coeffs, grads)),
        np.asarray(ref.encode_ref(coeffs, grads)),
        rtol=1e-5,
        atol=1e-5,
    )
