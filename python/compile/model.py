"""Layer-2: the per-worker shard-gradient compute graphs.

Each model family exposes `*_loss(theta, x, y)` and `*_grad(theta, x, y)`
over a **flat** parameter vector and one data shard; gradients are sums
(not means) over the shard's samples so the master's decoded gradient is
exactly `∇F = Σ_n ∇F(D_n; θ)`.

All matmuls — forward and backward — lower through the Layer-1 Pallas
kernel (`kernels.matmul.pl_matmul`, which carries a custom VJP built from
itself). `jax.grad` of these functions therefore produces an HLO module
whose hot loops are the Pallas tiles.

`coded_grad` additionally fuses the gradient-code combine
(`kernels.encode.pl_encode`) so a worker's entire contribution for a
single-level code is one executable call.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.encode import pl_encode
from .kernels.matmul import pl_matmul

# ---------------------------------------------------------------- linreg


def linreg_loss(theta, x, y):
    """½‖Xθ − y‖² summed over the shard (y: [m, 1])."""
    pred = pl_matmul(x, theta[:, None])[:, 0]
    r = pred - y[:, 0]
    return 0.5 * jnp.sum(r * r)


def linreg_grad(theta, x, y):
    """Closed-form `Xᵀ(Xθ − y)` through the Pallas kernel."""
    pred = pl_matmul(x, theta[:, None])[:, 0]
    r = pred - y[:, 0]
    return pl_matmul(x.T, r[:, None])[:, 0]


# ------------------------------------------------------------------- mlp


def mlp_loss(theta, x, y, *, hidden):
    """Summed softmax-CE of the one-hidden-layer ReLU MLP (y one-hot)."""
    d = x.shape[1]
    c = y.shape[1]
    w1, b1, w2, b2 = ref.mlp_unflatten(theta, d, hidden, c)
    z1 = pl_matmul(x, w1) + b1
    a = jax.nn.relu(z1)
    logits = pl_matmul(a, w2) + b2
    logz = jax.scipy.special.logsumexp(logits, axis=1)
    return jnp.sum(logz - jnp.sum(y * logits, axis=1))


def mlp_grad(theta, x, y, *, hidden):
    """`jax.grad` of `mlp_loss` — backward matmuls are Pallas too (custom
    VJP on `pl_matmul`)."""
    return jax.grad(mlp_loss)(theta, x, y, hidden=hidden)


# ---------------------------------------------------- fused coded gradient


def coded_grad(theta, xs, ys, coeffs, *, hidden):
    """Worker-side fused contribution for a single-level code:
    `Σ_k coeffs[k] · ∇F(D_k; θ)` with `xs: [K, m, d]`, `ys: [K, m, c]`.

    The shard gradients are computed by the Pallas-backed model and the
    combine by the Pallas encode kernel, all in one HLO module.
    """
    grads = jax.vmap(lambda xk, yk: mlp_grad(theta, xk, yk, hidden=hidden))(xs, ys)
    return pl_encode(coeffs, grads)
