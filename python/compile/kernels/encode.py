"""Layer-1: the gradient *encode* combine as a Pallas kernel.

Worker `n`'s coded block is `Σ_k c_k · g_k[block]` — a coefficient-weighted
reduction over the `s+1` shard gradients it holds. The kernel tiles the
coordinate axis (`L` can be large) and keeps the small coefficient vector
resident; one pass per output tile.

In the deployed system the Rust coordinator performs this combine (the
paper's cost model omits encode/decode cost because it is ~`(s+1)·L` flops
against `(M/N)·b·L` for the gradients). The kernel exists so the *fused*
"coded gradient" artifact (`model.coded_grad`) can compute
`Σ_k c_k · ∇F(D_k; θ)` entirely inside one HLO module — used by the
single-level fast path and benchmarked in §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Coordinate-axis tile.
BL = 512


def _encode_kernel(c_ref, g_ref, o_ref):
    # o[l] = Σ_k c[k] · g[k, l] for one tile of l.
    o_ref[...] = jnp.sum(c_ref[...][:, None] * g_ref[...], axis=0)


@functools.partial(jax.jit, static_argnames=("bl",))
def pl_encode(coeffs, grads, bl=BL):
    """Weighted reduction `coeffs @ grads` with `coeffs: [K]`,
    `grads: [K, L] → [L]`, tiled over `L`."""
    k, l = grads.shape
    assert coeffs.shape == (k,), f"coeffs {coeffs.shape} vs grads {grads.shape}"
    lp = (l + bl - 1) // bl * bl
    gp = jnp.pad(grads, ((0, 0), (0, lp - l))) if lp != l else grads
    out = pl.pallas_call(
        _encode_kernel,
        grid=(lp // bl,),
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, bl), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bl,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lp,), jnp.float32),
        interpret=True,
    )(coeffs, gp)
    return out[:l]
