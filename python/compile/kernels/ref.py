"""Pure-jnp oracles for the Pallas kernels and the Layer-2 models.

Everything here is the *specification*; pytest asserts the Pallas/L2
implementations match it (`assert_allclose`), which is the core
correctness signal of the compile path.
"""

import jax
import jax.numpy as jnp


def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def encode_ref(coeffs, grads):
    return jnp.einsum("k,kl->l", coeffs, grads)


# ---------------------------------------------------------------- linreg

def linreg_loss_ref(theta, x, y):
    """½‖Xθ − y‖² summed over the shard; y: [m, 1]."""
    r = x @ theta - y[:, 0]
    return 0.5 * jnp.sum(r * r)


def linreg_grad_ref(theta, x, y):
    r = x @ theta - y[:, 0]
    return x.T @ r


# ------------------------------------------------------------------- mlp

def mlp_unflatten(theta, d, h, c):
    """Split the flat parameter vector into (W1, b1, W2, b2)."""
    i = 0
    w1 = theta[i : i + d * h].reshape(d, h)
    i += d * h
    b1 = theta[i : i + h]
    i += h
    w2 = theta[i : i + h * c].reshape(h, c)
    i += h * c
    b2 = theta[i : i + c]
    return w1, b1, w2, b2


def mlp_dim(d, h, c):
    return d * h + h + h * c + c


def mlp_loss_ref(theta, x, y, *, hidden):
    """Summed softmax cross-entropy of the one-hidden-layer ReLU MLP.
    `y` is one-hot `[m, c]`."""
    d = x.shape[1]
    c = y.shape[1]
    w1, b1, w2, b2 = mlp_unflatten(theta, d, hidden, c)
    z1 = x @ w1 + b1
    a = jax.nn.relu(z1)
    logits = a @ w2 + b2
    logz = jax.scipy.special.logsumexp(logits, axis=1)
    return jnp.sum(logz - jnp.sum(y * logits, axis=1))


def mlp_grad_ref(theta, x, y, *, hidden):
    return jax.grad(mlp_loss_ref)(theta, x, y, hidden=hidden)
