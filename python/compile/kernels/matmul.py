"""Layer-1: tiled Pallas matmul — the compute hot-spot of every model here.

The shard-gradient graphs (Layer 2) are matmul-dominated: `Xθ`, `Xᵀr`,
MLP forward (`X·W1`, `A·W2`) and backward (`Aᵀ·dZ2`, `dZ2·W2ᵀ`, `Xᵀ·dZ1`).
All of them route through `pl_matmul`, a Pallas kernel with an explicit
HBM→VMEM tiling schedule via `BlockSpec`:

* grid `(M/bm, N/bn, K/bk)`, MXU-aligned default tiles `128×128×128`;
* the output tile lives in VMEM across the `k` sweep (revisiting grid —
  the accumulator never round-trips to HBM);
* f32 accumulation.

TPU mapping notes (DESIGN.md §Hardware-Adaptation): the paper is
hardware-agnostic (cost model in CPU cycles), so there is no CUDA kernel
to port; the adaptation is the choice of VMEM-resident accumulator tiles
and 128-alignment for the MXU systolic array. On this CPU-only image the
kernel runs under `interpret=True` (real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute); numerics are identical.

`pl_matmul` carries a `jax.custom_vjp` whose backward pass is two more
`pl_matmul` calls, so `jax.grad` of any Layer-2 model lowers *every*
matmul — forward and backward — through this kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned tile sizes (see module docstring).
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; k is the innermost grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(a, rows, cols):
    pr, pc = rows - a.shape[0], cols - a.shape[1]
    if pr == 0 and pc == 0:
        return a
    return jnp.pad(a, ((0, pr), (0, pc)))


def _ceil_mul(v, m):
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def _matmul_pallas(x, y, bm=BM, bn=BN, bk=BK):
    """Raw tiled matmul on padded operands."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"matmul shape mismatch {x.shape} @ {y.shape}"
    mp, np_, kp = _ceil_mul(m, bm), _ceil_mul(n, bn), _ceil_mul(k, bk)
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def pl_matmul(x, y):
    """`x @ y` through the Pallas kernel, differentiable (VJP is two more
    Pallas matmuls)."""
    return _matmul_pallas(x, y)


def _fwd(x, y):
    return _matmul_pallas(x, y), (x, y)


def _bwd(res, g):
    x, y = res
    gx = _matmul_pallas(g, y.T)
    gy = _matmul_pallas(x.T, g)
    return gx, gy


pl_matmul.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(bm=BM, bn=BN, bk=BK, dtype_bytes=4):
    """Estimated VMEM residency of one grid step: x-tile + y-tile +
    accumulator tile (used for the §Perf roofline table)."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
