"""Build-time compile path: L2 JAX models + L1 Pallas kernels + AOT lowering."""
