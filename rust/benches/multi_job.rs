//! Shared worker pool vs disjoint split — the perf-trajectory bench
//! behind `BENCH_multijob.json`.
//!
//! Scenario: two tenants of **unequal length** (150-step and 50-step
//! MLP jobs, same dataset size) on `N = 8` workers with §VI
//! shifted-exponential stragglers. Two arms, both on the *real
//! threaded* coordinator (virtual pacing, real gradients, real
//! decodes):
//!
//! * **shared** — one [`WorkerPool`] of 8; the pool interleaves the
//!   jobs' per-iteration broadcasts round-robin and reassigns the full
//!   fleet to the long job once the short one finishes. Makespan =
//!   the serialized sum of every round's Eq. (2) virtual runtime.
//! * **disjoint** — the classic static split: two independent 4-worker
//!   pools, each job's dataset re-sharded 4 ways and its `x^(f)`
//!   re-solved for `N = 4`. The pools run concurrently, so makespan =
//!   the slower pool's summed virtual runtime.
//!
//! Pooling wins on asymmetric tenants because the disjoint split
//! strands half the fleet when the short job ends — the production
//! story for multi-tenant straggler mitigation (redundancy priced per
//! cluster, not per job). On perfectly symmetric tenants the split is
//! competitive (larger-`N` order statistics decay slower than 1/N);
//! the headline config is the asymmetric one.
//!
//! The JSON artifact (same schema as
//! `sim::multi::MultiJobComparison::render_json`) tracks the makespan
//! improvement across PRs.
//!
//! Run: `cargo bench --bench multi_job` (set `BENCH_OUT` to move the
//! artifact; defaults to ./BENCH_multijob.json).

use bcgc::bench_harness::{banner, stamp_bench_meta};
use bcgc::coordinator::metrics::TrainReport;
use bcgc::coordinator::pool::{JobSpec, PoolConfig, WorkerPool};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::{host, host_factory};
use bcgc::sim::{MultiJobComparison, SimJob};

const N: usize = 8;
const STEPS: [usize; 2] = [150, 50];
const SEED: u64 = 2021;
const MU: f64 = 1e-3;
const T0: f64 = 50.0;

/// MLP dimensions shared by both tenants (each gets its own dataset).
const FEATURES: usize = 32;
const HIDDEN: usize = 64;
const CLASSES: usize = 10;
/// Total samples per job — fixed across arms (re-sharded per `N`).
const SAMPLES: usize = 512;

fn virtual_total(report: &TrainReport) -> f64 {
    report.iters.iter().map(|m| m.virtual_runtime).sum()
}

/// One single-job pool of `n` workers: the disjoint arm's half-pools.
fn run_isolated(job: usize, n: usize, steps: usize) -> bcgc::Result<f64> {
    let dist = ShiftedExponential::new(MU, T0);
    let ds = synthetic::classification(FEATURES, CLASSES, SAMPLES, n, 0.2, SEED + 1 + job as u64)?;
    let dim = host::HostExecutor::mlp_dim(FEATURES, HIDDEN, CLASSES);
    let spec = ProblemSpec::new(n, dim, SAMPLES, 1.0);
    let blocks = x_freq_blocks(&spec, &dist, dim)?;
    let mut pcfg = PoolConfig::new(n);
    pcfg.seed = SEED ^ (0xD15_701A17 + job as u64);
    let mut pool = WorkerPool::new(pcfg, StragglerSchedule::stationary(Box::new(dist)))?;
    JobSpec::new(spec, blocks)
        .steps(steps)
        .lr(2e-3)
        .eval_every(0)
        .seed(SEED + 1 + job as u64)
        .executor(host_factory(ds, host::HostModel::Mlp { hidden: HIDDEN }))
        .submit(&mut pool)?;
    let reports = pool.run_to_completion()?;
    Ok(virtual_total(&reports[0]))
}

fn main() {
    banner(
        "Multi-job coordinator — 2 jobs on one shared pool vs 2 disjoint half pools",
        "N=8 shared vs 2x4 split; 150+50-step MLP tenants; shifted-exp(mu=1e-3, t0=50); \
         threaded coordinator, virtual pacing; makespan in Eq. (2) virtual time.",
    );
    let dim = host::HostExecutor::mlp_dim(FEATURES, HIDDEN, CLASSES);
    let dist = ShiftedExponential::new(MU, T0);

    // --- Shared arm: one 8-worker pool, both tenants interleaved.
    let mut pcfg = PoolConfig::new(N);
    pcfg.seed = SEED;
    let mut pool =
        WorkerPool::new(pcfg, StragglerSchedule::stationary(Box::new(dist.clone()))).unwrap();
    for (j, &steps) in STEPS.iter().enumerate() {
        let ds =
            synthetic::classification(FEATURES, CLASSES, SAMPLES, N, 0.2, SEED + 1 + j as u64)
                .unwrap();
        let spec = ProblemSpec::new(N, dim, SAMPLES, 1.0);
        let blocks = x_freq_blocks(&spec, &dist, dim).unwrap();
        JobSpec::new(spec, blocks)
            .steps(steps)
            .lr(2e-3)
            .eval_every(0)
            .seed(SEED + 1 + j as u64)
            .executor(host_factory(ds, host::HostModel::Mlp { hidden: HIDDEN }))
            .submit(&mut pool)
            .unwrap();
    }
    pool.run_all().unwrap();
    let shared_rounds = pool.rounds();
    let shared_makespan = pool.virtual_makespan();
    let cross = pool.cross_job_dropped();
    let reports = pool.finish().unwrap();
    let shared_per_job: Vec<f64> = reports.iter().map(virtual_total).collect();
    let shared_decode_cache: Vec<(u64, u64)> = reports
        .iter()
        .map(|r| (r.decode_cache_hits, r.decode_cache_misses))
        .collect();
    for (j, r) in reports.iter().enumerate() {
        assert_eq!(r.steps(), STEPS[j], "job {j} dropped iterations");
        assert!(
            r.iters.iter().all(|m| m.grad_norm.is_finite()),
            "job {j} decoded a non-finite gradient"
        );
    }
    assert_eq!(cross, 0, "no contribution may carry an unknown job id");

    // --- Disjoint arm: two independent half pools, run back to back in
    // wall time; their virtual clocks are independent (concurrent).
    let disjoint_per_pool: Vec<f64> = STEPS
        .iter()
        .enumerate()
        .map(|(j, &steps)| run_isolated(j, N / 2, steps).unwrap())
        .collect();

    let cmp = MultiJobComparison {
        pool_n: N,
        split_n: N / 2,
        jobs: STEPS.iter().map(|&steps| SimJob { coords: dim, steps }).collect(),
        schedule_label: dist.label(),
        shared_rounds,
        shared_makespan,
        shared_per_job,
        shared_decode_cache,
        disjoint_per_pool,
    };
    print!("{}", cmp.render_report());
    assert!(
        cmp.shared_makespan <= cmp.disjoint_makespan(),
        "the shared pool must finish asymmetric tenants no later than a disjoint split \
         (shared {} vs disjoint {})",
        cmp.shared_makespan,
        cmp.disjoint_makespan()
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_multijob.json".into());
    let json = stamp_bench_meta(
        &cmp.render_json(),
        SEED,
        &format!(
            "N={N} split={} jobs={:?} L={dim} M={SAMPLES} mu={MU} t0={T0} threaded",
            N / 2,
            STEPS
        ),
    );
    std::fs::write(&out, json).expect("write bench artifact");
    println!("wrote {out}");
}
