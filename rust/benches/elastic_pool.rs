//! Elastic-vs-static under worker churn — the perf-trajectory bench
//! behind `BENCH_elastic.json`.
//!
//! Scenario: N = 20 workers, L = 2·10⁴ coordinates (the paper's Fig. 4
//! scale), stationary §VI stragglers (μ = 10⁻³, t0 = 50). At iteration
//! 100 of 300, two workers depart for good. Two arms, on common random
//! numbers:
//!
//! * **static** — the initial `x^(f)` (redundancy floor raised to s ≥ 2
//!   so the fixed-`N` code can still decode with two dead rows) kept
//!   for the whole run; the departed workers become permanent
//!   stragglers it must code around forever;
//! * **elastic** — same initial scheme; at the churn the coordinator
//!   re-solves `x^(f)` for the live `N' = 18` from its windowed online
//!   fit and installs the re-dimensioned scheme as a fresh epoch.
//!
//! The headline metric is the mean per-iteration overall runtime after
//! the churn (+grace); the JSON artifact tracks it across PRs.
//!
//! Run: `cargo bench --bench elastic_pool` (set `BENCH_OUT` to move
//! the artifact; defaults to ./BENCH_elastic.json).

use bcgc::bench_harness::{banner, stamp_bench_meta};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::sim::{compare_elastic_vs_static, ChurnSchedule, MultiSimConfig};

fn main() {
    banner(
        "Elastic worker pool — departures mid-run, re-dimensioned x^(f)",
        "N=20, L=2e4; 2 workers depart at iter 100 of 300; grace 40; CRN across arms.",
    );
    let (n, coords) = (20usize, 20_000usize);
    let (iters, churn_at, departures, grace, seed) = (300usize, 100usize, 2usize, 40usize, 2021u64);
    let spec = ProblemSpec::paper_default(n, coords);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let schedule = StragglerSchedule::stationary(Box::new(dist.clone()));
    // Floor the redundancy at the departure count so the static arm
    // stays decodable — the fairest non-adaptive baseline.
    let initial = x_freq_blocks(&spec, &dist, coords).unwrap().raise_min_level(departures);
    let churn = ChurnSchedule::none().then_depart(churn_at, departures);
    println!("initial x^(f) (floor s≥{departures}): {initial}");
    println!("churn schedule: {}\n", churn.label());

    let cfg = MultiSimConfig { iters, seed, comm_latency: 0.0 };
    let cmp = compare_elastic_vs_static(
        &spec,
        &initial,
        &schedule,
        &churn,
        &cfg,
        20 * n, // fit window: ~20 iterations of observations
        grace,
    )
    .unwrap();

    print!("{}", cmp.render_report());
    assert!(
        cmp.elastic_after() < cmp.static_after(),
        "the elastic coordinator must beat the static-N scheme after a departure"
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_elastic.json".into());
    let json = stamp_bench_meta(
        &cmp.render_json(),
        seed,
        &format!(
            "N={n} L={coords} iters={iters} churn_at={churn_at} departures={departures} grace={grace}"
        ),
    );
    std::fs::write(&out, json).expect("write bench artifact");
    println!("wrote {out}");
}
