//! Fig. 4(a) reproduction: expected overall runtime vs the number of
//! workers N ∈ {10, 20, 30, 40, 50} at L = 2·10⁴,
//! shifted-exponential(μ = 10⁻³, t0 = 50), M = 50, b = 1.
//!
//! Seven series as in the paper: the three proposed solutions
//! (x̂†, x̂^(t), x̂^(f)) and the four baselines (single-BCGC, Tandon
//! α-partial, Ferdinand r=L, Ferdinand r=L/2). Evaluation uses common
//! random numbers across schemes at each N.
//!
//! Paper headline to reproduce in shape: proposed ≈ coincident and
//! lowest; ~37% reduction vs the best baseline at N = 50.
//!
//! Run: `cargo bench --bench fig4a_vs_n`

use bcgc::bench_harness::{banner, Table};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::evaluate::{compare_schemes, reduction_vs_best_baseline};
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
use bcgc::util::rng::Rng;

fn main() {
    banner(
        "Fig. 4(a) — E[overall runtime] vs number of workers N",
        "L=2e4, shifted-exponential(mu=1e-3, t0=50), M=50, b=1; 2000 CRN trials/point.",
    );
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let kinds: Vec<SchemeKind> = SchemeKind::proposed()
        .into_iter()
        .chain(SchemeKind::baselines())
        .collect();

    let mut headers: Vec<String> = vec!["N".into()];
    headers.extend(kinds.iter().map(|k| k.label().to_string()));
    headers.push("reduction vs best baseline".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    for n in [10usize, 20, 30, 40, 50] {
        let spec = ProblemSpec::paper_default(n, 20_000);
        let mut rng = Rng::new(2021 + n as u64);
        let opts = SolveOptions::default();
        let mut schemes = Vec::new();
        for &kind in &kinds {
            let p = solve(&spec, &dist, kind, &opts, &mut rng).unwrap();
            schemes.push((kind.label().to_string(), p));
        }
        let rows = compare_schemes(&spec, &schemes, &dist, 2000, &mut rng);
        let proposed_best = rows[..3].iter().map(|r| r.mean()).fold(f64::INFINITY, f64::min);
        let baselines: Vec<f64> = rows[3..].iter().map(|r| r.mean()).collect();
        let red = reduction_vs_best_baseline(proposed_best, &baselines);
        let mut cells: Vec<String> = vec![n.to_string()];
        cells.extend(rows.iter().map(|r| format!("{:.0}", r.mean())));
        cells.push(format!("{red:.0}%"));
        table.row(&cells);

        // Shape assertions per point.
        for (i, row) in rows[..3].iter().enumerate() {
            assert!(
                row.mean() <= baselines.iter().cloned().fold(f64::INFINITY, f64::min) * 1.02,
                "proposed scheme {i} not competitive at N={n}: {}",
                row.mean()
            );
        }
    }
    table.print();
    println!("\nexpected shape: all series decrease with N; proposed three nearly coincide;");
    println!("paper quotes ~37% reduction vs best baseline at N=50.");
}
