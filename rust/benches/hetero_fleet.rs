//! Heterogeneity-aware vs pooled-i.i.d. re-solve on a 2-speed fleet —
//! the perf-trajectory bench behind `BENCH_hetero.json`.
//!
//! Scenario: N = 20 workers, L = 10⁴ coordinates; half the fleet is a
//! 4× slower machine generation (`T_slow = 4·T_fast` in distribution —
//! stationary, so this is pure heterogeneity, not drift). Both arms run
//! the same adaptive policy from the same naive uniform-s=1 partition
//! with no prior reference, on one CRN cycle-time stream; the *only*
//! difference is the sensing/actuation model:
//!
//! * **pooled** — the i.i.d. assumption the paper (and PRs 1–4)
//!   baked in: the mixed fleet is fitted as ONE family and `x^(f)`
//!   comes from pooled order statistics; every worker carries `1/N` of
//!   the data;
//! * **hetero** — per-worker windows keyed by stable `WorkerId`, the
//!   re-solve computed from the fleet's non-identical order statistics
//!   (`distribution::hetero`), and the dataset re-sharded in proportion
//!   to fitted mean rates, so fast workers carry more data instead of
//!   idling at the quorum barrier.
//!
//! The headline `improvement_pct` — how much faster the
//! heterogeneity-aware arm runs after both arms have converged — must
//! be strictly positive; the JSON artifact tracks it across PRs.
//!
//! Run: `cargo bench --bench hetero_fleet` (set `BENCH_OUT` to move the
//! artifact; defaults to ./BENCH_hetero.json).

use bcgc::bench_harness::{banner, stamp_bench_meta};
use bcgc::coordinator::adaptive::{AdaptiveConfig, HeteroConfig};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::sim::{compare_hetero_vs_pooled, MultiSimConfig};

fn main() {
    banner(
        "Heterogeneous fleet — per-worker models + speed-weighted shards vs pooled i.i.d.",
        "N=20 (10 fast + 10 slow, 4×), L=1e4; 400 iters, measured from 100; CRN across arms.",
    );
    let (n, n_slow, slow_factor, coords) = (20usize, 10usize, 4.0f64, 10_000usize);
    let (iters, seed, measure_from) = (400usize, 2021u64, 100usize);
    let spec = ProblemSpec::paper_default(n, coords);
    let fast = ShiftedExponential::new(1e-2, 50.0);
    let initial = BlockPartition::single_level(n, 1, coords);
    let base = AdaptiveConfig {
        window: 32 * n,
        min_samples: 16 * n,
        check_every: 10,
        cooldown: 20,
        drift_threshold: 0.2,
        ..Default::default()
    };
    let hetero_cfg = HeteroConfig {
        per_worker_window: 128,
        min_worker_samples: 16,
        speed_weighted_shards: true,
    };
    let cfg = MultiSimConfig { iters, seed, comm_latency: 0.0 };
    let cmp = compare_hetero_vs_pooled(
        &spec,
        &initial,
        &fast,
        n_slow,
        slow_factor,
        &cfg,
        base,
        hetero_cfg,
        measure_from,
    )
    .expect("comparison runs");
    println!("fleet: {}\n", cmp.fleet_label);

    let (p_after, h_after) = (cmp.pooled_after(), cmp.hetero_after());
    print!("{}", cmp.render_report());

    // Headline guarantees the artifact tracks a real effect.
    assert!(
        h_after < p_after,
        "the heterogeneity-aware re-solve ({h_after:.1}) must strictly beat the \
         pooled-i.i.d. baseline ({p_after:.1}) on a 2-speed fleet"
    );
    let min_fast = cmp.hetero_shard_counts[..n - n_slow].iter().min().copied().unwrap();
    let max_slow = cmp.hetero_shard_counts[n - n_slow..].iter().max().copied().unwrap();
    assert!(
        max_slow < min_fast,
        "speed-weighted actuation must load slow rows strictly lighter: {:?}",
        cmp.hetero_shard_counts
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_hetero.json".into());
    let stamped = stamp_bench_meta(
        &cmp.render_json(),
        seed,
        &format!(
            "N={n} L={coords} iters={iters} fleet=2speed({}fast+{n_slow}slow,{slow_factor}x)",
            n - n_slow
        ),
    );
    std::fs::write(&out, stamped).expect("write bench artifact");
    println!("wrote {out}");
}
