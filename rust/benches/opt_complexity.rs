//! §V complexity claims: the stochastic projected subgradient method
//! costs O(N log N) per iteration here (the paper bounds it O(N²) with a
//! dense projection), the closed forms cost O(N) given the order-stat
//! vectors, and decode-vector solves are cached on the hot path.
//!
//! Run: `cargo bench --bench opt_complexity`

use bcgc::bench_harness::{banner, black_box, fmt_ns, Bencher, Table};
use bcgc::coding::decoder::{decode_vector, DecodeCache};
use bcgc::coding::encoder::GradientCode;
use bcgc::distribution::order_stats::shifted_exp_exact;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::closed_form;
use bcgc::optimizer::projection::project_simplex;
use bcgc::optimizer::runtime_model::{sort_times, tau_hat_argmax, ProblemSpec, WorkModel};
use bcgc::util::rng::Rng;

fn main() {
    banner(
        "§V — optimizer cost scaling",
        "per-iteration subgradient step, closed-form solve, decode solve vs N.",
    );
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let b = Bencher::new(3, 15);

    let mut table = Table::new(&[
        "N",
        "subgradient iter",
        "x^(t) closed form",
        "order stats (exact)",
        "decode solve (cold)",
        "decode (cached)",
    ]);
    for n in [10usize, 20, 50, 100] {
        let spec = ProblemSpec::paper_default(n, 20_000);
        let os = shifted_exp_exact(&dist, n);
        let mut rng = Rng::new(n as u64);
        let mut x = vec![20_000.0 / n as f64; n];
        let mut t = vec![0.0; n];

        // One full subgradient iteration: sample, sort, argmax, step, project.
        let s_iter = b.run("subgrad", || {
            for v in t.iter_mut() {
                *v = dist.sample(&mut rng);
            }
            sort_times(&mut t);
            let (nstar, _) = tau_hat_argmax(&spec, &x, &t, WorkModel::GradientCoding);
            let ta = t[n - 1 - nstar];
            for (i, xi) in x.iter_mut().enumerate() {
                if i <= nstar {
                    *xi -= 1e-4 * ta * (i + 1) as f64;
                }
            }
            x = project_simplex(&x, 20_000.0);
            x[0]
        });

        let s_cf = b.run("closed-form", || {
            black_box(closed_form::x_time(&spec, &os).unwrap())
        });

        let s_os = b.run("order-stats", || {
            black_box(shifted_exp_exact(&dist, n))
        });

        // Decode solves at a mid redundancy level.
        let s = n / 3;
        let code = GradientCode::cyclic_mds(n, s, &mut rng).unwrap();
        let survivors: Vec<usize> = (0..n - s).collect();
        let s_cold = b.run("decode-cold", || {
            black_box(decode_vector(&code, &survivors).unwrap())
        });
        let mut cache = DecodeCache::new(64);
        let _ = cache.get(&code, &survivors).unwrap();
        let s_hot = b.run("decode-hot", || {
            cache.get(&code, &survivors).map(|a| a[0]).unwrap()
        });

        table.row(&[
            n.to_string(),
            fmt_ns(s_iter.median_ns()),
            fmt_ns(s_cf.median_ns()),
            fmt_ns(s_os.median_ns()),
            fmt_ns(s_cold.median_ns()),
            fmt_ns(s_hot.median_ns()),
        ]);
    }
    table.print();
    println!("\nsubgradient iteration should scale ~N log N; closed form ~N;");
    println!("cached decode should be orders of magnitude under the cold solve.");
}
