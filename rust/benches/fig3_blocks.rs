//! Fig. 3 reproduction: the optimized block structures
//! x̂†, x̂^(t), x̂^(f) at N = 20, L = 2·10⁴, μ = 10⁻³, t0 = 50.
//!
//! The paper's qualitative claim: the first block (no redundancy) and the
//! last block (tolerating N−1 stragglers) contain most of the L
//! coordinates. Printed as block tables plus an ASCII profile of the
//! per-level sizes.
//!
//! Run: `cargo bench --bench fig3_blocks`

use bcgc::bench_harness::{banner, Table};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::runtime_model::{expected_runtime, ProblemSpec};
use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
use bcgc::util::rng::Rng;

fn bar(value: usize, max: usize, width: usize) -> String {
    let filled = (value * width + max / 2) / max.max(1);
    "#".repeat(filled)
}

fn main() {
    banner(
        "Fig. 3 — optimized block structures",
        "N=20, L=2e4, shifted-exponential(mu=1e-3, t0=50), M=50, b=1.",
    );
    let spec = ProblemSpec::paper_default(20, 20_000);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(2021);
    let opts = SolveOptions::default();

    for kind in SchemeKind::proposed() {
        let p = solve(&spec, &dist, kind, &opts, &mut rng).unwrap();
        let stats = expected_runtime(&spec, &p, &dist, 4000, &mut rng);
        println!(
            "\n--- {} ---   E[runtime] = {:.0} ± {:.0}",
            kind.label(),
            stats.mean(),
            stats.ci95_half_width()
        );
        let max = p.sizes().iter().copied().max().unwrap_or(1);
        let mut table = Table::new(&["s (tolerated stragglers)", "x_s", "profile"]);
        for (s, &sz) in p.sizes().iter().enumerate() {
            if sz > 0 {
                table.row(&[s.to_string(), sz.to_string(), bar(sz, max, 40)]);
            }
        }
        table.print();
        // The paper's shape claim.
        let ends = p.sizes()[0] + p.sizes()[19];
        println!(
            "first+last blocks hold {:.0}% of the {} coordinates",
            100.0 * ends as f64 / p.total() as f64,
            p.total()
        );
    }
}
