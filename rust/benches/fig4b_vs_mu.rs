//! Fig. 4(b) reproduction: expected overall runtime vs the straggler rate
//! μ ∈ 10^{-3} … 10^{-2} (log-spaced), at t0 = 50, L = 2·10⁴, M = 50,
//! b = 1. The paper does not state N for this sweep; we use N = 30
//! (mid-range of Fig. 4(a)) — see DESIGN.md §5.
//!
//! Paper headline to reproduce in shape: all series decrease with μ
//! (E[T] = 1/μ + t0 shrinks); ~44% reduction vs the best baseline at
//! μ = 10^{-2.6}.
//!
//! Run: `cargo bench --bench fig4b_vs_mu`

use bcgc::bench_harness::{banner, Table};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::evaluate::{compare_schemes, reduction_vs_best_baseline};
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
use bcgc::util::rng::Rng;

fn main() {
    banner(
        "Fig. 4(b) — E[overall runtime] vs straggler rate mu",
        "N=30, L=2e4, t0=50, M=50, b=1; mu log-spaced in [1e-3, 1e-2]; 2000 CRN trials/point.",
    );
    let n = 30usize;
    let kinds: Vec<SchemeKind> = SchemeKind::proposed()
        .into_iter()
        .chain(SchemeKind::baselines())
        .collect();

    let mut headers: Vec<String> = vec!["mu".into()];
    headers.extend(kinds.iter().map(|k| k.label().to_string()));
    headers.push("reduction vs best baseline".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    let mut prev_proposed = f64::INFINITY;
    for exp in [-3.0f64, -2.8, -2.6, -2.4, -2.2, -2.0] {
        let mu = 10f64.powf(exp);
        let dist = ShiftedExponential::new(mu, 50.0);
        let spec = ProblemSpec::paper_default(n, 20_000);
        let mut rng = Rng::new(4242 + (exp * -10.0) as u64);
        let opts = SolveOptions::default();
        let mut schemes = Vec::new();
        for &kind in &kinds {
            let p = solve(&spec, &dist, kind, &opts, &mut rng).unwrap();
            schemes.push((kind.label().to_string(), p));
        }
        let rows = compare_schemes(&spec, &schemes, &dist, 2000, &mut rng);
        let proposed_best = rows[..3].iter().map(|r| r.mean()).fold(f64::INFINITY, f64::min);
        let baselines: Vec<f64> = rows[3..].iter().map(|r| r.mean()).collect();
        let red = reduction_vs_best_baseline(proposed_best, &baselines);
        let mut cells: Vec<String> = vec![format!("1e{exp:.1}")];
        cells.extend(rows.iter().map(|r| format!("{:.0}", r.mean())));
        cells.push(format!("{red:.0}%"));
        table.row(&cells);

        assert!(
            proposed_best <= prev_proposed * 1.02,
            "proposed runtime should decrease with mu"
        );
        prev_proposed = proposed_best;
    }
    table.print();
    println!("\nexpected shape: every series decreases with mu (mean cycle time 1/mu + t0);");
    println!("paper quotes ~44% reduction vs best baseline at mu = 1e-2.6.");
}
