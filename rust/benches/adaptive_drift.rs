//! Adaptive-vs-static under a drifting shifted-exponential straggler
//! model — the perf-trajectory bench behind `BENCH_adaptive.json`.
//!
//! Scenario: N = 20 workers, L = 2·10⁴ coordinates (the paper's Fig. 4
//! scale). Phase 0 is a mild straggler regime (μ = 10⁻², t0 = 50); at
//! iteration 150 the cluster degrades to the paper's §VI regime
//! (μ = 10⁻³, t0 = 50) — a 6× jump in mean cycle time and a 10× fatter
//! exponential tail. Three arms, all on common random numbers:
//!
//! * **static** — `x^(f)` optimized for phase 0, kept for the whole run
//!   (what the non-adaptive paper system would do);
//! * **adaptive** — same initial scheme, online MLE + drift-triggered
//!   closed-form re-solve (the adaptive coding engine);
//! * **oracle** — `x^(f)` optimized for phase 1 from iteration 0 (the
//!   adaptive arm's post-shift upper bound).
//!
//! The headline metric is the mean per-iteration overall runtime after
//! the shift (+grace); the JSON artifact tracks it across PRs.
//!
//! Run: `cargo bench --bench adaptive_drift` (set `BENCH_OUT` to move
//! the artifact; defaults to ./BENCH_adaptive.json).

use bcgc::bench_harness::{banner, stamp_bench_meta};
use bcgc::coordinator::adaptive::AdaptiveConfig;
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::sim::{compare_adaptive_vs_static, MultiSimConfig};

fn main() {
    banner(
        "Adaptive coding engine — drifting shifted-exponential",
        "N=20, L=2e4; mu 1e-2 -> 1e-3 at iter 150 of 450; grace 50; CRN across arms.",
    );
    let (n, coords) = (20usize, 20_000usize);
    let (iters, shift_at, grace, seed) = (450usize, 150usize, 50usize, 2021u64);
    let spec = ProblemSpec::paper_default(n, coords);
    let d0 = ShiftedExponential::new(1e-2, 50.0);
    let d1 = ShiftedExponential::new(1e-3, 50.0);
    let schedule =
        StragglerSchedule::stationary(Box::new(d0.clone())).then(shift_at, Box::new(d1.clone()));
    let initial = x_freq_blocks(&spec, &d0, coords).unwrap();
    let oracle = x_freq_blocks(&spec, &d1, coords).unwrap();
    println!("initial x^(f): {initial}");
    println!("oracle  x^(f): {oracle}\n");

    let acfg = AdaptiveConfig {
        window: 20 * n,
        min_samples: 10 * n,
        check_every: 10,
        cooldown: 20,
        drift_threshold: 0.2,
        ..Default::default()
    };
    let cfg = MultiSimConfig { iters, seed, comm_latency: 0.0 };
    let cmp = compare_adaptive_vs_static(
        &spec,
        &initial,
        Some(&oracle),
        &schedule,
        &cfg,
        acfg,
        grace,
    )
    .unwrap();

    print!("{}", cmp.render_report());
    assert!(
        cmp.adaptive_after() < cmp.static_after(),
        "adaptive must beat the stale static scheme after the shift"
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_adaptive.json".into());
    let json = stamp_bench_meta(
        &cmp.render_json(),
        seed,
        &format!("N={n} L={coords} iters={iters} shift_at={shift_at} grace={grace}"),
    );
    std::fs::write(&out, json).expect("write bench artifact");
    println!("wrote {out}");
}
