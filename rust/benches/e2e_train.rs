//! End-to-end coordinator benchmark: coded distributed GD throughput and
//! the coordination overhead split (decode, virtual-runtime accounting),
//! coded vs uncoded, on the host backend (PJRT compute time would
//! dominate and mask coordination costs; the PJRT path is validated in
//! tests and exercised by `examples/train_mlp.rs`).
//!
//! Run: `cargo bench --bench e2e_train`

use bcgc::bench_harness::{banner, fmt_ns, Table};
use bcgc::coordinator::trainer::{train_stationary, TrainConfig};
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::host_factory;
use bcgc::util::rng::Rng;

fn main() {
    banner(
        "E2E — coded distributed GD throughput (host backend)",
        "N=8 workers, 16-class MLP (d=32, h=64), 60 steps per scheme.",
    );
    let n = 8usize;
    let (d, h, c, shard) = (32usize, 64usize, 16usize, 64usize);
    let dim = HostExecutor::mlp_dim(d, h, c);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let spec = ProblemSpec::new(n, dim, shard * n, 1.0);
    let steps = 60usize;

    let mut table = Table::new(&[
        "scheme",
        "steps/s",
        "wall/iter",
        "decode/iter",
        "decode share",
        "E[virtual runtime]",
        "cache hit rate",
    ]);
    for kind in [
        SchemeKind::Uncoded,
        SchemeKind::SingleBlock,
        SchemeKind::ClosedFormFreq,
        SchemeKind::OptimalSubgradient,
    ] {
        let mut rng = Rng::new(11);
        let ds = synthetic::classification(d, c, shard * n, n, 0.2, 5).unwrap();
        let factory = host_factory(ds, HostModel::Mlp { hidden: h });
        let blocks = solve(&spec, &dist, kind, &SolveOptions::fast(), &mut rng).unwrap();
        let mut cfg = TrainConfig::new(spec, blocks);
        cfg.steps = steps;
        cfg.lr = 1e-3;
        cfg.eval_every = 0;
        cfg.seed = 11;
        let t0 = std::time::Instant::now();
        let report = train_stationary(cfg, Box::new(dist.clone()), factory).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let wall_iter = report.wall_ns_stats().mean();
        let decode_iter = report.decode_ns_stats().mean();
        let hits = report.decode_cache_hits as f64;
        let total = hits + report.decode_cache_misses as f64;
        table.row(&[
            kind.label().to_string(),
            format!("{:.1}", steps as f64 / wall),
            fmt_ns(wall_iter),
            fmt_ns(decode_iter),
            format!("{:.2}%", 100.0 * decode_iter / wall_iter),
            format!("{:.0}", report.virtual_runtime_stats().mean()),
            format!("{:.0}%", 100.0 * hits / total.max(1.0)),
        ]);
    }
    table.print();
    println!("\nthe decode share is the coordinator's overhead on the real hot path;");
    println!("virtual runtime is the paper's Eq. (2) metric (lower = better scheme).");
}
