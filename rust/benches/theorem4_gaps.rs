//! Theorem 4 validation: the multiplicative optimality gaps of the two
//! closed-form solutions are sub-linear in N —
//! `E[τ̂(x^(t),T)]/τ̂* = O((log N)²)` and `E[τ̂(x^(f),T)]/τ̂* = O(log N)`,
//! and x^(f) weakly dominates x^(t).
//!
//! The true optimum τ̂* is bracketed by a *provable lower bound*
//! (Jensen: τ̂* ≥ τ̂(x^(t), t) = unit·m^(t), used in the paper's own
//! proof) and the best observed scheme (subgradient x†). We report the
//! gap against both; the paper's claim is validated if the measured
//! gaps stay far below the analytic envelopes and grow slowly in N.
//!
//! Run: `cargo bench --bench theorem4_gaps`

use bcgc::bench_harness::{banner, Table};
use bcgc::distribution::order_stats::shifted_exp_exact;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::closed_form;
use bcgc::optimizer::evaluate::compare_schemes;
use bcgc::optimizer::rounding::round_to_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
use bcgc::util::rng::Rng;
use bcgc::util::special::harmonic;

fn main() {
    banner(
        "Theorem 4 — sub-linear optimality gaps of x^(t) and x^(f)",
        "L=2e4, shifted-exponential(mu=1e-3, t0=50); gap = E[tau(x)] / lower bound.",
    );
    let l = 20_000usize;
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mu_t0 = 1e-3 * 50.0;

    let mut table = Table::new(&[
        "N",
        "gap x^(t) (vs LB)",
        "gap x^(f) (vs LB)",
        "gap x^dag (vs LB)",
        "envelope (H_N+1)(H_N+mu t0)/(mu t0)^2",
        "envelope H_N/(mu t0)+1",
    ]);

    let mut prev_ratio_t = 0.0f64;
    for n in [5usize, 10, 20, 40, 80] {
        let spec = ProblemSpec::paper_default(n, l);
        let os = shifted_exp_exact(&dist, n);
        let mut rng = Rng::new(99 + n as u64);

        let xt = round_to_blocks(&closed_form::x_time(&spec, &os).unwrap(), l);
        let xf = round_to_blocks(&closed_form::x_freq(&spec, &os).unwrap(), l);
        let xdag = solve(
            &spec,
            &dist,
            SchemeKind::OptimalSubgradient,
            &SolveOptions::default(),
            &mut rng,
        )
        .unwrap();

        let rows = compare_schemes(
            &spec,
            &[("xt".into(), xt), ("xf".into(), xf), ("xdag".into(), xdag)],
            &dist,
            4000,
            &mut rng,
        );
        // Provable lower bound on τ̂*_avg-ct (paper's Theorem-4 proof):
        // τ̂* ≥ τ̂(x^(t), t) = unit · m^(t).
        let lb = spec.unit_work() * closed_form::m_of_t(&spec, &os.t);
        let gap_t = rows[0].mean() / lb;
        let gap_f = rows[1].mean() / lb;
        let gap_d = rows[2].mean() / lb;
        let h = harmonic(n);
        let env_t = (h + 1.0) * (h + mu_t0) / (mu_t0 * mu_t0);
        let env_f = h / mu_t0 + 1.0;
        table.row(&[
            n.to_string(),
            format!("{gap_t:.3}"),
            format!("{gap_f:.3}"),
            format!("{gap_d:.3}"),
            format!("{env_t:.0}"),
            format!("{env_f:.0}"),
        ]);

        // Claims: gaps stay small and within the analytic envelopes;
        // x^(f) ⪯ x^(t) (small tolerance); growth is sub-linear.
        assert!(gap_t <= env_t && gap_f <= env_f, "gap exceeds envelope at N={n}");
        assert!(gap_f <= gap_t * 1.03, "x^(f) should not trail x^(t) at N={n}");
        if prev_ratio_t > 0.0 {
            // Far from doubling when N doubles ⇒ sub-linear in practice.
            assert!(gap_t / prev_ratio_t < 1.6, "gap growth too fast at N={n}");
        }
        prev_ratio_t = gap_t;
    }
    table.print();
    println!("\npaper: gaps are O((log N)^2) and O(log N); observed gaps stay near 1");
    println!("(the closed forms are near-optimal) and grow sub-linearly, with x^(f) ⪯ x^(t).");
}
