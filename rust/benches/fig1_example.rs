//! Fig. 1 reproduction: the motivating example at N = 4, L = 4,
//! T = (1/10, 1/10, 1/4, 1)·T0.
//!
//! Regenerates the runtime of each subfigure's scheme — (b) uncoded /
//! Tandon s=1, (c) Tandon s=2, (d) the proposed coordinate scheme
//! s = (1,1,2,2) — both from the analytic Eq. (2) and from the
//! discrete-event simulator, and checks real encode/decode round-trips
//! for every survivor pattern the timeline produces.
//!
//! Run: `cargo bench --bench fig1_example`

use bcgc::bench_harness::{banner, Table};
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::{tau_s, ProblemSpec};
use bcgc::sim::{simulate_iteration, SimConfig};

fn main() {
    banner(
        "Fig. 1 — motivating example",
        "N=4 workers, L=4 coordinates, T = (0.1, 0.1, 0.25, 1)·T0, unit work (M/N)·b = 1.\n\
         Paper claim: coordinate gradient coding s=(1,1,2,2) finishes at 1.0·T0,\n\
         beating uniform s=1 (2.0·T0) and uniform s=2 (1.2·T0).",
    );
    let spec = ProblemSpec::new(4, 4, 4, 1.0);
    let times = vec![0.1, 0.1, 0.25, 1.0];

    let schemes: Vec<(&str, Vec<usize>)> = vec![
        ("uncoded s=(0,0,0,0)", vec![0, 0, 0, 0]),
        ("Tandon GC s=1 [Fig 1(b)]", vec![1, 1, 1, 1]),
        ("Tandon GC s=2 [Fig 1(c)]", vec![2, 2, 2, 2]),
        ("proposed s=(1,1,2,2) [Fig 1(d)]", vec![1, 1, 2, 2]),
    ];

    let mut table = Table::new(&["scheme", "tau (Eq. 2)", "event-sim", "paper"]);
    let paper = ["4.00", "2.00", "1.20", "1.00"];
    for ((name, s), want) in schemes.iter().zip(paper.iter()) {
        let tau = tau_s(&spec, s, &times);
        let blocks = BlockPartition::from_s_vector(4, s).unwrap();
        let sim = simulate_iteration(&spec, &blocks, &times, &SimConfig::default());
        table.row(&[
            name.to_string(),
            format!("{tau:.2}"),
            format!("{:.2}", sim.completion_time),
            want.to_string(),
        ]);
        assert!((tau - sim.completion_time).abs() < 1e-9);
    }
    table.print();

    // Shape assertions (the figure's claims).
    let t_prop = tau_s(&spec, &[1, 1, 2, 2], &times);
    let t_s1 = tau_s(&spec, &[1, 1, 1, 1], &times);
    let t_s2 = tau_s(&spec, &[2, 2, 2, 2], &times);
    assert!(t_prop < t_s2 && t_s2 < t_s1, "ordering must match the paper");
    println!(
        "\nproposed vs best uniform: {:.0}% reduction (paper: 17%)",
        (1.0 - t_prop / t_s2) * 100.0
    );
}
