//! Async position-aware rounds — the headline bench behind
//! `BENCH_async.json`.
//!
//! Scenario: the `benches/multi_job.rs` tenant mix (two MLP jobs of
//! unequal length on one `N = 8` shared pool, §VI shifted-exponential
//! stragglers) replayed under three dispatch policies, all on the real
//! threaded coordinator (virtual pacing, real gradients, real decodes):
//!
//! * **serialized** — `WorkerPool::run_all`: one decode-to-completion
//!   barrier per round; makespan = Σ of every round's Eq. (2) runtime.
//! * **async exact** — `WorkerPool::run_all_async` with
//!   `max_inflight = 2`: job B's iteration `t+1` is broadcast while job
//!   A's tail blocks are still in flight; each row's queued backlog is
//!   priced into Eq. (2) and (past a skew threshold) folded into the
//!   fitted cycle-time models fed to the scheme re-solve. Decode stays
//!   exact.
//! * **async semi** — same, plus `SemiAsyncConfig`: blocks short only
//!   of deeply-backlogged rows decode approximately (least squares,
//!   tracked error bound) and reconcile when the exact quorum lands.
//!
//! PR 4 measured *naive* overlap at 2–6× WORSE than serialized rounds
//! (head-of-line blocking on the shared worker FIFOs). The claim here
//! is that position-aware overlap turns that loss into a strict win on
//! asymmetric tenants and never regresses past serialized on the
//! symmetric control pair — both asserted below.
//!
//! The JSON artifact (schema:
//! `sim::multi::AsyncRoundsComparison::render_json`) also reports each
//! arm's convergence-vs-virtual-time frontier and the semi-async
//! error-bound accounting.
//!
//! Run: `cargo bench --bench async_rounds` (set `BENCH_OUT` to move the
//! artifact; defaults to ./BENCH_async.json).

use bcgc::bench_harness::{banner, stamp_bench_meta};
use bcgc::coordinator::adaptive::AdaptiveConfig;
use bcgc::coordinator::master::SemiAsyncConfig;
use bcgc::coordinator::metrics::TrainReport;
use bcgc::coordinator::pool::{AsyncConfig, JobSpec, PoolConfig, WorkerPool};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::{host, host_factory};
use bcgc::sim::{pipelined_frontier, serialized_frontier, AsyncArm, AsyncRoundsComparison, SimJob};

const N: usize = 8;
/// Headline pair: asymmetric tenants (the short job's rounds can hide
/// inside the long job's straggler tails).
const STEPS: [usize; 2] = [150, 50];
/// Control pair: symmetric tenants (no asymmetry to exploit; the
/// pipeline must not lose what the barrier had).
const SYM_STEPS: [usize; 2] = [100, 100];
const SEED: u64 = 2021;
const MU: f64 = 1e-3;
const T0: f64 = 50.0;

/// MLP dimensions shared by both tenants (each gets its own dataset).
const FEATURES: usize = 32;
const HIDDEN: usize = 64;
const CLASSES: usize = 10;
const SAMPLES: usize = 512;

/// Semi-async decode knobs for the third arm: flag rows deep at 3/4 of
/// a mean round's backlog and accept generous LS residuals. The bench
/// asserts the ACCOUNTING (reconciled + discarded = decoded), not that
/// approximation fired on any particular seed.
const SEMI: SemiAsyncConfig =
    SemiAsyncConfig { max_shortfall: 1, backlog_factor: 0.75, max_residual: 25.0 };

fn async_cfg(semi: Option<SemiAsyncConfig>) -> AsyncConfig {
    AsyncConfig {
        max_inflight: 2,
        backlog_pricing: true,
        reprice_threshold: 0.25,
        semi_async: semi,
    }
}

struct ArmRun {
    makespan: f64,
    rounds: usize,
    reports: Vec<TrainReport>,
}

/// One full threaded-pool run of the two-tenant mix under `cfg`
/// (`None` = the serialized barrier). All arms share the pool seed, so
/// they draw from identical straggler streams.
fn run_arm(steps: [usize; 2], cfg: Option<AsyncConfig>) -> bcgc::Result<ArmRun> {
    let dist = ShiftedExponential::new(MU, T0);
    let dim = host::HostExecutor::mlp_dim(FEATURES, HIDDEN, CLASSES);
    let mut pcfg = PoolConfig::new(N);
    pcfg.seed = SEED;
    pcfg.async_rounds = cfg;
    let mut pool = WorkerPool::new(pcfg, StragglerSchedule::stationary(Box::new(dist.clone())))?;
    for (job, &steps_j) in steps.iter().enumerate() {
        let ds =
            synthetic::classification(FEATURES, CLASSES, SAMPLES, N, 0.2, SEED + 1 + job as u64)?;
        let spec = ProblemSpec::new(N, dim, SAMPLES, 1.0);
        let blocks = x_freq_blocks(&spec, &dist, dim)?;
        JobSpec::new(spec, blocks)
            .steps(steps_j)
            .lr(2e-3)
            .eval_every(10)
            .seed(SEED + 10 + job as u64)
            .adaptive(AdaptiveConfig::default())
            .executor(host_factory(ds, host::HostModel::Mlp { hidden: HIDDEN }))
            .submit(&mut pool)?;
    }
    pool.run_all_async()?;
    assert_eq!(pool.cross_job_dropped(), 0, "no contribution may carry an unknown job id");
    let rounds = pool.rounds();
    let makespan = pool.virtual_makespan();
    let reports = pool.finish()?;
    for (j, r) in reports.iter().enumerate() {
        assert_eq!(r.steps(), steps[j], "job {j} dropped iterations");
        assert!(
            r.iters.iter().all(|m| m.grad_norm.is_finite()),
            "job {j} decoded a non-finite gradient"
        );
    }
    Ok(ArmRun { makespan, rounds, reports })
}

/// Fold one arm's pool run into a comparison row: per-job virtual
/// totals, queue-wait peak, semi-async accounting, and the
/// convergence-vs-virtual-time frontier.
fn summarize(label: &str, run: &ArmRun, pipelined: bool) -> AsyncArm {
    let vr: Vec<Vec<f64>> = run
        .reports
        .iter()
        .map(|r| r.iters.iter().map(|m| m.virtual_runtime).collect())
        .collect();
    let loss: Vec<Vec<(usize, f32)>> = run.reports.iter().map(|r| r.loss_curve.clone()).collect();
    let frontier = if pipelined {
        pipelined_frontier(&vr, &loss)
    } else {
        serialized_frontier(&vr, &loss)
    };
    AsyncArm {
        label: label.into(),
        makespan: run.makespan,
        rounds: run.rounds,
        per_job_total: vr.iter().map(|v| v.iter().sum()).collect(),
        max_queue_wait: run
            .reports
            .iter()
            .flat_map(|r| r.iters.iter())
            .map(|m| m.queue_wait)
            .fold(0.0, f64::max),
        approx_decodes: run.reports.iter().map(|r| r.approx_decodes).sum(),
        approx_reconciled: run.reports.iter().map(|r| r.approx_reconciled).sum(),
        approx_discarded: run.reports.iter().map(|r| r.approx_discarded).sum(),
        max_approx_bound: run.reports.iter().map(|r| r.max_approx_bound).fold(0.0, f64::max),
        frontier,
    }
}

fn main() {
    banner(
        "Async position-aware rounds — pipelined dispatch vs the serialized barrier",
        "N=8 shared pool; 150+50-step MLP tenants (symmetric 100+100 control); \
         shifted-exp(mu=1e-3, t0=50); max_inflight=2, backlog-priced schemes, semi-async \
         decode; makespan in Eq. (2) virtual time.",
    );
    let dim = host::HostExecutor::mlp_dim(FEATURES, HIDDEN, CLASSES);
    let dist = ShiftedExponential::new(MU, T0);

    let serial = run_arm(STEPS, None).unwrap();
    let exact = run_arm(STEPS, Some(async_cfg(None))).unwrap();
    let semi = run_arm(STEPS, Some(async_cfg(Some(SEMI)))).unwrap();
    let sym_serial = run_arm(SYM_STEPS, None).unwrap();
    let sym_async = run_arm(SYM_STEPS, Some(async_cfg(None))).unwrap();

    let cmp = AsyncRoundsComparison {
        n: N,
        jobs: STEPS.iter().map(|&steps| SimJob { coords: dim, steps }).collect(),
        schedule_label: dist.label(),
        serialized: summarize("serialized barrier", &serial, false),
        async_exact: summarize("async exact (mi=2)", &exact, true),
        async_semi: summarize("async semi (mi=2)", &semi, true),
        sym_serialized_makespan: sym_serial.makespan,
        sym_async_makespan: sym_async.makespan,
    };
    print!("{}", cmp.render_report());

    // Headline: position-aware async must STRICTLY beat the serialized
    // barrier on asymmetric tenants (naive overlap measured 2-6x WORSE
    // in PR 4; position pricing is what flips the sign).
    assert!(
        cmp.async_exact.makespan < cmp.serialized.makespan,
        "async exact {} must beat serialized {}",
        cmp.async_exact.makespan,
        cmp.serialized.makespan
    );
    assert!(
        cmp.async_semi.makespan < cmp.serialized.makespan,
        "async semi {} must beat serialized {}",
        cmp.async_semi.makespan,
        cmp.serialized.makespan
    );
    // Control: never regress past serialized on symmetric tenants
    // (small slack: the arms' round-to-job mappings can diverge).
    assert!(
        cmp.sym_ratio() <= 1.05,
        "symmetric control regressed: async {} vs serialized {}",
        cmp.sym_async_makespan,
        cmp.sym_serialized_makespan
    );
    // Semi-async accounting: every approximate decode is either
    // reconciled against its exact quorum or discarded at an epoch
    // swap / job finish — none may leak past the run.
    for arm in [&cmp.serialized, &cmp.async_exact, &cmp.async_semi] {
        assert_eq!(
            arm.approx_decodes,
            arm.approx_reconciled + arm.approx_discarded,
            "{} leaked approx decodes",
            arm.label
        );
        assert!(arm.max_approx_bound.is_finite(), "{}: non-finite error bound", arm.label);
        assert!(arm.frontier.iter().all(|f| !f.is_empty()), "{}: empty frontier", arm.label);
    }
    assert_eq!(cmp.serialized.approx_decodes, 0, "the barrier arm cannot approx-decode");
    assert_eq!(cmp.async_exact.approx_decodes, 0, "the exact arm cannot approx-decode");

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_async.json".into());
    let json = stamp_bench_meta(
        &cmp.render_json(),
        SEED,
        &format!(
            "N={N} jobs={STEPS:?} sym={SYM_STEPS:?} L={dim} M={SAMPLES} mu={MU} t0={T0} \
             mi=2 threaded"
        ),
    );
    std::fs::write(&out, json).expect("write bench artifact");
    println!("wrote {out}");
}
