//! Sample-granular loads + rotated partial-sum streaming on a 2-speed
//! fleet — the perf-trajectory bench behind `BENCH_partial.json`.
//!
//! Scenario: N = 10 workers (5 fast + 5 slow, 2.5×), L = 10³
//! coordinates, M = 7000 samples, single-level s = 1 partition. The
//! speed ratio 2.5:1 is deliberately NOT representable at the
//! simulator's shard granularity (the fast quota is 5.71 of 40 virtual
//! shards), while 7000 samples split exactly 1000/400 per row. Three
//! arms on one CRN cycle-time stream:
//!
//! 1. **shard-quantized** — speed-weighted loads rounded to whole
//!    virtual shards (the PR 9 state of the art): fast rows run ~5%
//!    heavy, so the quorum barrier waits on them;
//! 2. **continuous** — the same oracle weights apportioned over
//!    individual samples (`redistribute_samples_weighted`): quota
//!    error under one sample, expected per-row finish times equalized;
//! 3. **streaming** — continuous loads *plus* 4-part rotated
//!    partial-sum streaming: a straggler's early strides fill part
//!    quorums the whole-block protocol would have waited its full
//!    round for.
//!
//! Headline: `continuous_gain_pct` AND `streaming_gain_pct` must both
//! be strictly positive — each refinement beats the previous arm on
//! mean iteration makespan. The JSON artifact tracks both across PRs.
//!
//! Run: `cargo bench --bench partial_stragglers` (set `BENCH_OUT` to
//! move the artifact; defaults to ./BENCH_partial.json).

use bcgc::bench_harness::{banner, stamp_bench_meta};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::sim::{compare_partial_streaming, MultiSimConfig};

fn main() {
    banner(
        "Partial stragglers — sample-granular loads + rotated partial-sum streaming",
        "N=10 (5 fast + 5 slow, 2.5×), L=1e3, M=7000, s=1, 4 parts; 600 iters; CRN across arms.",
    );
    let (n, n_slow, slow_factor) = (10usize, 5usize, 2.5f64);
    let (coords, samples, parts) = (1_000usize, 7_000usize, 4usize);
    let (iters, seed) = (600usize, 2021u64);
    let spec = ProblemSpec::paper_default(n, coords);
    let fast = ShiftedExponential::new(1e-3, 50.0); // mean 1050
    let blocks = BlockPartition::single_level(n, 1, coords);
    let cfg = MultiSimConfig { iters, seed, comm_latency: 0.0 };
    let cmp = compare_partial_streaming(
        &spec,
        &blocks,
        &fast,
        n_slow,
        slow_factor,
        samples,
        parts,
        &cfg,
    )
    .expect("comparison runs");
    println!("fleet: {}\n", cmp.fleet_label);

    print!("{}", cmp.render_report());

    // Headline guarantees the artifact tracks a real effect.
    let (q, c, s) = (cmp.quantized_mean(), cmp.continuous_mean(), cmp.streaming_mean());
    assert!(
        c < q,
        "sample-granular apportionment ({c:.1}) must strictly beat shard-quantized \
         loads ({q:.1}) when the speed ratio is not a multiple of 1/m"
    );
    assert!(
        s < c,
        "rotated {parts}-part streaming ({s:.1}) must strictly beat the whole-block \
         continuous arm ({c:.1})"
    );
    // The continuous arm's apportionment is exact on this fleet.
    assert_eq!(
        cmp.sample_counts,
        vec![1000, 1000, 1000, 1000, 1000, 400, 400, 400, 400, 400],
        "2.5:1 weights over 7000 samples must split exactly"
    );

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_partial.json".into());
    let stamped = stamp_bench_meta(
        &cmp.render_json(),
        seed,
        &format!(
            "N={n} L={coords} M={samples} parts={parts} iters={iters} \
             fleet=2speed({}fast+{n_slow}slow,{slow_factor}x)",
            n - n_slow
        ),
    );
    std::fs::write(&out, stamped).expect("write bench artifact");
    println!("wrote {out}");
}
