//! Micro-benchmarks of the coordinator hot path, used by the §Perf pass:
//! runtime-model evaluation, simplex projection, block encode, decode
//! (cold/cached), straggler sampling, event-sim playout — plus the
//! large-L data-plane section behind `BENCH_hotpath.json`: at L = 1M the
//! fused f32 encode kernel must strictly beat the one-pass-per-source
//! axpy baseline, and the cached decode+combine must land within 3× of
//! a memcpy over the same bytes.
//!
//! Run: `cargo bench --bench hotpath`

use bcgc::bench_harness::{banner, black_box, fmt_ns, stamp_bench_meta, Bencher, Sample, Table};
use bcgc::coding::decoder::{decode_into, DecodeCache};
use bcgc::coding::encoder::GradientCode;
use bcgc::coding::scheme::CodingScheme;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::linalg::kernels::{fused_combine_f32, naive_combine_f32_to_f64};
use bcgc::optimizer::projection::{project_simplex, project_simplex_bisect};
use bcgc::optimizer::rounding::round_to_blocks;
use bcgc::optimizer::runtime_model::{sort_times, tau_hat_sorted, ProblemSpec, WorkModel};
use bcgc::sim::{simulate_iteration, SimConfig};
use bcgc::util::buffers::BufferPool;
use bcgc::util::rng::Rng;

fn main() {
    banner("hot path micro-benchmarks", "N=20 (paper's Fig. 3 scale) unless noted.");
    let seed = 3u64;
    let n = 20usize;
    let l = 20_000usize;
    let spec = ProblemSpec::paper_default(n, l);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(seed);
    let b = Bencher::new(5, 25);

    // A representative optimized partition.
    let os = bcgc::distribution::order_stats::shifted_exp_exact(&dist, n);
    let xf = bcgc::optimizer::closed_form::x_freq(&spec, &os).unwrap();
    let blocks = round_to_blocks(&xf, l);
    let scheme = CodingScheme::new(blocks.clone(), &mut rng).unwrap();
    let x = blocks.as_f64();
    let mut times = dist.sample_vec(n, &mut rng);
    sort_times(&mut times);

    let mut table = Table::new(&["op", "median", "p10", "p90"]);
    let mut add = |name: &str, s: Sample| {
        table.row(&[
            name.to_string(),
            fmt_ns(s.median_ns()),
            fmt_ns(s.p10_ns()),
            fmt_ns(s.p90_ns()),
        ]);
    };

    add("tau_hat eval (Eq. 5)", b.run("tau", || {
        black_box(tau_hat_sorted(&spec, &x, &times, WorkModel::GradientCoding))
    }));

    let v: Vec<f64> = (0..n).map(|_| rng.normal_with(1000.0, 300.0)).collect();
    add("simplex projection (sort)", b.run("proj", || black_box(project_simplex(&v, l as f64))));
    add(
        "simplex projection (bisect)",
        b.run("projb", || black_box(project_simplex_bisect(&v, l as f64, 1e-9))),
    );

    // Worker-side block encode over full-dim shard grads.
    let max_s = scheme.blocks().max_level();
    let shard_grads: Vec<Vec<f64>> = (0..max_s + 1)
        .map(|_| (0..l).map(|_| rng.normal()).collect())
        .collect();
    let ranges = scheme.ranges();
    add("encode all blocks (1 worker)", b.run("encode", || {
        let mut acc = 0.0;
        for r in &ranges {
            let out = scheme.encode_block_range(0, r, &shard_grads);
            acc += out[0];
        }
        acc
    }));

    // Master-side decode of the largest block, cold vs cached.
    let r_big = *ranges.iter().max_by_key(|r| r.len()).unwrap();
    let code = scheme.code(r_big.s);
    let survivors: Vec<usize> = (0..n - r_big.s).collect();
    let contributions: Vec<Vec<f64>> = (0..n - r_big.s)
        .map(|_| (0..r_big.len()).map(|_| rng.normal()).collect())
        .collect();
    add("decode vector solve (cold)", b.run("dcold", || {
        black_box(bcgc::coding::decoder::decode_vector(code, &survivors).unwrap())
    }));
    let mut cache = DecodeCache::new(64);
    let _ = cache.get(code, &survivors).unwrap();
    add("decode block (cached vec + combine)", b.run("dhot", || {
        let a = cache.get(code, &survivors).unwrap().to_vec();
        let picked: Vec<&[f64]> = contributions.iter().map(|c| c.as_slice()).collect();
        black_box(bcgc::coding::decoder::decode(&a, &picked))
    }));

    add("straggler sample+sort (N=20)", b.run("sample", || {
        let mut t = dist.sample_vec(n, &mut rng);
        sort_times(&mut t);
        t[0]
    }));

    add("event-sim playout (N=20)", b.run("sim", || {
        black_box(simulate_iteration(&spec, &blocks, &times, &SimConfig::default()))
    }));

    // Scaling spot-check at N=50.
    {
        let n2 = 50usize;
        let spec2 = ProblemSpec::paper_default(n2, l);
        let dist2 = ShiftedExponential::new(1e-3, 50.0);
        let os2 = bcgc::distribution::order_stats::shifted_exp_exact(&dist2, n2);
        let xf2 = bcgc::optimizer::closed_form::x_freq(&spec2, &os2).unwrap();
        let blocks2 = round_to_blocks(&xf2, l);
        let mut t2 = dist2.sample_vec(n2, &mut rng);
        sort_times(&mut t2);
        let x2 = blocks2.as_f64();
        add("tau_hat eval (N=50)", b.run("tau50", || {
            black_box(tau_hat_sorted(&spec2, &x2, &t2, WorkModel::GradientCoding))
        }));
        add("event-sim playout (N=50)", b.run("sim50", || {
            black_box(simulate_iteration(&spec2, &blocks2, &t2, &SimConfig::default()))
        }));
    }

    table.print();

    // ---- Large-L data plane (the BENCH_hotpath.json acceptance rows) ----
    //
    // One L = 1M block at s = 5: the worker's fused f32 encode over the
    // 6 held shard gradients vs the one-pass-per-source axpy it
    // replaced, and the master's cached decode+combine over the 15
    // survivor codewords vs a memcpy of the same survivor bytes.
    let big_l = 1_000_000usize;
    let big_s = 5usize;
    banner(
        "large-L data plane",
        "L=1M, N=20, s=5: fused f32 encode vs axpy; cached decode_into vs memcpy.",
    );
    let code_big = GradientCode::cyclic_mds(n, big_s, &mut rng).unwrap();
    let big_b = Bencher::new(2, 9);

    // Worker side: 6 full-length f32 shard gradients, row-0 coefficients.
    let shards32: Vec<Vec<f32>> = (0..big_s + 1)
        .map(|_| (0..big_l).map(|_| rng.normal() as f32).collect())
        .collect();
    let enc_sources: Vec<(f64, &[f32])> = code_big.supports[0]
        .iter()
        .enumerate()
        .map(|(k, &subset)| (code_big.b[(0, subset)], shards32[k].as_slice()))
        .collect();
    let pool = BufferPool::new(4);
    let s_enc_fused = big_b.run("enc_fused", || {
        let mut out = pool.take(big_l);
        fused_combine_f32(&enc_sources, big_l, &mut out);
        let v = out[0];
        pool.put(out);
        v
    });
    let s_enc_axpy = big_b.run("enc_axpy", || {
        let out = naive_combine_f32_to_f64(&enc_sources, big_l);
        black_box(out[0])
    });

    // Master side: 15 survivor codewords on the f32 wire, decode vector
    // served by the cache, combine written straight into a preallocated
    // gradient slice.
    let survivors_big: Vec<usize> = (0..n - big_s).collect();
    let wire: Vec<Vec<f32>> = survivors_big
        .iter()
        .map(|&w| {
            let srcs: Vec<(f64, &[f32])> = code_big.supports[w]
                .iter()
                .enumerate()
                .map(|(k, &subset)| (code_big.b[(w, subset)], shards32[k].as_slice()))
                .collect();
            let mut out = Vec::new();
            fused_combine_f32(&srcs, big_l, &mut out);
            out
        })
        .collect();
    let picked: Vec<&[f32]> = wire.iter().map(|c| c.as_slice()).collect();
    let mut cache_big = DecodeCache::new(8);
    let _ = cache_big.get(&code_big, &survivors_big).unwrap();
    let mut grad_out = vec![0.0f64; big_l];
    let s_decode = big_b.run("dec_into", || {
        let a = cache_big.get(&code_big, &survivors_big).unwrap().to_vec();
        decode_into(&a, &picked, &mut grad_out);
        grad_out[0]
    });
    // Baseline: memcpy the same survivor bytes (15 × 1M f32).
    let mut stage = vec![0.0f32; big_l];
    let s_memcpy = big_b.run("memcpy", || {
        for c in &picked {
            stage.copy_from_slice(c);
        }
        black_box(stage[0])
    });

    let mut big_table = Table::new(&["op", "median", "p10", "p90"]);
    for s in [&s_enc_fused, &s_enc_axpy, &s_decode, &s_memcpy] {
        big_table.row(&[
            s.name.clone(),
            fmt_ns(s.median_ns()),
            fmt_ns(s.p10_ns()),
            fmt_ns(s.p90_ns()),
        ]);
    }
    big_table.print();

    let enc_speedup = s_enc_axpy.median_ns() / s_enc_fused.median_ns();
    let dec_vs_memcpy = s_decode.median_ns() / s_memcpy.median_ns();
    println!("\nfused encode speedup over axpy: {enc_speedup:.2}x");
    println!("cached decode+combine vs memcpy: {dec_vs_memcpy:.2}x");

    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"hotpath\",\n");
    json.push_str(&format!(
        "  \"large_l\": {{\"l\": {big_l}, \"n\": {n}, \"s\": {big_s}, \"survivors\": {}}},\n",
        survivors_big.len()
    ));
    json.push_str("  \"rows\": [\n");
    let rows = [&s_enc_fused, &s_enc_axpy, &s_decode, &s_memcpy];
    for (i, s) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"op\": \"{}\", \"median_ns\": {:.1}, \"p10_ns\": {:.1}, \"p90_ns\": {:.1}}}{}\n",
            s.name,
            s.median_ns(),
            s.p10_ns(),
            s.p90_ns(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"encode_fused_speedup\": {enc_speedup:.3},\n"));
    json.push_str(&format!("  \"decode_vs_memcpy\": {dec_vs_memcpy:.3}\n"));
    json.push_str("}\n");
    let stamped = stamp_bench_meta(
        &json,
        seed,
        &format!("N={n} L={big_l} s={big_s} fused-data-plane"),
    );
    std::fs::write("BENCH_hotpath.json", &stamped).expect("write BENCH_hotpath.json");
    println!("\nwrote BENCH_hotpath.json");

    // Acceptance gates (after the artifact is on disk, so a failure
    // still leaves the numbers inspectable).
    assert!(
        s_enc_fused.median_ns() < s_enc_axpy.median_ns(),
        "fused encode ({}) must strictly beat the axpy baseline ({}) at L={big_l}",
        fmt_ns(s_enc_fused.median_ns()),
        fmt_ns(s_enc_axpy.median_ns()),
    );
    assert!(
        dec_vs_memcpy <= 3.0,
        "cached decode+combine ({}) must be within 3x of memcpy ({}) over the same bytes",
        fmt_ns(s_decode.median_ns()),
        fmt_ns(s_memcpy.median_ns()),
    );
}
