//! Micro-benchmarks of the coordinator hot path, used by the §Perf pass:
//! runtime-model evaluation, simplex projection, block encode, decode
//! (cold/cached), straggler sampling, event-sim playout.
//!
//! Run: `cargo bench --bench hotpath`

use bcgc::bench_harness::{banner, black_box, fmt_ns, Bencher, Table};
use bcgc::coding::decoder::DecodeCache;
use bcgc::coding::scheme::CodingScheme;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::projection::{project_simplex, project_simplex_bisect};
use bcgc::optimizer::rounding::round_to_blocks;
use bcgc::optimizer::runtime_model::{sort_times, tau_hat_sorted, ProblemSpec, WorkModel};
use bcgc::sim::{simulate_iteration, SimConfig};
use bcgc::util::rng::Rng;

fn main() {
    banner("hot path micro-benchmarks", "N=20 (paper's Fig. 3 scale) unless noted.");
    let n = 20usize;
    let l = 20_000usize;
    let spec = ProblemSpec::paper_default(n, l);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(3);
    let b = Bencher::new(5, 25);

    // A representative optimized partition.
    let os = bcgc::distribution::order_stats::shifted_exp_exact(&dist, n);
    let xf = bcgc::optimizer::closed_form::x_freq(&spec, &os).unwrap();
    let blocks = round_to_blocks(&xf, l);
    let scheme = CodingScheme::new(blocks.clone(), &mut rng).unwrap();
    let x = blocks.as_f64();
    let mut times = dist.sample_vec(n, &mut rng);
    sort_times(&mut times);

    let mut table = Table::new(&["op", "median", "p10", "p90"]);
    let mut add = |name: &str, s: bcgc::bench_harness::Sample| {
        table.row(&[
            name.to_string(),
            fmt_ns(s.median_ns()),
            fmt_ns(s.p10_ns()),
            fmt_ns(s.p90_ns()),
        ]);
    };

    add("tau_hat eval (Eq. 5)", b.run("tau", || {
        black_box(tau_hat_sorted(&spec, &x, &times, WorkModel::GradientCoding))
    }));

    let v: Vec<f64> = (0..n).map(|_| rng.normal_with(1000.0, 300.0)).collect();
    add("simplex projection (sort)", b.run("proj", || black_box(project_simplex(&v, l as f64))));
    add(
        "simplex projection (bisect)",
        b.run("projb", || black_box(project_simplex_bisect(&v, l as f64, 1e-9))),
    );

    // Worker-side block encode over full-dim shard grads.
    let max_s = scheme.blocks().max_level();
    let shard_grads: Vec<Vec<f64>> = (0..max_s + 1)
        .map(|_| (0..l).map(|_| rng.normal()).collect())
        .collect();
    let ranges = scheme.ranges();
    add("encode all blocks (1 worker)", b.run("encode", || {
        let mut acc = 0.0;
        for r in &ranges {
            let out = scheme.encode_block_range(0, r, &shard_grads);
            acc += out[0];
        }
        acc
    }));

    // Master-side decode of the largest block, cold vs cached.
    let r_big = *ranges.iter().max_by_key(|r| r.len()).unwrap();
    let code = scheme.code(r_big.s);
    let survivors: Vec<usize> = (0..n - r_big.s).collect();
    let contributions: Vec<Vec<f64>> = (0..n - r_big.s)
        .map(|_| (0..r_big.len()).map(|_| rng.normal()).collect())
        .collect();
    add("decode vector solve (cold)", b.run("dcold", || {
        black_box(bcgc::coding::decoder::decode_vector(code, &survivors).unwrap())
    }));
    let mut cache = DecodeCache::new(64);
    let _ = cache.get(code, &survivors).unwrap();
    add("decode block (cached vec + combine)", b.run("dhot", || {
        let a = cache.get(code, &survivors).unwrap().to_vec();
        let picked: Vec<&[f64]> = contributions.iter().map(|c| c.as_slice()).collect();
        black_box(bcgc::coding::decoder::decode(&a, &picked))
    }));

    add("straggler sample+sort (N=20)", b.run("sample", || {
        let mut t = dist.sample_vec(n, &mut rng);
        sort_times(&mut t);
        t[0]
    }));

    add("event-sim playout (N=20)", b.run("sim", || {
        black_box(simulate_iteration(&spec, &blocks, &times, &SimConfig::default()))
    }));

    // Scaling spot-check at N=50.
    {
        let n2 = 50usize;
        let spec2 = ProblemSpec::paper_default(n2, l);
        let dist2 = ShiftedExponential::new(1e-3, 50.0);
        let os2 = bcgc::distribution::order_stats::shifted_exp_exact(&dist2, n2);
        let xf2 = bcgc::optimizer::closed_form::x_freq(&spec2, &os2).unwrap();
        let blocks2 = round_to_blocks(&xf2, l);
        let mut t2 = dist2.sample_vec(n2, &mut rng);
        sort_times(&mut t2);
        let x2 = blocks2.as_f64();
        add("tau_hat eval (N=50)", b.run("tau50", || {
            black_box(tau_hat_sorted(&spec2, &x2, &t2, WorkModel::GradientCoding))
        }));
        add("event-sim playout (N=50)", b.run("sim50", || {
            black_box(simulate_iteration(&spec2, &blocks2, &t2, &SimConfig::default()))
        }));
    }

    table.print();
    let _ = BlockPartition::single_level(2, 0, 2); // keep import used
}
