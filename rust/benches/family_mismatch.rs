//! Family mismatch — what the always-shifted-exp re-solve used to cost
//! on a heavy-tailed pool. The perf-trajectory bench behind
//! `BENCH_family.json`.
//!
//! Scenario: N = 20 workers, L = 2·10⁴ coordinates, and a **stationary
//! heavy-tailed shifted-Weibull** pool (k = 0.6 — CV ≈ 2, far from the
//! paper's exponential tail). Both adaptive arms start from the same
//! naive uniform-s=1 partition with no prior reference, so each
//! re-solves as soon as its estimator window fills; the *only*
//! difference is the family the re-solve may model:
//!
//! * **forced shifted-exp** — `family = "shifted-exp"` (PR 1/2's
//!   behavior): the window is always fitted to §V-C's model and the
//!   partition comes from Theorem 3's exact exponential order stats —
//!   of the wrong distribution;
//! * **auto** — `family = "auto"`: KS-gated selection picks the Weibull
//!   fit (or the empirical ECDF) and `x^(f)` is computed from that
//!   model's CRN-seeded Monte-Carlo order-stat moments;
//! * **oracle** — `x^(f)` from the *true* pool model, static from
//!   iteration 0 (both arms' upper bound).
//!
//! All arms share one CRN cycle-time stream, so the headline
//! `penalty_pct` — how much slower the forced-exponential arm runs
//! after both arms have converged — is a pure scheme difference. The
//! JSON artifact tracks it across PRs.
//!
//! Run: `cargo bench --bench family_mismatch` (set `BENCH_OUT` to move
//! the artifact; defaults to ./BENCH_family.json).

use bcgc::bench_harness::{banner, stamp_bench_meta, Table};
use bcgc::coordinator::adaptive::AdaptiveConfig;
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::distribution::fit::FamilyPolicy;
use bcgc::distribution::runtime_dist::OrderStatConfig;
use bcgc::distribution::weibull::Weibull;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::closed_form::x_freq_blocks_model;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::sim::{simulate_adaptive, simulate_static, MultiSimConfig, MultiSimReport};

fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "null".into()
    }
}

fn arm_json(label: &str, r: &MultiSimReport, measure_from: usize) -> String {
    let families: Vec<String> = r
        .swaps
        .iter()
        .map(|s| {
            s.family
                .as_ref()
                .map_or_else(|| "null".to_string(), |f| format!("\"{f}\""))
        })
        .collect();
    format!(
        "  \"{label}\": {{\"mean_after\": {}, \"total\": {}, \"swaps\": {}, \"families\": [{}]}}",
        num(r.mean_from(measure_from)),
        num(r.total()),
        r.swaps.len(),
        families.join(", ")
    )
}

fn main() {
    banner(
        "Family mismatch — shifted-exp lock-in vs distribution-agnostic re-solve",
        "N=20, L=2e4; stationary heavy-tail Weibull(k=0.6, scale=800, shift=50) pool; \
         400 iters, measured from 80; CRN across arms.",
    );
    let (n, coords) = (20usize, 20_000usize);
    let (iters, seed, measure_from) = (400usize, 2021u64, 80usize);
    let spec = ProblemSpec::paper_default(n, coords);
    let pool = Weibull::new(0.6, 800.0, 50.0);
    println!("pool: {} (mean {:.0})", pool.label(), pool.mean());
    let schedule = StragglerSchedule::stationary(Box::new(pool.clone()));
    let initial = BlockPartition::single_level(n, 1, coords);
    let oracle =
        x_freq_blocks_model(&spec, &pool, coords, &OrderStatConfig::default()).unwrap();
    println!("oracle x^(f): {oracle}\n");

    let mk = |family: FamilyPolicy| AdaptiveConfig {
        window: 32 * n,
        min_samples: 16 * n,
        check_every: 10,
        cooldown: 20,
        drift_threshold: 0.2,
        family,
        ..Default::default()
    };
    let cfg = MultiSimConfig { iters, seed, comm_latency: 0.0 };
    let forced =
        simulate_adaptive(&spec, &initial, &schedule, &cfg, mk(FamilyPolicy::ShiftedExp))
            .unwrap();
    let auto = simulate_adaptive(&spec, &initial, &schedule, &cfg, mk(FamilyPolicy::Auto))
        .unwrap();
    let oracle_run = simulate_static(&spec, &oracle, &schedule, &cfg);

    let (f_after, a_after, o_after) = (
        forced.mean_from(measure_from),
        auto.mean_from(measure_from),
        oracle_run.mean_from(measure_from),
    );
    let mut table = Table::new(&["arm", "E[τ] after convergence", "Σ runtime", "swaps"]);
    table.row(&[
        "forced shifted-exp".into(),
        format!("{f_after:.1}"),
        format!("{:.0}", forced.total()),
        forced.swaps.len().to_string(),
    ]);
    table.row(&[
        "auto (family-selected)".into(),
        format!("{a_after:.1}"),
        format!("{:.0}", auto.total()),
        auto.swaps.len().to_string(),
    ]);
    table.row(&[
        "oracle (true Weibull)".into(),
        format!("{o_after:.1}"),
        format!("{:.0}", oracle_run.total()),
        "0".into(),
    ]);
    table.print();
    for s in &auto.swaps {
        println!(
            "auto swap at iter {:3}: family={} E[T]={}",
            s.installed_at_iter,
            s.family.as_deref().unwrap_or("-"),
            s.estimated_mean.map_or_else(|| "-".into(), |v| format!("{v:.0}")),
        );
    }
    let penalty_pct = 100.0 * (f_after / a_after - 1.0);
    println!("\nshifted-exp lock-in penalty after convergence: {penalty_pct:.1}%");
    assert!(
        a_after < f_after,
        "the auto-selected family ({a_after:.1}) must beat the forced shifted-exp \
         re-solve ({f_after:.1}) on a Weibull pool"
    );
    assert!(
        !auto.swaps.is_empty()
            && auto
                .swaps
                .iter()
                .all(|s| s.family.as_deref() != Some("shifted-exp")),
        "auto must leave the exponential family on Weibull data (weibull or the \
         empirical fallback): {:?}",
        auto.swaps.iter().map(|s| s.family.clone()).collect::<Vec<_>>()
    );

    let mut json = String::from("{\n");
    json.push_str("  \"bench\": \"family_mismatch\",\n");
    json.push_str(&format!("  \"n\": {n},\n  \"coords\": {coords},\n  \"iters\": {iters},\n"));
    json.push_str(&format!("  \"measure_from\": {measure_from},\n"));
    json.push_str(&format!("  \"pool\": \"{}\",\n", pool.label()));
    json.push_str(&arm_json("forced_shifted_exp", &forced, measure_from));
    json.push_str(",\n");
    json.push_str(&arm_json("auto", &auto, measure_from));
    json.push_str(",\n");
    json.push_str(&format!(
        "  \"oracle\": {{\"mean_after\": {}, \"total\": {}}},\n",
        num(o_after),
        num(oracle_run.total())
    ));
    json.push_str(&format!("  \"penalty_pct\": {}\n}}\n", num(penalty_pct)));

    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_family.json".into());
    let stamped = stamp_bench_meta(
        &json,
        seed,
        &format!("N={n} L={coords} iters={iters} pool=weibull(0.6,800,50)"),
    );
    std::fs::write(&out, stamped).expect("write bench artifact");
    println!("wrote {out}");
}
