//! Ablations of the design choices DESIGN.md calls out:
//!
//! 1. Subgradient iteration budget vs solution quality (cold start —
//!    measures the solver itself, without the closed-form safety net's
//!    candidates winning the playoff).
//! 2. Warm start (closed form) vs cold start (uniform).
//! 3. Coding granularity: free coordinates vs chunked layers vs whole
//!    tensors (footnotes 2–3 extension).
//! 4. Heterogeneous per-coordinate work: weighted optimizer vs
//!    count-based optimizer under a skewed workload (footnote 4).
//! 5. Non-i.i.d. robustness: the paper assumes i.i.d. workers; how much
//!    does the i.i.d.-optimized partition lose when one worker is
//!    persistently k× slower?
//!
//! Run: `cargo bench --bench ablation`

use bcgc::bench_harness::{banner, Table};
use bcgc::distribution::order_stats::shifted_exp_exact;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::closed_form;
use bcgc::optimizer::evaluate::compare_schemes;
use bcgc::optimizer::layered::{chunked_layer_sizes, layer_aligned_partition, mlp_layer_sizes};
use bcgc::optimizer::rounding::round_to_blocks;
use bcgc::optimizer::runtime_model::{expected_tau_hat, ProblemSpec, WorkModel};
use bcgc::optimizer::subgradient::{self, SubgradientOptions};
use bcgc::optimizer::weighted;
use bcgc::util::rng::Rng;

fn main() {
    banner("ablations", "design-choice studies (see bench source for details)");
    let n = 20usize;
    let l = 20_000usize;
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let spec = ProblemSpec::paper_default(n, l);
    let os = shifted_exp_exact(&dist, n);

    // ---------------------------------------------- 1. iteration budget
    println!("\n[1] subgradient iterations vs quality (cold start, no playoff net)");
    let mut t1 = Table::new(&["iters", "E[tau] (CRN)", "vs closed form x^(f)"]);
    let xf = closed_form::x_freq(&spec, &os).unwrap();
    let mut crn = Rng::new(505);
    let xf_val =
        expected_tau_hat(&spec, &xf, &dist, WorkModel::GradientCoding, 3000, &mut crn).mean();
    for iters in [100usize, 500, 2000, 8000] {
        let mut rng = Rng::new(42); // same stochastic path prefix
        let opts = SubgradientOptions {
            iters,
            playoff_trials: 1, // effectively disable the playoff net
            ..Default::default()
        };
        let sol = subgradient::solve(&spec, &dist, None, &opts, &mut rng).unwrap();
        let mut crn = Rng::new(505);
        let val = expected_tau_hat(&spec, &sol.x, &dist, WorkModel::GradientCoding, 3000, &mut crn)
            .mean();
        t1.row(&[
            iters.to_string(),
            format!("{:.3e}", val),
            format!("{:+.1}%", (val / xf_val - 1.0) * 100.0),
        ]);
    }
    t1.print();

    // ---------------------------------------------- 2. warm vs cold
    println!("\n[2] warm start (x^(f)) vs cold start (uniform), 2000 iters");
    let mut t2 = Table::new(&["start", "E[tau] (CRN)"]);
    for (name, warm) in [("cold (uniform)", None), ("warm (x^(f))", Some(xf.clone()))] {
        let mut rng = Rng::new(43);
        let opts = SubgradientOptions { iters: 2000, playoff_trials: 1, ..Default::default() };
        let sol = subgradient::solve(&spec, &dist, warm, &opts, &mut rng).unwrap();
        let mut crn = Rng::new(606);
        let val = expected_tau_hat(&spec, &sol.x, &dist, WorkModel::GradientCoding, 3000, &mut crn)
            .mean();
        t2.row(&[name.to_string(), format!("{:.3e}", val)]);
    }
    t2.print();

    // ---------------------------------------------- 3. coding granularity
    println!("\n[3] coding granularity (footnotes 2-3): free vs chunked vs whole tensors");
    let layers = mlp_layer_sizes(64, 256, 10); // L = 19210
    let l3: usize = layers.iter().sum();
    let spec3 = ProblemSpec::paper_default(n, l3);
    let os3 = shifted_exp_exact(&dist, n);
    let x3 = closed_form::x_time(&spec3, &os3).unwrap();
    let schemes = vec![
        ("free coordinates".to_string(), round_to_blocks(&x3, l3)),
        (
            "512-chunked layers".to_string(),
            layer_aligned_partition(&x3, &chunked_layer_sizes(&layers, 512)).unwrap(),
        ),
        (
            "whole tensors (4 layers)".to_string(),
            layer_aligned_partition(&x3, &layers).unwrap(),
        ),
    ];
    let mut rng = Rng::new(44);
    let rows = compare_schemes(&spec3, &schemes, &dist, 3000, &mut rng);
    let mut t3 = Table::new(&["granularity", "E[tau]", "levels used", "penalty vs free"]);
    let free = rows[0].mean();
    for (row, (_, p)) in rows.iter().zip(schemes.iter()) {
        t3.row(&[
            row.label.clone(),
            format!("{:.3e}", row.mean()),
            p.levels_used().to_string(),
            format!("{:+.1}%", (row.mean() / free - 1.0) * 100.0),
        ]);
    }
    t3.print();

    // ---------------------------------------------- 4. weighted work
    println!("\n[4] heterogeneous per-coordinate work (footnote 4): head 10% costs 10x");
    let lw = 2000usize;
    let specw = ProblemSpec::paper_default(n, lw);
    let mut weights = vec![1.0; lw];
    for w in weights.iter_mut().take(lw / 10) {
        *w = 10.0;
    }
    let weighted_p = weighted::closed_form_weighted(&specw, &os.t, &weights).unwrap();
    let count_p = round_to_blocks(&closed_form::x_time(&specw, &os).unwrap(), lw);
    let mut t4 = Table::new(&["optimizer", "E[tau_w] (CRN, 3000 trials)"]);
    let mut rngw = Rng::new(77);
    let trials = 3000;
    let mut acc_w = 0.0;
    let mut acc_c = 0.0;
    for _ in 0..trials {
        let times = dist.sample_vec(n, &mut rngw);
        acc_w += weighted::tau_weighted(&specw, &weighted_p.s_vector(), &weights, &times);
        acc_c += weighted::tau_weighted(&specw, &count_p.s_vector(), &weights, &times);
    }
    t4.row(&["mass-aware (weighted)".into(), format!("{:.3e}", acc_w / trials as f64)]);
    t4.row(&["count-based (paper base)".into(), format!("{:.3e}", acc_c / trials as f64)]);
    t4.print();
    println!(
        "\nmass-aware gain over count-based: {:.1}%",
        (1.0 - acc_w / acc_c) * 100.0
    );

    // ---------------------------------------------- 5. non-iid robustness
    println!("\n[5] non-iid robustness: worker 0 persistently k-times slower");
    println!("    (schemes optimized under the iid assumption, evaluated non-iid)");
    use bcgc::optimizer::runtime_model::tau_hat;
    let xf_blocks = round_to_blocks(&xf, l);
    // Remedy variant: floor every block at redundancy ≥ 1 (the level-0
    // block is the only one that must wait for *every* worker, so it is
    // the single point of failure under a persistent straggler).
    let floored = {
        let mut sizes = xf_blocks.sizes().to_vec();
        sizes[1] += sizes[0];
        sizes[0] = 0;
        bcgc::optimizer::blocks::BlockPartition::new(sizes)
    };
    let single = bcgc::optimizer::baselines::single_bcgc(&spec, &os);
    let uncoded = bcgc::optimizer::baselines::uncoded(&spec);
    let mut t5 = Table::new(&[
        "slowdown k",
        "E[tau] x^(f)",
        "E[tau] x^(f), s>=1 floor",
        "E[tau] single-BCGC",
        "E[tau] uncoded",
    ]);
    for k in [1.0f64, 2.0, 5.0, 10.0] {
        let mut rng5 = Rng::new(808);
        let trials = 3000;
        let mut acc = [0.0f64; 4];
        for _ in 0..trials {
            let mut times = dist.sample_vec(n, &mut rng5);
            times[0] *= k; // persistent straggler, violating iid
            for (a, p) in acc.iter_mut().zip([&xf_blocks, &floored, &single, &uncoded]) {
                *a += tau_hat(&spec, &p.as_f64(), &times, WorkModel::GradientCoding);
            }
        }
        t5.row(&[
            format!("{k}x"),
            format!("{:.3e}", acc[0] / trials as f64),
            format!("{:.3e}", acc[1] / trials as f64),
            format!("{:.3e}", acc[2] / trials as f64),
            format!("{:.3e}", acc[3] / trials as f64),
        ]);
    }
    t5.print();
    println!("\nfinding: the iid-optimal partition's level-0 block must wait for ALL");
    println!("workers, so a ≥5x persistent straggler erases its lead; flooring every");
    println!("block at s ≥ 1 (one coordinate-shift of the partition) restores");
    println!("robustness at a small iid-regime premium. Uncoded degrades linearly.");
}
