//! Sim/coordinator parity property: the event-driven simulator
//! ([`bcgc::sim::simulate_iteration`]), the closed-form Eq. (2)
//! accounting the threaded coordinator reports
//! ([`bcgc::coordinator::straggler::virtual_runtime`]), and the
//! per-worker block completion stamps its workers attach to every
//! contribution ([`block_completion_stamps`]) must all tell the same
//! story, across random partitions and cycle-time distributions.
//!
//! Concretely: block `j` decodes at the `(N − s_j)`-th smallest of the
//! workers' completion stamps for `j`, the iteration completes at the
//! max over blocks, and that equals both the simulator's completion time
//! and `virtual_runtime`.

use bcgc::coding::scheme::CodingScheme;
use bcgc::coordinator::straggler::{block_completion_stamps, virtual_runtime};
use bcgc::distribution::{
    pareto::Pareto, shifted_exp::ShiftedExponential, weibull::Weibull, CycleTimeDistribution,
};
use bcgc::optimizer::rounding::round_to_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::sim::{simulate_iteration, SimConfig};
use bcgc::testing::{gens, Runner};

#[test]
fn sim_completion_equals_stamp_quorum_and_eq2() {
    Runner::new(150, 0xADA7).run("sim/coordinator parity", |rng| {
        let n = gens::usize_in(rng, 2, 12);
        let coords = n + gens::usize_in(rng, 0, 60);
        let spec = ProblemSpec::new(n, coords, n * 8, 1.0);
        let x = gens::feasible_x(rng, n, coords as f64);
        let blocks = round_to_blocks(&x, coords);
        let scheme = CodingScheme::new(blocks.clone(), rng).map_err(|e| e.to_string())?;

        let dist: Box<dyn CycleTimeDistribution> = match rng.below(3) {
            0 => Box::new(ShiftedExponential::new(
                1e-3 + rng.uniform() * 0.02,
                1.0 + rng.uniform() * 60.0,
            )),
            1 => Box::new(Weibull::new(
                0.8 + rng.uniform() * 2.0,
                5.0 + rng.uniform() * 20.0,
                0.5,
            )),
            _ => Box::new(Pareto::new(1.5 + rng.uniform() * 2.0, 1.0 + rng.uniform())),
        };
        let times = dist.sample_vec(n, rng);

        // Arm 1: event-driven playout.
        let sim = simulate_iteration(&spec, &blocks, &times, &SimConfig::default());

        // Arm 2: per-(worker, block) completion stamps → quorum decode
        // times (exactly the stamps the threaded workers attach).
        let stamps: Vec<Vec<f64>> = times
            .iter()
            .map(|&t| block_completion_stamps(&spec, &scheme, t))
            .collect();
        let ranges = blocks.ranges();
        let mut completion = 0.0f64;
        for (j, r) in ranges.iter().enumerate() {
            let mut arrivals: Vec<f64> = stamps.iter().map(|s| s[j]).collect();
            arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let decode = arrivals[n - r.s - 1]; // (N − s)-th smallest
            let sim_decode = sim.block_decode_times[j];
            if (sim_decode - decode).abs() > 1e-9 * decode.max(1.0) {
                return Err(format!(
                    "block {j}: sim decode {sim_decode} vs stamp quorum {decode}"
                ));
            }
            completion = completion.max(decode);
        }
        if (sim.completion_time - completion).abs() > 1e-9 * completion.max(1.0) {
            return Err(format!(
                "completion: sim {} vs stamps {completion}",
                sim.completion_time
            ));
        }

        // Arm 3: the Eq. (2) closed form the trainer records.
        let vr = virtual_runtime(&spec, &scheme, &times);
        if (vr - sim.completion_time).abs() > 1e-9 * vr.max(1.0) {
            return Err(format!(
                "virtual_runtime {vr} vs sim completion {}",
                sim.completion_time
            ));
        }
        Ok(())
    });
}
