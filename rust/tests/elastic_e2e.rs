//! Elastic worker pool, end to end: workers leave and join mid-training,
//! the trainer re-dimensions the coding scheme around the live roster as
//! fresh scheme epochs, and training completes every iteration with
//! exact decoding inside each epoch. Complements the master-level
//! binding/quorum tests (`rust/src/coordinator/master.rs`) and the
//! virtual-time churn parity test (`rust/src/sim/multi.rs`).

use bcgc::coordinator::membership::MemberStatus;
use bcgc::coordinator::metrics::MembershipEvent;
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::coordinator::trainer::{train, ElasticConfig, TrainConfig, TrainSession};
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::host_factory;
use bcgc::testing::suite_seed;

fn mlp_setup(
    n: usize,
    seed: u64,
) -> (bcgc::runtime::ExecutorFactory, ProblemSpec, usize) {
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    (factory, spec, dim)
}

#[test]
fn shrinking_the_pool_by_two_redimensions_and_completes_every_iteration() {
    // N = 8 → 6: two workers drain before iteration 12. The trainer
    // re-dimensions before the same iteration's step, so no iteration
    // ever runs against an undecodable roster; later one worker joins
    // back and is absorbed as another epoch.
    let n = 8usize;
    let steps = 45usize;
    let seed = suite_seed(11);
    let (factory, spec, dim) = mlp_setup(n, seed);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let blocks = x_freq_blocks(&spec, &dist, dim).unwrap();

    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = steps;
    cfg.lr = 2e-3;
    cfg.eval_every = 15;
    cfg.seed = seed;
    cfg.elastic = Some(ElasticConfig {
        churn_threshold: 1,
        departures: vec![(12, 2)],
        arrivals: vec![(25, 1)],
    });
    let schedule = StragglerSchedule::stationary(Box::new(dist));
    let report = train(cfg, schedule, factory).unwrap();

    // Every iteration ran and decoded a full gradient.
    assert_eq!(report.steps(), steps);
    assert!(report.iters.iter().all(|m| m.blocks_decoded >= 1 && m.grad_norm.is_finite()));
    // Clean drains are departures, not failures.
    assert!(report.failed_workers.is_empty());

    // Pool-size trajectory: 8 until the departure, then 6, then 7 once
    // the join's epoch swap lands (the join waits for its confirmation,
    // so the exact swap iteration may trail the arrival by a step).
    for m in &report.iters {
        match m.iter {
            i if i < 12 => assert_eq!(m.workers, n, "iter {i}"),
            i if i < 25 => assert_eq!(m.workers, n - 2, "iter {i}"),
            i => assert!(m.workers == n - 2 || m.workers == n - 1, "iter {i}: {}", m.workers),
        }
    }
    assert_eq!(
        report.iters.last().unwrap().workers,
        n - 1,
        "the arrival must eventually be absorbed"
    );

    // Membership log: two leaves, one join, and ≥ 2 re-dimensions whose
    // sizes match the trajectory.
    let leaves =
        report.membership.iter().filter(|m| matches!(m.event, MembershipEvent::Leave { .. }));
    assert_eq!(leaves.count(), 2);
    let joins =
        report.membership.iter().filter(|m| matches!(m.event, MembershipEvent::Join { .. }));
    assert_eq!(joins.count(), 1);
    let redims: Vec<(usize, usize)> = report
        .membership
        .iter()
        .filter_map(|m| match m.event {
            MembershipEvent::Redimension { from_n, to_n, .. } => Some((from_n, to_n)),
            _ => None,
        })
        .collect();
    assert_eq!(redims[0], (8, 6));
    assert!(redims.contains(&(6, 7)), "{redims:?}");

    // Each re-dimension is a fresh scheme epoch sized to the roster.
    assert!(report.epochs() >= 3, "expected ≥ 2 re-dimension epochs");
    let last_epoch = report.scheme_epochs.last().unwrap();
    assert_eq!(last_epoch.block_sizes.len(), n - 1);
    assert_eq!(last_epoch.block_sizes.iter().sum::<usize>(), dim);

    // Training still converged through the churn.
    let first = report.first_loss().unwrap();
    let last = report.final_loss().unwrap();
    assert!(last < first, "loss {first} -> {last}");
}

#[test]
fn departure_below_threshold_is_absorbed_as_a_dead_row_then_rebound() {
    // churn_threshold = 2: the first departure does NOT re-dimension —
    // the fixed scheme (redundancy floor s ≥ 1) absorbs the dead row
    // like a fatal straggler — and the second departure trips the
    // threshold and shrinks N 8 → 6.
    let n = 8usize;
    let steps = 30usize;
    let seed = suite_seed(13);
    let (factory, spec, dim) = mlp_setup(n, seed);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let blocks = x_freq_blocks(&spec, &dist, dim).unwrap().raise_min_level(1);

    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = steps;
    cfg.lr = 2e-3;
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.elastic = Some(ElasticConfig {
        churn_threshold: 2,
        departures: vec![(8, 1), (18, 1)],
        arrivals: vec![],
    });
    let schedule = StragglerSchedule::stationary(Box::new(dist));
    let report = train(cfg, schedule, factory).unwrap();

    assert_eq!(report.steps(), steps);
    assert!(report.iters.iter().all(|m| m.grad_norm.is_finite()));
    // Between the departures the scheme keeps its 8 rows (one dead).
    for m in &report.iters {
        match m.iter {
            i if i < 18 => assert_eq!(m.workers, n, "iter {i}"),
            i => assert_eq!(m.workers, n - 2, "iter {i}"),
        }
    }
    let redims: Vec<(usize, usize)> = report
        .membership
        .iter()
        .filter_map(|m| match m.event {
            MembershipEvent::Redimension { from_n, to_n, .. } => Some((from_n, to_n)),
            _ => None,
        })
        .collect();
    assert_eq!(redims, vec![(8, 6)], "exactly one re-dimension, at the threshold");
}

#[test]
fn join_is_not_assigned_work_until_the_next_epoch_swap() {
    let n = 4usize;
    let seed = suite_seed(17);
    let (factory, spec, dim) = mlp_setup(n, seed);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let blocks = x_freq_blocks(&spec, &dist, dim).unwrap();
    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = 30;
    cfg.lr = 2e-3;
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.elastic = Some(ElasticConfig::default());
    let schedule = StragglerSchedule::stationary(Box::new(dist));

    let mut session = TrainSession::start(cfg, schedule, factory).unwrap();
    session.step(0).unwrap();
    let id = session.add_worker(1).unwrap();
    assert_eq!(id, n, "ids are allocated monotonically");
    assert_eq!(session.registry().status(id), Some(MemberStatus::Pending));
    assert_eq!(session.registry().row_of(id), None, "a join holds no row yet");

    // Step until the join's confirmation triggers a re-dimension; every
    // iteration before the swap must run with the old N (the pending
    // worker is assigned no work).
    let mut swapped_at = None;
    for iter in 1..20 {
        if session.maybe_redimension(iter).unwrap() {
            swapped_at = Some(iter);
            break;
        }
        assert_eq!(session.registry().n(), n, "no rebind before the epoch swap");
        session.step(iter).unwrap();
    }
    let swapped_at = swapped_at.expect("a confirmed join must trigger a re-dimension");
    assert_eq!(session.registry().n(), n + 1);
    assert_eq!(session.registry().status(id), Some(MemberStatus::Active));
    let row = session.registry().row_of(id).expect("bound to a row after the swap");
    assert_eq!(row, n, "rows are assigned in ascending id order");

    // The re-dimensioned epoch runs with the join contributing.
    for iter in swapped_at..swapped_at + 3 {
        session.step(iter).unwrap();
    }
    let report = session.finish().unwrap();
    for m in &report.iters {
        if m.iter < swapped_at {
            assert_eq!(m.workers, n, "iter {} ran before the swap", m.iter);
        }
    }
    assert_eq!(report.iters.last().unwrap().workers, n + 1);
    assert!(report.iters.iter().all(|m| m.grad_norm.is_finite()));
}
