//! Multi-job coordinator, end to end: several training jobs share ONE
//! worker pool with exact per-job gradient decode and full isolation —
//! one tenant's trouble (executor failures, its own stragglers) never
//! stalls or corrupts another tenant's quorum, and pool-level churn
//! re-dimensions every job's scheme off the shared membership epoch.
//! Complements the master-level cross-job drop test
//! (`rust/src/coordinator/master.rs`) and the virtual-time
//! shared-vs-split comparison (`rust/src/sim/multi.rs`).

use std::sync::Arc;

use bcgc::coordinator::metrics::MembershipEvent;
use bcgc::coordinator::pool::{ElasticConfig, JobSpec, PoolConfig, WorkerPool};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::{host_factory, ExecutorFactory, GradExecutor};
use bcgc::testing::suite_seed;

fn stationary(mu: f64) -> StragglerSchedule {
    StragglerSchedule::stationary(Box::new(ShiftedExponential::new(mu, 50.0)))
}

#[test]
fn two_jobs_decode_their_own_exact_gradients_on_one_pool() {
    // Two tenants with different models and datasets, θ0 = 0 for both:
    // each job's first decoded gradient must equal the direct sum over
    // its OWN dataset's shards — any cross-job codeword leakage would
    // corrupt the match.
    let n = 4usize;
    let seed = suite_seed(31);

    let ds_a = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim_a = HostExecutor::mlp_dim(8, 16, 4);
    let (ds_b, _) = synthetic::linear_regression(24, 16 * n, n, 0.05, seed + 1).unwrap();
    let dim_b = 24usize;

    let mut pool = WorkerPool::new(PoolConfig::new(n), stationary(1e-3)).unwrap();
    let spec_a = ProblemSpec::new(n, dim_a, 16 * n, 1.0);
    let mut sizes = vec![0usize; n];
    sizes[1] = dim_a / 3;
    sizes[3] = dim_a - dim_a / 3;
    let a = JobSpec::new(spec_a, BlockPartition::new(sizes))
        .steps(6)
        .lr(2e-3)
        .eval_every(3)
        .seed(seed)
        .init_scale(0.0)
        .executor(host_factory(ds_a.clone(), HostModel::Mlp { hidden: 16 }))
        .submit(&mut pool)
        .unwrap();
    let spec_b = ProblemSpec::new(n, dim_b, 16 * n, 1.0);
    let b = JobSpec::new(spec_b, BlockPartition::single_level(n, 1, dim_b))
        .steps(6)
        .lr(5e-3)
        .eval_every(3)
        .seed(seed + 1)
        .init_scale(0.0)
        .executor(host_factory(ds_b.clone(), HostModel::LinearRegression))
        .submit(&mut pool)
        .unwrap();
    assert_eq!((a, b), (0, 1), "job ids are allocated in submit order");

    pool.run_all().unwrap();
    assert_eq!(pool.rounds(), 12, "6 + 6 interleaved iterations");
    assert_eq!(pool.cross_job_dropped(), 0);
    // JobHandle metrics are readable mid-flight (before finish).
    assert!(pool.job(0).cache_stats().1 >= 1, "job 0 decoded at least one block");
    assert!(pool.job(0).done() && pool.job(1).done());
    let reports = pool.finish().unwrap();

    // Exact decode per job at θ0 = 0.
    for (r, (ds, model, dim)) in reports.iter().zip([
        (ds_a, HostModel::Mlp { hidden: 16 }, dim_a),
        (ds_b, HostModel::LinearRegression, dim_b),
    ]) {
        let mut exec = HostExecutor::new(ds, model).unwrap();
        let theta0 = vec![0.0f32; dim];
        let mut g = vec![0.0f64; dim];
        for s in 0..n {
            for (acc, v) in g.iter_mut().zip(exec.grad_shard(&theta0, s).unwrap()) {
                *acc += v as f64;
            }
        }
        let want: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(want > 0.0);
        assert!(
            (r.iters[0].grad_norm - want).abs() < 1e-6 * (1.0 + want),
            "decoded {} vs direct {}",
            r.iters[0].grad_norm,
            want
        );
        assert_eq!(r.steps(), 6);
        assert!(r.iters.iter().all(|m| m.grad_norm.is_finite()));
        assert_eq!(r.iters.iter().map(|m| m.stale_epoch_contributions).sum::<usize>(), 0);
        // Both jobs converge on their own loss.
        assert!(r.final_loss().unwrap() < r.first_loss().unwrap());
    }
}

#[test]
fn per_job_executor_failure_never_stalls_the_healthy_tenant() {
    // Worker 3 cannot build job 1's executor (a per-tenant dependency
    // problem): job 1's redundancy must absorb it like a straggler,
    // job 0 must keep decoding with all four workers, and the shared
    // thread must survive (transient, not fatal).
    let n = 4usize;
    let seed = suite_seed(37);
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);

    let mut pool = WorkerPool::new(PoolConfig::new(n), stationary(1e-3)).unwrap();
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    JobSpec::new(spec, BlockPartition::single_level(n, 0, dim))
        .steps(8)
        .lr(2e-3)
        .eval_every(4)
        .seed(seed)
        .executor(host_factory(ds.clone(), HostModel::Mlp { hidden: 16 }))
        .submit(&mut pool)
        .unwrap();
    let base = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let flaky: ExecutorFactory = Arc::new(move |worker| {
        if worker == 3 {
            Err(bcgc::Error::Runtime("injected: worker 3 lacks job 1's dataset".into()))
        } else {
            base(worker)
        }
    });
    JobSpec::new(spec, BlockPartition::single_level(n, 1, dim))
        .steps(8)
        .lr(2e-3)
        .eval_every(4)
        .seed(seed + 1)
        .executor(flaky)
        .submit(&mut pool)
        .unwrap();

    pool.run_all().unwrap();
    let reports = pool.finish().unwrap();
    // Job 0 needed ALL FOUR workers every iteration (s = 0): the other
    // tenant's broken worker must not have leaked into its quorum.
    assert_eq!(reports[0].steps(), 8);
    assert!(reports[0].iters.iter().all(|m| m.grad_norm.is_finite()));
    // Job 1 completed every iteration coded around the failure, and a
    // per-job transient failure is not a pool-level fatality.
    assert_eq!(reports[1].steps(), 8);
    assert!(reports[1].iters.iter().all(|m| m.grad_norm.is_finite()));
    assert!(reports[1].failed_workers.is_empty());
    assert!(reports[1].final_loss().unwrap() < reports[1].first_loss().unwrap());
}

#[test]
fn pool_churn_redimensions_every_job_off_one_membership_epoch() {
    // One scheduled departure: BOTH tenants must re-dimension N → N−1
    // as fresh scheme epochs, complete every iteration, and keep their
    // decode exact through the swap.
    let n = 6usize;
    let seed = suite_seed(41);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let dim = HostExecutor::mlp_dim(8, 16, 4);

    let mut pcfg = PoolConfig::new(n);
    pcfg.seed = seed;
    pcfg.elastic = Some(ElasticConfig {
        churn_threshold: 1,
        departures: vec![(5, 1)],
        arrivals: vec![],
    });
    let mut pool = WorkerPool::new(pcfg, stationary(1e-3)).unwrap();
    for j in 0..2u64 {
        let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed + j).unwrap();
        let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
        let blocks = x_freq_blocks(&spec, &dist, dim).unwrap();
        JobSpec::new(spec, blocks)
            .steps(16)
            .lr(2e-3)
            .eval_every(8)
            .seed(seed + j)
            .executor(host_factory(ds, HostModel::Mlp { hidden: 16 }))
            .submit(&mut pool)
            .unwrap();
    }

    pool.run_all().unwrap();
    let reports = pool.finish().unwrap();
    for (j, r) in reports.iter().enumerate() {
        assert_eq!(r.steps(), 16, "job {j} dropped iterations through churn");
        assert!(r.iters.iter().all(|m| m.grad_norm.is_finite()));
        let redims: Vec<(usize, usize)> = r
            .membership
            .iter()
            .filter_map(|m| match m.event {
                MembershipEvent::Redimension { from_n, to_n, .. } => Some((from_n, to_n)),
                _ => None,
            })
            .collect();
        assert_eq!(redims, vec![(n, n - 1)], "job {j}: {redims:?}");
        // The re-dimension is a fresh scheme epoch sized to the roster.
        let last = r.scheme_epochs.last().unwrap();
        assert_eq!(last.block_sizes.len(), n - 1, "job {j}");
        assert_eq!(last.block_sizes.iter().sum::<usize>(), dim, "job {j}");
        // Pool size trajectory: n before the swap, n−1 after.
        assert_eq!(r.iters.first().unwrap().workers, n, "job {j}");
        assert_eq!(r.iters.last().unwrap().workers, n - 1, "job {j}");
        // Cache stats accumulated across both epochs (satellite:
        // counters survive install_scheme).
        assert!(r.decode_cache_misses >= 2, "job {j}: misses across 2 epochs");
    }
}
