//! Adaptive coding engine, end to end: the straggler distribution shifts
//! mid-training, the threaded trainer hot-swaps to a re-optimized scheme
//! without dropping an iteration, and — in the multi-iteration simulator
//! at paper scale — the adaptive run's post-shift mean virtual runtime
//! beats the static scheme that was optimal for the initial distribution.

use bcgc::coordinator::adaptive::AdaptiveConfig;
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::coordinator::trainer::{train, train_stationary, TrainConfig};
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::closed_form::x_freq_blocks;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::host_factory;
use bcgc::sim::{compare_adaptive_vs_static, MultiSimConfig};

#[test]
fn threaded_trainer_hot_swaps_mid_training_without_dropping_iterations() {
    let n = 6usize;
    let steps = 60usize;
    let shift_at = 25usize;
    let seed = 42u64;
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);

    // Strong drift: mean cycle time 100 → 1050, tail 10x fatter.
    let d0 = ShiftedExponential::new(2e-2, 50.0);
    let d1 = ShiftedExponential::new(1e-3, 50.0);
    let blocks = x_freq_blocks(&spec, &d0, dim).unwrap();

    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = steps;
    cfg.lr = 2e-3;
    cfg.eval_every = 15;
    cfg.seed = seed;
    cfg.adaptive = Some(AdaptiveConfig {
        window: 20 * n,
        min_samples: 10 * n,
        check_every: 5,
        cooldown: 5,
        drift_threshold: 0.35,
        ..Default::default()
    });
    let schedule =
        StragglerSchedule::stationary(Box::new(d0.clone())).then(shift_at, Box::new(d1.clone()));
    let report = train(cfg, schedule, factory).unwrap();

    // No iteration dropped: every step ran and decoded a full gradient.
    assert_eq!(report.steps(), steps);
    assert!(report.iters.iter().all(|m| m.blocks_decoded >= 1 && m.grad_norm.is_finite()));
    assert!(report.failed_workers.is_empty());

    // The drift was detected and a new scheme epoch installed, after the
    // shift (the reference matches phase 0, so phase 0 never triggers).
    assert!(report.epochs() >= 2, "expected at least one hot swap");
    assert!(
        report.scheme_epochs.iter().any(|e| e.installed_at_iter > shift_at),
        "swap must follow the distribution shift: {:?}",
        report
            .scheme_epochs
            .iter()
            .map(|e| e.installed_at_iter)
            .collect::<Vec<_>>()
    );
    // The re-solve was driven by a fit that moved decisively toward the
    // new regime (early swaps may fit a pre/post mixture, so bound the
    // direction rather than the exact value). `estimated_mean` is the
    // family-agnostic hook: with `family = auto` the mixture window may
    // legitimately be fitted by a non-exponential family, in which case
    // no `mu` is recorded.
    let last = report.scheme_epochs.last().unwrap();
    assert!(last.family.is_some(), "adaptive swaps record their family");
    let fitted_mean = last.estimated_mean.expect("adaptive swap records its fit");
    assert!(
        fitted_mean > 1.5 * d0.mean() && fitted_mean < 1.5 * d1.mean(),
        "fitted mean {fitted_mean} should sit between the regimes ({} → {})",
        d0.mean(),
        d1.mean()
    );

    // Epochs recorded per iteration are monotone and end > 0.
    let epochs: Vec<usize> = report.iters.iter().map(|m| m.epoch).collect();
    assert!(epochs.windows(2).all(|w| w[0] <= w[1]));
    assert!(*epochs.last().unwrap() >= 1);

    // Training still converged through the swap.
    let first = report.first_loss().unwrap();
    let last_loss = report.final_loss().unwrap();
    assert!(last_loss < first, "loss {first} -> {last_loss}");
}

#[test]
fn static_run_records_exactly_one_epoch() {
    let n = 4usize;
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, 5).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, 1, dim));
    cfg.steps = 8;
    cfg.eval_every = 0;
    cfg.seed = 5;
    let report = train_stationary(cfg, Box::new(ShiftedExponential::new(1e-3, 50.0)), factory)
        .unwrap();
    assert_eq!(report.epochs(), 1);
    assert_eq!(report.stale_epoch_total(), 0);
    assert!(report.iters.iter().all(|m| m.epoch == 0));
}

#[test]
fn adaptive_beats_static_after_shift_in_multi_iteration_simulator() {
    // Paper scale, virtual time only: N = 20, L = 2e4, 300 iterations,
    // the distribution shifting at iteration 100. The static arm keeps
    // the phase-0-optimal x^(f); the adaptive arm re-fits and re-solves.
    let (n, coords) = (20usize, 20_000usize);
    let (iters, shift_at, grace) = (300usize, 100usize, 60usize);
    let spec = ProblemSpec::paper_default(n, coords);
    let d0 = ShiftedExponential::new(1e-2, 50.0);
    let d1 = ShiftedExponential::new(1e-3, 50.0);
    let schedule =
        StragglerSchedule::stationary(Box::new(d0.clone())).then(shift_at, Box::new(d1.clone()));
    let initial = x_freq_blocks(&spec, &d0, coords).unwrap();
    let oracle = x_freq_blocks(&spec, &d1, coords).unwrap();
    assert_ne!(
        initial.sizes(),
        oracle.sizes(),
        "the two regimes must demand different partitions for this test to bite"
    );

    let acfg = AdaptiveConfig {
        window: 20 * n,
        min_samples: 10 * n,
        check_every: 10,
        cooldown: 20,
        drift_threshold: 0.2,
        ..Default::default()
    };
    let cfg = MultiSimConfig { iters, seed: 77, comm_latency: 0.0 };
    let cmp = compare_adaptive_vs_static(
        &spec,
        &initial,
        Some(&oracle),
        &schedule,
        &cfg,
        acfg,
        grace,
    )
    .unwrap();

    assert!(!cmp.adaptive_run.swaps.is_empty(), "the engine must re-plan after the shift");
    let (s_after, a_after) = (cmp.static_after(), cmp.adaptive_after());
    assert!(
        a_after < s_after,
        "adaptive ({a_after:.1}) must beat static-optimal-for-phase-0 ({s_after:.1}) after the shift"
    );
    // And it should land close to the oracle (estimation error only).
    let o_after = cmp.oracle_after().unwrap();
    assert!(
        a_after < o_after * 1.15,
        "adaptive ({a_after:.1}) should approach the oracle ({o_after:.1})"
    );
    // Before the shift nothing fires and the arms are CRN-identical.
    let first_swap = cmp.adaptive_run.swaps[0].installed_at_iter;
    assert!(first_swap > shift_at);
    assert_eq!(
        cmp.adaptive_run.completion_times[..first_swap],
        cmp.static_run.completion_times[..first_swap]
    );
}
