//! Partial-straggler streaming, end to end on the threaded pool
//! (PR 10): sample-granular dispatch and rotated per-part coded deltas
//! must never change *what* decodes — only when.
//!
//! Three anchors:
//!
//! * **Exact decode every iteration** — with θ pinned (lr = 0) the
//!   decoded gradient of a streaming job (`stream_parts ≥ 2`) equals
//!   the direct full-dataset gradient every single iteration, and the
//!   whole-block sample-granular variant (`stream_parts = 1`) agrees
//!   too. Rotation parts re-order the f32 wire sums, so the comparison
//!   is to accumulation tolerance, not bits.
//! * **Span compute is bit-stable** — the executor contract the
//!   streaming checkpoints ride on: a prefix + remainder pair of
//!   [`bcgc::runtime::GradExecutor::grad_span_into`] calls into ONE
//!   accumulator is bit-equal to the whole-span call (same per-sample
//!   f32 addends in the same order).
//! * **Approx ledger balances under overlap** — semi-async decodes with
//!   streaming on still satisfy
//!   `approx_decodes == approx_reconciled + approx_discarded`, with
//!   both tenants completing and tenant isolation intact.

use bcgc::coordinator::master::SemiAsyncConfig;
use bcgc::coordinator::pool::{AsyncConfig, JobSpec, PoolConfig, WorkerPool};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::{host_factory, GradExecutor};
use bcgc::testing::suite_seed;
use bcgc::util::rng::Rng;

const N: usize = 6;

fn stationary(mu: f64) -> StragglerSchedule {
    StragglerSchedule::stationary(Box::new(ShiftedExponential::new(mu, 50.0)))
}

#[test]
fn streaming_job_decodes_the_exact_gradient_every_iteration() {
    // θ0 = 0 with lr = 0 keeps the model pinned, so EVERY iteration's
    // decoded gradient must equal the direct full-dataset sum — for the
    // whole-block sample-granular mode (parts = 1) and for genuine
    // rotated streaming (parts = 2, 4). Parts that don't divide the
    // per-row span exercise the uneven-stride boundaries.
    let seed = suite_seed(101);
    let steps = 12usize;
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let ds = synthetic::classification(8, 4, 16 * N, N, 0.2, seed).unwrap();

    // Direct full-dataset gradient at θ0 = 0, f64-accumulated per span.
    let mut exec = HostExecutor::new(ds.clone(), HostModel::Mlp { hidden: 16 }).unwrap();
    let theta0 = vec![0.0f32; dim];
    let mut g = vec![0.0f32; dim];
    exec.grad_span_into(&theta0, 0, exec.num_samples(), &mut g).unwrap();
    let want: f64 = g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
    assert!(want > 0.0);

    for parts in [1usize, 2, 4] {
        let mut pcfg = PoolConfig::new(N);
        pcfg.seed = seed;
        let mut pool = WorkerPool::new(pcfg, stationary(1e-3)).unwrap();
        let spec = ProblemSpec::new(N, dim, 16 * N, 1.0);
        JobSpec::new(spec, BlockPartition::single_level(N, 1, dim))
            .steps(steps)
            .lr(0.0) // pin θ so every decode is checkable against θ0
            .eval_every(0)
            .seed(seed)
            .init_scale(0.0)
            .stream_parts(parts)
            .executor(host_factory(ds.clone(), HostModel::Mlp { hidden: 16 }))
            .submit(&mut pool)
            .unwrap();
        pool.run_all().unwrap();
        let report = pool.finish().unwrap().pop().unwrap();

        assert_eq!(report.steps(), steps, "parts={parts}");
        for m in &report.iters {
            assert!(
                (m.grad_norm - want).abs() < 1e-5 * (1.0 + want),
                "parts={parts} iter {}: decoded {} vs direct {} — streamed parts must \
                 sum to the exact whole-block gradient",
                m.iter,
                m.grad_norm,
                want
            );
            assert_eq!(m.stale_epoch_contributions, 0, "parts={parts} iter {}", m.iter);
        }
        // The partial ledger mirrors the mode: rotation parts complete
        // every block part-wise; the whole-block modes never touch it.
        if parts >= 2 {
            assert!(
                report.partial_decodes > 0,
                "parts={parts}: streaming ran but no block completed part-wise"
            );
            assert_eq!(report.partial_decodes, report.partial_blocks_total(), "parts={parts}");
            for m in &report.iters {
                assert_eq!(
                    m.partial_blocks, m.blocks_decoded,
                    "parts={parts} iter {}: a pure-streaming round must complete every \
                     block part-wise",
                    m.iter
                );
                assert!(m.partial_contributions > 0, "parts={parts} iter {}", m.iter);
            }
        } else {
            assert_eq!(report.partial_decodes, 0, "parts={parts}");
            assert_eq!(report.partial_blocks_total(), 0, "parts={parts}");
        }
    }
}

#[test]
fn span_prefix_plus_remainder_is_bit_equal_to_the_whole_span() {
    // The executor contract the worker's stride checkpoints rely on:
    // splitting a span at ANY boundary and accumulating both pieces
    // into one buffer reproduces the whole-span gradient bit for bit.
    let seed = suite_seed(103);
    let ds = synthetic::classification(8, 4, 16 * N, N, 0.2, seed).unwrap();
    let mut exec = HostExecutor::new(ds, HostModel::Mlp { hidden: 16 }).unwrap();
    let dim = exec.dim();
    let total = exec.num_samples();
    let mut rng = Rng::new(seed ^ 0xF00D);
    let theta: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 0.3).collect();

    let (lo, hi) = (total / 8, total - total / 8);
    let mut whole = vec![0.0f32; dim];
    exec.grad_span_into(&theta, lo, hi, &mut whole).unwrap();
    for k in 0..6 {
        let mid = lo + (hi - lo) * k / 5;
        let mut split = vec![0.0f32; dim];
        exec.grad_span_into(&theta, lo, mid, &mut split).unwrap();
        exec.grad_span_into(&theta, mid, hi, &mut split).unwrap();
        assert!(
            split.iter().zip(whole.iter()).all(|(a, b)| a.to_bits() == b.to_bits()),
            "mid={mid}: prefix+remainder must be bit-equal to the whole span"
        );
    }
}

#[test]
fn semi_async_streaming_balances_the_approx_ledger() {
    // Two streaming tenants under overlapped rounds with an aggressive
    // semi-async policy: approximate decodes may fire on blocks whose
    // part quorums haven't filled, and every one of them must be
    // reconciled or discarded — never silently kept.
    let seed = suite_seed(107);
    let steps = [10usize, 7usize];
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let mut pcfg = PoolConfig::new(N);
    pcfg.seed = seed;
    pcfg.async_rounds = Some(AsyncConfig {
        max_inflight: 2,
        backlog_pricing: true,
        reprice_threshold: 0.25,
        semi_async: Some(SemiAsyncConfig {
            max_shortfall: 1,
            backlog_factor: 0.25,
            max_residual: 1e9,
        }),
    });
    let mut pool = WorkerPool::new(pcfg, stationary(1e-3)).unwrap();
    for (j, &s) in steps.iter().enumerate() {
        let ds = synthetic::classification(8, 4, 16 * N, N, 0.2, seed + j as u64).unwrap();
        let spec = ProblemSpec::new(N, dim, 16 * N, 1.0);
        JobSpec::new(spec, BlockPartition::single_level(N, 1, dim))
            .steps(s)
            .lr(2e-3)
            .eval_every(4)
            .seed(seed + 100 + j as u64)
            .stream_parts(4)
            .executor(host_factory(ds, HostModel::Mlp { hidden: 16 }))
            .submit(&mut pool)
            .unwrap();
    }
    pool.run_all_async().unwrap();
    assert_eq!(pool.cross_job_dropped(), 0, "tenant isolation broke under streaming");
    let reports = pool.finish().unwrap();
    for (j, r) in reports.iter().enumerate() {
        assert_eq!(r.steps(), steps[j], "job {j} dropped iterations");
        assert!(r.iters.iter().all(|m| m.grad_norm.is_finite()), "job {j}");
        assert_eq!(
            r.approx_decodes,
            r.approx_reconciled + r.approx_discarded,
            "job {j} leaked approx decodes with streaming on"
        );
        assert_eq!(r.approx_decodes, r.approx_blocks_total(), "job {j}");
        assert!(
            r.partial_decodes > 0,
            "job {j}: streaming tenants must complete blocks part-wise"
        );
        // Part buffers are pooled like whole-block payloads; the run
        // must recycle them through the wire freelist.
        assert!(r.wire_pool_returned > 0, "job {j}: no wire buffers recycled");
    }
}
