//! Async round engine, end to end: the pipelined position-aware
//! dispatcher (`WorkerPool::run_all_async`) against the serialized
//! barrier (`run_all`).
//!
//! The anchor property is the ISSUE's: **async with queue depth 0
//! reproduces the serialized schedule exactly**. At `max_inflight = 1`
//! every dispatch waits for the previous finalize, so every backlog is
//! zero, every queue-position offset is exactly `0.0`, and the virtual
//! accounting folds in the same order with the same operands — the two
//! engines must agree bit for bit, not approximately.
//!
//! Two equality tests split by what virtual pacing can promise:
//!
//! * With `s = 0` schemes every block needs EVERY live row, so the
//!   decode's contributor set is arrival-order independent and the
//!   whole run — gradients, θ, losses — is bit-deterministic: compare
//!   everything.
//! * With `s ≥ 1`, which `N − s` rows decode a block is a thread race
//!   under virtual pacing (no sleeping), so only the *virtual*
//!   quantities (Eq. (2) runtimes, makespan) are deterministic:
//!   compare exactly those.
//!
//! The overlapped test (`max_inflight = 2`, semi-async decode on)
//! asserts the invariants that survive real concurrency: every
//! approximate decode is reconciled or discarded, cross-job and stale
//! contributions recycle their wire buffers, and both tenants finish
//! every iteration.

use bcgc::coordinator::master::SemiAsyncConfig;
use bcgc::coordinator::metrics::TrainReport;
use bcgc::coordinator::pool::{AsyncConfig, JobSpec, PoolConfig, WorkerPool};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::host_factory;
use bcgc::testing::suite_seed;

const N: usize = 6;
const STEPS: [usize; 2] = [12, 8];

fn stationary(mu: f64) -> StragglerSchedule {
    StragglerSchedule::stationary(Box::new(ShiftedExponential::new(mu, 50.0)))
}

/// Build the standard two-tenant pool: two MLP jobs with `s`-redundant
/// single-level schemes, identical across arms for a given `seed`.
fn build_pool(seed: u64, s: usize, async_cfg: Option<AsyncConfig>) -> WorkerPool {
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let mut pcfg = PoolConfig::new(N);
    pcfg.seed = seed;
    pcfg.async_rounds = async_cfg;
    let mut pool = WorkerPool::new(pcfg, stationary(1e-3)).unwrap();
    for (j, &steps) in STEPS.iter().enumerate() {
        let ds = synthetic::classification(8, 4, 16 * N, N, 0.2, seed + j as u64).unwrap();
        let spec = ProblemSpec::new(N, dim, 16 * N, 1.0);
        JobSpec::new(spec, BlockPartition::single_level(N, s, dim))
            .steps(steps)
            .lr(2e-3)
            .eval_every(4)
            .seed(seed + 100 + j as u64)
            .executor(host_factory(ds, HostModel::Mlp { hidden: 16 }))
            .submit(&mut pool)
            .unwrap();
    }
    pool
}

fn bits(x: f64) -> u64 {
    x.to_bits()
}

/// Zero-depth pipeline knobs: one inflight round, everything else on.
fn depth_zero() -> AsyncConfig {
    AsyncConfig {
        max_inflight: 1,
        backlog_pricing: true,
        reprice_threshold: 0.25,
        semi_async: Some(SemiAsyncConfig::default()),
    }
}

#[test]
fn depth_zero_async_is_bit_equal_to_serialized_on_s0_schemes() {
    // s = 0: every block decodes from ALL live rows, so the decoded
    // gradients are arrival-order independent and the serialized and
    // async runs must agree bit for bit end to end.
    let seed = suite_seed(61);
    let mut serial = build_pool(seed, 0, None);
    serial.run_all().unwrap();
    let serial_rounds = serial.rounds();
    let serial_makespan = serial.virtual_makespan();
    let serial_reports = serial.finish().unwrap();

    let mut asynch = build_pool(seed, 0, Some(depth_zero()));
    asynch.run_all_async().unwrap();
    assert_eq!(asynch.rounds(), serial_rounds, "same round count");
    assert_eq!(
        bits(asynch.virtual_makespan()),
        bits(serial_makespan),
        "virtual makespan must be IDENTICAL, not close: async {} vs serialized {}",
        asynch.virtual_makespan(),
        serial_makespan
    );
    let async_reports = asynch.finish().unwrap();

    for (j, (a, s)) in async_reports.iter().zip(&serial_reports).enumerate() {
        assert_eq!(a.steps(), STEPS[j], "job {j}");
        assert_eq!(a.iters.len(), s.iters.len(), "job {j}");
        for (t, (ia, is)) in a.iters.iter().zip(&s.iters).enumerate() {
            assert_eq!(
                bits(ia.virtual_runtime),
                bits(is.virtual_runtime),
                "job {j} iter {t}: vr {} vs {}",
                ia.virtual_runtime,
                is.virtual_runtime
            );
            assert_eq!(
                bits(ia.grad_norm),
                bits(is.grad_norm),
                "job {j} iter {t}: grad {} vs {}",
                ia.grad_norm,
                is.grad_norm
            );
            assert_eq!(ia.queue_wait, 0.0, "job {j} iter {t}: backlog must be zero");
            assert_eq!(ia.approx_blocks, 0, "job {j} iter {t}: no approx at depth zero");
        }
        // Same losses to the last bit (f32 eval on identical θ).
        let la: Vec<(usize, u32)> = a.loss_curve.iter().map(|&(i, l)| (i, l.to_bits())).collect();
        let ls: Vec<(usize, u32)> = s.loss_curve.iter().map(|&(i, l)| (i, l.to_bits())).collect();
        assert_eq!(la, ls, "job {j}: loss curves diverged");
        assert_eq!(
            (a.approx_decodes, a.approx_reconciled, a.approx_discarded),
            (0, 0, 0),
            "job {j}: semi-async must never fire at queue depth 0"
        );
    }
}

#[test]
fn depth_zero_async_matches_serialized_virtual_accounting_with_redundancy() {
    // s = 1: WHICH n−1 rows decode each block is a thread race under
    // virtual pacing, so gradients are not comparable across runs —
    // but the Eq. (2) virtual accounting depends only on the sampled
    // times and the dispatch order, and must still match bit for bit.
    let seed = suite_seed(67);
    let mut serial = build_pool(seed, 1, None);
    serial.run_all().unwrap();
    let serial_makespan = serial.virtual_makespan();
    let serial_reports = serial.finish().unwrap();

    let mut asynch = build_pool(seed, 1, Some(depth_zero()));
    asynch.run_all_async().unwrap();
    assert_eq!(bits(asynch.virtual_makespan()), bits(serial_makespan));
    let async_reports = asynch.finish().unwrap();

    for (j, (a, s)) in async_reports.iter().zip(&serial_reports).enumerate() {
        let va: Vec<u64> = a.iters.iter().map(|m| bits(m.virtual_runtime)).collect();
        let vs: Vec<u64> = s.iters.iter().map(|m| bits(m.virtual_runtime)).collect();
        assert_eq!(va, vs, "job {j}: virtual runtime sequences diverged");
        assert!(a.iters.iter().all(|m| m.queue_wait == 0.0), "job {j}");
        assert!(a.iters.iter().all(|m| m.grad_norm.is_finite()), "job {j}");
        assert_eq!(a.steps(), STEPS[j], "job {j}");
    }
}

fn overlap_invariants(r: &TrainReport, j: usize, steps: usize) {
    assert_eq!(r.steps(), steps, "job {j} dropped iterations");
    assert!(r.iters.iter().all(|m| m.grad_norm.is_finite()), "job {j}");
    assert!(r.iters.iter().all(|m| m.queue_wait >= 0.0 && m.queue_wait.is_finite()), "job {j}");
    // Every approximate decode is accounted for exactly once: either
    // reconciled against its late exact quorum or discarded (epoch
    // swap / finish). Exact counts are thread-racy; the identity is not.
    assert_eq!(
        r.approx_decodes,
        r.approx_reconciled + r.approx_discarded,
        "job {j} leaked approx decodes"
    );
    assert_eq!(r.approx_decodes, r.approx_blocks_total(), "job {j}: per-iter counts disagree");
    assert!(r.max_approx_bound >= 0.0 && r.max_approx_bound.is_finite(), "job {j}");
    if r.approx_decodes == 0 {
        assert_eq!(r.max_approx_bound, 0.0, "job {j}: bound without an approx decode");
    }
    // Overlapped rounds drop stale/cross-job arrivals back into the
    // wire freelist: recycling must at least cover what decodes took.
    assert!(r.wire_pool_returned > 0, "job {j}: no wire buffers recycled");
}

#[test]
fn overlapped_rounds_keep_isolation_and_approx_accounting() {
    // max_inflight = 2 with an aggressive semi-async policy: job B's
    // rounds dispatch while job A's tails are in flight, so stale and
    // off-cycle contributions actually occur; the run must stay
    // isolated (zero cross-job drops), complete both tenants, and
    // balance the approximate-decode ledger.
    let seed = suite_seed(71);
    let cfg = AsyncConfig {
        max_inflight: 2,
        backlog_pricing: true,
        reprice_threshold: 0.25,
        semi_async: Some(SemiAsyncConfig {
            max_shortfall: 1,
            backlog_factor: 0.25,
            max_residual: 1e9,
        }),
    };
    let mut pool = build_pool(seed, 1, Some(cfg));
    pool.run_all_async().unwrap();
    assert!(pool.rounds() >= STEPS.iter().sum::<usize>(), "one round per completed iteration");
    assert_eq!(pool.cross_job_dropped(), 0, "tenant isolation broke under overlap");
    let makespan = pool.virtual_makespan();
    assert!(makespan > 0.0 && makespan.is_finite());
    let reports = pool.finish().unwrap();
    for (j, r) in reports.iter().enumerate() {
        overlap_invariants(r, j, STEPS[j]);
    }
}
