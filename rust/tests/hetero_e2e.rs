//! Heterogeneity-aware engine, end to end on the threaded coordinator:
//! a 2-speed fleet under the `[hetero]` policy keeps decoding exactly
//! every iteration while the per-worker sensing → fleet re-solve →
//! speed-weighted shard actuation loop runs, and after the first
//! re-solve the slow workers carry strictly fewer shards than the fast
//! ones. Complements the controller-level identity-keying regressions
//! (`rust/src/coordinator/adaptive.rs`) and the virtual-time
//! hetero-vs-pooled comparison (`rust/src/sim/multi.rs`).

use bcgc::coordinator::adaptive::{AdaptiveConfig, HeteroConfig};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::coordinator::trainer::{train_fleet, TrainConfig};
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::{host_factory, GradExecutor};
use bcgc::sim::two_speed_fleet;
use bcgc::testing::suite_seed;

#[test]
fn two_speed_fleet_decodes_exactly_and_weights_shards_after_the_first_resolve() {
    // 3 fast + 3 slow (6×) machines. θ0 = 0 with lr = 0 keeps the model
    // pinned, so EVERY iteration's decoded gradient must equal the
    // direct full-dataset sum — before, through, and after the
    // speed-weighted re-shard (which moves data between subsets but
    // must never change the decoded total).
    let n = 6usize;
    let steps = 36usize;
    let seed = suite_seed(47);
    let fast = ShiftedExponential::new(1e-2, 50.0);
    let fleet = two_speed_fleet(n, 3, &fast, 6.0);

    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds.clone(), HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);

    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, 1, dim));
    cfg.steps = steps;
    cfg.lr = 0.0; // pin θ so every decode is checkable against θ0
    cfg.eval_every = 0;
    cfg.seed = seed;
    cfg.init_scale = 0.0;
    cfg.adaptive = Some(AdaptiveConfig {
        window: 60 * n,
        min_samples: 10 * n,
        check_every: 5,
        cooldown: 10,
        drift_threshold: 0.2,
        hetero: Some(HeteroConfig {
            per_worker_window: 64,
            min_worker_samples: 8,
            speed_weighted_shards: true,
        }),
        ..Default::default()
    });
    let schedule = StragglerSchedule::stationary(Box::new(fast));
    let report = train_fleet(cfg, schedule, fleet, factory).unwrap();

    // The mixture drifts far from the fast-only prior: at least one
    // re-solve landed (epoch 0 + ≥ 1 install).
    assert!(
        report.scheme_epochs.len() >= 2,
        "the 2-speed fleet must trigger a re-solve: {} epochs",
        report.scheme_epochs.len()
    );

    // Exact decode EVERY iteration: the recorded grad norm equals the
    // direct Σ over all dataset shards at θ0 = 0.
    let mut exec = HostExecutor::new(ds, HostModel::Mlp { hidden: 16 }).unwrap();
    let theta0 = vec![0.0f32; dim];
    let mut g = vec![0.0f64; dim];
    for s in 0..n {
        for (acc, v) in g.iter_mut().zip(exec.grad_shard(&theta0, s).unwrap()) {
            *acc += v as f64;
        }
    }
    let want: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
    assert!(want > 0.0);
    assert_eq!(report.steps(), steps);
    for m in &report.iters {
        assert!(
            (m.grad_norm - want).abs() < 1e-6 * (1.0 + want),
            "iter {}: decoded {} vs direct {} — the weighted re-shard must not change \
             the decoded gradient",
            m.iter,
            m.grad_norm,
            want
        );
    }
}

#[test]
fn slow_workers_carry_strictly_fewer_shards_after_the_first_resolve() {
    // Same fleet shape, driven through the session so the live shard
    // map is inspectable: after the first hetero re-solve the slow ids'
    // subsets back strictly fewer shards than the fast ids'.
    use bcgc::coordinator::trainer::TrainSession;
    let n = 6usize;
    let seed = suite_seed(53);
    let fast = ShiftedExponential::new(1e-2, 50.0);
    let fleet = two_speed_fleet(n, 3, &fast, 6.0);

    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);

    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, 1, dim));
    cfg.steps = 40;
    cfg.lr = 2e-3;
    cfg.eval_every = 20;
    cfg.seed = seed;
    cfg.adaptive = Some(AdaptiveConfig {
        window: 60 * n,
        min_samples: 10 * n,
        check_every: 5,
        cooldown: 10,
        drift_threshold: 0.2,
        hetero: Some(HeteroConfig {
            per_worker_window: 64,
            min_worker_samples: 8,
            speed_weighted_shards: true,
        }),
        ..Default::default()
    });
    let schedule = StragglerSchedule::stationary(Box::new(fast));
    let mut session = TrainSession::start_fleet(cfg, schedule, fleet, factory).unwrap();

    let mut resolved_at = None;
    for iter in 0..40 {
        session.adapt(iter).unwrap();
        if resolved_at.is_none() && session.epoch() > 0 {
            resolved_at = Some(iter);
        }
        session.step(iter).unwrap();
    }
    let resolved_at = resolved_at.expect("the 2-speed fleet must trigger a re-solve");

    // Ids 0..3 are fast, 3..6 slow (identity roster: no churn here, so
    // row == id). The live shard map must load them by fitted speed.
    let map = session.job().shard_map().clone();
    let counts: Vec<usize> = map.iter().map(Vec::len).collect();
    assert_eq!(counts.iter().sum::<usize>(), n, "every shard stays covered exactly once");
    let min_fast = *counts[..3].iter().min().unwrap();
    let max_slow = *counts[3..].iter().max().unwrap();
    assert!(
        max_slow < min_fast,
        "after the re-solve at iter {resolved_at}, slow workers must carry strictly \
         fewer shards: {counts:?}"
    );
    // The load multipliers mirror the placement (Σρ = N preserves work).
    let rho = session.job().load_multipliers().to_vec();
    assert!((rho.iter().sum::<f64>() - n as f64).abs() < 1e-9, "{rho:?}");
    assert!(rho[..3].iter().all(|&r| r >= 1.0), "{rho:?}");
    assert!(rho[3..].iter().all(|&r| r <= 1.0), "{rho:?}");

    let report = session.finish().unwrap();
    assert!(report.iters.iter().all(|m| m.grad_norm.is_finite()));
    assert!(
        report.final_loss().unwrap() < report.first_loss().unwrap(),
        "training must still converge under weighted shards"
    );
}
