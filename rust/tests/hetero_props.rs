//! Property tests for the heterogeneity-aware engine, driven by
//! `testing::Runner` (replay any failure with `BCGC_PROP_SEED`; crank
//! cases with `BCGC_PROP_CASES` — see `rust/src/testing/mod.rs`):
//!
//! * the fleet's Monte-Carlo order statistics collapse to the exact
//!   i.i.d. quadrature when every worker shares one model — bit-exact
//!   on the shared-handle (pooled-fallback) route, and within MC
//!   tolerance under CRN for per-worker clones;
//! * the speed-weighted shard split covers every shard exactly once,
//!   keeps every subset within one shard of its exact quota, and is
//!   permutation-equivariant in the worker order.

use std::sync::Arc;

use bcgc::coordinator::master::{redistribute_shards_weighted, shard_quota_weighted};
use bcgc::distribution::hetero::{fleet_mc_order_stats, HeteroFleet};
use bcgc::distribution::order_stats::shifted_exp_exact;
use bcgc::distribution::runtime_dist::{OrderStatConfig, RuntimeDistribution};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::testing::{gens, Runner};

/// Heavy MC properties keep the runner's seed (so `BCGC_PROP_SEED`
/// still pins the stream) but cap the case count.
fn capped_runner(cap: usize) -> Runner {
    let r = Runner::default();
    Runner::new(r.cases.min(cap), r.seed)
}

#[test]
fn homogeneous_fleet_mc_collapses_to_the_exact_iid_quadrature_under_crn() {
    capped_runner(20).run("hetero-mc-collapses-to-iid", |rng| {
        let n = gens::usize_in(rng, 3, 10);
        let mu = gens::f64_in(rng, 1e-3, 1e-2);
        let t0 = gens::f64_in(rng, 20.0, 100.0);
        let d = ShiftedExponential::new(mu, t0);
        let exact = shifted_exp_exact(&d, n);

        // Route 1 — shared handle (every worker fell back to the pooled
        // fit): the homogeneous special case must be EXACT, not MC.
        let shared = HeteroFleet::homogeneous(Arc::new(d.clone()), n);
        if !shared.is_homogeneous() {
            return Err("a shared-handle fleet must detect as homogeneous".into());
        }
        let os = shared.order_stat_moments(n, &OrderStatConfig::default());
        for k in 0..n {
            if os.t[k] != exact.t[k] || os.t_prime[k] != exact.t_prime[k] {
                return Err(format!(
                    "k={k}: homogeneous route must be bit-identical to the quadrature \
                     ({} vs {}, {} vs {})",
                    os.t[k], exact.t[k], os.t_prime[k], exact.t_prime[k]
                ));
            }
        }

        // Route 2 — per-worker clones (distinct handles): the generic
        // non-identical MC must agree with the i.i.d. closed form
        // within Monte-Carlo tolerance, and be CRN-deterministic.
        let clones = HeteroFleet::per_worker(
            (0..n)
                .map(|_| Arc::new(d.clone()) as Arc<dyn RuntimeDistribution>)
                .collect(),
        );
        if clones.is_homogeneous() {
            return Err("distinct handles must take the MC route".into());
        }
        let cfg = OrderStatConfig { trials: 20_000, seed: rng.next_u64() };
        let mc = clones.order_stat_moments(n, &cfg);
        let mc2 = fleet_mc_order_stats(&clones, &cfg);
        for k in 0..n {
            if mc.t[k] != mc2.t[k] || mc.t_prime[k] != mc2.t_prime[k] {
                return Err(format!("k={k}: CRN must make the MC bit-reproducible"));
            }
            let rel_t = (mc.t[k] - exact.t[k]).abs() / exact.t[k];
            let rel_p = (mc.t_prime[k] - exact.t_prime[k]).abs() / exact.t_prime[k];
            if rel_t > 0.06 || rel_p > 0.06 {
                return Err(format!(
                    "k={k}: hetero MC strays from the i.i.d. quadrature: t rel {rel_t:.4}, \
                     t' rel {rel_p:.4} (n={n}, mu={mu:.2e}, t0={t0:.1})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn weighted_shard_split_covers_once_within_quota_gap() {
    Runner::default().run("weighted-split-cover-quota", |rng| {
        let n = gens::usize_in(rng, 1, 24);
        let m = gens::usize_in(rng, 1, 60);
        let weights: Vec<f64> = (0..n).map(|_| gens::f64_in(rng, 0.05, 10.0)).collect();
        let map = redistribute_shards_weighted(&weights, m);
        if map.len() != n {
            return Err(format!("map has {} subsets, want {n}", map.len()));
        }
        // Exact cover: every shard in exactly one subset.
        let mut seen = vec![0usize; m];
        for backing in &map {
            for &s in backing {
                if s >= m {
                    return Err(format!("shard {s} out of range (m={m})"));
                }
                seen[s] += 1;
            }
        }
        if seen.iter().any(|&c| c != 1) {
            return Err(format!("cover violated: {seen:?} (weights {weights:?})"));
        }
        // Quota gap: every subset within one shard of its exact quota.
        let total: f64 = weights.iter().sum();
        for (i, backing) in map.iter().enumerate() {
            let q = weights[i] * m as f64 / total;
            if (backing.len() as f64 - q).abs() >= 1.0 {
                return Err(format!(
                    "subset {i}: count {} vs quota {q:.3} breaks the ≤1-shard gap",
                    backing.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn weighted_shard_counts_are_permutation_equivariant() {
    Runner::default().run("weighted-split-equivariance", |rng| {
        let n = gens::usize_in(rng, 2, 16);
        let m = gens::usize_in(rng, 1, 48);
        // Continuous random weights: remainder ties have measure zero,
        // so the apportionment sees each worker only through its own
        // quota and must follow any reshuffle of the workers.
        let weights: Vec<f64> = (0..n).map(|_| gens::f64_in(rng, 0.05, 10.0)).collect();
        let base = shard_quota_weighted(&weights, m);
        // A random permutation (Fisher–Yates off the case RNG).
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            perm.swap(i, j);
        }
        let permuted_w: Vec<f64> = perm.iter().map(|&i| weights[i]).collect();
        let permuted_c = shard_quota_weighted(&permuted_w, m);
        for (slot, &i) in perm.iter().enumerate() {
            if permuted_c[slot] != base[i] {
                return Err(format!(
                    "worker {i} changed count under permutation: {base:?} → {permuted_c:?} \
                     (perm {perm:?}, weights {weights:?}, m={m})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn weighted_split_matches_fleet_rates_end_to_end() {
    // The composition the engine actually runs: fleet → rates →
    // weighted split. Fast workers never receive fewer shards than
    // slow ones.
    capped_runner(40).run("fleet-rates-into-split", |rng| {
        let n = gens::usize_in(rng, 2, 12);
        let n_slow = gens::usize_in(rng, 1, n - 1);
        let factor = gens::f64_in(rng, 1.5, 8.0);
        let fast = ShiftedExponential::new(1e-2, 50.0);
        let slow = ShiftedExponential::new(fast.mu / factor, fast.t0 * factor);
        let fleet = HeteroFleet::per_worker(
            (0..n)
                .map(|w| {
                    if w < n - n_slow {
                        Arc::new(fast.clone()) as Arc<dyn RuntimeDistribution>
                    } else {
                        Arc::new(slow.clone())
                    }
                })
                .collect(),
        );
        let m = gens::usize_in(rng, n, 4 * n);
        let map = redistribute_shards_weighted(&fleet.rates(), m);
        let counts: Vec<usize> = map.iter().map(Vec::len).collect();
        let min_fast = counts[..n - n_slow].iter().min().unwrap();
        let max_slow = counts[n - n_slow..].iter().max().unwrap();
        if max_slow > min_fast {
            return Err(format!(
                "a slow worker out-carries a fast one: {counts:?} (factor {factor:.2})"
            ));
        }
        Ok(())
    });
}
