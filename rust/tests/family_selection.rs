//! Property tests for the distribution-agnostic re-solve: Monte-Carlo
//! order-stat moments agree with the exact shifted-exp quadrature under
//! common random numbers, `family = "auto"` recovers the generating
//! family on synthetic windows (reusing `fit_weibull_mom`'s sample
//! generators), and every family's model routes through the generic
//! `x^(f)` re-solve to a feasible partition.

use bcgc::coordinator::adaptive::{resolve_partition, ResolveStrategy};
use bcgc::distribution::fit::{select_model, FamilyPolicy, FitMethod, FittedModel};
use bcgc::distribution::order_stats::shifted_exp_exact;
use bcgc::distribution::runtime_dist::{
    mc_order_stats, ModelFamily, OrderStatConfig, RuntimeDistribution,
};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::weibull::Weibull;
use bcgc::distribution::{CycleTimeDistribution, TwoPoint};
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::util::rng::Rng;

#[test]
fn mc_order_stats_match_the_exact_shifted_exp_quadrature() {
    // Satellite property: the Monte-Carlo route (what Weibull fits use)
    // agrees with the exact Eq.(11)/Lemma-2 quadrature route within MC
    // tolerance, and is CRN-reproducible.
    for (mu, t0) in [(1e-3, 50.0), (1e-2, 50.0), (2e-2, 100.0)] {
        let d = ShiftedExponential::new(mu, t0);
        for n in [5usize, 12, 20] {
            let exact = shifted_exp_exact(&d, n);
            let cfg = OrderStatConfig { trials: 60_000, seed: 0xC0FFEE ^ n as u64 };
            let mc = mc_order_stats(&d, n, &cfg);
            let mc_again = mc_order_stats(&d, n, &cfg);
            for k in 0..n {
                // CRN: bit-identical on the same seed.
                assert_eq!(mc.t[k], mc_again.t[k]);
                assert_eq!(mc.t_prime[k], mc_again.t_prime[k]);
                let rel_t = (mc.t[k] - exact.t[k]).abs() / exact.t[k];
                let rel_p = (mc.t_prime[k] - exact.t_prime[k]).abs() / exact.t_prime[k];
                assert!(rel_t < 0.02, "mu={mu} n={n} k={k}: rel_t={rel_t}");
                assert!(rel_p < 0.02, "mu={mu} n={n} k={k}: rel_p={rel_p}");
            }
        }
    }
}

#[test]
fn auto_recovers_the_generating_family_on_synthetic_windows() {
    let mut rng = Rng::new(2021);
    // Shifted-exp data → shifted-exp (the paper's model keeps priority).
    let exp = ShiftedExponential::new(1e-3, 50.0);
    let window = exp.sample_vec(4000, &mut rng);
    let m = select_model(&window, FamilyPolicy::Auto, FitMethod::Mle).unwrap();
    assert_eq!(m.family(), ModelFamily::ShiftedExp, "picked {}", m.label());
    assert!((m.mean() - exp.mean()).abs() / exp.mean() < 0.1);

    // Weibull data (the fit_weibull_mom synthetic generators) → Weibull.
    for (shape, scale, shift) in [(2.0f64, 10.0f64, 5.0f64), (0.8, 100.0, 20.0)] {
        let d = Weibull::new(shape, scale, shift);
        let window = d.sample_vec(4000, &mut rng);
        let m = select_model(&window, FamilyPolicy::Auto, FitMethod::Mle).unwrap();
        match &m {
            FittedModel::Weibull(w) => {
                assert!(
                    (w.shape - shape).abs() / shape < 0.2,
                    "fitted shape {} vs true {shape}",
                    w.shape
                );
                assert!((m.mean() - d.mean()).abs() / d.mean() < 0.05);
            }
            other => panic!("Weibull(k={shape}) window selected {}", other.label()),
        }
    }

    // A bimodal mixture no parametric family can track → empirical.
    let two = TwoPoint::new(1.0, 6.0, 0.5);
    let window = two.sample_vec(3000, &mut rng);
    let m = select_model(&window, FamilyPolicy::Auto, FitMethod::Mle).unwrap();
    assert_eq!(m.family(), ModelFamily::Empirical, "picked {}", m.label());
}

#[test]
fn every_family_routes_through_the_generic_resolve_to_a_feasible_partition() {
    let mut rng = Rng::new(7);
    let exp = ShiftedExponential::new(1e-3, 50.0);
    let weib = Weibull::new(0.7, 900.0, 50.0);
    let trace = exp.sample_vec(400, &mut rng);
    let emp = bcgc::distribution::Empirical::new(trace);
    let warm = vec![125.0; 16]; // from an N=16 epoch; the pool shrank
    for n_new in [12usize, 16] {
        let spec = ProblemSpec::paper_default(n_new, 2_000);
        for d in [&exp as &dyn RuntimeDistribution, &weib, &emp] {
            for strategy in [
                ResolveStrategy::ClosedFormFreq,
                ResolveStrategy::Subgradient { iters: 150, playoff_trials: 100 },
            ] {
                let p = resolve_partition(
                    &strategy,
                    &spec,
                    d,
                    Some(warm.as_slice()),
                    2_000,
                    &mut rng,
                )
                .unwrap();
                assert_eq!(p.n(), n_new, "{} / {strategy:?}", d.label());
                assert_eq!(p.total(), 2_000, "{} / {strategy:?}", d.label());
            }
        }
    }
}

#[test]
fn fitted_models_rebuild_into_their_own_family() {
    let mut rng = Rng::new(99);
    let weib = Weibull::new(0.8, 200.0, 30.0);
    let window = weib.sample_vec(3000, &mut rng);
    for policy in [FamilyPolicy::ShiftedExp, FamilyPolicy::Weibull, FamilyPolicy::Empirical] {
        let m = select_model(&window, policy, FitMethod::Moments).unwrap();
        let d = m.build();
        assert_eq!(d.model_family().name(), m.family().name());
        // Moments survive the round trip (empirical exactly, parametric
        // families to within their estimator's accuracy on 3k samples).
        assert!((d.mean() - m.mean()).abs() / m.mean() < 1e-6, "{}", m.label());
        let os = d.order_stat_moments(6, &OrderStatConfig::default());
        for k in 1..6 {
            assert!(os.t[k] >= os.t[k - 1]);
            assert!(os.t_prime[k] >= os.t_prime[k - 1]);
        }
    }
}
