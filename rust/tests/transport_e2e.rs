//! End-to-end exercises of the transport boundary (PR 9).
//!
//! The in-process transport must reproduce the classic channel path
//! bit-for-bit, and — under `--features tcp` — the same training run
//! over real loopback sockets must (a) match the in-process run
//! bit-for-bit on a serialized `s = 0` schedule, (b) converge with
//! redundancy while decoding exactly every iteration, and (c) surface
//! peer failures detected by the heartbeat/lease layer as the same
//! `Left` → membership re-dimension path a clean drain takes, with no
//! hang. Wire-level counters land in `TrainReport::wire`.

use bcgc::coordinator::metrics::TrainReport;
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::coordinator::trainer::{train, TrainConfig};
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::{host_factory, ExecutorFactory};
use bcgc::testing::suite_seed;
use bcgc::transport::WireSnapshot;

/// A small MLP job dimensioned for `n` workers with every block at
/// redundancy level `s` (`s = 0`: every block needs every live row, so
/// decode order is canonical and runs are bit-comparable).
fn setup(n: usize, s: usize, steps: usize, seed: u64) -> (TrainConfig, ExecutorFactory) {
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, s, dim));
    cfg.steps = steps;
    cfg.lr = 2e-3;
    cfg.eval_every = 5;
    cfg.seed = seed;
    (cfg, factory)
}

fn schedule() -> StragglerSchedule {
    StragglerSchedule::stationary(Box::new(ShiftedExponential::new(1e-3, 50.0)))
}

/// Everything numeric an iteration produced, as bits — wall-clock
/// metrics excluded, they are the one legitimately nondeterministic
/// column.
fn fingerprint(report: &TrainReport) -> Vec<(usize, usize, usize, usize, u64, u64)> {
    report
        .iters
        .iter()
        .map(|m| {
            (
                m.iter,
                m.epoch,
                m.workers,
                m.blocks_decoded,
                m.grad_norm.to_bits(),
                m.virtual_runtime.to_bits(),
            )
        })
        .collect()
}

#[test]
fn inproc_transport_is_bit_for_bit_deterministic_on_a_serialized_run() {
    let seed = suite_seed(31);
    let (cfg_a, f_a) = setup(4, 0, 20, seed);
    let (cfg_b, f_b) = setup(4, 0, 20, seed);
    let a = train(cfg_a, schedule(), f_a).unwrap();
    let b = train(cfg_b, schedule(), f_b).unwrap();

    assert_eq!(a.steps(), 20);
    assert_eq!(fingerprint(&a), fingerprint(&b));
    assert_eq!(a.loss_curve, b.loss_curve);
    // No wire: the in-process transport reports all-zero counters.
    assert_eq!(a.wire, WireSnapshot::default());
}

#[cfg(feature = "tcp")]
mod tcp {
    use std::io::Write;
    use std::net::{SocketAddr, TcpStream};
    use std::sync::mpsc;
    use std::thread;
    use std::time::Duration;

    use bcgc::coordinator::channel::{BlockContribution, WorkerEvent};
    use bcgc::coordinator::metrics::MembershipEvent;
    use bcgc::coordinator::trainer::{train, ElasticConfig, TrainSession};
    use bcgc::coordinator::PacingMode;
    use bcgc::transport::codec::{frame_block, frame_hello, read_frame, MAX_FRAME};
    use bcgc::transport::tcp::{serve_worker, FactoryRegistry, TcpTransport, TcpTransportConfig};
    use bcgc::transport::{Transport, TransportConfig, WireSnapshot};
    use bcgc::util::buffers::BufferPool;

    use super::*;

    /// Spawn `count` real worker peers serving the single trainer job
    /// (job id 0) over loopback TCP.
    fn spawn_peers(
        addr: SocketAddr,
        factory: &ExecutorFactory,
        count: usize,
    ) -> Vec<thread::JoinHandle<WireSnapshot>> {
        (0..count)
            .map(|_| {
                let registry = FactoryRegistry::new();
                registry.register(0, factory.clone());
                thread::spawn(move || serve_worker(addr, registry).expect("peer run"))
            })
            .collect()
    }

    /// Handshakes like a real peer, then goes silent — no heartbeats,
    /// no blocks — while holding the socket open, until the returned
    /// sender is dropped. The lease sweeper must declare it gone.
    fn spawn_silent_peer(addr: SocketAddr) -> mpsc::Sender<()> {
        let (release_tx, release_rx) = mpsc::channel::<()>();
        thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.write_all(&frame_hello().expect("fits")).expect("hello");
            let _assign = read_frame(&mut stream, MAX_FRAME).expect("assign");
            let _ = release_rx.recv_timeout(Duration::from_secs(60));
        });
        release_tx
    }

    /// Handshakes, then disconnects outright: the reader's EOF must
    /// surface as an immediate `Left` without waiting out the lease.
    fn spawn_eof_peer(addr: SocketAddr) -> thread::JoinHandle<()> {
        thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.write_all(&frame_hello().expect("fits")).expect("hello");
            let _assign = read_frame(&mut stream, MAX_FRAME).expect("assign");
        })
    }

    #[test]
    fn loopback_training_matches_the_inproc_run_bit_for_bit() {
        let seed = suite_seed(37);
        let n = 4;
        let (cfg, f) = setup(n, 0, 18, seed);
        let reference = train(cfg, schedule(), f).unwrap();

        let (mut cfg, f) = setup(n, 0, 18, seed);
        let tcp = TcpTransportConfig::bind_loopback().unwrap();
        let addr = tcp.addr().unwrap();
        cfg.transport = TransportConfig::Tcp(tcp);
        let peers = spawn_peers(addr, &f, n);
        let report = train(cfg, schedule(), f).unwrap();
        for p in peers {
            p.join().expect("peer thread");
        }

        // Real sockets, identical numerics: every gradient, virtual
        // runtime and loss matches the in-process run bit-for-bit.
        assert_eq!(fingerprint(&reference), fingerprint(&report));
        assert_eq!(reference.loss_curve, report.loss_curve);

        let w = report.wire;
        assert!(w.frames_sent > 0 && w.bytes_sent > 0, "{w:?}");
        assert!(w.frames_recv > 0 && w.bytes_recv > 0, "{w:?}");
        assert_eq!(w.leases_expired, 0, "{w:?}");
        assert_eq!(report.failed_workers, Vec::<usize>::new());
    }

    #[test]
    fn loopback_training_with_redundancy_converges() {
        let seed = suite_seed(41);
        let n = 5;
        let (mut cfg, f) = setup(n, 1, 40, seed);
        let tcp = TcpTransportConfig::bind_loopback().unwrap();
        let addr = tcp.addr().unwrap();
        cfg.transport = TransportConfig::Tcp(tcp);
        let peers = spawn_peers(addr, &f, n);
        let report = train(cfg, schedule(), f).unwrap();
        for p in peers {
            p.join().expect("peer thread");
        }

        // s = 1: each block decodes exactly from its first N − 1
        // arrivals, whatever order the sockets deliver them in.
        assert_eq!(report.steps(), 40);
        assert!(report.iters.iter().all(|m| m.blocks_decoded >= 1 && m.grad_norm.is_finite()));
        let first = report.first_loss().unwrap();
        let last = report.final_loss().unwrap();
        assert!(last < first, "loss {first} -> {last}");
        assert!(report.failed_workers.is_empty());
    }

    #[test]
    fn an_expired_lease_surfaces_as_a_leave_and_redimensions_the_pool() {
        let seed = suite_seed(43);
        let n = 4;
        let (mut cfg, f) = setup(n, 1, 40, seed);
        cfg.elastic =
            Some(ElasticConfig { churn_threshold: 1, departures: vec![], arrivals: vec![] });
        let mut tcp = TcpTransportConfig::bind_loopback().unwrap();
        tcp.lease_ttl_ms = 300;
        tcp.heartbeat_ms = 50;
        let addr = tcp.addr().unwrap();
        cfg.transport = TransportConfig::Tcp(tcp);

        let peers = spawn_peers(addr, &f, n - 1);
        let release = spawn_silent_peer(addr);

        let mut session = TrainSession::start(cfg, schedule(), f).unwrap();
        // The silent peer contributes nothing; s = 1 absorbs it like a
        // fatal straggler while its lease runs down.
        for iter in 0..5 {
            session.step(iter).unwrap();
        }
        std::thread::sleep(Duration::from_millis(700));

        // The sweeper's `Left` lands in the event queue; the next
        // collect consumes it and the re-dimension path fires.
        let mut swapped_at = None;
        for iter in 5..40 {
            if session.maybe_redimension(iter).unwrap() {
                swapped_at = Some(iter);
                break;
            }
            session.step(iter).unwrap();
        }
        let swapped_at = swapped_at.expect("lease expiry never re-dimensioned the pool");
        assert_eq!(session.registry().n(), n - 1);
        for iter in swapped_at..swapped_at + 3 {
            session.step(iter).unwrap();
        }
        let report = session.finish().unwrap();
        drop(release);
        for p in peers {
            p.join().expect("peer thread");
        }

        assert!(report.wire.leases_expired >= 1, "{:?}", report.wire);
        let leaves = report
            .membership
            .iter()
            .filter(|m| matches!(m.event, MembershipEvent::Leave { .. }))
            .count();
        assert_eq!(leaves, 1);
        let redims: Vec<(usize, usize)> = report
            .membership
            .iter()
            .filter_map(|m| match m.event {
                MembershipEvent::Redimension { from_n, to_n, .. } => Some((from_n, to_n)),
                _ => None,
            })
            .collect();
        assert_eq!(redims, vec![(n, n - 1)]);
        assert!(report.iters.iter().all(|m| m.grad_norm.is_finite()));
        assert_eq!(report.iters.last().unwrap().workers, n - 1);
    }

    #[test]
    fn a_slow_multi_chunk_frame_keeps_the_lease_alive() {
        // Regression: the lease used to renew only on *complete* frames,
        // so a peer dribbling one large block across many small writes
        // under a short TTL was declared gone mid-transfer. Raw inbound
        // bytes are proof of life now — the transfer below takes ~5× the
        // TTL end to end, yet no `Left` may surface before the block.
        let mut tcp = TcpTransportConfig::bind_loopback().unwrap();
        tcp.lease_ttl_ms = 250;
        tcp.heartbeat_ms = 40;
        let addr = tcp.addr().unwrap();
        let (event_tx, event_rx) = mpsc::channel();
        let mut transport =
            TcpTransport::new(tcp, event_tx, PacingMode::Virtual, BufferPool::default()).unwrap();

        let peer = thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            stream.write_all(&frame_hello().expect("fits")).expect("hello");
            let _assign = read_frame(&mut stream, MAX_FRAME).expect("assign");
            let c = BlockContribution {
                job: 0,
                iter: 0,
                epoch: 0,
                worker: 0,
                row: 0,
                block_idx: 0,
                virtual_time: 1.0,
                coded: vec![1.0f32; 50_000],
            };
            let frame = frame_block(&c).expect("fits");
            // ~200 KiB in 8 KiB chunks, 50 ms apart: every silence
            // window stays far under the 250 ms TTL, but a whole-frame
            // wait would blow through it five times over.
            for chunk in frame.chunks(8 * 1024) {
                stream.write_all(chunk).expect("chunk");
                stream.flush().expect("flush");
                thread::sleep(Duration::from_millis(50));
            }
            stream
        });

        transport.attach_worker(0).expect("attach");
        let mut got_block = false;
        let deadline = std::time::Instant::now() + Duration::from_secs(20);
        while std::time::Instant::now() < deadline {
            match event_rx.recv_timeout(Duration::from_millis(100)) {
                Ok(WorkerEvent::Joined { .. }) => {}
                Ok(WorkerEvent::Block(c)) => {
                    assert_eq!(c.coded.len(), 50_000);
                    got_block = true;
                    break;
                }
                Ok(WorkerEvent::Left { .. }) => {
                    panic!("lease expired mid-transfer despite steady inbound bytes")
                }
                Ok(_) => panic!("unexpected event during the slow transfer"),
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => panic!("transport hung up"),
            }
        }
        assert!(got_block, "the slow block never arrived");
        assert_eq!(transport.wire_stats().leases_expired, 0);
        let _stream = peer.join().expect("peer thread");
        transport.shutdown();
    }

    #[test]
    fn a_peer_that_disconnects_is_counted_out_immediately() {
        let seed = suite_seed(47);
        let n = 4;
        let (mut cfg, f) = setup(n, 1, 25, seed);
        cfg.elastic =
            Some(ElasticConfig { churn_threshold: 1, departures: vec![], arrivals: vec![] });
        // Default (long) lease TTL: only the EOF path can explain a
        // prompt Leave here.
        let tcp = TcpTransportConfig::bind_loopback().unwrap();
        let addr = tcp.addr().unwrap();
        cfg.transport = TransportConfig::Tcp(tcp);

        let peers = spawn_peers(addr, &f, n - 1);
        let eof = spawn_eof_peer(addr);
        let report = train(cfg, schedule(), f).unwrap();
        eof.join().expect("eof peer");
        for p in peers {
            p.join().expect("peer thread");
        }

        assert_eq!(report.steps(), 25);
        assert!(report.iters.iter().all(|m| m.grad_norm.is_finite()));
        let leaves = report
            .membership
            .iter()
            .filter(|m| matches!(m.event, MembershipEvent::Leave { .. }))
            .count();
        assert_eq!(leaves, 1);
        let redims: Vec<(usize, usize)> = report
            .membership
            .iter()
            .filter_map(|m| match m.event {
                MembershipEvent::Redimension { from_n, to_n, .. } => Some((from_n, to_n)),
                _ => None,
            })
            .collect();
        assert_eq!(redims, vec![(n, n - 1)]);
        assert_eq!(report.iters.last().unwrap().workers, n - 1);
    }
}
