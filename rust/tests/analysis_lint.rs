//! Fixture tests for the `bcgc-lint` rules: each rule has a violating
//! snippet (finding), a clean/fixed form (no finding), and an allow
//! check — plus the full-tree gate asserting the real crate is clean.
//!
//! Fixtures are plain strings handed to `lint_source` under a path
//! chosen to put them in the rule's scope; nothing here touches the
//! filesystem except the final `lint_tree` walk.

use bcgc::analysis::{lint_source, lint_tree, Finding, Rule};

fn count(findings: &[Finding], rule: Rule) -> usize {
    findings.iter().filter(|f| f.rule == rule).count()
}

fn lines(findings: &[Finding], rule: Rule) -> Vec<usize> {
    findings.iter().filter(|f| f.rule == rule).map(|f| f.line).collect()
}

// ---------------------------------------------------------------------------
// determinism
// ---------------------------------------------------------------------------

#[test]
fn determinism_flags_wall_clock_in_library_code() {
    let src = "pub fn pace() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    let f = lint_source("rust/src/coordinator/pacing.rs", src);
    assert_eq!(count(&f, Rule::Determinism), 1);
    assert_eq!(lines(&f, Rule::Determinism), [2]);
}

#[test]
fn determinism_exempts_measurement_paths_and_tests() {
    let src = "pub fn pace() -> std::time::Instant {\n    std::time::Instant::now()\n}\n";
    for path in
        ["rust/src/bench_harness/timer.rs", "rust/src/runtime/host.rs", "rust/src/bin/tool.rs"]
    {
        assert_eq!(count(&lint_source(path, src), Rule::Determinism), 0, "{path}");
    }
    let test_mod = "#[cfg(test)]\nmod tests {\n    fn t() {\n        let _ = std::time::Instant::now();\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pacing.rs", test_mod);
    assert_eq!(count(&f, Rule::Determinism), 0);
}

#[test]
fn determinism_allow_needs_a_reason() {
    let with = "fn t() {\n    // lint: allow(determinism) — wall-clock metric only, not control flow\n    let _ = std::time::Instant::now();\n}\n";
    let without = "fn t() {\n    // lint: allow(determinism)\n    let _ = std::time::Instant::now();\n}\n";
    assert_eq!(count(&lint_source("rust/src/coordinator/p.rs", with), Rule::Determinism), 0);
    assert_eq!(count(&lint_source("rust/src/coordinator/p.rs", without), Rule::Determinism), 1);
}

// ---------------------------------------------------------------------------
// panic_hygiene
// ---------------------------------------------------------------------------

#[test]
fn panic_hygiene_flags_unwrap_in_coordinator() {
    let src = "pub fn pick(v: &[u32]) -> u32 {\n    *v.first().unwrap()\n}\nfn read(v: &[u32]) -> u32 {\n    *v.first().expect(\"nonempty\")\n}\n";
    let f = lint_source("rust/src/coordinator/helper.rs", src);
    assert_eq!(lines(&f, Rule::PanicHygiene), [2, 5]);
    // Outside the coordinator the rule does not apply.
    assert_eq!(count(&lint_source("rust/src/linalg/kernels.rs", src), Rule::PanicHygiene), 0);
}

#[test]
fn panic_hygiene_covers_the_transport_layer() {
    // PR 9: the transport is the other side of the worker boundary —
    // the same no-panic contract applies to its non-test code.
    let src = "pub fn peer(v: &[u32]) -> u32 {\n    *v.first().unwrap()\n}\n";
    let f = lint_source("rust/src/transport/tcp.rs", src);
    assert_eq!(lines(&f, Rule::PanicHygiene), [2]);
}

#[test]
fn panic_hygiene_accepts_recovering_forms_and_allows() {
    let clean = "pub fn pick(v: &[u32]) -> u32 {\n    v.first().copied().unwrap_or_else(|| 0)\n}\n";
    assert_eq!(count(&lint_source("rust/src/coordinator/h.rs", clean), Rule::PanicHygiene), 0);
    let allowed = "pub fn pick(v: &[u32]) -> u32 {\n    // lint: allow(panic_hygiene) — caller guarantees non-empty by construction\n    *v.first().unwrap()\n}\n";
    assert_eq!(count(&lint_source("rust/src/coordinator/h.rs", allowed), Rule::PanicHygiene), 0);
}

// ---------------------------------------------------------------------------
// ledger_discipline
// ---------------------------------------------------------------------------

#[test]
fn ledger_flags_counter_bumped_without_witness() {
    let rogue = "impl M {\n    fn bump(&mut self) {\n        self.approx_decodes += 1;\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/master.rs", rogue);
    assert_eq!(lines(&f, Rule::LedgerDiscipline), [3]);
}

#[test]
fn ledger_accepts_writes_beside_their_witness() {
    let settled = "impl M {\n    fn finalize(&mut self) {\n        self.approx_decodes += 1;\n        self.outcome = self.take_outcome();\n    }\n    fn drop_rest(&mut self) {\n        self.discarded += self.pending.drain(..).count();\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/master.rs", settled);
    assert_eq!(count(&f, Rule::LedgerDiscipline), 0);
}

/// PR 10: the streamed-part ledger counters ride the same discipline —
/// a part acceptance is witnessed by its buffered arrival, a part-wise
/// completion by the drain of the redundant whole arrivals, and the
/// run-level accumulator only moves by the outcome's own count.
#[test]
fn ledger_covers_the_partial_counters() {
    let rogue = "impl M {\n    fn bump(&mut self) {\n        self.partial_contributions += 1;\n    }\n    fn done(&mut self) {\n        self.partial_blocks += 1;\n    }\n    fn tally(&mut self) {\n        self.partial_decodes += 1;\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/master.rs", rogue);
    assert_eq!(lines(&f, Rule::LedgerDiscipline), [3, 6, 9]);
    let settled = "impl M {\n    fn accept(&mut self, c: PartialBlockContribution) {\n        self.part_arrivals[c.part].push((c.row, c.coded));\n        self.partial_contributions += 1;\n        self.wire_pool.put(b);\n    }\n    fn complete(&mut self) {\n        self.partial_blocks += 1;\n        for (_, buf) in self.arrivals.drain(..) {\n            self.wire_pool.put(buf);\n        }\n    }\n    fn tally(&mut self, outcome: &IterOutcome) {\n        self.partial_decodes += outcome.partial_blocks;\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/master.rs", settled);
    assert_eq!(count(&f, Rule::LedgerDiscipline), 0, "findings: {f:?}");
}

#[test]
fn ledger_reads_and_declarations_do_not_count() {
    let reads = "impl M {\n    fn report(&self) -> usize {\n        self.approx_decodes + self.approx_discarded\n    }\n}\nstruct S {\n    approx_reconciled: usize,\n}\n";
    let f = lint_source("rust/src/coordinator/metrics.rs", reads);
    assert_eq!(count(&f, Rule::LedgerDiscipline), 0);
}

// ---------------------------------------------------------------------------
// buffer_ownership
// ---------------------------------------------------------------------------

#[test]
fn ownership_flags_pool_take_without_recycle() {
    let leak = "impl W {\n    fn fetch(&mut self) -> Vec<f32> {\n        self.wire_pool.take(64)\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/worker.rs", leak);
    assert_eq!(lines(&f, Rule::BufferOwnership), [3]);
    let paired = "impl W {\n    fn cycle(&mut self) {\n        let b = self.wire_pool.take(64);\n        self.wire_pool.put(b);\n    }\n}\n";
    assert_eq!(
        count(&lint_source("rust/src/coordinator/worker.rs", paired), Rule::BufferOwnership),
        0
    );
    // Iterator adapters named `take` are not pool receipts.
    let iter = "impl W {\n    fn head(&self) -> Vec<u32> {\n        self.items.iter().take(3).copied().collect()\n    }\n}\n";
    assert_eq!(
        count(&lint_source("rust/src/coordinator/worker.rs", iter), Rule::BufferOwnership),
        0
    );
}

/// The deliberate-violation canary required by the issue: a drop path
/// that counts the drop but forgets to recycle the owned wire buffer
/// — exactly the bug class the rule exists for (and the class fixed
/// for real in `worker.rs`'s send-failure path this PR).
#[test]
fn ownership_canary_counted_drop_without_recycle_is_caught() {
    let canary = "impl M {\n    fn drop_late(&mut self, c: BlockContribution) {\n        self.late += 1;\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/master.rs", canary);
    assert_eq!(lines(&f, Rule::BufferOwnership), [3]);
    let fixed = "impl M {\n    fn drop_late(&mut self, c: BlockContribution) {\n        self.late += 1;\n        self.wire_pool.put(c.coded);\n    }\n}\n";
    assert_eq!(
        count(&lint_source("rust/src/coordinator/master.rs", fixed), Rule::BufferOwnership),
        0
    );
    // By-ref observers never owned the buffer; their caller recycles.
    let by_ref = "impl M {\n    fn note_late(&mut self, c: &BlockContribution) {\n        self.late += 1;\n    }\n}\n";
    assert_eq!(
        count(&lint_source("rust/src/coordinator/master.rs", by_ref), Rule::BufferOwnership),
        0
    );
}

/// PR 10: streamed-part payloads carry pooled buffers exactly like
/// whole blocks — a function that owns a `PartialBlockContribution`
/// (by value, or by matching `WorkerEvent::Partial(`) and counts a
/// drop must recycle on that path too.
#[test]
fn ownership_covers_streamed_part_payloads() {
    let canary = "impl M {\n    fn drop_stale_part(&mut self, c: PartialBlockContribution) {\n        self.stale_epoch += 1;\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/master.rs", canary);
    assert_eq!(lines(&f, Rule::BufferOwnership), [3]);
    let fixed = "impl M {\n    fn drop_stale_part(&mut self, c: PartialBlockContribution) {\n        self.stale_epoch += 1;\n        self.wire_pool.put(c.coded);\n    }\n}\n";
    assert_eq!(
        count(&lint_source("rust/src/coordinator/master.rs", fixed), Rule::BufferOwnership),
        0
    );
    // Matching the event variant marks ownership the same way.
    let router = "impl P {\n    fn route(&mut self, ev: WorkerEvent) {\n        if let WorkerEvent::Partial(c) = ev {\n            self.cross_job_dropped += 1;\n        }\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pool.rs", router);
    assert_eq!(count(&f, Rule::BufferOwnership), 1, "findings: {f:?}");
    // By-ref observers of a part never owned its buffer.
    let by_ref = "impl M {\n    fn note(&mut self, c: &PartialBlockContribution) {\n        self.late += 1;\n    }\n}\n";
    assert_eq!(
        count(&lint_source("rust/src/coordinator/master.rs", by_ref), Rule::BufferOwnership),
        0
    );
}

// ---------------------------------------------------------------------------
// lock_order
// ---------------------------------------------------------------------------

#[test]
fn lock_order_flags_direct_inversion() {
    let bad = "impl P {\n    fn bad(&self) {\n        let g = self.wire_pool.lock().unwrap();\n        let s = self.store.lock().unwrap();\n        drop(s);\n        drop(g);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pool.rs", bad);
    assert_eq!(lines(&f, Rule::LockOrder), [4]);
}

#[test]
fn lock_order_accepts_table_order_nesting() {
    let good = "impl P {\n    fn good(&self) {\n        let s = self.store.lock().unwrap();\n        let g = self.wire_pool.lock().unwrap();\n        drop(g);\n        drop(s);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pool.rs", good);
    assert_eq!(count(&f, Rule::LockOrder), 0);
}

/// The required indirection case: the outer fn holds a buffer-pool
/// guard returned by one helper while a *second* helper transiently
/// takes the observation-store lock — an inversion no single function
/// body shows.
#[test]
fn lock_order_sees_through_same_file_helpers() {
    let src = "impl P {\n    fn lock_pool(&self) -> MutexGuard<'_, Vec<f32>> {\n        self.wire_pool.lock().unwrap()\n    }\n    fn observe(&self) {\n        let g = self.lock_pool();\n        self.fit_store();\n        drop(g);\n    }\n    fn fit_store(&self) {\n        let s = self.store.lock().unwrap();\n        drop(s);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/adaptive.rs", src);
    assert_eq!(lines(&f, Rule::LockOrder), [7], "findings: {f:?}");
}

#[test]
fn lock_order_helper_in_table_order_is_clean() {
    let src = "impl P {\n    fn observe(&self) {\n        let s = self.store.lock().unwrap();\n        self.recycle();\n        drop(s);\n    }\n    fn recycle(&self) {\n        let g = self.wire_pool.lock().unwrap();\n        drop(g);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/adaptive.rs", src);
    assert_eq!(count(&f, Rule::LockOrder), 0, "findings: {f:?}");
}

#[test]
fn lock_order_drop_releases_the_guard() {
    // Same two acquisitions as the direct-inversion case, but the
    // pool guard is dropped first — no overlap, no finding.
    let src = "impl P {\n    fn seq(&self) {\n        let g = self.wire_pool.lock().unwrap();\n        drop(g);\n        let s = self.store.lock().unwrap();\n        drop(s);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pool.rs", src);
    assert_eq!(count(&f, Rule::LockOrder), 0, "findings: {f:?}");
}

#[test]
fn lock_order_unknown_receiver_must_declare_a_rank() {
    let src = "impl P {\n    fn odd(&self) {\n        let q = self.registry.lock().unwrap();\n        drop(q);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pool.rs", src);
    assert_eq!(lines(&f, Rule::LockOrder), [3]);
}

#[test]
fn lock_order_allow_is_honored_with_reason() {
    let src = "impl P {\n    fn bad(&self) {\n        let g = self.wire_pool.lock().unwrap();\n        // lint: allow(lock_order) — startup path, single-threaded by construction\n        let s = self.store.lock().unwrap();\n        drop(s);\n        drop(g);\n    }\n}\n";
    let f = lint_source("rust/src/coordinator/pool.rs", src);
    assert_eq!(count(&f, Rule::LockOrder), 0, "findings: {f:?}");
}

/// PR 9's send-path contract: recycling a wire buffer into the pool
/// while the socket-writer guard is still live is an inversion (writer
/// outranks buffer-pool); dropping the guard first is the clean form
/// `transport::tcp` actually uses.
#[test]
fn lock_order_writer_must_release_before_pool_recycle() {
    let bad = "impl S {\n    fn send(&self) {\n        let w = self.writer.lock().unwrap();\n        let p = self.wire_pool.lock().unwrap();\n        drop(p);\n        drop(w);\n    }\n}\n";
    let f = lint_source("rust/src/transport/tcp.rs", bad);
    assert_eq!(lines(&f, Rule::LockOrder), [4], "findings: {f:?}");
    let good = "impl S {\n    fn send(&self) {\n        let w = self.writer.lock().unwrap();\n        drop(w);\n        let p = self.wire_pool.lock().unwrap();\n        drop(p);\n    }\n}\n";
    assert_eq!(count(&lint_source("rust/src/transport/tcp.rs", good), Rule::LockOrder), 0);
}

/// The lease table sits between the observation store and the
/// buffer pool: store → lease nests cleanly, lease → store inverts.
#[test]
fn lock_order_ranks_the_lease_table() {
    let good = "impl T {\n    fn sweep(&self) {\n        let s = self.store.lock().unwrap();\n        let l = self.leases.lock().unwrap();\n        drop(l);\n        drop(s);\n    }\n}\n";
    assert_eq!(count(&lint_source("rust/src/transport/lease.rs", good), Rule::LockOrder), 0);
    let bad = "impl T {\n    fn sweep(&self) {\n        let l = self.leases.lock().unwrap();\n        let s = self.store.lock().unwrap();\n        drop(s);\n        drop(l);\n    }\n}\n";
    let f = lint_source("rust/src/transport/lease.rs", bad);
    assert_eq!(lines(&f, Rule::LockOrder), [4], "findings: {f:?}");
}

// ---------------------------------------------------------------------------
// bench_stamping
// ---------------------------------------------------------------------------

#[test]
fn bench_stamping_requires_stamp_bench_meta() {
    let bad = "fn main() {\n    std::fs::write(\"BENCH_probe.json\", \"{}\").unwrap();\n}\n";
    let f = lint_source("rust/benches/probe.rs", bad);
    assert_eq!(count(&f, Rule::BenchStamping), 1);
    let good = "fn main() {\n    let mut doc = String::new();\n    bcgc::bench_harness::stamp_bench_meta(&mut doc, seed, &config);\n    std::fs::write(\"BENCH_probe.json\", doc).unwrap();\n}\n";
    assert_eq!(count(&lint_source("rust/benches/probe.rs", good), Rule::BenchStamping), 0);
    // A bench with no artifact, and non-bench files, are out of scope.
    let plain = "fn main() {\n    println!(\"elapsed\");\n}\n";
    assert_eq!(count(&lint_source("rust/benches/plain.rs", plain), Rule::BenchStamping), 0);
    assert_eq!(count(&lint_source("rust/src/coordinator/m.rs", bad), Rule::BenchStamping), 0);
}

// ---------------------------------------------------------------------------
// full tree
// ---------------------------------------------------------------------------

/// The gate CI enforces: the real tree is clean. Any new violation
/// either gets fixed or carries an explicit, reasoned allow.
#[test]
fn full_tree_is_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_tree(root).expect("tree walk failed");
    assert!(report.files >= 46, "walked only {} files — wrong root?", report.files);
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(report.findings.is_empty(), "bcgc-lint findings:\n{}", rendered.join("\n"));
}
