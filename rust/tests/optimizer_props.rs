//! Property tests over the optimizer: the paper's structural results
//! (Lemma 1, Theorem 1, Theorem 2 optimality) plus solver invariants.

use bcgc::distribution::order_stats::{estimate, shifted_exp_exact};
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::closed_form;
use bcgc::optimizer::projection::project_simplex;
use bcgc::optimizer::rounding::round_to_blocks;
use bcgc::optimizer::runtime_model::{tau_hat, tau_s, ProblemSpec, WorkModel};
use bcgc::testing::{gens, Runner};

#[test]
fn prop_theorem1_tau_equivalence() {
    // τ(s, T) == τ̂(x(s), T) for every monotone s and every T.
    Runner::new(200, 0x7411).run("tau-equivalence", |rng| {
        let n = gens::usize_in(rng, 2, 12);
        let l = gens::usize_in(rng, 1, 120);
        let s = gens::monotone_s(rng, n, l);
        let times = gens::positive_times(rng, n);
        let spec = ProblemSpec::new(n, l, n, 1.0);
        let p = BlockPartition::from_s_vector(n, &s).map_err(|e| e.to_string())?;
        let a = tau_s(&spec, &s, &times);
        let b = tau_hat(&spec, &p.as_f64(), &times, WorkModel::GradientCoding);
        if (a - b).abs() > 1e-9 * a.max(1.0) {
            return Err(format!("τ={a} vs τ̂={b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_lemma1_sorting_never_hurts() {
    // For ANY (possibly non-monotone) s, the sorted version has
    // τ(sorted(s), T) ≤ τ(s, T): the exchange argument behind Lemma 1.
    Runner::new(200, 0x7412).run("lemma1-sorting", |rng| {
        let n = gens::usize_in(rng, 2, 10);
        let l = gens::usize_in(rng, 2, 80);
        let s = gens::any_s(rng, n, l);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        let times = gens::positive_times(rng, n);
        let spec = ProblemSpec::new(n, l, n, 1.0);
        let orig = tau_s(&spec, &s, &times);
        let improved = tau_s(&spec, &sorted, &times);
        if improved > orig * (1.0 + 1e-12) {
            return Err(format!("sorting increased runtime: {orig} -> {improved} (s={s:?})"));
        }
        Ok(())
    });
}

#[test]
fn prop_theorem2_closed_form_is_deterministic_optimum() {
    // At deterministic t, x^(t) achieves m and every feasible x is ≥ m.
    Runner::new(100, 0x7413).run("theorem2-optimality", |rng| {
        let n = gens::usize_in(rng, 2, 12);
        let l = gens::usize_in(rng, n, 500);
        let t = gens::increasing_times(rng, n);
        let spec = ProblemSpec::new(n, l, n, 1.0);
        let (xt, m) =
            closed_form::x_from_deterministic_t(&spec, &t, WorkModel::GradientCoding)
                .map_err(|e| e.to_string())?;
        let opt = tau_hat(&spec, &xt, &t, WorkModel::GradientCoding);
        if (opt - spec.unit_work() * m).abs() > 1e-6 * opt {
            return Err(format!("x^(t) does not achieve m: {opt} vs {}", spec.unit_work() * m));
        }
        for _ in 0..20 {
            let x = gens::feasible_x(rng, n, l as f64);
            let v = tau_hat(&spec, &x, &t, WorkModel::GradientCoding);
            if v < opt * (1.0 - 1e-9) {
                return Err(format!("feasible x beats closed form: {v} < {opt}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rounding_feasible_and_close() {
    Runner::new(150, 0x7414).run("rounding", |rng| {
        let n = gens::usize_in(rng, 2, 20);
        let l = gens::usize_in(rng, n, 5000);
        let x = gens::feasible_x(rng, n, l as f64);
        let p = round_to_blocks(&x, l);
        if p.total() != l {
            return Err(format!("rounded total {} != {l}", p.total()));
        }
        for (i, &sz) in p.sizes().iter().enumerate() {
            if (sz as f64 - x[i]).abs() >= 1.0 + 1e-9 {
                return Err(format!("block {i} moved by ≥1: {} vs {}", sz, x[i]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_projection_feasibility_and_optimality_vs_candidates() {
    Runner::new(150, 0x7415).run("projection", |rng| {
        let n = gens::usize_in(rng, 2, 15);
        let l = 1.0 + rng.uniform() * 1000.0;
        let v: Vec<f64> = (0..n).map(|_| rng.normal_with(0.0, l)).collect();
        let p = project_simplex(&v, l);
        let sum: f64 = p.iter().sum();
        if (sum - l).abs() > 1e-6 * l || p.iter().any(|&x| x < 0.0) {
            return Err(format!("infeasible projection (sum {sum}, target {l})"));
        }
        // No random feasible point is closer to v.
        let d_opt: f64 = p.iter().zip(v.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
        for _ in 0..20 {
            let q = gens::feasible_x(rng, n, l);
            let d: f64 = q.iter().zip(v.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
            if d < d_opt - 1e-9 {
                return Err(format!("candidate closer than projection: {d} < {d_opt}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_order_stats_monotone_and_jensen() {
    Runner::new(20, 0x7416).run("order-stats", |rng| {
        let n = gens::usize_in(rng, 2, 30);
        let mu = 10f64.powf(rng.uniform_range(-3.5, -1.0));
        let t0 = rng.uniform_range(1.0, 100.0);
        let d = ShiftedExponential::new(mu, t0);
        let os = shifted_exp_exact(&d, n);
        for k in 1..n {
            if os.t[k] < os.t[k - 1] || os.t_prime[k] < os.t_prime[k - 1] {
                return Err(format!("order stats not monotone at k={k}"));
            }
        }
        // Jensen: t'_k ≤ t_k.
        for k in 0..n {
            if os.t_prime[k] > os.t[k] * (1.0 + 1e-9) {
                return Err(format!("Jensen violated at k={k}: {} > {}", os.t_prime[k], os.t[k]));
            }
        }
        // Cross-check against Monte Carlo at moderate size.
        if n <= 12 {
            let mc = estimate(&d, n, 30_000, rng);
            for k in 0..n {
                let rel = (os.t[k] - mc.t[k]).abs() / os.t[k];
                if rel > 0.05 {
                    return Err(format!("exact vs MC t mismatch at k={k}: rel {rel}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theorem4_shape_xf_beats_xt_in_expectation() {
    // x^(f) ⪯ x^(t) under shifted-exponential (Theorem 4's ordering),
    // checked with common random numbers at several operating points.
    Runner::new(12, 0x7417).run("xf-vs-xt", |rng| {
        use bcgc::optimizer::evaluate::compare_schemes;
        let n = gens::usize_in(rng, 5, 30);
        let l = 4000;
        let mu = 10f64.powf(rng.uniform_range(-3.2, -2.0));
        let d = ShiftedExponential::new(mu, 50.0);
        let spec = ProblemSpec::paper_default(n, l);
        let os = shifted_exp_exact(&d, n);
        let xt = round_to_blocks(&closed_form::x_time(&spec, &os).unwrap(), l);
        let xf = round_to_blocks(&closed_form::x_freq(&spec, &os).unwrap(), l);
        let rows = compare_schemes(
            &spec,
            &[("xt".into(), xt), ("xf".into(), xf)],
            &d,
            4000,
            rng,
        );
        // Allow a small tolerance: the ordering is an expectation-level
        // statement and both are within a few percent of optimal.
        if rows[1].mean() > rows[0].mean() * 1.03 {
            return Err(format!(
                "x^(f) ({}) much worse than x^(t) ({}) at N={n}, mu={mu:.2e}",
                rows[1].mean(),
                rows[0].mean()
            ));
        }
        Ok(())
    });
}
