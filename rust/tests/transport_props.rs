//! Property tests for the transport wire codec (`bcgc::transport::codec`):
//! every frame kind round-trips bit-exactly across randomized payloads
//! (including zero-length, single-element and ragged coded blocks, and
//! adversarial f32/f64 bit patterns), truncated and garbage frames error
//! instead of panicking, and the incremental stream parser reassembles
//! frame sequences across arbitrary chunk boundaries.
//!
//! All properties run under [`bcgc::testing::Runner`], so
//! `BCGC_PROP_SEED` / `BCGC_PROP_CASES` replay and widen them exactly
//! like the coding/kernel property suites.

use std::sync::Arc;

use bcgc::coding::scheme::CodingScheme;
use bcgc::coordinator::channel::{BlockContribution, PartialBlockContribution, WorkerTask};
use bcgc::coordinator::PacingMode;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::testing::{gens, Runner};
use bcgc::transport::codec::{
    decode_frame, frame_assign, frame_block, frame_failed, frame_goodbye, frame_heartbeat,
    frame_hello, frame_partial, frame_task, next_frame, read_frame, Frame, WireTask, MAX_FRAME,
};
use bcgc::util::rng::Rng;
use bcgc::Error;

/// An f32 drawn from the full bit space plus the named troublemakers —
/// round-trips are compared on bits, so NaN payloads and signed zeros
/// must survive too.
fn rand_f32(rng: &mut Rng) -> f32 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => f32::INFINITY,
        3 => f32::NEG_INFINITY,
        4 => f32::NAN,
        5 => f32::MIN_POSITIVE / 2.0, // subnormal
        _ => f32::from_bits(rng.next_u64() as u32),
    }
}

/// A contribution with adversarial payload lengths: empty, one element,
/// or a ragged mid-size buffer.
fn rand_block(rng: &mut Rng) -> BlockContribution {
    let len = match rng.below(4) {
        0 => 0,
        1 => 1,
        _ => gens::usize_in(rng, 2, 300),
    };
    BlockContribution {
        job: rng.below(1 << 20) as usize,
        iter: rng.below(1 << 20) as usize,
        epoch: rng.below(1 << 10) as usize,
        worker: rng.below(1 << 16) as usize,
        row: rng.below(1 << 16) as usize,
        block_idx: rng.below(1 << 10) as usize,
        virtual_time: f64::from_bits(rng.next_u64()),
        coded: (0..len).map(|_| rand_f32(rng)).collect(),
    }
}

/// A rotation-part delta with the same adversarial payload coverage as
/// [`rand_block`].
fn rand_partial(rng: &mut Rng) -> PartialBlockContribution {
    let base = rand_block(rng);
    let parts = gens::usize_in(rng, 1, 9);
    let samples_total = rng.below(1 << 20) as usize;
    PartialBlockContribution {
        job: base.job,
        iter: base.iter,
        epoch: base.epoch,
        worker: base.worker,
        row: base.row,
        block_idx: base.block_idx,
        part: rng.below(parts as u64) as usize,
        parts,
        samples_done: samples_total / 2,
        samples_total,
        virtual_time: base.virtual_time,
        coded: base.coded,
    }
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn block_frames_roundtrip_bit_exactly() {
    Runner::default().run("block-roundtrip", |rng| {
        let c = rand_block(rng);
        let frame = frame_block(&c).map_err(|e| format!("frame: {e}"))?;
        let body =
            read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
        let Frame::Block(got) = decode_frame(&body).map_err(|e| format!("decode: {e}"))? else {
            return Err("decoded to a different frame kind".into());
        };
        if (got.job, got.iter, got.epoch, got.worker, got.row, got.block_idx)
            != (c.job, c.iter, c.epoch, c.worker, c.row, c.block_idx)
        {
            return Err("header fields drifted".into());
        }
        if got.virtual_time.to_bits() != c.virtual_time.to_bits() {
            return Err("virtual_time drifted".into());
        }
        if bits32(&got.coded) != bits32(&c.coded) {
            return Err(format!("payload drifted at len {}", c.coded.len()));
        }
        Ok(())
    });
}

#[test]
fn partial_frames_roundtrip_bit_exactly() {
    Runner::default().run("partial-roundtrip", |rng| {
        let c = rand_partial(rng);
        let frame = frame_partial(&c).map_err(|e| format!("frame: {e}"))?;
        let body =
            read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
        let Frame::Partial(got) = decode_frame(&body).map_err(|e| format!("decode: {e}"))? else {
            return Err("decoded to a different frame kind".into());
        };
        if (got.job, got.iter, got.epoch, got.worker, got.row, got.block_idx)
            != (c.job, c.iter, c.epoch, c.worker, c.row, c.block_idx)
        {
            return Err("header fields drifted".into());
        }
        if (got.part, got.parts, got.samples_done, got.samples_total)
            != (c.part, c.parts, c.samples_done, c.samples_total)
        {
            return Err("rotation fields drifted".into());
        }
        if got.virtual_time.to_bits() != c.virtual_time.to_bits() {
            return Err("virtual_time drifted".into());
        }
        if bits32(&got.coded) != bits32(&c.coded) {
            return Err(format!("payload drifted at len {}", c.coded.len()));
        }
        Ok(())
    });
}

#[test]
fn control_frames_roundtrip() {
    Runner::default().run("control-roundtrip", |rng| {
        // Hello carries nothing but must still round-trip.
        let hello = frame_hello().map_err(|e| format!("frame: {e}"))?;
        let body =
            read_frame(&mut hello.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
        if !matches!(decode_frame(&body).map_err(|e| format!("decode: {e}"))?, Frame::Hello) {
            return Err("hello did not round-trip".into());
        }

        // Assign: identity plus the liveness contract plus pacing.
        let worker = rng.below(1 << 32) as usize;
        let (ttl, hb) = (rng.next_u64(), rng.next_u64());
        let pacing = if rng.below(2) == 0 {
            PacingMode::Virtual
        } else {
            PacingMode::RealScaled { ns_per_unit: rng.uniform_range(0.0, 1e9) }
        };
        let frame = frame_assign(worker, ttl, hb, pacing).map_err(|e| format!("frame: {e}"))?;
        let body =
            read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
        match decode_frame(&body).map_err(|e| format!("decode: {e}"))? {
            Frame::Assign { worker: w, lease_ttl_ms, heartbeat_ms, pacing: p } => {
                if (w, lease_ttl_ms, heartbeat_ms) != (worker, ttl, hb) || p != pacing {
                    return Err("assign fields drifted".into());
                }
            }
            _ => return Err("assign decoded to a different frame kind".into()),
        }

        // Heartbeat / Goodbye: bare worker ids.
        let hb_frame = frame_heartbeat(worker).map_err(|e| format!("frame: {e}"))?;
        let gb_frame = frame_goodbye(worker).map_err(|e| format!("frame: {e}"))?;
        for (frame, goodbye) in [(hb_frame, false), (gb_frame, true)] {
            let body =
                read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
            match (decode_frame(&body).map_err(|e| format!("decode: {e}"))?, goodbye) {
                (Frame::Heartbeat { worker: w }, false) | (Frame::Goodbye { worker: w }, true) => {
                    if w != worker {
                        return Err("worker id drifted".into());
                    }
                }
                _ => return Err("liveness frame decoded to a different kind".into()),
            }
        }

        // Failed: arbitrary (possibly empty, possibly non-ASCII) reason.
        let reason = match rng.below(3) {
            0 => String::new(),
            1 => "exécuteur mort — ¯\\_(ツ)_/¯".to_string(),
            _ => (0..gens::usize_in(rng, 1, 40))
                .map(|_| char::from(32 + (rng.below(95) as u8)))
                .collect(),
        };
        let job = rng.below(1 << 20) as usize;
        let iter = rng.below(1 << 20) as usize;
        let fatal = rng.below(2) == 1;
        let frame =
            frame_failed(worker, job, iter, &reason, fatal).map_err(|e| format!("frame: {e}"))?;
        let body =
            read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
        match decode_frame(&body).map_err(|e| format!("decode: {e}"))? {
            Frame::Failed { worker: w, job: j, iter: i, reason: r, fatal: f } => {
                if (w, j, i, f) != (worker, job, iter, fatal) || r != reason {
                    return Err("failed fields drifted".into());
                }
            }
            _ => return Err("failed decoded to a different frame kind".into()),
        }
        Ok(())
    });
}

#[test]
fn compute_tasks_roundtrip_everything_but_the_factory() {
    // Schemes are expensive to generate; fewer cases keep the suite
    // quick while still sweeping ragged partitions (zero-size levels
    // included) and adversarial float payloads.
    let runner = Runner::default();
    Runner::new(runner.cases.clamp(1, 40), runner.seed).run("task-roundtrip", |rng| {
        let n = gens::usize_in(rng, 3, 5);
        let mut sizes = vec![0usize; n];
        for s in sizes.iter_mut() {
            *s = gens::usize_in(rng, 0, 6);
        }
        if sizes.iter().sum::<usize>() == 0 {
            sizes[0] = 1;
        }
        let scheme = Arc::new(
            CodingScheme::new(BlockPartition::new(sizes), rng).map_err(|e| e.to_string())?,
        );
        let theta: Vec<f32> = (0..gens::usize_in(rng, 0, 50)).map(|_| rand_f32(rng)).collect();
        let shards: Vec<Vec<usize>> = (0..n)
            .map(|_| (0..rng.below(4)).map(|_| rng.below(64) as usize).collect())
            .collect();
        let job = rng.below(1 << 10) as usize;
        let iter = rng.below(1 << 20) as usize;
        let epoch = rng.below(1 << 10) as usize;
        let row = rng.below(n as u64) as usize;
        let cycle_time = rng.uniform_range(1e-6, 1e3);
        let unit_work = rng.uniform_range(1e-6, 1e3);
        // Half the cases carry a sample-granular slice map + rotation
        // parts, half stay on the shard-granular wire shape.
        let slices = if rng.below(2) == 0 {
            None
        } else {
            let mut lo = 0usize;
            let map: Vec<(usize, usize)> = (0..n)
                .map(|_| {
                    let hi = lo + gens::usize_in(rng, 0, 40);
                    let span = (lo, hi);
                    lo = hi;
                    span
                })
                .collect();
            Some(Arc::new(map))
        };
        let parts = gens::usize_in(rng, 1, 8);
        let task = WorkerTask::Compute {
            job,
            iter,
            epoch,
            row,
            scheme: scheme.clone(),
            shards: Arc::new(shards.clone()),
            theta: Arc::new(theta.clone()),
            factory: Arc::new(|_| Err(Error::Runtime("factories never cross the wire".into()))),
            cycle_time,
            unit_work,
            slices: slices.clone(),
            parts,
        };

        let frame = frame_task(&task).map_err(|e| format!("frame: {e}"))?;
        let body =
            read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
        let Frame::Task(WireTask::Compute {
            job: gj,
            iter: gi,
            epoch: ge,
            row: gr,
            scheme: gs,
            shards: gsh,
            theta: gt,
            cycle_time: gc,
            unit_work: gu,
            slices: gsl,
            parts: gp,
        }) = decode_frame(&body).map_err(|e| format!("decode: {e}"))?
        else {
            return Err("compute decoded to a different frame kind".into());
        };
        if (gj, gi, ge, gr) != (job, iter, epoch, row) {
            return Err("task header drifted".into());
        }
        if gsl.as_deref() != slices.as_deref() || gp != parts {
            return Err("slice map / parts drifted".into());
        }
        if gc.to_bits() != cycle_time.to_bits() || gu.to_bits() != unit_work.to_bits() {
            return Err("task timing fields drifted".into());
        }
        if bits32(&gt) != bits32(&theta) {
            return Err("theta drifted".into());
        }
        if *gsh != shards {
            return Err("shard map drifted".into());
        }
        if gs.n() != scheme.n() || gs.blocks().sizes() != scheme.blocks().sizes() {
            return Err("scheme shape drifted".into());
        }
        for r in scheme.ranges() {
            if gs.code(r.s).b.data() != scheme.code(r.s).b.data()
                || gs.code(r.s).supports != scheme.code(r.s).supports
            {
                return Err(format!("code for level s={} drifted", r.s));
            }
        }

        // Drain / Shutdown round-trip as bare tags.
        for (task, want_drain) in [(WorkerTask::Drain, true), (WorkerTask::Shutdown, false)] {
            let frame = frame_task(&task).map_err(|e| format!("frame: {e}"))?;
            let body =
                read_frame(&mut frame.as_slice(), MAX_FRAME).map_err(|e| format!("read: {e}"))?;
            let ok = match decode_frame(&body).map_err(|e| format!("decode: {e}"))? {
                Frame::Task(WireTask::Drain) => want_drain,
                Frame::Task(WireTask::Shutdown) => !want_drain,
                _ => false,
            };
            if !ok {
                return Err("control task decoded to a different kind".into());
            }
        }
        Ok(())
    });
}

#[test]
fn truncated_and_garbage_frames_error_not_panic() {
    Runner::default().run("fuzz-robustness", |rng| {
        // Every strict prefix of a well-formed body must error.
        let frame = frame_block(&rand_block(rng)).map_err(|e| format!("frame: {e}"))?;
        let body = &frame[4..];
        for cut in 0..body.len() {
            if decode_frame(&body[..cut]).is_ok() {
                return Err(format!("truncated body ({cut} of {}) decoded", body.len()));
            }
        }
        // Random bytes through the stream parser: may reject, may wait
        // for more input, may even parse — but never panics and never
        // grows the pending buffer on its own.
        let len = gens::usize_in(rng, 0, 64);
        let mut garbage: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let before = garbage.len();
        match next_frame(&mut garbage, MAX_FRAME) {
            Ok(Some(b)) => {
                let _ = decode_frame(&b);
            }
            Ok(None) | Err(_) => {}
        }
        if garbage.len() > before {
            return Err("parser grew the pending buffer".into());
        }
        Ok(())
    });
}

#[test]
fn stream_parser_reassembles_frames_across_arbitrary_chunking() {
    Runner::default().run("chunked-reassembly", |rng| {
        let k = gens::usize_in(rng, 1, 6);
        let frames: Vec<Vec<u8>> = (0..k)
            .map(|_| match rng.below(5) {
                0 => frame_hello(),
                1 => frame_heartbeat(rng.below(1 << 16) as usize),
                2 => frame_goodbye(rng.below(1 << 16) as usize),
                3 => frame_partial(&rand_partial(rng)),
                _ => frame_block(&rand_block(rng)),
            })
            .map(|f| f.expect("small frames always fit"))
            .collect();
        let stream: Vec<u8> = frames.iter().flatten().copied().collect();

        let mut pending: Vec<u8> = Vec::new();
        let mut got: Vec<Vec<u8>> = Vec::new();
        let mut i = 0;
        while i < stream.len() {
            let step = gens::usize_in(rng, 1, 17).min(stream.len() - i);
            pending.extend_from_slice(&stream[i..i + step]);
            i += step;
            while let Some(body) = next_frame(&mut pending, MAX_FRAME).map_err(|e| e.to_string())?
            {
                got.push(body);
            }
        }
        let want: Vec<Vec<u8>> = frames.iter().map(|f| f[4..].to_vec()).collect();
        if got != want {
            return Err(format!("reassembled {} frames, wanted {}", got.len(), want.len()));
        }
        if !pending.is_empty() {
            return Err("bytes left over after the last frame".into());
        }
        Ok(())
    });
}
