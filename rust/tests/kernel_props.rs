//! Property tests over the fused data-plane kernels (proptest-lite
//! runner): the tiled/fused/pooled paths must agree with the naive
//! one-pass-per-source reference across random schemes, survivor
//! arrival orders and awkward tile boundaries.

use bcgc::coding::decoder::{decode, decode_into, decode_vector};
use bcgc::coding::encoder::GradientCode;
use bcgc::coding::scheme::CodingScheme;
use bcgc::linalg::kernels::{
    fused_combine_f32, fused_combine_f64, fused_combine_into_f64, fused_combine_into_f64_auto,
    naive_combine_f32_to_f64, naive_combine_f64, PAR_MIN_LEN, TILE,
};
use bcgc::testing::{gens, Runner};
use bcgc::util::buffers::BufferPool;
use bcgc::util::rng::Rng;

/// Draw a combine length that stresses the tiling: empty, single
/// element, one off a tile boundary in either direction, exact
/// multiples, or a ragged multi-tile length.
fn awkward_len(rng: &mut Rng) -> usize {
    match gens::usize_in(rng, 0, 6) {
        0 => 0,
        1 => 1,
        2 => TILE - 1,
        3 => TILE,
        4 => TILE + 1,
        5 => gens::usize_in(rng, 2, TILE - 2),
        _ => gens::usize_in(rng, 2, 4) * TILE + gens::usize_in(rng, 0, 9),
    }
}

#[test]
fn prop_fused_combines_match_naive_reference() {
    Runner::new(120, 0xF05E).run("fused-vs-naive", |rng| {
        let k = gens::usize_in(rng, 1, 6);
        let len = awkward_len(rng);
        // Zero coefficients exercised explicitly (identity / frac-rep
        // codes are mostly zeros, and the fused kernels skip them).
        let coefs: Vec<f64> = (0..k)
            .map(|_| if rng.uniform() < 0.25 { 0.0 } else { rng.normal() })
            .collect();
        let srcs64: Vec<Vec<f64>> =
            (0..k).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
        let s64: Vec<(f64, &[f64])> =
            coefs.iter().copied().zip(srcs64.iter().map(|s| s.as_slice())).collect();
        let want64 = naive_combine_f64(&s64, len);
        let mut got64 = vec![f64::NAN; gens::usize_in(rng, 0, 5)]; // dirty
        fused_combine_f64(&s64, len, &mut got64);
        if got64.len() != len || got64.iter().zip(want64.iter()).any(|(a, b)| a != b) {
            return Err(format!("f64 fused != naive at len {len}, k {k}"));
        }

        let srcs32: Vec<Vec<f32>> = srcs64
            .iter()
            .map(|s| s.iter().map(|&v| v as f32).collect())
            .collect();
        let s32: Vec<(f64, &[f32])> =
            coefs.iter().copied().zip(srcs32.iter().map(|s| s.as_slice())).collect();
        let want32 = naive_combine_f32_to_f64(&s32, len);
        let mut into = vec![f64::NAN; len]; // dirty slice, fully overwritten
        fused_combine_into_f64(&s32, &mut into);
        if into.iter().zip(want32.iter()).any(|(a, b)| a != b) {
            return Err(format!("into_f64 fused != naive at len {len}, k {k}"));
        }
        let mut wire = vec![9.0f32; gens::usize_in(rng, 0, 5)]; // dirty
        fused_combine_f32(&s32, len, &mut wire);
        if wire.len() != len {
            return Err(format!("wire length {} != {len}", wire.len()));
        }
        for (w, v) in wire.iter().zip(want32.iter()) {
            let err = (*w as f64 - v).abs() / (1.0 + v.abs());
            if err > 1e-6 {
                return Err(format!("f32 wire off by {err:.2e} at len {len}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scheme_f32_encode_matches_f64_encode_on_random_schemes() {
    // The worker's pooled f32 wire encode must agree with the f64 codec
    // path (the one coding_props pins against the generic encode) to
    // within a single f32 rounding of the result.
    Runner::new(60, 0xE27C).run("scheme-f32-encode", |rng| {
        let n = gens::usize_in(rng, 2, 8);
        let coords = gens::usize_in(rng, n, 3 * TILE);
        let x = gens::feasible_x(rng, n, coords as f64);
        let blocks = bcgc::optimizer::rounding::round_to_blocks(&x, coords);
        let scheme = CodingScheme::new(blocks, rng).map_err(|e| e.to_string())?;
        let max_s = scheme.blocks().max_level();
        let w = gens::usize_in(rng, 0, n - 1);
        let shard32: Vec<Vec<f32>> = (0..max_s + 1)
            .map(|_| (0..coords).map(|_| rng.normal() as f32).collect())
            .collect();
        let shard64: Vec<Vec<f64>> = shard32
            .iter()
            .map(|g| g.iter().map(|&v| v as f64).collect())
            .collect();
        let pool = BufferPool::new(8);
        for r in scheme.ranges() {
            let want = scheme.encode_block_range(w, &r, &shard64);
            // Recycled (dirty) pool buffer: take → encode → put → take.
            let mut wire = pool.take(r.len());
            scheme.encode_block_range_f32_into(w, &r, &shard32, &mut wire);
            if wire.len() != r.len() {
                return Err(format!("wire len {} != block len {}", wire.len(), r.len()));
            }
            for (a, b) in wire.iter().zip(want.iter()) {
                let err = (*a as f64 - b).abs() / (1.0 + b.abs());
                if err > 1e-6 {
                    return Err(format!("s={} block encode off by {err:.2e}", r.s));
                }
            }
            pool.put(wire);
        }
        Ok(())
    });
}

#[test]
fn prop_decode_into_exact_over_random_survivor_orders() {
    // f32 wire end-to-end: encode through the fused f32 kernel, decode
    // through `decode_into` with survivors arriving in a random order,
    // and the recovered block must equal Σ_i g_i to f32-rounding.
    Runner::new(80, 0xDEC0).run("decode-into-orders", |rng| {
        let n = gens::usize_in(rng, 2, 10);
        let s = gens::usize_in(rng, 0, n - 1);
        let dim = awkward_len(rng).max(1);
        let code = GradientCode::cyclic_mds(n, s, rng).map_err(|e| e.to_string())?;
        let grads: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        let want: Vec<f64> = (0..dim)
            .map(|d| grads.iter().map(|g| g[d] as f64).sum())
            .collect();
        // Worker wire contributions via the fused f32 encode kernel.
        let wire: Vec<Vec<f32>> = (0..n)
            .map(|w| {
                let sources: Vec<(f64, &[f32])> = code.supports[w]
                    .iter()
                    .map(|&i| (code.b[(w, i)], grads[i].as_slice()))
                    .collect();
                let mut out = Vec::new();
                fused_combine_f32(&sources, dim, &mut out);
                out
            })
            .collect();
        // Random arrival order; the decode contract pairs coefficients
        // with ASCENDING survivor ids (the master sorts arrivals).
        let arrival = rng.sample_indices(n, n - s);
        let mut sorted = arrival.clone();
        sorted.sort_unstable();
        let a = decode_vector(&code, &sorted).map_err(|e| e.to_string())?;
        let picked: Vec<&[f32]> = sorted.iter().map(|&w| wire[w].as_slice()).collect();
        let mut got = vec![f64::NAN; dim];
        decode_into(&a, &picked, &mut got);
        // Oracle: the f64 decode over f64-widened wire values.
        let wide: Vec<Vec<f64>> = sorted
            .iter()
            .map(|&w| wire[w].iter().map(|&v| v as f64).collect())
            .collect();
        let refs: Vec<&[f64]> = wide.iter().map(|c| c.as_slice()).collect();
        let oracle = decode(&a, &refs);
        // Forward-error budget of the f32 wire: each contribution is
        // exact to one f32 rounding (2⁻²⁴), amplified by its decode
        // coefficient — random codes can be ill-conditioned, so the
        // bound is computed, not guessed.
        let amp: f64 = a
            .iter()
            .zip(picked.iter())
            .map(|(&ak, c)| ak.abs() * c.iter().fold(0.0f64, |m, &v| m.max(v.abs() as f64)))
            .sum();
        let tol = 1e-6 * (1.0 + amp);
        for d in 0..dim {
            if got[d] != oracle[d] {
                return Err(format!(
                    "n={n} s={s} arrival={arrival:?}: decode_into {} != decode {} at {d}",
                    got[d], oracle[d]
                ));
            }
            let err = (got[d] - want[d]).abs();
            if err > tol {
                return Err(format!(
                    "n={n} s={s} S={sorted:?} dim {d}: got {} want {} (err {err:.2e} > {tol:.2e})",
                    got[d], want[d]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pooled_buffers_never_leak_stale_data() {
    // Shrinking, growing and interleaving buffer sizes through one pool:
    // a recycled buffer must behave exactly like a fresh allocation.
    Runner::new(60, 0xB00F).run("pool-recycling", |rng| {
        let pool = BufferPool::new(4);
        for _ in 0..8 {
            // ≥ 1: a length-0 encode leaves the buffer unallocated, and
            // `put` drops (without counting) buffers that never allocated.
            let len = awkward_len(rng).max(1);
            let src: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let coef = rng.normal();
            let sources = [(coef, src.as_slice())];
            let want = naive_combine_f32_to_f64(&sources, len);
            let mut buf = pool.take(len);
            fused_combine_f32(&sources, len, &mut buf);
            if buf.len() != len {
                return Err(format!("pooled buffer wrong length {}", buf.len()));
            }
            for (g, w) in buf.iter().zip(want.iter()) {
                let err = (*g as f64 - w).abs() / (1.0 + w.abs());
                if err > 1e-6 {
                    return Err(format!("stale data through pool at len {len}"));
                }
            }
            pool.put(buf);
        }
        let st = pool.stats();
        if st.hits + st.misses != 8 || st.returned != 8 {
            return Err(format!("pool stats off: {st:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_combine_bit_identical_to_serial() {
    // Few cases — each allocates multi-megabyte sources — but enough to
    // vary the ragged tail across thread-chunk boundaries.
    Runner::new(4, 0x9A51).run("parallel-combine", |rng| {
        let len = PAR_MIN_LEN + gens::usize_in(rng, 0, 3 * TILE + 5);
        let k = gens::usize_in(rng, 2, 5);
        let srcs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let coefs: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let sources: Vec<(f64, &[f32])> =
            coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
        let mut serial = vec![0.0f64; len];
        fused_combine_into_f64(&sources, &mut serial);
        let mut par = vec![f64::NAN; len];
        fused_combine_into_f64_auto(&sources, &mut par);
        if par.iter().zip(serial.iter()).any(|(a, b)| a != b) {
            return Err(format!("parallel != serial at len {len}, k {k}"));
        }
        Ok(())
    });
}
