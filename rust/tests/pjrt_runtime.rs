//! PJRT runtime integration: load the AOT HLO artifacts, execute them,
//! and cross-check numerics against the pure-Rust host oracle.
//!
//! Requires `make artifacts` (the Makefile's `test` target orders this);
//! tests are skipped with a loud message when artifacts are absent.
//!
//! The whole file is gated on the `pjrt` cargo feature: the default
//! build has no `xla` bindings, so `PjrtExecutor` is a stub whose `load`
//! always errors — running these tests would only exercise the stub.
//! Build with `--features pjrt` (and the xla/anyhow deps wired in
//! Cargo.toml) to run them for real.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;
use std::sync::Arc;

use bcgc::data::synthetic;
use bcgc::runtime::artifact::Manifest;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::pjrt::PjrtExecutor;
use bcgc::runtime::GradExecutor;
use bcgc::util::rng::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.toml").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {} — run `make artifacts`", dir.display());
        None
    }
}

#[test]
fn manifest_lists_expected_entries() {
    let Some(dir) = artifact_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    let names: Vec<&str> = m.names().collect();
    assert!(names.contains(&"linreg_d32_s16"), "{names:?}");
    assert!(names.contains(&"mlp_d16_h32_c4_s8"), "{names:?}");
    assert!(names.contains(&"mlp_d64_h256_c10_s128"), "{names:?}");
}

#[test]
fn linreg_pjrt_matches_host_oracle() {
    let Some(dir) = artifact_dir() else { return };
    let n = 4;
    let (ds, _) = synthetic::linear_regression(32, 16 * n, n, 0.1, 77).unwrap();
    let mut pjrt = PjrtExecutor::load(&dir, "linreg_d32_s16", ds.clone()).unwrap();
    let mut host = HostExecutor::new(ds, HostModel::LinearRegression).unwrap();
    let mut rng = Rng::new(5);
    let theta: Vec<f32> = (0..32).map(|_| rng.normal() as f32 * 0.3).collect();
    for shard in 0..n {
        let a = pjrt.grad_shard(&theta, shard).unwrap();
        let b = host.grad_shard(&theta, shard).unwrap();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert!((x - y).abs() < 1e-3 * (1.0 + y.abs()), "{x} vs {y}");
        }
    }
    let la = pjrt.loss(&theta).unwrap();
    let lb = host.loss(&theta).unwrap();
    assert!((la - lb).abs() < 1e-2 * (1.0 + lb.abs()), "loss {la} vs {lb}");
}

#[test]
fn mlp_pjrt_matches_host_oracle() {
    let Some(dir) = artifact_dir() else { return };
    let n = 4;
    let ds = synthetic::classification(16, 4, 8 * n, n, 0.2, 13).unwrap();
    let mut pjrt = PjrtExecutor::load(&dir, "mlp_d16_h32_c4_s8", ds.clone()).unwrap();
    let mut host = HostExecutor::new(ds, HostModel::Mlp { hidden: 32 }).unwrap();
    assert_eq!(pjrt.dim(), host.dim());
    let dim = pjrt.dim();
    let mut rng = Rng::new(9);
    let theta: Vec<f32> = (0..dim).map(|_| rng.normal() as f32 * 0.2).collect();
    for shard in 0..n {
        let a = pjrt.grad_shard(&theta, shard).unwrap();
        let b = host.grad_shard(&theta, shard).unwrap();
        let mut max_rel = 0.0f32;
        for (x, y) in a.iter().zip(b.iter()) {
            max_rel = max_rel.max((x - y).abs() / (1.0 + y.abs()));
        }
        assert!(max_rel < 1e-3, "shard {shard}: max rel err {max_rel}");
    }
    let la = pjrt.loss(&theta).unwrap();
    let lb = host.loss(&theta).unwrap();
    assert!((la - lb).abs() < 1e-2 * (1.0 + lb.abs()), "loss {la} vs {lb}");
}

#[test]
fn dataset_shape_mismatch_rejected() {
    let Some(dir) = artifact_dir() else { return };
    // Wrong feature dim for the artifact.
    let (ds, _) = synthetic::linear_regression(16, 64, 4, 0.1, 1).unwrap();
    assert!(PjrtExecutor::load(&dir, "linreg_d32_s16", ds).is_err());
    // Wrong shard size.
    let (ds, _) = synthetic::linear_regression(32, 32 * 4, 4, 0.1, 1).unwrap();
    assert!(PjrtExecutor::load(&dir, "linreg_d32_s16", ds).is_err());
}

#[test]
fn coded_training_over_pjrt_end_to_end() {
    // The full stack: optimizer → codec → coordinator threads → PJRT
    // executors running the AOT Pallas/JAX artifacts → decoded exact
    // gradient → descending loss.
    let Some(dir) = artifact_dir() else { return };
    use bcgc::coordinator::trainer::{train_stationary, TrainConfig};
    use bcgc::distribution::shifted_exp::ShiftedExponential;
    use bcgc::optimizer::runtime_model::ProblemSpec;
    use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
    use bcgc::runtime::pjrt_factory;

    let n = 4usize;
    let ds = synthetic::classification(16, 4, 8 * n, n, 0.2, 99).unwrap();
    let dim = 16 * 32 + 32 + 32 * 4 + 4; // mlp_d16_h32_c4_s8
    let factory = pjrt_factory(dir, "mlp_d16_h32_c4_s8".into(), ds);
    let spec = ProblemSpec::new(n, dim, 8 * n, 1.0);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(99);
    let blocks = solve(&spec, &dist, SchemeKind::ClosedFormFreq, &SolveOptions::fast(), &mut rng)
        .unwrap();
    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = 25;
    cfg.lr = 5e-3;
    cfg.eval_every = 5;
    cfg.seed = 99;
    let report = train_stationary(cfg, Box::new(dist), factory).unwrap();
    let first = report.first_loss().unwrap();
    let last = report.final_loss().unwrap();
    assert!(last < first, "PJRT coded training must descend: {first} -> {last}");
    assert_eq!(report.steps(), 25);
}

#[test]
fn unknown_entry_rejected() {
    let Some(dir) = artifact_dir() else { return };
    let (ds, _) = synthetic::linear_regression(32, 64, 4, 0.1, 1).unwrap();
    assert!(PjrtExecutor::load(&dir, "not_a_real_entry", Arc::clone(&ds)).is_err());
}
