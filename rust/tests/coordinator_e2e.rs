//! Coordinator end-to-end: coded distributed GD over the thread topology
//! produces *exactly* the uncoded full gradient (up to f32/f64 transport
//! noise) regardless of straggler pattern, and training converges.

use std::sync::Arc;

use bcgc::coordinator::trainer::{train_stationary, TrainConfig};
use bcgc::coordinator::PacingMode;
use bcgc::data::synthetic;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::Deterministic;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::runtime::host::{HostExecutor, HostModel};
use bcgc::runtime::{host_factory, GradExecutor};
use bcgc::testing::suite_seed;

fn mlp_setup(n: usize, seed: u64) -> (Arc<bcgc::data::Dataset>, usize) {
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    (ds, dim)
}

fn run_once(
    blocks: BlockPartition,
    n: usize,
    steps: usize,
    dead: Vec<usize>,
    seed: u64,
) -> bcgc::coordinator::metrics::TrainReport {
    let (ds, dim) = mlp_setup(n, seed);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = steps;
    cfg.lr = 2e-3; // summed (not mean) loss ⇒ conservative step size
    cfg.eval_every = (steps / 4).max(1);
    cfg.seed = seed;
    cfg.dead_workers = dead;
    train_stationary(cfg, Box::new(ShiftedExponential::new(1e-3, 50.0)), factory).unwrap()
}

#[test]
fn coded_training_reduces_loss_multi_level() {
    let n = 6;
    let seed = suite_seed(3);
    let (_, dim) = mlp_setup(n, seed);
    // A genuinely multi-level partition.
    let mut sizes = vec![0usize; n];
    sizes[0] = dim / 2;
    sizes[2] = dim / 4;
    sizes[n - 1] = dim - sizes[0] - sizes[2];
    let report = run_once(BlockPartition::new(sizes), n, 200, vec![], seed);
    let first = report.first_loss().unwrap();
    let last = report.final_loss().unwrap();
    assert!(last < first * 0.85, "loss {first} -> {last}");
    assert_eq!(report.steps(), 200);
    assert!(report.failed_workers.is_empty());
}

#[test]
fn coded_gradient_equals_uncoded_gradient_trajectory() {
    // Same seed ⇒ same data, same init, same T stream. A multi-level
    // coded run and an uncoded run must produce (nearly) identical loss
    // curves because the decoded gradient is exact.
    let n = 4;
    let seed = suite_seed(11);
    let (_, dim) = mlp_setup(n, seed);
    let uncoded = run_once(BlockPartition::single_level(n, 0, dim), n, 20, vec![], seed);
    let mut sizes = vec![0usize; n];
    sizes[1] = dim / 3;
    sizes[3] = dim - dim / 3;
    let coded = run_once(BlockPartition::new(sizes), n, 20, vec![], seed);
    for ((i1, l1), (i2, l2)) in uncoded.loss_curve.iter().zip(coded.loss_curve.iter()) {
        assert_eq!(i1, i2);
        assert!(
            (l1 - l2).abs() < 2e-2 * (1.0 + l1.abs()),
            "iter {i1}: uncoded {l1} vs coded {l2}"
        );
    }
}

#[test]
fn survives_dead_workers_up_to_min_redundancy() {
    let n = 5;
    let seed = suite_seed(7);
    let (_, dim) = mlp_setup(n, seed);
    // All blocks tolerate ≥ 2 stragglers.
    let mut sizes = vec![0usize; n];
    sizes[2] = dim / 2;
    sizes[4] = dim - dim / 2;
    let report = run_once(BlockPartition::new(sizes), n, 15, vec![1, 3], seed);
    let first = report.first_loss().unwrap();
    let last = report.final_loss().unwrap();
    assert!(last < first, "loss {first} -> {last}");
    assert!(report.failed_workers.contains(&1));
    assert!(report.failed_workers.contains(&3));
}

#[test]
fn stalls_are_detected_not_hung() {
    let n = 4;
    let (ds, dim) = mlp_setup(n, 9);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    // Level-0 block cannot tolerate any dead worker.
    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, 0, dim));
    cfg.steps = 3;
    cfg.dead_workers = vec![2];
    cfg.seed = 9;
    cfg.stall_timeout = std::time::Duration::from_millis(500);
    let err = train_stationary(cfg, Box::new(Deterministic::new(1.0)), factory).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("unrecoverable") || msg.contains("stalled"), "{msg}");
}

#[test]
fn real_pacing_mode_runs() {
    let n = 4;
    let (ds, dim) = mlp_setup(n, 13);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut sizes = vec![0usize; n];
    sizes[1] = dim;
    let mut cfg = TrainConfig::new(spec, BlockPartition::new(sizes));
    cfg.steps = 5;
    cfg.eval_every = 5;
    cfg.seed = 13;
    // Tiny scale so the test stays fast but sleeps actually happen.
    cfg.pacing = PacingMode::RealScaled { ns_per_unit: 0.05 };
    let report = train_stationary(cfg, Box::new(Deterministic::new(1.0)), factory).unwrap();
    assert_eq!(report.steps(), 5);
}

#[test]
fn virtual_runtime_metrics_recorded() {
    let n = 4;
    let seed = suite_seed(17);
    let (_, dim) = mlp_setup(n, seed);
    let report = run_once(BlockPartition::single_level(n, 1, dim), n, 10, vec![], seed);
    let stats = report.virtual_runtime_stats();
    assert_eq!(stats.count(), 10);
    assert!(stats.mean() > 0.0);
    assert!(report.decode_cache_misses >= 1);
    assert!(report.decode_ns_stats().mean() > 0.0);
}

#[test]
fn eval_every_zero_disables_loss_curve() {
    let n = 4;
    let (ds, dim) = mlp_setup(n, 19);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, 1, dim));
    cfg.steps = 4;
    cfg.eval_every = 0;
    let report =
        train_stationary(cfg, Box::new(Deterministic::new(1.0)), factory).unwrap();
    assert!(report.loss_curve.is_empty());
}

#[test]
#[allow(deprecated)]
fn deprecated_trainer_shim_still_runs() {
    // The pre-pool `Trainer` survives as a shim for one release; it
    // must keep producing the same kind of report as `train()`.
    use bcgc::coordinator::trainer::Trainer;
    let n = 4;
    let (ds, dim) = mlp_setup(n, 29);
    let factory = host_factory(ds, HostModel::Mlp { hidden: 16 });
    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut cfg = TrainConfig::new(spec, BlockPartition::single_level(n, 1, dim));
    cfg.steps = 3;
    cfg.eval_every = 0;
    cfg.seed = 29;
    let report = Trainer::new(cfg, Box::new(Deterministic::new(1.0)), factory).run().unwrap();
    assert_eq!(report.steps(), 3);
}

#[test]
fn wire_buffer_pool_amortizes_to_zero_allocations() {
    // Steady state of the zero-copy data plane: coded-block wire buffers
    // come from the shared freelist, so pool misses (fresh allocations)
    // plateau at the in-flight high-water mark while hits grow with the
    // iteration count — i.e. zero per-block heap allocation once warm.
    let n = 4;
    let steps = 40;
    let seed = suite_seed(31);
    let (_, dim) = mlp_setup(n, seed);
    let mut sizes = vec![0usize; n];
    sizes[1] = dim / 2;
    sizes[2] = dim - dim / 2;
    let report = run_once(BlockPartition::new(sizes), n, steps, vec![], seed);
    let blocks = 2u64;
    let sent = (steps * n) as u64 * blocks;
    assert_eq!(report.wire_pool_hits + report.wire_pool_misses, sent);
    // In-flight bound: at most N buffers queued per block plus slack for
    // the decode-then-recycle window — independent of `steps`.
    assert!(
        report.wire_pool_misses <= 3 * n as u64 * blocks,
        "pool misses did not plateau: {} misses over {} sends",
        report.wire_pool_misses,
        sent
    );
    assert!(report.wire_pool_hits > 4 * report.wire_pool_misses);
    assert!(report.wire_pool_returned >= report.wire_pool_hits);
}

#[test]
fn decoded_gradient_norm_matches_direct_sum() {
    // One iteration from θ0 = 0: the recorded grad_norm must equal the
    // norm of the directly-computed Σ_i g_i.
    let n = 4;
    let seed = suite_seed(23);
    let ds = synthetic::classification(8, 4, 16 * n, n, 0.2, seed).unwrap();
    let dim = HostExecutor::mlp_dim(8, 16, 4);
    let factory = host_factory(ds.clone(), HostModel::Mlp { hidden: 16 });

    let spec = ProblemSpec::new(n, dim, 16 * n, 1.0);
    let mut sizes = vec![0usize; n];
    sizes[1] = dim / 2;
    sizes[2] = dim - dim / 2;
    let mut cfg = TrainConfig::new(spec, BlockPartition::new(sizes));
    cfg.steps = 1;
    cfg.eval_every = 0;
    cfg.init_scale = 0.0; // θ0 = 0
    cfg.seed = seed;
    let report = train_stationary(cfg, Box::new(Deterministic::new(1.0)), factory).unwrap();

    let mut exec = HostExecutor::new(ds, HostModel::Mlp { hidden: 16 }).unwrap();
    let theta0 = vec![0.0f32; dim];
    let mut g = vec![0.0f64; dim];
    for s in 0..n {
        for (acc, v) in g.iter_mut().zip(exec.grad_shard(&theta0, s).unwrap()) {
            *acc += v as f64;
        }
    }
    let norm: f64 = g.iter().map(|x| x * x).sum::<f64>().sqrt();
    assert!(norm > 0.0);
    assert!(
        (report.iters[0].grad_norm - norm).abs() < 1e-6 * (1.0 + norm),
        "decoded {} vs direct {}",
        report.iters[0].grad_norm,
        norm
    );
}
