//! Cross-module integration: optimizer → codec → simulator agree with
//! the analytic runtime model, and the full solve-evaluate loop
//! reproduces the paper's qualitative ordering on a small instance.

use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::CycleTimeDistribution;
use bcgc::optimizer::evaluate::{compare_schemes, reduction_vs_best_baseline};
use bcgc::optimizer::runtime_model::{tau_hat, ProblemSpec, WorkModel};
use bcgc::optimizer::solver::{solve, SchemeKind, SolveOptions};
use bcgc::sim::{simulate_iteration, SimConfig};
use bcgc::util::rng::Rng;

#[test]
fn solver_to_simulator_consistency() {
    // For every scheme the facade produces, the event simulator's playout
    // matches the closed-form Eq. (5) on fresh random draws.
    let spec = ProblemSpec::paper_default(10, 1000);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(101);
    let opts = SolveOptions::fast();
    for kind in [
        SchemeKind::ClosedFormTime,
        SchemeKind::ClosedFormFreq,
        SchemeKind::SingleBlock,
        SchemeKind::FerdinandFull,
        SchemeKind::Uncoded,
    ] {
        let p = solve(&spec, &dist, kind, &opts, &mut rng).unwrap();
        for _ in 0..50 {
            let times = dist.sample_vec(10, &mut rng);
            let sim = simulate_iteration(&spec, &p, &times, &SimConfig::default());
            let closed = tau_hat(&spec, &p.as_f64(), &times, WorkModel::GradientCoding);
            assert!(
                (sim.completion_time - closed).abs() < 1e-9 * closed.max(1.0),
                "{}: sim {} vs closed {}",
                kind.label(),
                sim.completion_time,
                closed
            );
        }
    }
}

#[test]
fn paper_qualitative_ordering_small_instance() {
    // Proposed ≼ single-BCGC ≼ uncoded, and a meaningful reduction vs the
    // best baseline — Fig. 4's story at a test-sized operating point.
    let spec = ProblemSpec::paper_default(12, 2000);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(55);
    let opts = SolveOptions::fast();

    let mut schemes = Vec::new();
    for kind in [
        SchemeKind::ClosedFormFreq,
        SchemeKind::SingleBlock,
        SchemeKind::TandonAlpha,
        SchemeKind::FerdinandFull,
        SchemeKind::Uncoded,
    ] {
        schemes.push((
            kind.label().to_string(),
            solve(&spec, &dist, kind, &opts, &mut rng).unwrap(),
        ));
    }
    let rows = compare_schemes(&spec, &schemes, &dist, 6000, &mut rng);
    let proposed = rows[0].mean();
    let single = rows[1].mean();
    let uncoded = rows[4].mean();
    assert!(proposed <= single * 1.001, "proposed {proposed} vs single {single}");
    assert!(single < uncoded, "single {single} vs uncoded {uncoded}");
    let baselines: Vec<f64> = rows[1..].iter().map(|r| r.mean()).collect();
    let red = reduction_vs_best_baseline(proposed, &baselines);
    assert!(red > 5.0, "expected a meaningful reduction, got {red:.1}%");
}

#[test]
fn config_file_drives_experiment() {
    use bcgc::config::{ExperimentConfig, TomlDoc};
    let doc = TomlDoc::parse(
        r#"
        name = "itest"
        workers = 6
        coords = 600
        trials = 200
        seed = 3
        [distribution]
        kind = "shifted_exp"
        mu = 1e-3
        t0 = 50
        "#,
    )
    .unwrap();
    let cfg = ExperimentConfig::from_doc(&doc).unwrap();
    let spec = cfg.spec();
    let dist = cfg.distribution.build();
    let mut rng = Rng::new(cfg.seed);
    let p = solve(&spec, dist.as_ref(), SchemeKind::ClosedFormFreq, &SolveOptions::fast(), &mut rng)
        .unwrap();
    assert_eq!(p.total(), 600);
    let stats = bcgc::optimizer::runtime_model::expected_runtime(
        &spec, &p, dist.as_ref(), cfg.trials, &mut rng,
    );
    assert!(stats.mean() > 0.0);
}

#[test]
fn mds_vs_gc_work_model_crossover() {
    // Sanity of the Ferdinand transplant: under the MDS work model its
    // own allocation is optimal (equalized), but evaluated under the GC
    // model it is strictly worse than the GC closed form.
    let spec = ProblemSpec::paper_default(10, 2000);
    let dist = ShiftedExponential::new(1e-3, 50.0);
    let mut rng = Rng::new(77);
    let opts = SolveOptions::fast();
    let gc = solve(&spec, &dist, SchemeKind::ClosedFormTime, &opts, &mut rng).unwrap();
    let mds = solve(&spec, &dist, SchemeKind::FerdinandFull, &opts, &mut rng).unwrap();
    let rows = compare_schemes(
        &spec,
        &[("gc".into(), gc), ("mds".into(), mds)],
        &dist,
        6000,
        &mut rng,
    );
    assert!(
        rows[0].mean() < rows[1].mean(),
        "GC closed form {} should beat MDS transplant {}",
        rows[0].mean(),
        rows[1].mean()
    );
}
