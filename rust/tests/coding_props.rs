//! Property tests over the gradient-coding codec (proptest-lite runner).

use bcgc::coding::decoder::{decode, decode_vector};
use bcgc::coding::encoder::GradientCode;
use bcgc::coding::scheme::CodingScheme;
use bcgc::optimizer::blocks::BlockPartition;
use bcgc::testing::{gens, Runner};

/// Encode all workers' contributions for random shard gradients.
fn contributions(code: &GradientCode, grads: &[Vec<f64>]) -> Vec<Vec<f64>> {
    (0..code.n)
        .map(|w| {
            let held: Vec<&[f64]> =
                code.supports[w].iter().map(|&i| grads[i].as_slice()).collect();
            code.encode(w, &held)
        })
        .collect()
}

#[test]
fn prop_exact_recovery_random_survivor_sets() {
    Runner::new(150, 0xC0DE).run("exact-recovery", |rng| {
        let n = gens::usize_in(rng, 2, 12);
        let s = gens::usize_in(rng, 0, n - 1);
        let dim = gens::usize_in(rng, 1, 5);
        let code = GradientCode::cyclic_mds(n, s, rng).map_err(|e| e.to_string())?;
        let grads: Vec<Vec<f64>> =
            (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
        let want: Vec<f64> = (0..dim).map(|d| grads.iter().map(|g| g[d]).sum()).collect();
        let contribs = contributions(&code, &grads);
        // Random survivor set of exactly N − s workers.
        let survivors = rng.sample_indices(n, n - s);
        let a = decode_vector(&code, &survivors).map_err(|e| e.to_string())?;
        let picked: Vec<&[f64]> = survivors.iter().map(|&w| contribs[w].as_slice()).collect();
        let got = decode(&a, &picked);
        for d in 0..dim {
            let err = (got[d] - want[d]).abs() / (1.0 + want[d].abs());
            if err > 1e-5 {
                return Err(format!(
                    "n={n} s={s} S={survivors:?} dim {d}: got {} want {} (err {err:.2e})",
                    got[d], want[d]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decode_vector_supported_on_survivors_only() {
    Runner::new(80, 0xD0DE).run("decode-support", |rng| {
        let n = gens::usize_in(rng, 3, 10);
        let s = gens::usize_in(rng, 1, n - 1);
        let code = GradientCode::cyclic_mds(n, s, rng).map_err(|e| e.to_string())?;
        let survivors = rng.sample_indices(n, n - s);
        let a = decode_vector(&code, &survivors).map_err(|e| e.to_string())?;
        if a.len() != n - s {
            return Err(format!("decode vector length {} != {}", a.len(), n - s));
        }
        // aᵀ·B_S must reproduce the all-ones row exactly.
        let b_s = code.b.select_rows(&survivors);
        let recon = b_s.vecmat(&a);
        if recon.iter().any(|r| (r - 1.0).abs() > 1e-6) {
            return Err(format!("aᵀB_S != 1: {recon:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_fractional_repetition_group_structure() {
    Runner::new(60, 0xF0F0).run("frac-rep", |rng| {
        // Pick (s+1) | N pairs.
        let s = gens::usize_in(rng, 1, 4);
        let groups = gens::usize_in(rng, 1, 4);
        let n = (s + 1) * groups;
        let code = GradientCode::fractional_repetition(n, s).map_err(|e| e.to_string())?;
        let grads: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.normal()]).collect();
        let want: f64 = grads.iter().map(|g| g[0]).sum();
        let contribs = contributions(&code, &grads);
        let survivors = rng.sample_indices(n, n - s);
        let a = decode_vector(&code, &survivors).map_err(|e| e.to_string())?;
        let picked: Vec<&[f64]> = survivors.iter().map(|&w| contribs[w].as_slice()).collect();
        let got = decode(&a, &picked);
        if (got[0] - want).abs() > 1e-9 * (1.0 + want.abs()) {
            return Err(format!("got {} want {want}", got[0]));
        }
        // Frac-rep decode vectors are 0/1 selections.
        if a.iter().any(|&v| v != 0.0 && v != 1.0) {
            return Err(format!("non-binary decode vector {a:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_scheme_block_encode_consistent_with_code_encode() {
    Runner::new(60, 0xABCD).run("scheme-encode", |rng| {
        let n = gens::usize_in(rng, 2, 8);
        let coords = gens::usize_in(rng, n, 60);
        let x = gens::feasible_x(rng, n, coords as f64);
        let blocks = bcgc::optimizer::rounding::round_to_blocks(&x, coords);
        let scheme = CodingScheme::new(blocks, rng).map_err(|e| e.to_string())?;
        let max_s = scheme.blocks().max_level();
        let w = gens::usize_in(rng, 0, n - 1);
        // Full-length shard grads for the worker's held subsets.
        let shard_grads: Vec<Vec<f64>> = (0..max_s + 1)
            .map(|_| (0..coords).map(|_| rng.normal()).collect())
            .collect();
        for r in scheme.ranges() {
            let fast = scheme.encode_block_range(w, &r, &shard_grads);
            // Slow path: restrict then use the code's generic encode.
            let restricted: Vec<Vec<f64>> = shard_grads[..r.s + 1]
                .iter()
                .map(|g| g[r.start..r.end].to_vec())
                .collect();
            let refs: Vec<&[f64]> = restricted.iter().map(|v| v.as_slice()).collect();
            let slow = scheme.code(r.s).encode(w, &refs);
            for (a, b) in fast.iter().zip(slow.iter()) {
                if (a - b).abs() > 1e-12 {
                    return Err(format!("encode mismatch at block s={}", r.s));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_theorem1_s_x_bijection() {
    Runner::new(120, 0x1234).run("theorem1-bijection", |rng| {
        let n = gens::usize_in(rng, 2, 10);
        let l = gens::usize_in(rng, 1, 200);
        let s = gens::monotone_s(rng, n, l);
        let p = BlockPartition::from_s_vector(n, &s).map_err(|e| e.to_string())?;
        if p.s_vector() != s {
            return Err("s → x → s roundtrip failed".into());
        }
        if p.total() != l {
            return Err("total mismatch".into());
        }
        Ok(())
    });
}
