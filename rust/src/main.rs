//! `bcgc` — launcher CLI for the block coordinate gradient coding system.
//!
//! Subcommands:
//! * `optimize`  — compute a scheme's block partition for given (N, L, μ, t0).
//! * `compare`   — expected-runtime table of all schemes at one operating point.
//! * `simulate`  — discrete-event playout of one iteration.
//! * `adaptive`  — multi-iteration adaptive-vs-static playout under a
//!                 drifting straggler distribution (optionally emits JSON).
//! * `train`     — run coded distributed GD (host or PJRT backend), with
//!                 optional mid-training drift and online re-optimization.
//! * `multi`     — run several concurrent training jobs on ONE shared
//!                 worker pool (the multi-job coordinator).
//! * `serve-worker` — join a TCP master as one worker peer (`tcp`
//!                 feature; pairs with `train --transport tcp`).
//! * `artifacts` — list the AOT artifact manifest.
//!
//! Unknown or misspelled options are a hard error (`Args::check_unused`).

use std::sync::Arc;

use bcgc::cli::Args;
use bcgc::coordinator::adaptive::{AdaptiveConfig, HeteroConfig};
use bcgc::coordinator::pool::{JobSpec, PoolConfig, ScheduleMode, WorkerPool};
use bcgc::coordinator::straggler::StragglerSchedule;
use bcgc::coordinator::trainer::{train, train_fleet, ElasticConfig, TrainConfig};
use bcgc::coordinator::PacingMode;
use bcgc::data::synthetic;
use bcgc::distribution::fit::FamilyPolicy;
use bcgc::distribution::runtime_dist::OrderStatConfig;
use bcgc::distribution::shifted_exp::ShiftedExponential;
use bcgc::distribution::weibull::Weibull;
use bcgc::optimizer::closed_form;
use bcgc::optimizer::evaluate::{compare_schemes, reduction_vs_best_baseline};
use bcgc::optimizer::runtime_model::ProblemSpec;
use bcgc::optimizer::solver::{self, SchemeKind, SolveOptions};
use bcgc::runtime::{host, host_factory, pjrt_factory};
use bcgc::sim::{compare_adaptive_vs_static, simulate_iteration, MultiSimConfig, SimConfig};
use bcgc::util::rng::Rng;
use bcgc::{bench_harness::Table, Result};

fn main() {
    bcgc::util::logging::init();
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> Result<()> {
    let out = match args.subcommand() {
        Some("optimize") => cmd_optimize(args),
        Some("compare") => cmd_compare(args),
        Some("simulate") => cmd_simulate(args),
        Some("adaptive") => cmd_adaptive(args),
        Some("train") => cmd_train(args),
        Some("multi") => cmd_multi(args),
        Some("serve-worker") => cmd_serve_worker(args),
        Some("artifacts") => cmd_artifacts(args),
        _ => {
            print_usage();
            return Ok(());
        }
    };
    // A command that succeeded while silently ignoring options the user
    // passed is a lie — typos fail loudly instead.
    out.and_then(|()| args.check_unused())
}

fn print_usage() {
    println!(
        "bcgc — optimization-based block coordinate gradient coding\n\n\
         USAGE: bcgc <subcommand> [options]\n\n\
         SUBCOMMANDS\n\
           optimize   --workers N --coords L [--mu 1e-3 --t0 50 --scheme x_f|x_t|subgradient|...]\n\
           compare    --workers N --coords L [--mu 1e-3 --t0 50 --trials 2000]\n\
           simulate   --workers N --coords L [--mu 1e-3 --t0 50 --comm-latency 0]\n\
           adaptive   --workers N --coords L [--iters 450 --shift-at 150 --mu 1e-2 --mu2 1e-3\n\
                       --grace 50 --window 400 --check-every 10 --json BENCH_adaptive.json]\n\
                      [--family auto|shifted-exp|weibull|empirical]  (estimator family policy)\n\
                      [--dist2 weibull --shape2 0.7 --scale2 1000 --shift2 50]  (heavy-tail phase 1)\n\
           train      --workers N [--steps 100 --lr 0.01 --model mlp|linreg --backend host|pjrt]\n\
                      [--shift-at K --mu2 M --t0-2 T  --adaptive [--adapt-window W --adapt-every K\n\
                       --family auto|shifted-exp|weibull|empirical]]\n\
                      [--elastic [--churn-at K --churn-count 1 --arrive-at K2 --arrive-count 1\n\
                       --churn-threshold 1]]  (elastic pool: re-dimensions N on membership change)\n\
                      [--hetero [--slow-factor 4 --slow-count N/2 --hetero-min-samples 24\n\
                       --hetero-window 128]]  (2-speed fleet + per-worker sensing, fleet-model\n\
                       re-solve and speed-weighted shards; implies --adaptive)\n\
           multi      --jobs 2 --workers 8 [--steps 60 --steps2 S --lr 2e-3 --mu 1e-3 --t0 50\n\
                       --schedule round_robin|weighted --adaptive --elastic --churn-at K\n\
                       --config file.toml]  (K concurrent jobs on ONE shared worker pool)\n\
           serve-worker --addr HOST:PORT [--workers N --model mlp|linreg --seed S ...]\n\
                       (tcp feature: join a `train --transport tcp` master as one peer;\n\
                        pass the SAME model/dataset flags as the master's train command)\n\
           artifacts  [--dir artifacts]\n\n\
         `train` also takes --transport inproc|tcp; tcp binds --listen (default 127.0.0.1:0)\n\
         and waits for N `serve-worker` peers [--lease-ttl-ms 1000 --heartbeat-ms 250].\n"
    );
}

fn scheme_kind(name: &str) -> Result<SchemeKind> {
    Ok(match name {
        "subgradient" | "x_dag" => SchemeKind::OptimalSubgradient,
        "x_t" | "time" => SchemeKind::ClosedFormTime,
        "x_f" | "freq" => SchemeKind::ClosedFormFreq,
        "single" | "single-bcgc" => SchemeKind::SingleBlock,
        "tandon" => SchemeKind::TandonAlpha,
        "ferdinand" | "ferdinand-l" => SchemeKind::FerdinandFull,
        "ferdinand-l2" => SchemeKind::FerdinandHalf,
        "uncoded" => SchemeKind::Uncoded,
        other => {
            return Err(bcgc::Error::InvalidArgument(format!("unknown scheme {other:?}")))
        }
    })
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let n: usize = args.get("workers", 20)?;
    let coords: usize = args.get("coords", 20_000)?;
    let mu: f64 = args.get("mu", 1e-3)?;
    let t0: f64 = args.get("t0", 50.0)?;
    let kind = scheme_kind(args.value("scheme").unwrap_or("x_f"))?;
    let spec = ProblemSpec::paper_default(n, coords);
    let dist = ShiftedExponential::new(mu, t0);
    let mut rng = Rng::new(args.get("seed", 2021u64)?);
    let p = solver::solve(&spec, &dist, kind, &SolveOptions::default(), &mut rng)?;
    println!("scheme : {}", kind.label());
    println!("blocks : {p}");
    println!("levels : {:?}", p.sizes());
    let stats =
        bcgc::optimizer::runtime_model::expected_runtime(&spec, &p, &dist, 4000, &mut rng);
    println!("E[runtime] ≈ {:.1} ± {:.1}", stats.mean(), stats.ci95_half_width());
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    // Either --config <file.toml> (see configs/) or inline flags.
    let (spec, dist, trials, seed): (ProblemSpec, Box<dyn bcgc::distribution::CycleTimeDistribution>, usize, u64) =
        if let Some(path) = args.value("config") {
            let cfg = bcgc::config::ExperimentConfig::load(std::path::Path::new(path))?;
            println!("experiment: {} ({})", cfg.name, cfg.distribution.build().label());
            (cfg.spec(), cfg.distribution.build(), cfg.trials, cfg.seed)
        } else {
            let n: usize = args.get("workers", 20)?;
            let coords: usize = args.get("coords", 20_000)?;
            let mu: f64 = args.get("mu", 1e-3)?;
            let t0: f64 = args.get("t0", 50.0)?;
            (
                ProblemSpec::paper_default(n, coords),
                Box::new(ShiftedExponential::new(mu, t0)),
                args.get("trials", 2000)?,
                args.get("seed", 2021u64)?,
            )
        };
    let mut rng = Rng::new(seed);
    let opts = SolveOptions::default();

    let mut schemes = Vec::new();
    for kind in SchemeKind::proposed().into_iter().chain(SchemeKind::baselines()) {
        let p = solver::solve(&spec, dist.as_ref(), kind, &opts, &mut rng)?;
        schemes.push((kind.label().to_string(), p));
    }
    let rows = compare_schemes(&spec, &schemes, dist.as_ref(), trials, &mut rng);
    let mut table = Table::new(&["scheme", "E[runtime]", "95% CI", "levels used"]);
    for (row, (_, p)) in rows.iter().zip(schemes.iter()) {
        table.row(&[
            row.label.clone(),
            format!("{:.1}", row.mean()),
            format!("±{:.1}", row.stats.ci95_half_width()),
            format!("{}", p.levels_used()),
        ]);
    }
    table.print();
    let ours = rows[..3].iter().map(|r| r.mean()).fold(f64::INFINITY, f64::min);
    let base: Vec<f64> = rows[3..].iter().map(|r| r.mean()).collect();
    println!(
        "\nbest proposed vs best baseline: {:.1}% reduction",
        reduction_vs_best_baseline(ours, &base)
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let n: usize = args.get("workers", 20)?;
    let coords: usize = args.get("coords", 20_000)?;
    let mu: f64 = args.get("mu", 1e-3)?;
    let t0: f64 = args.get("t0", 50.0)?;
    let comm: f64 = args.get("comm-latency", 0.0)?;
    let spec = ProblemSpec::paper_default(n, coords);
    let dist = ShiftedExponential::new(mu, t0);
    let mut rng = Rng::new(args.get("seed", 2021u64)?);
    let p = solver::solve(
        &spec,
        &dist,
        SchemeKind::ClosedFormFreq,
        &SolveOptions::default(),
        &mut rng,
    )?;
    use bcgc::distribution::CycleTimeDistribution;
    let times = dist.sample_vec(n, &mut rng);
    let out = simulate_iteration(&spec, &p, &times, &SimConfig { comm_latency: comm });
    println!("blocks            : {p}");
    println!("completion time   : {:.2}", out.completion_time);
    println!("messages (late)   : {} ({})", out.messages, out.late_messages);
    println!("block decode times: {:?}", out.block_decode_times);
    Ok(())
}

fn cmd_adaptive(args: &Args) -> Result<()> {
    // Phase-1 Weibull knobs are only read with `--dist2 weibull`;
    // declared so they are inert (not "unknown") without it.
    args.declare(&["shape2", "scale2", "shift2"]);
    let n: usize = args.get("workers", 20)?;
    let coords: usize = args.get("coords", 20_000)?;
    let iters: usize = args.get("iters", 450)?;
    let shift_at: usize = args.get("shift-at", 150)?;
    let grace: usize = args.get("grace", 50)?;
    let mu: f64 = args.get("mu", 1e-2)?;
    let t0: f64 = args.get("t0", 50.0)?;
    let mu2: f64 = args.get("mu2", 1e-3)?;
    let t0b: f64 = args.get("t0-2", t0)?;
    let seed: u64 = args.get("seed", 2021)?;
    if shift_at == 0 || shift_at >= iters {
        return Err(bcgc::Error::InvalidArgument(
            "--shift-at must lie strictly inside (0, --iters)".into(),
        ));
    }

    let spec = ProblemSpec::paper_default(n, coords);
    let d0 = ShiftedExponential::new(mu, t0);
    // Phase 1 may be a heavy-tailed shifted Weibull (`--dist2 weibull`):
    // the scenario the distribution-agnostic re-solve exists for. The
    // oracle partition is solved from the true phase-1 model either way.
    let weibull_phase = args.value("dist2") == Some("weibull") || args.value("shape2").is_some();
    let (schedule, oracle) = if weibull_phase {
        let d1 = Weibull::new(
            args.get("shape2", 0.7)?,
            args.get("scale2", 1.0 / mu2)?,
            args.get("shift2", t0b)?,
        );
        let oracle =
            closed_form::x_freq_blocks_model(&spec, &d1, coords, &OrderStatConfig::default())?;
        (
            StragglerSchedule::stationary(Box::new(d0.clone())).then(shift_at, Box::new(d1)),
            oracle,
        )
    } else {
        let d1 = ShiftedExponential::new(mu2, t0b);
        let oracle = closed_form::x_freq_blocks(&spec, &d1, coords)?;
        (
            StragglerSchedule::stationary(Box::new(d0.clone())).then(shift_at, Box::new(d1)),
            oracle,
        )
    };
    let initial = closed_form::x_freq_blocks(&spec, &d0, coords)?;
    println!("schedule        : {}", schedule.label());
    println!("initial x^(f)   : {initial}");
    println!("oracle  x^(f)   : {oracle}");

    let family_arg = args.value("family").unwrap_or("auto");
    let family = FamilyPolicy::parse(family_arg).ok_or_else(|| {
        bcgc::Error::InvalidArgument(format!(
            "--family {family_arg:?}: expected auto|shifted-exp|weibull|empirical"
        ))
    })?;
    let acfg = AdaptiveConfig {
        window: args.get("window", 20 * n)?,
        check_every: args.get("check-every", 10)?,
        cooldown: args.get("cooldown", 20)?,
        min_samples: args.get("min-samples", 10 * n)?,
        drift_threshold: args.get("drift-threshold", 0.2)?,
        family,
        ..Default::default()
    };
    let sim_cfg = MultiSimConfig { iters, seed, comm_latency: args.get("comm-latency", 0.0)? };
    let json_path = args.value("json").map(str::to_string);
    // Every option is parsed by now: fail on typos BEFORE simulating.
    args.check_unused()?;
    let cmp = compare_adaptive_vs_static(
        &spec,
        &initial,
        Some(&oracle),
        &schedule,
        &sim_cfg,
        acfg,
        grace,
    )?;

    print!("{}", cmp.render_report());
    if let Some(path) = json_path {
        let json = bcgc::bench_harness::stamp_bench_meta(
            &cmp.render_json(),
            seed,
            &format!("N={n} L={coords} iters={iters} shift_at={shift_at} family={family_arg}"),
        );
        std::fs::write(&path, json)?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    // Documented options read only inside conditional branches below —
    // declared up front so an inert-but-valid flag is not diagnosed as
    // a typo by check_unused.
    args.declare(&[
        "features",
        "hidden",
        "classes",
        "artifact-dir",
        "entry",
        "mu2",
        "t0-2",
        "ns-per-unit",
        "family",
        "adapt-window",
        "adapt-every",
        "adapt-cooldown",
        "adapt-min-samples",
        "drift-threshold",
        "churn-threshold",
        "churn-count",
        "arrive-count",
        "slow-factor",
        "slow-count",
        "hetero-min-samples",
        "hetero-window",
        "listen",
        "lease-ttl-ms",
        "heartbeat-ms",
        "accept-timeout-ms",
    ]);
    let n: usize = args.get("workers", 8)?;
    let steps: usize = args.get("steps", 100)?;
    let lr: f64 = args.get("lr", 0.02)?;
    let mu: f64 = args.get("mu", 1e-3)?;
    let t0: f64 = args.get("t0", 50.0)?;
    let model = args.value("model").unwrap_or("mlp").to_string();
    let backend = args.value("backend").unwrap_or("host").to_string();
    let seed: u64 = args.get("seed", 2021)?;

    let (factory, dim) = match (model.as_str(), backend.as_str()) {
        ("linreg", "host") => {
            let d: usize = args.get("features", 128)?;
            let (ds, _) = synthetic::linear_regression(d, n * 64, n, 0.05, seed)?;
            (host_factory(ds, host::HostModel::LinearRegression), d)
        }
        ("mlp", "host") => {
            let d: usize = args.get("features", 32)?;
            let h: usize = args.get("hidden", 64)?;
            let c: usize = args.get("classes", 10)?;
            let ds = synthetic::classification(d, c, n * 64, n, 0.2, seed)?;
            (host_factory(ds, host::HostModel::Mlp { hidden: h }), host::HostExecutor::mlp_dim(d, h, c))
        }
        (m, "pjrt") => {
            let dir = std::path::PathBuf::from(args.value("artifact-dir").unwrap_or("artifacts"));
            let manifest = bcgc::runtime::artifact::Manifest::load(&dir)?;
            let entry_name = args
                .value("entry")
                .map(str::to_string)
                .unwrap_or_else(|| {
                    manifest
                        .names()
                        .find(|nm| nm.starts_with(m))
                        .unwrap_or("mlp_d64_h256_c10_s128")
                        .to_string()
                });
            let e = manifest.get(&entry_name)?.clone();
            let ds = if e.kind == "linreg" {
                synthetic::linear_regression(e.features, e.shard * n, n, 0.05, seed)?.0
            } else {
                synthetic::classification(e.features, e.targets, e.shard * n, n, 0.2, seed)?
            };
            (pjrt_factory(dir, entry_name, ds), e.param_dim)
        }
        (m, b) => {
            return Err(bcgc::Error::InvalidArgument(format!(
                "unsupported model/backend combo {m}/{b}"
            )))
        }
    };

    let spec = ProblemSpec::new(n, dim, n * 64, 1.0);
    let dist = ShiftedExponential::new(mu, t0);
    let mut rng = Rng::new(seed);
    let blocks = solver::solve(
        &spec,
        &dist,
        scheme_kind(args.value("scheme").unwrap_or("x_f"))?,
        &SolveOptions::fast(),
        &mut rng,
    )?;
    println!("blocks: {blocks}");

    // Optional mid-training drift + online re-optimization.
    let shift_at: usize = args.get("shift-at", 0)?;
    let schedule = if shift_at > 0 {
        let mu2: f64 = args.get("mu2", mu)?;
        let t02: f64 = args.get("t0-2", t0)?;
        StragglerSchedule::stationary(Box::new(dist.clone()))
            .then(shift_at, Box::new(ShiftedExponential::new(mu2, t02)))
    } else {
        StragglerSchedule::stationary(Box::new(dist.clone()))
    };

    let mut cfg = TrainConfig::new(spec, blocks);
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.eval_every = args.get("eval-every", 10)?;
    cfg.seed = seed;
    if args.flag("real-pacing") {
        cfg.pacing = PacingMode::RealScaled { ns_per_unit: args.get("ns-per-unit", 50.0)? };
    }
    // --transport tcp: bind a listener here (before the pool exists)
    // so N `bcgc serve-worker --addr <printed>` peers can connect and
    // queue in the accept backlog while the pool attaches them.
    match args.value("transport").unwrap_or("inproc") {
        "inproc" => {}
        #[cfg(feature = "tcp")]
        "tcp" => {
            let listen = args.value("listen").unwrap_or("127.0.0.1:0");
            let tcp = bcgc::transport::tcp::TcpTransportConfig {
                listener: Arc::new(std::net::TcpListener::bind(listen)?),
                lease_ttl_ms: args.get("lease-ttl-ms", 1000)?,
                heartbeat_ms: args.get("heartbeat-ms", 250)?,
                accept_timeout_ms: args.get("accept-timeout-ms", 30_000)?,
            };
            println!(
                "listen: {} — waiting for {n} peers (`bcgc serve-worker --addr <that>`)",
                tcp.addr()?
            );
            cfg.transport = bcgc::transport::TransportConfig::Tcp(tcp);
        }
        #[cfg(not(feature = "tcp"))]
        "tcp" => {
            return Err(bcgc::Error::InvalidArgument(
                "--transport tcp needs the framed-TCP transport; rebuild with --features tcp"
                    .into(),
            ))
        }
        other => {
            return Err(bcgc::Error::InvalidArgument(format!(
                "--transport {other:?}: expected inproc|tcp"
            )))
        }
    }
    // --hetero: a 2-speed fleet plus the heterogeneity-aware engine
    // (per-worker sensing, fleet-model re-solve, speed-weighted
    // shards). It is an extension of the adaptive policy, so it
    // implies --adaptive.
    let hetero = args.flag("hetero");
    if args.flag("adaptive") || hetero {
        let d = AdaptiveConfig::default();
        let family_arg = args.value("family").unwrap_or("auto");
        let family = FamilyPolicy::parse(family_arg).ok_or_else(|| {
            bcgc::Error::InvalidArgument(format!(
                "--family {family_arg:?}: expected auto|shifted-exp|weibull|empirical"
            ))
        })?;
        let hd = HeteroConfig::default();
        cfg.adaptive = Some(AdaptiveConfig {
            window: args.get("adapt-window", d.window)?,
            check_every: args.get("adapt-every", d.check_every)?,
            cooldown: args.get("adapt-cooldown", d.cooldown)?,
            min_samples: args.get("adapt-min-samples", d.min_samples)?,
            drift_threshold: args.get("drift-threshold", d.drift_threshold)?,
            family,
            hetero: hetero.then_some(HeteroConfig {
                per_worker_window: args.get("hetero-window", hd.per_worker_window)?,
                min_worker_samples: args.get("hetero-min-samples", hd.min_worker_samples)?,
                speed_weighted_shards: true,
            }),
            ..d
        });
    }
    // Elastic worker pool: scheduled churn + membership-driven
    // re-dimensioning of the scheme.
    if args.flag("elastic") || args.value("churn-at").is_some() || args.value("arrive-at").is_some()
    {
        let mut e = ElasticConfig {
            churn_threshold: args.get("churn-threshold", 1)?,
            ..Default::default()
        };
        if args.value("churn-at").is_some() {
            let at: usize = args.require("churn-at")?;
            let count: usize = args.get("churn-count", 1)?;
            if at == 0 || at >= steps {
                return Err(bcgc::Error::InvalidArgument(
                    "--churn-at must lie strictly inside (0, --steps)".into(),
                ));
            }
            if count >= n {
                return Err(bcgc::Error::InvalidArgument(
                    "--churn-count must leave at least one worker".into(),
                ));
            }
            e.departures.push((at, count));
        }
        if args.value("arrive-at").is_some() {
            let at: usize = args.require("arrive-at")?;
            if at == 0 || at >= steps {
                return Err(bcgc::Error::InvalidArgument(
                    "--arrive-at must lie strictly inside (0, --steps)".into(),
                ));
            }
            e.arrivals.push((at, args.get("arrive-count", 1)?));
        }
        cfg.elastic = Some(e);
    }
    // The 2-speed fleet behind --hetero: the first N−slow_count ids
    // keep the base model, the rest are slow-factor× slower.
    let fleet = if hetero {
        let slow_factor: f64 = args.get("slow-factor", 4.0)?;
        let slow_count: usize = args.get("slow-count", n / 2)?;
        if slow_count >= n {
            return Err(bcgc::Error::InvalidArgument(
                "--slow-count must leave at least one fast worker".into(),
            ));
        }
        if slow_factor < 1.0 {
            return Err(bcgc::Error::InvalidArgument(
                "--slow-factor must be ≥ 1".into(),
            ));
        }
        println!(
            "fleet : {} fast {} + {slow_count} slow ({slow_factor}× slower)",
            n - slow_count,
            bcgc::distribution::CycleTimeDistribution::label(&dist),
        );
        Some(bcgc::sim::two_speed_fleet(n, slow_count, &dist, slow_factor))
    } else {
        None
    };
    // Every option is parsed by now: fail on typos BEFORE training.
    args.check_unused()?;
    let report = match fleet {
        Some(fleet) => train_fleet(cfg, schedule, fleet, factory)?,
        None => train(cfg, schedule, factory)?,
    };
    println!("{}", report.summary());
    if report.scheme_epochs.len() > 1 {
        println!("\nscheme epochs:\n{}", report.render_epochs());
    }
    if !report.membership.is_empty() {
        println!("\nmembership:\n{}", report.render_membership());
    }
    println!("\nloss curve:\n{}", report.render_loss_curve());
    Ok(())
}

/// `bcgc multi` — several concurrent training jobs multiplexed over
/// ONE shared worker pool. Each job is a host-backend MLP over its own
/// synthetic dataset and its own `x^(f)` scheme; the pool interleaves
/// per-iteration broadcasts under the chosen scheduler and reports
/// per-job summaries plus the shared virtual makespan.
fn cmd_multi(args: &Args) -> Result<()> {
    use bcgc::distribution::CycleTimeDistribution;
    // Pool/job dimensioning: inline flags, optionally seeded from a
    // `[pool]`/`[jobs]` config file.
    let cfg_file = args
        .value("config")
        .map(|p| bcgc::config::ExperimentConfig::load(std::path::Path::new(p)))
        .transpose()?;
    let pool_cfg_file = cfg_file.as_ref().and_then(|c| c.pool.clone());
    let jobs_cfg_file = cfg_file.as_ref().and_then(|c| c.jobs.clone());

    let n: usize = args.get(
        "workers",
        pool_cfg_file.as_ref().and_then(|p| p.workers).unwrap_or(8),
    )?;
    let jobs: usize =
        args.get("jobs", jobs_cfg_file.as_ref().map(|j| j.count).unwrap_or(2))?;
    if jobs == 0 {
        return Err(bcgc::Error::InvalidArgument("--jobs must be ≥ 1".into()));
    }
    let steps0: usize = args.get(
        "steps",
        jobs_cfg_file.as_ref().and_then(|j| j.steps.first().copied()).unwrap_or(60),
    )?;
    let steps2: usize = args.get("steps2", 0)?;
    let lr: f64 = args.get("lr", 2e-3)?;
    let mu: f64 = args.get("mu", 1e-3)?;
    let t0: f64 = args.get("t0", 50.0)?;
    let seed: u64 = args.get("seed", 2021)?;
    let schedule_arg = args
        .value("schedule")
        .map(str::to_string)
        .or_else(|| pool_cfg_file.as_ref().map(|p| p.schedule.clone()))
        .unwrap_or_else(|| "round_robin".into());
    let schedule_mode = ScheduleMode::parse(&schedule_arg).ok_or_else(|| {
        bcgc::Error::InvalidArgument(format!(
            "--schedule {schedule_arg:?}: expected round_robin|weighted"
        ))
    })?;
    // Per-job step counts: [jobs].steps from the config, then --steps
    // (all jobs) with --steps2 overriding job 1.
    let mut steps: Vec<usize> = (0..jobs)
        .map(|j| {
            jobs_cfg_file
                .as_ref()
                .and_then(|c| c.steps.get(j).copied())
                .unwrap_or(steps0)
        })
        .collect();
    if steps2 > 0 && jobs >= 2 {
        steps[1] = steps2;
    }

    let dist = ShiftedExponential::new(mu, t0);
    let mut pcfg = PoolConfig::new(n);
    pcfg.seed = seed;
    pcfg.schedule = schedule_mode;
    if args.flag("elastic") || args.value("churn-at").is_some() {
        let mut e = ElasticConfig {
            churn_threshold: args.get("churn-threshold", 1)?,
            ..Default::default()
        };
        if args.value("churn-at").is_some() {
            let at: usize = args.require("churn-at")?;
            let count: usize = args.get("churn-count", 1)?;
            if count >= n {
                return Err(bcgc::Error::InvalidArgument(
                    "--churn-count must leave at least one worker".into(),
                ));
            }
            e.departures.push((at, count));
        }
        pcfg.elastic = Some(e);
    }
    // Adaptive policy: `[adaptive]` (+ its `[hetero]` extension) from
    // the config file when declared there, the default policy under a
    // bare `--adaptive` flag.
    let config_adaptive: Option<AdaptiveConfig> = cfg_file
        .as_ref()
        .map(|c| c.adaptive_config())
        .transpose()?
        .flatten();
    let adaptive_cfg: Option<AdaptiveConfig> = if config_adaptive.is_some() {
        config_adaptive
    } else if args.flag("adaptive") {
        Some(AdaptiveConfig::default())
    } else {
        None
    };
    args.declare(&["adaptive", "churn-threshold", "churn-count"]);
    // Every option is parsed by now: fail on typos BEFORE training.
    args.check_unused()?;
    let mut pool = WorkerPool::new(pcfg, StragglerSchedule::stationary(Box::new(dist.clone())))?;

    let (d, h, c, shard) = (32usize, 64usize, 10usize, 64usize);
    let dim = host::HostExecutor::mlp_dim(d, h, c);
    println!(
        "pool   : N={n} workers, schedule={}, stragglers {}",
        schedule_mode.name(),
        dist.label()
    );
    for (j, &job_steps) in steps.iter().enumerate() {
        // Each tenant owns its dataset (distinct seed) and its own
        // x^(f) scheme solved for the shared pool's N.
        let job_seed = seed.wrapping_add(1 + j as u64);
        let ds = synthetic::classification(d, c, shard * n, n, 0.2, job_seed)?;
        let factory = host_factory(ds, host::HostModel::Mlp { hidden: h });
        let spec = ProblemSpec::new(n, dim, shard * n, 1.0);
        let mut rng = Rng::new(job_seed);
        let blocks = solver::solve(
            &spec,
            &dist,
            SchemeKind::ClosedFormFreq,
            &SolveOptions::fast(),
            &mut rng,
        )?;
        let mut js = JobSpec::new(spec, blocks)
            .steps(job_steps)
            .lr(lr)
            .eval_every((job_steps / 4).max(1))
            .seed(job_seed)
            .executor(factory);
        if let Some(a) = adaptive_cfg.clone() {
            js = js.adaptive(a);
        }
        let id = js.submit(&mut pool)?;
        println!("job {id}  : {d}-feature {c}-class MLP, L={dim}, {job_steps} steps");
    }

    pool.run_all()?;
    let makespan = pool.virtual_makespan();
    let rounds = pool.rounds();
    let cross = pool.cross_job_dropped();
    let reports = pool.finish()?;

    let mut table = Table::new(&[
        "job", "steps", "epochs", "E[virt]/iter", "loss first→last", "cache hit",
    ]);
    for (j, r) in reports.iter().enumerate() {
        table.row(&[
            j.to_string(),
            r.steps().to_string(),
            r.epochs().to_string(),
            format!("{:.1}", r.virtual_runtime_stats().mean()),
            format!(
                "{}→{}",
                r.first_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
                r.final_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
            ),
            format!("{}/{}", r.decode_cache_hits, r.decode_cache_hits + r.decode_cache_misses),
        ]);
    }
    table.print();
    println!(
        "\nshared pool: {rounds} rounds, virtual makespan {makespan:.0}, \
         cross-job drops {cross}"
    );
    for (j, r) in reports.iter().enumerate() {
        assert!(
            r.iters.iter().all(|m| m.grad_norm.is_finite()),
            "job {j} decoded a non-finite gradient"
        );
    }
    Ok(())
}

/// `bcgc serve-worker` — run ONE worker peer against a TCP master.
///
/// The wire never carries executor factories (only job ids), so the
/// peer rebuilds the master's job-0 dataset and model locally: invoke
/// it with the SAME --workers/--model/--features/--hidden/--classes/
/// --seed the master's `train --transport tcp` was given, or the
/// gradients it computes will be for a different problem.
#[cfg(feature = "tcp")]
fn cmd_serve_worker(args: &Args) -> Result<()> {
    use bcgc::transport::tcp::{serve_worker, FactoryRegistry};
    args.declare(&["features", "hidden", "classes"]);
    let addr: String = args.require("addr")?;
    let n: usize = args.get("workers", 8)?;
    let seed: u64 = args.get("seed", 2021)?;
    let model = args.value("model").unwrap_or("mlp").to_string();
    let factory = match model.as_str() {
        "linreg" => {
            let d: usize = args.get("features", 128)?;
            let (ds, _) = synthetic::linear_regression(d, n * 64, n, 0.05, seed)?;
            host_factory(ds, host::HostModel::LinearRegression)
        }
        "mlp" => {
            let d: usize = args.get("features", 32)?;
            let h: usize = args.get("hidden", 64)?;
            let c: usize = args.get("classes", 10)?;
            let ds = synthetic::classification(d, c, n * 64, n, 0.2, seed)?;
            host_factory(ds, host::HostModel::Mlp { hidden: h })
        }
        other => {
            return Err(bcgc::Error::InvalidArgument(format!(
                "serve-worker supports host models mlp|linreg, not {other:?}"
            )))
        }
    };
    // Fail on typos BEFORE blocking on the connect retry loop.
    args.check_unused()?;
    let registry = FactoryRegistry::new();
    registry.register(0, factory);
    println!("peer  : connecting to {addr} ({model}, N={n}, seed {seed})");
    let wire = serve_worker(addr.as_str(), registry)?;
    println!(
        "peer  : drained — tx {}f/{}B rx {}f/{}B",
        wire.frames_sent, wire.bytes_sent, wire.frames_recv, wire.bytes_recv
    );
    Ok(())
}

#[cfg(not(feature = "tcp"))]
fn cmd_serve_worker(_args: &Args) -> Result<()> {
    Err(bcgc::Error::InvalidArgument(
        "serve-worker needs the framed-TCP transport; rebuild with --features tcp".into(),
    ))
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let dir = std::path::PathBuf::from(args.value("dir").unwrap_or("artifacts"));
    let manifest = bcgc::runtime::artifact::Manifest::load(&dir)?;
    let mut table = Table::new(&["entry", "kind", "features", "targets", "shard", "param_dim"]);
    for name in manifest.names() {
        let e = manifest.get(name)?;
        table.row(&[
            e.name.clone(),
            e.kind.clone(),
            e.features.to_string(),
            e.targets.to_string(),
            e.shard.to_string(),
            e.param_dim.to_string(),
        ]);
    }
    table.print();
    let _ = Arc::new(()); // keep Arc import local usage
    Ok(())
}
