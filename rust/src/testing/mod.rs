//! Property-testing mini-framework (no `proptest`/`quickcheck` offline).
//!
//! A [`Runner`] drives N seeded cases through a user property; failures
//! are re-reported with the generating seed so they can be replayed by
//! constructing `Rng::new(seed)`. Generators are just closures over
//! [`Rng`]; [`gens`] collects the common ones used by the test suites.

use crate::util::rng::Rng;

/// Property-test driver.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Runner {
    fn default() -> Self {
        // Fixed seed: deterministic CI. Override locally to fuzz more.
        Self { cases: 100, seed: 0xBC6C }
    }
}

impl Runner {
    pub fn new(cases: usize, seed: u64) -> Self {
        Self { cases, seed }
    }

    /// Run `prop` on `cases` independently-seeded RNGs. The property
    /// returns `Err(message)` to fail; panics are *not* caught (they
    /// still identify the case via the logged seed in the message of
    /// `assert!` calls the caller writes).
    pub fn run<F>(&self, name: &str, mut prop: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        let mut meta = Rng::new(self.seed);
        for case in 0..self.cases {
            let case_seed = meta.next_u64();
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property {name:?} failed on case {case} (replay with Rng::new({case_seed:#x})): {msg}"
                );
            }
        }
    }
}

/// Common generators.
pub mod gens {
    use super::*;

    /// Integer in `[lo, hi]`.
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// Float in `[lo, hi)`.
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.uniform_range(lo, hi)
    }

    /// A monotone nondecreasing redundancy vector `s` of length `l` with
    /// levels `< n` (Lemma-1-shaped input).
    pub fn monotone_s(rng: &mut Rng, n: usize, l: usize) -> Vec<usize> {
        let mut s: Vec<usize> = (0..l).map(|_| rng.below(n as u64) as usize).collect();
        s.sort_unstable();
        s
    }

    /// Arbitrary (not necessarily monotone) redundancy vector.
    pub fn any_s(rng: &mut Rng, n: usize, l: usize) -> Vec<usize> {
        (0..l).map(|_| rng.below(n as u64) as usize).collect()
    }

    /// A strictly positive, strictly increasing time vector of length `n`.
    pub fn increasing_times(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut t = Vec::with_capacity(n);
        let mut acc = 0.01 + rng.uniform() * 10.0;
        for _ in 0..n {
            acc += 0.01 + rng.exponential(1.0);
            t.push(acc);
        }
        t
    }

    /// Positive i.i.d. times (unsorted).
    pub fn positive_times(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| 0.01 + rng.exponential(0.5)).collect()
    }

    /// A feasible continuous block vector (`x ≥ 0`, `Σx = l`).
    pub fn feasible_x(rng: &mut Rng, n: usize, l: f64) -> Vec<f64> {
        let raw: Vec<f64> = (0..n).map(|_| rng.exponential(1.0)).collect();
        let sum: f64 = raw.iter().sum();
        raw.iter().map(|&v| v / sum * l).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runner_passes_trivial_property() {
        Runner::default().run("trivial", |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn runner_reports_failures_with_seed() {
        Runner::new(3, 1).run("always-fails", |_| Err("boom".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        Runner::default().run("gen-bounds", |rng| {
            let n = gens::usize_in(rng, 2, 9);
            if !(2..=9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let s = gens::monotone_s(rng, n, 30);
            if s.windows(2).any(|w| w[0] > w[1]) {
                return Err("monotone_s not monotone".into());
            }
            if s.iter().any(|&v| v >= n) {
                return Err("monotone_s out of range".into());
            }
            let t = gens::increasing_times(rng, n);
            if t.windows(2).any(|w| w[0] >= w[1]) {
                return Err("times not strictly increasing".into());
            }
            let x = gens::feasible_x(rng, n, 100.0);
            let sum: f64 = x.iter().sum();
            if (sum - 100.0).abs() > 1e-9 || x.iter().any(|&v| v < 0.0) {
                return Err("feasible_x infeasible".into());
            }
            Ok(())
        });
    }
}
