//! Property-testing mini-framework (no `proptest`/`quickcheck` offline).
//!
//! A [`Runner`] drives N seeded cases through a user property; failures
//! are re-reported with the generating seed so they can be replayed by
//! constructing `Rng::new(seed)`. Generators are just closures over
//! [`Rng`]; [`gens`] collects the common ones used by the test suites.
//!
//! ## Environment knobs (CI replay / nightly fuzzing)
//!
//! [`Runner::default`] honors two environment variables, so a CI
//! failure is replayable locally and a nightly job can crank case
//! counts without code edits:
//!
//! * `BCGC_PROP_SEED` — the meta seed (decimal, or hex with an `0x`
//!   prefix). Every case seed derives from it, so one value pins the
//!   whole run: `BCGC_PROP_SEED=0xBC6C cargo test` reproduces the
//!   default CI stream, and CI's seed-matrix step sweeps several
//!   values to surface seed/timing-dependent flakes.
//! * `BCGC_PROP_CASES` — the number of cases per property (≥ 1):
//!   `BCGC_PROP_CASES=10000 cargo test` for a fuzzing pass.
//!
//! A malformed value is a loud panic, never a silent fall-back — a
//! typo'd replay seed must not quietly re-run the default stream.
//! Suites built on explicit seeds rather than the runner (the threaded
//! e2e tests) derive theirs through [`suite_seed`], which mixes
//! `BCGC_PROP_SEED` into each test's default.

use crate::util::rng::Rng;

/// Parse a runner knob: decimal, or hex with an `0x`/`0X` prefix.
fn parse_u64_knob(name: &str, raw: &str) -> u64 {
    let s = raw.trim();
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse::<u64>(),
    };
    match parsed {
        Ok(v) => v,
        Err(_) => panic!(
            "{name}={raw:?}: expected a u64 (decimal or 0x-prefixed hex) — refusing to \
             silently run the default stream"
        ),
    }
}

fn env_knob(name: &str) -> Option<u64> {
    std::env::var(name).ok().map(|raw| parse_u64_knob(name, &raw))
}

/// Mix `BCGC_PROP_SEED` (when set) into an explicitly-seeded test's
/// default seed — the hook the threaded e2e suites use so the CI
/// seed-matrix re-runs them on genuinely different streams while the
/// default invocation stays bit-identical to the historical one.
pub fn suite_seed(default: u64) -> u64 {
    match env_knob("BCGC_PROP_SEED") {
        // splitmix-style mix: distinct (env, default) pairs land far
        // apart, and default ^ 0 keeps nothing magic about zero.
        Some(env) => {
            let mut z = env
                .wrapping_add(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(default.wrapping_mul(0xBF58_476D_1CE4_E5B9));
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        }
        None => default,
    }
}

/// Property-test driver.
pub struct Runner {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Runner {
    fn default() -> Self {
        // Fixed seed: deterministic CI. `BCGC_PROP_SEED` /
        // `BCGC_PROP_CASES` override without code edits (see module
        // docs).
        let cases = match env_knob("BCGC_PROP_CASES") {
            Some(0) => panic!("BCGC_PROP_CASES must be ≥ 1"),
            Some(c) => c as usize,
            None => 100,
        };
        let seed = env_knob("BCGC_PROP_SEED").unwrap_or(0xBC6C);
        Self { cases, seed }
    }
}

impl Runner {
    pub fn new(cases: usize, seed: u64) -> Self {
        Self { cases, seed }
    }

    /// Run `prop` on `cases` independently-seeded RNGs. The property
    /// returns `Err(message)` to fail; panics are *not* caught (they
    /// still identify the case via the logged seed in the message of
    /// `assert!` calls the caller writes).
    pub fn run<F>(&self, name: &str, mut prop: F)
    where
        F: FnMut(&mut Rng) -> Result<(), String>,
    {
        let mut meta = Rng::new(self.seed);
        for case in 0..self.cases {
            let case_seed = meta.next_u64();
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = prop(&mut rng) {
                panic!(
                    "property {name:?} failed on case {case} (replay with Rng::new({case_seed:#x})): {msg}"
                );
            }
        }
    }
}

/// Common generators.
pub mod gens {
    use super::*;

    /// Integer in `[lo, hi]`.
    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo + 1) as u64) as usize
    }

    /// Float in `[lo, hi)`.
    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.uniform_range(lo, hi)
    }

    /// A monotone nondecreasing redundancy vector `s` of length `l` with
    /// levels `< n` (Lemma-1-shaped input).
    pub fn monotone_s(rng: &mut Rng, n: usize, l: usize) -> Vec<usize> {
        let mut s: Vec<usize> = (0..l).map(|_| rng.below(n as u64) as usize).collect();
        s.sort_unstable();
        s
    }

    /// Arbitrary (not necessarily monotone) redundancy vector.
    pub fn any_s(rng: &mut Rng, n: usize, l: usize) -> Vec<usize> {
        (0..l).map(|_| rng.below(n as u64) as usize).collect()
    }

    /// A strictly positive, strictly increasing time vector of length `n`.
    pub fn increasing_times(rng: &mut Rng, n: usize) -> Vec<f64> {
        let mut t = Vec::with_capacity(n);
        let mut acc = 0.01 + rng.uniform() * 10.0;
        for _ in 0..n {
            acc += 0.01 + rng.exponential(1.0);
            t.push(acc);
        }
        t
    }

    /// Positive i.i.d. times (unsorted).
    pub fn positive_times(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| 0.01 + rng.exponential(0.5)).collect()
    }

    /// A feasible continuous block vector (`x ≥ 0`, `Σx = l`).
    pub fn feasible_x(rng: &mut Rng, n: usize, l: f64) -> Vec<f64> {
        let raw: Vec<f64> = (0..n).map(|_| rng.exponential(1.0)).collect();
        let sum: f64 = raw.iter().sum();
        raw.iter().map(|&v| v / sum * l).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_parsing_accepts_decimal_and_hex_and_rejects_garbage() {
        assert_eq!(parse_u64_knob("X", "123"), 123);
        assert_eq!(parse_u64_knob("X", "0xBC6C"), 0xBC6C);
        assert_eq!(parse_u64_knob("X", "0XFF"), 255);
        assert_eq!(parse_u64_knob("X", "  42 "), 42);
        let err = std::panic::catch_unwind(|| parse_u64_knob("BCGC_PROP_SEED", "fast"));
        assert!(err.is_err(), "garbage must panic, not silently default");
    }

    #[test]
    fn suite_seed_is_the_default_without_the_env_knob() {
        // The tests never *set* the variable (env is process-global and
        // the suite runs multi-threaded); absence is the testable
        // branch, and the mixing function is exercised via its
        // determinism under the CI seed matrix.
        if std::env::var("BCGC_PROP_SEED").is_err() {
            assert_eq!(suite_seed(11), 11);
            assert_eq!(suite_seed(0), 0);
        }
    }

    #[test]
    fn runner_passes_trivial_property() {
        Runner::default().run("trivial", |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("uniform out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\" failed")]
    fn runner_reports_failures_with_seed() {
        Runner::new(3, 1).run("always-fails", |_| Err("boom".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        Runner::default().run("gen-bounds", |rng| {
            let n = gens::usize_in(rng, 2, 9);
            if !(2..=9).contains(&n) {
                return Err(format!("usize_in out of range: {n}"));
            }
            let s = gens::monotone_s(rng, n, 30);
            if s.windows(2).any(|w| w[0] > w[1]) {
                return Err("monotone_s not monotone".into());
            }
            if s.iter().any(|&v| v >= n) {
                return Err("monotone_s out of range".into());
            }
            let t = gens::increasing_times(rng, n);
            if t.windows(2).any(|w| w[0] >= w[1]) {
                return Err("times not strictly increasing".into());
            }
            let x = gens::feasible_x(rng, n, 100.0);
            let sum: f64 = x.iter().sum();
            if (sum - 100.0).abs() > 1e-9 || x.iter().any(|&v| v < 0.0) {
                return Err("feasible_x infeasible".into());
            }
            Ok(())
        });
    }
}
