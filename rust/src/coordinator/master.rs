//! Per-job decode state: broadcast, collect, decode-on-arrival.
//!
//! One [`Master`] is the decode engine of **one job** on the shared
//! worker pool — it is keyed by `(job, epoch)`: it owns the job's
//! **current scheme epoch** ([`Master::install_scheme`] swaps in a
//! re-optimized — possibly re-*dimensioned* (different `N`) —
//! [`CodingScheme`] between iterations together with that epoch's roster
//! (row → stable worker id binding)), and its collect path rejects
//! contributions stamped with a superseded epoch exactly like
//! stale-iteration messages — coded blocks from two different codes must
//! never mix into one decode. Contributions whose id↔row binding does
//! not match the live roster are dropped the same way (a drained
//! worker's row may belong to someone else next epoch), as are
//! contributions stamped with **another job's id** (each job has its own
//! code; cross-job codewords are as corrupting as cross-epoch ones).
//!
//! Collection is **resumable** so the pool can multiplex one event
//! channel across jobs: [`Master::begin_collect`] opens an iteration,
//! [`Master::offer`] feeds it one event at a time (returning whether the
//! full gradient is assembled), and [`Master::take_outcome`] closes it.
//! The single-consumer convenience [`Master::collect`] drives a whole
//! iteration off a private receiver — the shape the master-level tests
//! use.
//!
//! All quorum accounting is **row**-indexed (rows are what the code's
//! survivor sets are made of); stable worker ids appear only at the
//! roster boundary and in the membership signals surfaced through
//! [`IterOutcome`].
//!
//! The decode-vector cache lives for the whole life of the job: its map
//! is reset on every epoch swap (decode vectors are specific to one
//! code's coefficients) but its **hit/miss counters accumulate across
//! epochs**, so a job's end-of-run cache statistics describe the whole
//! run, not just the last scheme.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::decoder::{decode_into, decode_into_add, decode_vector_ls, DecodeCache};
use crate::coding::scheme::CodingScheme;
use crate::coordinator::channel::{
    BlockContribution, JobId, PartialBlockContribution, ShardMap, SliceMap, WorkerEvent,
    WorkerTask,
};
use crate::runtime::ExecutorFactory;
use crate::transport::TaskSender;
use crate::util::buffers::{BufferPool, PoolStats};
use crate::{Error, Result};

/// Outcome of one collected iteration.
pub struct IterOutcome {
    /// The exact full gradient `Σ_n g_n`.
    pub gradient: Vec<f64>,
    /// Wall ns the master spent inside decode solves/combines.
    pub decode_ns: u64,
    /// Contributions that arrived after their block had decoded.
    pub late_contributions: usize,
    /// Contributions encoded under a superseded scheme epoch (dropped
    /// before they could touch a decode).
    pub stale_epoch: usize,
    /// Current-epoch contributions whose (worker id, row) stamp did not
    /// match the live roster binding (dropped).
    pub mismatched_binding: usize,
    /// Contributions stamped with a different job's id (dropped — the
    /// pool normally routes by job before they reach a master, so a
    /// nonzero count means a misrouted or forged codeword was refused).
    pub cross_job: usize,
    /// Workers (stable ids) that reported a **fatal** failure (their
    /// thread exited; exclude them from every job's future quorum
    /// accounting). Transient per-iteration failures only affect the
    /// current iteration's satisfiability bookkeeping.
    pub failed: Vec<usize>,
    /// Workers (stable ids) that announced a ready thread this
    /// iteration — joins the registry should confirm for the next
    /// epoch rebind.
    pub joined: Vec<usize>,
    /// Workers (stable ids) that drained cleanly this iteration;
    /// mid-iteration this was accounted like a fatal straggler.
    pub left: Vec<usize>,
    /// Blocks applied from a semi-async **least-squares approximate**
    /// decode (quorum short only of deeply-backlogged rows); empty in
    /// fully-exact mode. Each entry's exact quorum is tracked in the
    /// master's pending-reconcile set until it lands or is discarded.
    pub approx: Vec<ApproxDecode>,
    /// Streamed per-part coded deltas accepted into a rotation-part
    /// quorum this iteration (0 when streaming is off).
    pub partial_contributions: usize,
    /// Blocks whose decode completed through the rotation-part path
    /// (every part folded via [`decode_into_add`]) rather than a
    /// whole-contribution quorum.
    pub partial_blocks: usize,
}

/// Semi-asynchronous decode policy: when a block's quorum is short only
/// of deeply-backlogged rows, the master may apply a least-squares
/// approximate decode now and reconcile (or discard) when the exact
/// quorum lands. Convergence survives the bounded decode error
/// (Stochastic Gradient Coding, Bitar et al.), which is exactly the
/// slack an overlapped pipeline needs.
#[derive(Debug, Clone)]
pub struct SemiAsyncConfig {
    /// Maximum rows a quorum may be short by for an approximate decode
    /// (0 disables semi-async decoding).
    pub max_shortfall: usize,
    /// A row counts as *deeply backlogged* when its queued virtual time
    /// exceeds this multiple of the job's expected round time (the
    /// pool's dispatch layer computes the mask).
    pub backlog_factor: f64,
    /// Skip the approximation when the least-squares residual
    /// `‖B_Sᵀa − 1‖₂` exceeds this (the decode error is bounded by
    /// `residual · ‖G‖_F`).
    pub max_residual: f64,
}

impl Default for SemiAsyncConfig {
    fn default() -> Self {
        Self { max_shortfall: 1, backlog_factor: 2.0, max_residual: 0.5 }
    }
}

/// One block applied from a least-squares approximate decode.
#[derive(Debug, Clone)]
pub struct ApproxDecode {
    pub block_idx: usize,
    /// Survivors the least-squares solve used.
    pub used: usize,
    /// Rows short of the exact quorum (`need − used`).
    pub shortfall: usize,
    /// `‖B_Sᵀa − 1‖₂` of the least-squares solve.
    pub residual: f64,
    /// Tracked error bound `residual · sqrt(Σ_{j∈S}‖c_j‖₂²)` — the
    /// observable surrogate for `residual · ‖G‖_F` (it uses the coded
    /// contributions' energy in place of the unobserved gradients').
    pub bound: f64,
}

/// A completed reconciliation: the exact quorum landed for a block that
/// was applied approximately. `delta = exact − approximate` over the
/// block's coordinate range; the job applies `θ[start..end] −= lr·delta`
/// ([`crate::coordinator::state::ModelState::correct`]), landing θ where
/// an exact decode would have put it.
#[derive(Debug, Clone)]
pub struct ReconcileOutcome {
    pub iter: usize,
    pub block_idx: usize,
    /// Coordinate range of the block in the job's gradient/θ.
    pub start: usize,
    pub end: usize,
    pub delta: Vec<f64>,
    /// The bound that was tracked while the approximation was live.
    pub bound: f64,
}

/// An approximately-decoded block waiting for its exact quorum: the
/// retained arrivals, the applied approximate block gradient, and the
/// scheme coordinates needed to finish the exact decode later.
struct PendingReconcile {
    iter: usize,
    block_idx: usize,
    start: usize,
    end: usize,
    need: usize,
    /// Redundancy level — fetches the right per-level code for the
    /// exact decode.
    s: usize,
    arrivals: Vec<(usize, Vec<f32>)>,
    approx: Vec<f64>,
    bound: f64,
}

struct BlockState {
    need: usize,
    arrivals: Vec<(usize, Vec<f32>)>, // (row, coded f32 wire buffer)
    /// Per rotation part `p`: streamed coded deltas `(row, buffer)` not
    /// yet folded. Emptied (buffers recycled) the moment part `p`'s
    /// quorum fills and its decode lands via [`decode_into_add`].
    part_arrivals: Vec<Vec<(usize, Vec<f32>)>>,
    /// Rotation parts already folded into the gradient slice.
    part_done: Vec<bool>,
    /// How many entries of `part_done` are set.
    parts_decoded: usize,
    /// Per-row bitmask of rotation parts received for this block
    /// (duplicate-part detection + part-path satisfiability). Parts are
    /// capped at 32 ([`MAX_STREAM_PARTS`]).
    psent: Vec<u32>,
    /// Exactly decoded — arrivals recycled, later copies are `late`.
    decoded: bool,
    /// Applied from a least-squares approximate decode; arrivals are
    /// RETAINED so the exact quorum can still assemble (in-collect the
    /// block silently upgrades to exact; at `take_outcome` the leftovers
    /// move into the pending-reconcile set).
    approx: Option<ApproxDecode>,
}

impl BlockState {
    /// Complete for quorum accounting (exact or approximate).
    fn complete(&self) -> bool {
        self.decoded || self.approx.is_some()
    }
}

/// Cap on rotation parts: per-row receipt state is a `u32` bitmask.
pub const MAX_STREAM_PARTS: usize = 32;

/// In-flight state of one iteration's collection.
struct CollectState {
    iter: usize,
    blocks: Vec<BlockState>,
    gradient: Vec<f64>,
    decoded_count: usize,
    late: usize,
    stale_epoch: usize,
    mismatched: usize,
    cross_job: usize,
    decode_ns: u64,
    failed: Vec<usize>,
    joined: Vec<usize>,
    left: Vec<usize>,
    /// Per-(row, block) delivery state: `sent[row][b]` is true once that
    /// row's contribution to block `b` was received this iteration.
    sent: Vec<Vec<bool>>,
    alive: Vec<bool>,
    /// Rows flagged deeply backlogged at dispatch (async engine) — the
    /// only rows a semi-async approximate decode may go short of.
    deep: Vec<bool>,
    /// Semi-async decode policy (`None` = exact decodes only).
    semi: Option<SemiAsyncConfig>,
    /// Rotation parts the iteration was dispatched with (1 = no
    /// streaming; partial frames carrying a different value are
    /// refused like stale epochs).
    parts: usize,
    /// Streamed deltas accepted into a part quorum this iteration.
    partial_contributions: usize,
    /// Blocks completed through the part path this iteration.
    partial_blocks: usize,
}

/// Decode-on-arrival collector; owns the decode-vector cache across
/// iterations *and epochs* (survivor patterns repeat, so cached solves
/// dominate).
pub struct Master {
    job: JobId,
    scheme: Arc<CodingScheme>,
    epoch: usize,
    dim: usize,
    /// Row → stable worker id for the current epoch.
    roster: Vec<usize>,
    /// Subset → dataset shards for the current epoch.
    shards: Arc<ShardMap>,
    /// Sample-granular subset spans overriding `shards` when set (the
    /// sample-level actuation / streaming path); travels with every
    /// broadcast task.
    slices: Option<Arc<SliceMap>>,
    /// Rotation parts for partial-straggler streaming (1 = off).
    parts: usize,
    cache: DecodeCache,
    /// Freelist the wire buffers are recycled into after decode (shared
    /// with the pool's workers when running on a [`WorkerPool`];
    /// otherwise a private pool, so recycling is unconditional).
    ///
    /// [`WorkerPool`]: crate::coordinator::pool::WorkerPool
    wire_pool: BufferPool,
    collect: Option<CollectState>,
    /// Approximately-decoded blocks from closed iterations whose exact
    /// quorum has not landed yet (semi-async mode). Entries are keyed
    /// by `(iter, block)` within the current epoch; an epoch swap
    /// discards them (their arrivals belong to the superseded code).
    pending: Vec<PendingReconcile>,
    /// Completed reconciliations the job has not applied yet.
    reconciled: Vec<ReconcileOutcome>,
    /// Lifetime count of pending reconciles discarded before their
    /// exact quorum landed (epoch swaps, failed solves, shutdown).
    discarded: usize,
    /// Receive timeout before declaring the iteration stalled.
    pub timeout: Duration,
}

impl Master {
    /// A job-0 master whose epoch-0 roster binds row `r` to worker id
    /// `r` and whose subsets are backed 1:1 by dataset shards (the
    /// static-pool identity; elastic sessions install rebound rosters
    /// later).
    pub fn new(scheme: Arc<CodingScheme>, dim: usize) -> Self {
        let n = scheme.n();
        Self::with_roster(scheme, dim, (0..n).collect())
    }

    /// A job-0 master with an explicit epoch-0 roster (row → stable id).
    pub fn with_roster(scheme: Arc<CodingScheme>, dim: usize, roster: Vec<usize>) -> Self {
        Self::for_job(0, scheme, dim, roster)
    }

    /// A master decoding for job `job` on a shared pool.
    pub fn for_job(
        job: JobId,
        scheme: Arc<CodingScheme>,
        dim: usize,
        roster: Vec<usize>,
    ) -> Self {
        assert_eq!(roster.len(), scheme.n(), "roster must bind every code row");
        let shards = Arc::new(identity_shards(scheme.n()));
        Self {
            job,
            scheme,
            epoch: 0,
            dim,
            roster,
            shards,
            slices: None,
            parts: 1,
            cache: DecodeCache::new(4096),
            wire_pool: BufferPool::default(),
            collect: None,
            pending: Vec::new(),
            reconciled: Vec::new(),
            discarded: 0,
            timeout: Duration::from_secs(30),
        }
    }

    /// Share a wire-buffer pool with the workers feeding this master
    /// (the [`WorkerPool`] wires its pool in at submit so decoded
    /// arrival buffers cycle back to the encoders).
    ///
    /// [`WorkerPool`]: crate::coordinator::pool::WorkerPool
    pub fn set_wire_pool(&mut self, pool: BufferPool) {
        self.wire_pool = pool;
    }

    /// Statistics of the wire-buffer pool this master recycles into.
    /// When the pool is shared across a [`WorkerPool`], the counters
    /// are pool-wide (every job and worker on the pool contributes).
    ///
    /// [`WorkerPool`]: crate::coordinator::pool::WorkerPool
    pub fn wire_pool_stats(&self) -> PoolStats {
        self.wire_pool.stats()
    }

    /// Decode-vector cache statistics, accumulated across every scheme
    /// epoch this master has served (`install_scheme` resets the cached
    /// vectors, never the counters).
    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// The job this master decodes for.
    pub fn job(&self) -> JobId {
        self.job
    }

    /// The scheme epoch tasks are currently issued under.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        &self.scheme
    }

    /// The current epoch's roster (row → stable worker id).
    pub fn roster(&self) -> &[usize] {
        &self.roster
    }

    /// The current epoch's subset → dataset shards mapping.
    pub fn shard_map(&self) -> &Arc<ShardMap> {
        &self.shards
    }

    /// The current epoch's sample-granular subset spans, if installed.
    pub fn slice_map(&self) -> Option<&Arc<SliceMap>> {
        self.slices.as_ref()
    }

    /// Rotation parts broadcasts are currently issued with (1 = no
    /// streaming).
    pub fn stream_parts(&self) -> usize {
        self.parts
    }

    /// Install sample-granular subset spans (and the rotation-part
    /// count) for subsequent broadcasts; `None` restores the
    /// shard-granular path exactly. Like scheme swaps, this happens
    /// between iterations only.
    pub fn install_slices(&mut self, slices: Option<Arc<SliceMap>>, parts: usize) {
        assert!(self.collect.is_none(), "slice swaps happen between iterations");
        assert!(parts >= 1 && parts <= MAX_STREAM_PARTS, "parts must be in [1, 32]");
        if let Some(s) = &slices {
            assert_eq!(s.len(), self.scheme.n(), "slice map must cover every subset");
        }
        self.slices = slices;
        self.parts = if self.slices.is_some() { parts } else { 1 };
    }

    fn row_of(&self, worker: usize) -> Option<usize> {
        self.roster.iter().position(|&id| id == worker)
    }

    /// Install a new scheme as epoch `epoch`, rebinding rows to
    /// `roster` and subsets to `shards` (pass the previous mappings for
    /// a same-`N` re-optimization). Decode vectors are specific to one
    /// code's coefficients (the cache keys only by `(s, survivor
    /// set)`), so the cache map is reset; hit/miss counters survive
    /// across epochs.
    pub fn install_scheme(
        &mut self,
        scheme: Arc<CodingScheme>,
        epoch: usize,
        roster: Vec<usize>,
        shards: Arc<ShardMap>,
    ) {
        assert!(epoch > self.epoch, "scheme epochs must be monotone");
        assert_eq!(roster.len(), scheme.n(), "roster must bind every code row");
        assert!(self.collect.is_none(), "scheme swaps happen between iterations");
        // Pending reconciles hold arrivals encoded under the superseded
        // code — they can never mix with the new epoch's coefficients.
        self.discard_pending();
        self.scheme = scheme;
        self.epoch = epoch;
        self.roster = roster;
        self.shards = shards;
        // Slice maps are sized to one epoch's subsets; the caller
        // re-installs a fresh one (from the same re-plan that produced
        // the scheme) if sample-granular actuation stays on.
        self.slices = None;
        self.parts = 1;
        self.cache.reset();
    }

    /// Discard every pending reconcile (epoch swap / shutdown),
    /// recycling the retained wire buffers. Returns how many
    /// approximations were abandoned; the lifetime total is
    /// [`Self::approx_discarded`]. Already-completed reconciliations
    /// ([`Self::take_reconciled`]) are kept — their θ-range corrections
    /// stay valid across scheme epochs (the model dimension is fixed).
    pub fn discard_pending(&mut self) -> usize {
        let dropped = self.pending.len();
        for entry in self.pending.drain(..) {
            for (_, buf) in entry.arrivals {
                self.wire_pool.put(buf);
            }
        }
        self.discarded += dropped;
        dropped
    }

    /// Approximately-decoded blocks still waiting for their exact quorum.
    pub fn pending_reconciles(&self) -> usize {
        self.pending.len()
    }

    /// Lifetime count of pending reconciles discarded unreconciled.
    pub fn approx_discarded(&self) -> usize {
        self.discarded
    }

    /// Drain the completed reconciliations (exact quorum landed for a
    /// block that was applied approximately); the job applies each
    /// delta with [`crate::coordinator::state::ModelState::correct`].
    pub fn take_reconciled(&mut self) -> Vec<ReconcileOutcome> {
        std::mem::take(&mut self.reconciled)
    }

    /// Broadcast one iteration's tasks under the current scheme epoch.
    /// `tasks[row]` is the task lane of the worker bound to that row —
    /// an in-process channel or a framed socket ([`crate::transport`]);
    /// `None` for rows whose worker already departed — the coded
    /// scheme absorbs them like any straggler. `times[row]` is its
    /// sampled cycle time; `unit_work` the epoch's `(M/N)·b`; `factory`
    /// builds this job's executor inside workers that have not served
    /// the job yet.
    #[allow(clippy::too_many_arguments)]
    pub fn broadcast(
        &self,
        iter: usize,
        theta: Arc<Vec<f32>>,
        times: &[f64],
        unit_work: f64,
        factory: &ExecutorFactory,
        tasks: &[Option<TaskSender>],
    ) {
        debug_assert_eq!(tasks.len(), self.scheme.n());
        for (row, tx) in tasks.iter().enumerate() {
            let Some(tx) = tx else { continue };
            // A send error just means that worker died; the coded scheme
            // absorbs it like any straggler.
            let _ = tx.send(WorkerTask::Compute {
                job: self.job,
                iter,
                epoch: self.epoch,
                row,
                scheme: self.scheme.clone(),
                shards: self.shards.clone(),
                theta: theta.clone(),
                factory: factory.clone(),
                cycle_time: times[row],
                unit_work,
                slices: self.slices.clone(),
                parts: self.parts,
            });
        }
    }

    /// Open the collection of iteration `iter`.
    ///
    /// `live` flags which **rows** are up at iteration start (dead /
    /// previously failed / departed workers excluded); it seeds the
    /// per-(row, block) outstanding-message tracking used to detect
    /// unrecoverable blocks without waiting for a timeout. Fails fast
    /// when a block already cannot reach quorum.
    pub fn begin_collect(&mut self, iter: usize, live: &[bool]) -> Result<()> {
        self.begin_collect_async(iter, live, &vec![false; live.len()], None)
    }

    /// [`Self::begin_collect`] with the async engine's extras: `deep`
    /// flags rows dispatched behind a deep backlog (the only rows a
    /// semi-async approximate decode may go short of), and `semi` is
    /// the approximate-decode policy (`None` keeps decodes exact).
    pub fn begin_collect_async(
        &mut self,
        iter: usize,
        live: &[bool],
        deep: &[bool],
        semi: Option<SemiAsyncConfig>,
    ) -> Result<()> {
        assert!(self.collect.is_none(), "previous iteration still collecting");
        let ranges = self.scheme.ranges();
        let n = self.scheme.n();
        debug_assert_eq!(live.len(), n);
        debug_assert_eq!(deep.len(), n);
        let parts = self.parts;
        let st = CollectState {
            iter,
            blocks: ranges
                .iter()
                .map(|r| BlockState {
                    need: n - r.s,
                    arrivals: Vec::new(),
                    part_arrivals: vec![Vec::new(); parts],
                    part_done: vec![false; parts],
                    parts_decoded: 0,
                    psent: vec![0u32; n],
                    decoded: false,
                    approx: None,
                })
                .collect(),
            gradient: vec![0.0f64; self.dim],
            decoded_count: 0,
            late: 0,
            stale_epoch: 0,
            mismatched: 0,
            cross_job: 0,
            decode_ns: 0,
            failed: Vec::new(),
            joined: Vec::new(),
            left: Vec::new(),
            sent: vec![vec![false; ranges.len()]; n],
            alive: live.to_vec(),
            deep: deep.to_vec(),
            semi,
            parts,
            partial_contributions: 0,
            partial_blocks: 0,
        };
        // Dead rows are known up front: fail fast when a block can
        // never reach quorum instead of waiting out the stall timeout.
        let r = check_still_satisfiable(&st, iter);
        self.collect = Some(st);
        if r.is_err() {
            self.collect = None;
        }
        r
    }

    /// Whether an iteration is currently being collected.
    pub fn is_collecting(&self) -> bool {
        self.collect.is_some()
    }

    /// Whether the open collection has already decoded every block
    /// (true immediately after `begin_collect` for a degenerate scheme
    /// with nothing to decode).
    pub fn collect_complete(&self) -> bool {
        self.collect
            .as_ref()
            .map(|st| st.decoded_count == st.blocks.len())
            .unwrap_or(false)
    }

    /// Feed one event into the open collection. Returns `true` once
    /// every block of the iteration has decoded (the caller then takes
    /// the outcome with [`Self::take_outcome`]).
    ///
    /// Faithful to §III: block `b` (redundancy `s`) decodes using the
    /// first `N − s` contributions to arrive; later ones are counted as
    /// `late_contributions` and dropped. Contributions stamped with a
    /// superseded scheme epoch are dropped as `stale_epoch`, a foreign
    /// job id as `cross_job`, a roster-mismatched binding as
    /// `mismatched_binding` — all before they can touch a decode. A
    /// [`WorkerEvent::Left`] or fatal failure arriving mid-iteration is
    /// accounted exactly like a fatal straggler: the row goes dead and
    /// satisfiability is re-checked immediately.
    pub fn offer(&mut self, ev: WorkerEvent) -> Result<bool> {
        // lint: allow(panic_hygiene) — API contract: offer outside a collection is a caller bug
        let mut st = self.collect.take().expect("offer outside begin_collect/take_outcome");
        let r = self.offer_inner(&mut st, ev);
        let done = st.decoded_count == st.blocks.len();
        self.collect = Some(st);
        if let Err(e) = r {
            self.collect = None;
            return Err(e);
        }
        Ok(done)
    }

    fn offer_inner(&mut self, st: &mut CollectState, ev: WorkerEvent) -> Result<()> {
        let iter = st.iter;
        match ev {
            WorkerEvent::Joined { worker } => {
                st.joined.push(worker);
            }
            WorkerEvent::Left { worker } => {
                crate::log_info!("worker {worker} drained (iter {iter})");
                st.left.push(worker);
                if let Some(row) = self.row_of(worker) {
                    if st.alive[row] {
                        st.alive[row] = false;
                        check_still_satisfiable(st, iter)?;
                        if st.semi.is_some() {
                            self.try_approx(st);
                        }
                    }
                }
            }
            WorkerEvent::Failed { worker, job, iter: ev_iter, reason, fatal } => {
                crate::log_warn!(
                    "worker {worker} failed in job {job} iter {ev_iter} (fatal={fatal}): {reason}"
                );
                if fatal {
                    st.failed.push(worker);
                }
                // A fatal failure kills the worker whenever its report
                // arrives; a transient one only voids the (job,
                // iteration) it happened in.
                if fatal || (job == self.job && ev_iter == iter) {
                    if let Some(row) = self.row_of(worker) {
                        if st.alive[row] {
                            st.alive[row] = false;
                            check_still_satisfiable(st, iter)?;
                            if st.semi.is_some() {
                                self.try_approx(st);
                            }
                        }
                    }
                }
            }
            WorkerEvent::Block(c) => {
                // Every drop path recycles the wire buffer — whoever
                // drops a contribution returns its buffer to the pool.
                if c.job != self.job {
                    // Another job's codeword: its coefficients belong to
                    // a different code entirely.
                    st.cross_job += 1;
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                if c.iter != iter {
                    // A previous iteration's straggler: in semi-async
                    // mode it may complete a pending reconcile's exact
                    // quorum; otherwise recycle it.
                    if let Some(c) = self.feed_pending(c) {
                        self.wire_pool.put(c.coded);
                    }
                    return Ok(());
                }
                if c.epoch != self.epoch {
                    // Encoded under a superseded scheme: its block
                    // index and coefficients belong to another code.
                    st.stale_epoch += 1;
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                let n = self.scheme.n();
                if c.row >= n || self.roster[c.row] != c.worker {
                    // The id↔row binding no longer matches the live
                    // roster (e.g. a drained worker's leftovers).
                    st.mismatched += 1;
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                self.on_block(st, c)?;
            }
            WorkerEvent::Partial(c) => {
                // Same drop discipline as whole blocks: whoever drops a
                // streamed delta recycles its wire buffer.
                if c.job != self.job {
                    st.cross_job += 1;
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                if c.iter != iter {
                    // A previous iteration's streamed delta can never
                    // complete a pending reconcile (those hold whole
                    // contributions); recycle it outright.
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                if c.epoch != self.epoch || c.parts != st.parts || c.part >= st.parts {
                    // Superseded scheme epoch, or a rotation geometry
                    // from a superseded dispatch — either way the delta
                    // belongs to another round's code.
                    st.stale_epoch += 1;
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                let n = self.scheme.n();
                if c.row >= n || self.roster[c.row] != c.worker {
                    st.mismatched += 1;
                    self.wire_pool.put(c.coded);
                    return Ok(());
                }
                self.on_partial(st, c)?;
            }
        }
        Ok(())
    }

    /// Close the open collection and return its outcome. Panics unless
    /// [`Self::offer`] reported completion.
    pub fn take_outcome(&mut self) -> IterOutcome {
        // lint: allow(panic_hygiene) — API contract: the doc comment promises this panic
        let mut st = self.collect.take().expect("take_outcome without an open collection");
        assert_eq!(st.decoded_count, st.blocks.len(), "collection not complete");
        // Blocks closing on an approximation owe an exact decode: their
        // retained arrivals move into the pending-reconcile set, keyed
        // (iter, block), together with the applied approximate gradient
        // so the eventual reconcile can form `delta = exact − approx`.
        let ranges = self.scheme.ranges();
        let mut approx = Vec::new();
        for (idx, b) in st.blocks.iter_mut().enumerate() {
            // Undecoded streamed deltas buffered behind a completed
            // block (e.g. one that closed on an approximation) are dead
            // weight now — recycle before the state drops.
            for part in b.part_arrivals.iter_mut() {
                for (_, buf) in part.drain(..) {
                    self.wire_pool.put(buf);
                }
            }
            let Some(record) = b.approx.take() else { continue };
            if b.decoded {
                continue; // upgraded in-collect; nothing owed
            }
            let r = &ranges[idx];
            self.pending.push(PendingReconcile {
                iter: st.iter,
                block_idx: idx,
                start: r.start,
                end: r.end,
                need: b.need,
                s: r.s,
                arrivals: std::mem::take(&mut b.arrivals),
                approx: st.gradient[r.start..r.end].to_vec(),
                bound: record.bound,
            });
            approx.push(record);
        }
        IterOutcome {
            gradient: st.gradient,
            decode_ns: st.decode_ns,
            late_contributions: st.late,
            stale_epoch: st.stale_epoch,
            mismatched_binding: st.mismatched,
            cross_job: st.cross_job,
            failed: st.failed,
            joined: st.joined,
            left: st.left,
            approx,
            partial_contributions: st.partial_contributions,
            partial_blocks: st.partial_blocks,
        }
    }

    /// Abort the open collection, if any (shutdown path). Buffered
    /// arrival buffers of undecoded blocks — whole contributions and
    /// streamed rotation deltas alike — go back to the wire pool.
    pub fn abort_collect(&mut self) {
        if let Some(st) = self.collect.take() {
            for block in st.blocks {
                for (_, buf) in block.arrivals {
                    self.wire_pool.put(buf);
                }
                for part in block.part_arrivals {
                    for (_, buf) in part {
                        self.wire_pool.put(buf);
                    }
                }
            }
        }
    }

    /// Collect events for iteration `iter` from a dedicated receiver
    /// until every block decodes — the single-job convenience over
    /// [`Self::begin_collect`] / [`Self::offer`] /
    /// [`Self::take_outcome`]. Multi-job pools route the shared event
    /// channel themselves.
    pub fn collect(
        &mut self,
        iter: usize,
        events: &Receiver<WorkerEvent>,
        live: &[bool],
    ) -> Result<IterOutcome> {
        self.begin_collect(iter, live)?;
        if self.collect_complete() {
            return Ok(self.take_outcome());
        }
        loop {
            let ev = match events.recv_timeout(self.timeout) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    let decoded = self.collect.as_ref().map(|s| s.decoded_count).unwrap_or(0);
                    let total = self.collect.as_ref().map(|s| s.blocks.len()).unwrap_or(0);
                    self.collect = None;
                    return Err(Error::Runtime(format!(
                        "iteration {iter}: stalled ({decoded}/{total} blocks decoded)"
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.collect = None;
                    return Err(Error::Runtime(format!(
                        "iteration {iter}: all workers disconnected"
                    )));
                }
            };
            if self.offer(ev)? {
                return Ok(self.take_outcome());
            }
        }
    }

    fn on_block(&mut self, st: &mut CollectState, c: BlockContribution) -> Result<()> {
        st.sent[c.row][c.block_idx] = true;
        let ranges = self.scheme.ranges();
        let b = &mut st.blocks[c.block_idx];
        if b.decoded {
            st.late += 1;
            self.wire_pool.put(c.coded);
            return Ok(());
        }
        b.arrivals.push((c.row, c.coded));
        if b.arrivals.len() < b.need {
            // Short of the exact quorum. In semi-async mode, see whether
            // any block is now blocked only on deeply-backlogged rows —
            // if so, apply a bounded least-squares approximation now
            // instead of idling behind another job's queue.
            if st.semi.is_some() {
                self.try_approx(st);
            }
            return Ok(());
        }
        // Decode now: the first `need` arrivals are the survivors.
        // Canonicalize to ascending row order — decode vectors are
        // order-aligned, and the cache keys by survivor *set*, so the
        // same set must always be presented in the same order.
        // lint: allow(determinism) — decode_ns metric only; control flow is virtual-time
        let t0 = Instant::now();
        let r = &ranges[c.block_idx];
        b.arrivals.sort_by_key(|(row, _)| *row);
        let survivors: Vec<usize> = b.arrivals.iter().map(|(row, _)| *row).collect();
        // Borrow the cached decode vector without copying it (§Perf opt 3):
        // the scheme handle is an independent Arc, so the cache's mutable
        // borrow of `self` does not conflict.
        let scheme = self.scheme.clone();
        let code = scheme.code(r.s);
        let a = self.cache.get(code, &survivors)?;
        let picked: Vec<&[f32]> = b.arrivals.iter().map(|(_, v)| v.as_slice()).collect();
        // Fused f32→f64 combine straight into the job's preallocated
        // gradient slice — no intermediate decode vector, no copy; the
        // kernel fans large blocks out over scoped threads. An exact
        // quorum landing in-collect silently *upgrades* an approximately
        // decoded block: the exact combine overwrites the approximation
        // and no reconcile is ever owed.
        decode_into(a, &picked, &mut st.gradient[r.start..r.end]);
        let was_approx = b.approx.take().is_some();
        b.decoded = true;
        for (_, buf) in b.arrivals.drain(..) {
            self.wire_pool.put(buf);
        }
        b.arrivals.shrink_to_fit();
        // The overwrite discarded any partially-folded rotation sums;
        // undecoded streamed deltas are redundant now — recycle them.
        for part in b.part_arrivals.iter_mut() {
            for (_, buf) in part.drain(..) {
                self.wire_pool.put(buf);
            }
        }
        if !was_approx {
            st.decoded_count += 1;
        }
        st.decode_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    /// One streamed rotation-part delta. Part `p` of block `b` decodes
    /// the moment `need` distinct rows' part-`p` deltas have arrived —
    /// the code is linear, so the same cached decode vector that
    /// combines whole contributions combines per-part deltas, and the
    /// result **accumulates** onto the block's gradient slice
    /// ([`decode_into_add`]). The block completes once every part has
    /// folded; a whole-contribution quorum landing first wins instead
    /// (its [`decode_into`] overwrite discards the partial sums).
    fn on_partial(&mut self, st: &mut CollectState, c: PartialBlockContribution) -> Result<()> {
        let ranges = self.scheme.ranges();
        let b = &mut st.blocks[c.block_idx];
        if b.decoded || b.part_done[c.part] {
            // The block (or this part) already folded — pure overhead,
            // same as a late whole contribution.
            st.late += 1;
            self.wire_pool.put(c.coded);
            return Ok(());
        }
        if b.approx.is_some() {
            // An approximate decode already occupies the gradient slice;
            // accumulating a part on top would corrupt it, and the
            // pending-reconcile path only understands whole
            // contributions. Count as late overhead.
            st.late += 1;
            self.wire_pool.put(c.coded);
            return Ok(());
        }
        if b.psent[c.row] & (1u32 << c.part) != 0 {
            // Duplicate (retry / requeue): recycle.
            st.late += 1;
            self.wire_pool.put(c.coded);
            return Ok(());
        }
        b.psent[c.row] |= 1u32 << c.part;
        if b.psent[c.row].count_ones() as usize == st.parts {
            // The row has delivered its entire allocation for this
            // block — it owes the block nothing more.
            st.sent[c.row][c.block_idx] = true;
        }
        b.part_arrivals[c.part].push((c.row, c.coded));
        st.partial_contributions += 1;
        if b.part_arrivals[c.part].len() < b.need {
            return Ok(());
        }
        // Part quorum filled: fold it into the gradient slice now.
        // lint: allow(determinism) — decode_ns metric only; control flow is virtual-time
        let t0 = Instant::now();
        let r = &ranges[c.block_idx];
        b.part_arrivals[c.part].sort_by_key(|(row, _)| *row);
        let survivors: Vec<usize> =
            b.part_arrivals[c.part].iter().map(|(row, _)| *row).collect();
        let scheme = self.scheme.clone();
        let code = scheme.code(r.s);
        let a = self.cache.get(code, &survivors)?;
        let picked: Vec<&[f32]> =
            b.part_arrivals[c.part].iter().map(|(_, v)| v.as_slice()).collect();
        decode_into_add(a, &picked, &mut st.gradient[r.start..r.end]);
        for (_, buf) in b.part_arrivals[c.part].drain(..) {
            self.wire_pool.put(buf);
        }
        b.part_done[c.part] = true;
        b.parts_decoded += 1;
        if b.parts_decoded == st.parts {
            // Every part folded: the block is complete. Any buffered
            // whole contributions are now redundant — recycle them.
            b.decoded = true;
            st.partial_blocks += 1;
            st.decoded_count += 1;
            for (_, buf) in b.arrivals.drain(..) {
                self.wire_pool.put(buf);
            }
            b.arrivals.shrink_to_fit();
        }
        st.decode_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    /// Semi-async sweep: approximately decode every incomplete block
    /// whose exact quorum is short (by at most `max_shortfall`) only of
    /// deeply-backlogged rows, applying the least-squares combine with
    /// its tracked error bound. Arrivals stay in place so the exact
    /// quorum can still upgrade the block in-collect or reconcile it
    /// after the iteration closes; solves that fail or exceed the
    /// residual cap are skipped silently — the block just keeps waiting.
    fn try_approx(&self, st: &mut CollectState) {
        let Some(semi) = st.semi.clone() else { return };
        if semi.max_shortfall == 0 {
            return;
        }
        let scheme = self.scheme.clone();
        let ranges = scheme.ranges();
        for (idx, b) in st.blocks.iter_mut().enumerate() {
            if b.complete() || b.arrivals.is_empty() {
                continue;
            }
            if b.parts_decoded > 0 {
                // Rotation parts already folded into this block's
                // gradient slice; a least-squares overwrite would mix
                // two partial decodes. The part path finishes it.
                continue;
            }
            let have = b.arrivals.len();
            let shortfall = b.need - have;
            if shortfall > semi.max_shortfall {
                continue;
            }
            // Every live row still owing this block must be deeply
            // backlogged — otherwise an exact decode is imminent and the
            // approximation buys nothing.
            let all_deep = st
                .alive
                .iter()
                .zip(st.sent.iter())
                .zip(st.deep.iter())
                .all(|((alive, sent), deep)| !*alive || sent[idx] || *deep);
            if !all_deep {
                continue;
            }
            // lint: allow(determinism) — decode_ns metric only; control flow is virtual-time
            let t0 = Instant::now();
            b.arrivals.sort_by_key(|(row, _)| *row);
            let survivors: Vec<usize> = b.arrivals.iter().map(|(row, _)| *row).collect();
            let code = scheme.code(ranges[idx].s);
            // `decode_vector_ls` guarantees a finite residual.
            let Ok((a, residual)) = decode_vector_ls(code, &survivors) else { continue };
            if residual > semi.max_residual {
                continue;
            }
            // Observable surrogate of the Cauchy–Schwarz bound
            // `residual·‖G‖_F`: the coded survivors' energy stands in
            // for the unobserved per-subset gradients'.
            let energy: f64 = b
                .arrivals
                .iter()
                .map(|(_, v)| v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
                .sum();
            let r = &ranges[idx];
            let picked: Vec<&[f32]> = b.arrivals.iter().map(|(_, v)| v.as_slice()).collect();
            decode_into(&a, &picked, &mut st.gradient[r.start..r.end]);
            b.approx = Some(ApproxDecode {
                block_idx: idx,
                used: have,
                shortfall,
                residual,
                bound: residual * energy.sqrt(),
            });
            st.decoded_count += 1;
            st.decode_ns += t0.elapsed().as_nanos() as u64;
        }
    }

    /// Try to complete a pending reconcile with a stale-iteration
    /// contribution. Consumes the contribution (returns `None`) when it
    /// belongs to a tracked entry — the buffer is retained until the
    /// entry reconciles or is discarded — and hands it back otherwise so
    /// the caller can recycle or reroute it.
    pub fn offer_pending(&mut self, c: BlockContribution) -> Option<BlockContribution> {
        self.feed_pending(c)
    }

    fn feed_pending(&mut self, c: BlockContribution) -> Option<BlockContribution> {
        if c.job != self.job || c.epoch != self.epoch {
            return Some(c);
        }
        if c.row >= self.roster.len() || self.roster[c.row] != c.worker {
            return Some(c);
        }
        let Some(pos) =
            self.pending.iter().position(|e| e.iter == c.iter && e.block_idx == c.block_idx)
        else {
            return Some(c); // not a tracked entry — hand the event back
        };
        let entry = &mut self.pending[pos];
        if entry.arrivals.iter().any(|&(row, _)| row == c.row) {
            // Duplicate row (retry / requeue): consume and recycle.
            self.wire_pool.put(c.coded);
            return None;
        }
        entry.arrivals.push((c.row, c.coded));
        if entry.arrivals.len() >= entry.need {
            let entry = self.pending.swap_remove(pos);
            self.reconcile_entry(entry);
        }
        None
    }

    /// The exact quorum landed for an approximately-applied block:
    /// decode exactly, queue `delta = exact − approx` for the job to
    /// apply, recycle the retained buffers. A failed solve discards the
    /// entry instead (counted in [`Self::approx_discarded`]).
    fn reconcile_entry(&mut self, mut entry: PendingReconcile) {
        entry.arrivals.sort_by_key(|(row, _)| *row);
        let survivors: Vec<usize> = entry.arrivals.iter().map(|(row, _)| *row).collect();
        let scheme = self.scheme.clone();
        let code = scheme.code(entry.s);
        let decoded = self.cache.get(code, &survivors).map(|a| {
            let picked: Vec<&[f32]> =
                entry.arrivals.iter().map(|(_, v)| v.as_slice()).collect();
            let mut exact = vec![0.0f64; entry.end - entry.start];
            decode_into(a, &picked, &mut exact);
            exact
        });
        for (_, buf) in entry.arrivals.drain(..) {
            self.wire_pool.put(buf);
        }
        match decoded {
            Ok(exact) => {
                let delta: Vec<f64> =
                    exact.iter().zip(entry.approx.iter()).map(|(e, a)| e - a).collect();
                self.reconciled.push(ReconcileOutcome {
                    iter: entry.iter,
                    block_idx: entry.block_idx,
                    start: entry.start,
                    end: entry.end,
                    delta,
                    bound: entry.bound,
                });
            }
            Err(_) => self.discarded += 1,
        }
    }
}

/// After a failure, verify every undecoded block can still reach its
/// quorum. A row counts toward a block only if it is alive *and* has
/// not yet delivered that block — tracking outstanding status per
/// (row, block) rather than per row, so an unrecoverable block is never
/// declared recoverable just because some row still owes messages to
/// *other* blocks.
///
/// With streaming on, a block has a second route to completion: every
/// rotation part reaching `need` deltas. A dead row's already-delivered
/// parts stay usable (that is the whole point of partial-straggler
/// streaming), so the block is unrecoverable only when the
/// whole-contribution path **and** the part path are both impossible.
/// Without streamed arrivals the part-path bound reduces to the
/// whole-path one, so non-streaming behavior is unchanged.
fn check_still_satisfiable(st: &CollectState, iter: usize) -> Result<()> {
    for (idx, b) in st.blocks.iter().enumerate() {
        if b.complete() {
            continue;
        }
        let pending = st
            .alive
            .iter()
            .zip(st.sent.iter())
            .filter(|&(a, s)| *a && !s[idx])
            .count();
        let whole_possible = b.arrivals.len() + pending >= b.need;
        // Part path: every not-yet-folded part must still be able to
        // reach `need` distinct rows (banked deltas + alive rows that
        // have not delivered that part yet).
        let parts_possible = (0..st.parts).all(|p| {
            if b.part_done[p] {
                return true;
            }
            let outstanding = st
                .alive
                .iter()
                .enumerate()
                .filter(|(row, alive)| {
                    // A row that already delivered the whole block
                    // streams nothing more for it.
                    **alive && !st.sent[*row][idx] && b.psent[*row] & (1u32 << p) == 0
                })
                .count();
            b.part_arrivals[p].len() + outstanding >= b.need
        });
        if !whole_possible && !parts_possible {
            return Err(Error::Runtime(format!(
                "iteration {iter}: block {idx} unrecoverable \
                 ({} arrivals, {} rows outstanding, need {})",
                b.arrivals.len(),
                pending,
                b.need
            )));
        }
    }
    Ok(())
}

/// The identity subset → shard mapping (subset `k` ↔ dataset shard `k`).
pub fn identity_shards(n: usize) -> ShardMap {
    (0..n).map(|k| vec![k]).collect()
}

/// Subset → dataset shards after re-dimensioning to `n` subsets over a
/// dataset sharded `num_shards` ways (equal-size shards —
/// `data::partition::equal_shards` enforces it). Every shard stays
/// covered by exactly one subset, so the decoded gradient still equals
/// the full-dataset gradient.
///
/// The split is **largest-remainder** (quota boundaries
/// `round(k·m/n)`): per-subset sample loads differ by at most one
/// shard — a max/min ratio of `1 + 1/⌊m/n⌋` — *and* the `+1`-loaded
/// subsets are spread evenly around the subset ring. The old
/// `shard % n` round-robin also kept the count gap at one, but piled
/// every remainder shard onto subsets `0..m mod n`; since a code row
/// holds a *contiguous window* of subsets, the surviving low-index rows
/// absorbed the whole overload, inflating their cycle times and biasing
/// the next online fit. Subsets beyond `num_shards` (a pool grown past
/// the data's sharding) back nothing and contribute exact zeros; the
/// empty subsets are spread evenly too.
pub fn redistribute_shards(n: usize, num_shards: usize) -> ShardMap {
    assert!(n >= 1, "need at least one subset");
    let mut map: ShardMap = vec![Vec::new(); n];
    let mut start = 0usize;
    for (k, backing) in map.iter_mut().enumerate() {
        // Largest-remainder quota boundary: after subset k, exactly
        // round((k+1)·m/n) shards are assigned.
        let end = (((k + 1) * num_shards + n / 2) / n).min(num_shards);
        backing.extend(start..end);
        start = end;
    }
    debug_assert_eq!(start, num_shards, "every shard must stay covered");
    map
}

/// Per-subset shard **counts** proportional to `weights` (Hamilton /
/// largest-remainder apportionment): subset `i`'s exact quota is
/// `q_i = w_i·m/Σw`; every subset gets `⌊q_i⌋` shards and the leftover
/// shards go to the largest fractional remainders (ties broken by
/// larger weight, then lower index). Guarantees `c_i ∈ {⌊q_i⌋, ⌈q_i⌉}`
/// — each subset within one shard of its exact quota — and, because
/// the apportionment depends on each weight only through its own quota,
/// permuting the workers permutes the counts with them (exact for
/// distinct remainders; ties resolve by the stated deterministic
/// order). Non-finite or non-positive weights count as zero; if no
/// positive weight remains the split degrades to uniform.
pub fn shard_quota_weighted(weights: &[f64], num_shards: usize) -> Vec<usize> {
    let n = weights.len();
    assert!(n >= 1, "need at least one subset");
    let w: Vec<f64> =
        weights.iter().map(|&v| if v.is_finite() && v > 0.0 { v } else { 0.0 }).collect();
    let total: f64 = w.iter().sum();
    if total <= 0.0 {
        let uniform = redistribute_shards(n, num_shards);
        return uniform.iter().map(Vec::len).collect();
    }
    let quotas: Vec<f64> = w.iter().map(|&v| v * num_shards as f64 / total).collect();
    let mut counts: Vec<usize> = quotas.iter().map(|&q| q.floor() as usize).collect();
    let assigned: usize = counts.iter().sum();
    let mut leftover = num_shards.saturating_sub(assigned);
    if leftover > 0 {
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            let (ra, rb) = (quotas[a] - quotas[a].floor(), quotas[b] - quotas[b].floor());
            rb.partial_cmp(&ra)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(w[b].partial_cmp(&w[a]).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.cmp(&b))
        });
        for &i in order.iter() {
            if leftover == 0 {
                break;
            }
            counts[i] += 1;
            leftover -= 1;
        }
    }
    debug_assert_eq!(counts.iter().sum::<usize>(), num_shards);
    counts
}

/// Subset → dataset shards proportional to per-worker `weights`
/// (fitted mean **rates** `1/E[T]` — the speed-weighted actuation of
/// the heterogeneity-aware engine). Subset `i` backs the contiguous
/// shard range sized by [`shard_quota_weighted`], so every shard stays
/// covered by exactly one subset and the decoded gradient still equals
/// the full-dataset gradient; fast workers simply carry more of it.
pub fn redistribute_shards_weighted(weights: &[f64], num_shards: usize) -> ShardMap {
    let counts = shard_quota_weighted(weights, num_shards);
    let mut map: ShardMap = Vec::with_capacity(counts.len());
    let mut start = 0usize;
    for c in counts {
        map.push((start..start + c).collect());
        start += c;
    }
    debug_assert_eq!(start, num_shards, "every shard must stay covered");
    map
}

/// Per-row data-load multipliers of a shard map relative to the
/// uniform `m/n` share: `ρ_i = c_i·n/m` (1 everywhere for a balanced
/// map, 0 for a subset that backs nothing). The virtual-time layer
/// scales row `i`'s cycle time by `ρ_i` so Eq. (2) accounting reflects
/// the weighted data placement (primary-subset load model: row `i`'s
/// per-unit work tracks the share of subset `i`, the subset it is the
/// first holder of).
pub fn load_multipliers(map: &ShardMap, num_shards: usize) -> Vec<f64> {
    let n = map.len().max(1);
    if num_shards == 0 {
        return vec![1.0; map.len()];
    }
    map.iter().map(|backing| backing.len() as f64 * n as f64 / num_shards as f64).collect()
}

/// Strict weight sanitation for the sample-granular apportioners: any
/// non-finite or **negative** weight is an [`Error::InvalidArgument`]
/// (the shard-granular [`shard_quota_weighted`] predates this and keeps
/// its documented silent degrade-to-uniform behavior for callers that
/// rely on it). Zero weights are legal — they renormalize away, and
/// the one-sample floor still covers their subset.
fn validate_weights(weights: &[f64]) -> Result<()> {
    for (i, &w) in weights.iter().enumerate() {
        if !w.is_finite() || w < 0.0 {
            return Err(Error::InvalidArgument(format!(
                "weight[{i}] = {w}: sample apportionment needs finite non-negative weights"
            )));
        }
    }
    Ok(())
}

/// Per-subset **sample** counts proportional to `weights` — the
/// sample-granular refinement of [`shard_quota_weighted`]. Hamilton /
/// largest-remainder apportionment over individual samples, so a
/// two-speed fleet whose speed ratio is not a multiple of `1/m` gets
/// its exact proportional load (quota error under one sample instead
/// of one shard). Two extra guarantees over the shard variant:
///
/// * **Strict sanitation**: non-finite or negative weights are refused
///   ([`Error::InvalidArgument`]) instead of silently producing an
///   arbitrary split; an all-zero weight vector degrades to the uniform
///   split (there is nothing to be proportional to).
/// * **One-sample floor**: whenever `samples ≥ n`, every subset gets at
///   least one sample — a live worker holding a code row is never
///   assigned zero work (the floor samples come off the largest
///   allocations, lowest index first on ties).
pub fn sample_quota_weighted(weights: &[f64], samples: usize) -> Result<Vec<usize>> {
    let n = weights.len();
    assert!(n >= 1, "need at least one subset");
    validate_weights(weights)?;
    let total: f64 = weights.iter().sum();
    let mut counts: Vec<usize> = if total <= 0.0 {
        let uniform = redistribute_shards(n, samples);
        uniform.iter().map(Vec::len).collect()
    } else {
        let quotas: Vec<f64> = weights.iter().map(|&v| v * samples as f64 / total).collect();
        let mut counts: Vec<usize> = quotas.iter().map(|&q| q.floor() as usize).collect();
        let assigned: usize = counts.iter().sum();
        let mut leftover = samples.saturating_sub(assigned);
        if leftover > 0 {
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by(|&a, &b| {
                let (ra, rb) = (quotas[a] - quotas[a].floor(), quotas[b] - quotas[b].floor());
                rb.partial_cmp(&ra)
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(
                        weights[b]
                            .partial_cmp(&weights[a])
                            .unwrap_or(std::cmp::Ordering::Equal),
                    )
                    .then(a.cmp(&b))
            });
            for &i in order.iter() {
                if leftover == 0 {
                    break;
                }
                counts[i] += 1;
                leftover -= 1;
            }
        }
        counts
    };
    // One-sample floor: top up empty subsets from the largest
    // allocation (deterministic: max count, lowest index on ties).
    if samples >= n {
        for i in 0..n {
            while counts[i] == 0 {
                let donor = (0..n)
                    .max_by(|&a, &b| counts[a].cmp(&counts[b]).then(b.cmp(&a)))
                    .unwrap_or(i);
                if counts[donor] <= 1 {
                    break; // nothing left to shave — samples < n after all
                }
                counts[donor] -= 1;
                counts[i] += 1;
            }
        }
    }
    debug_assert_eq!(counts.iter().sum::<usize>(), samples);
    Ok(counts)
}

/// Subset → contiguous sample spans proportional to per-worker
/// `weights` — the sample-granular actuation behind
/// [`redistribute_shards_weighted`]. Subset `i` owns the span
/// `[start_i, start_i + c_i)` with counts from
/// [`sample_quota_weighted`]; the spans partition `[0, samples)` in
/// subset order, so the decoded gradient covers every sample exactly
/// once. Requires span-capable executors
/// ([`crate::runtime::GradExecutor::supports_spans`]).
pub fn redistribute_samples_weighted(weights: &[f64], samples: usize) -> Result<SliceMap> {
    let counts = sample_quota_weighted(weights, samples)?;
    let mut map: SliceMap = Vec::with_capacity(counts.len());
    let mut start = 0usize;
    for c in counts {
        map.push((start, start + c));
        start += c;
    }
    debug_assert_eq!(start, samples, "every sample must stay covered");
    Ok(map)
}

/// Per-row data-load multipliers of a slice map relative to the
/// uniform `samples/n` share: `ρ_i = len_i·n/samples` — the
/// sample-granular mirror of [`load_multipliers`].
pub fn sample_load_multipliers(map: &SliceMap, samples: usize) -> Vec<f64> {
    let n = map.len().max(1);
    if samples == 0 {
        return vec![1.0; map.len()];
    }
    map.iter()
        .map(|&(lo, hi)| (hi - lo) as f64 * n as f64 / samples as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::blocks::BlockPartition;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    /// Build the full set of coded block events the worker bound to
    /// `row` (stable id `worker`) would emit for one iteration of job
    /// `job` under `scheme`, from per-subset global gradients
    /// (`subset_grads[k]` is subset `k`'s full-dimension gradient).
    #[allow(clippy::too_many_arguments)]
    fn job_row_contributions(
        scheme: &CodingScheme,
        job: JobId,
        iter: usize,
        epoch: usize,
        subset_grads: &[Vec<f64>],
        worker: usize,
        row: usize,
    ) -> Vec<WorkerEvent> {
        let held: Vec<Vec<f64>> = scheme
            .worker_subsets(row)
            .iter()
            .map(|&k| subset_grads[k].clone())
            .collect();
        scheme
            .ranges()
            .iter()
            .enumerate()
            .map(|(block_idx, r)| {
                WorkerEvent::Block(BlockContribution {
                    job,
                    iter,
                    epoch,
                    worker,
                    row,
                    block_idx,
                    virtual_time: 0.0,
                    // f32 wire format, like a real worker (tests compare
                    // decodes at 1e-5, inside the f32-rounding budget).
                    coded: scheme
                        .encode_block_range(row, r, &held)
                        .iter()
                        .map(|&v| v as f32)
                        .collect(),
                })
            })
            .collect()
    }

    fn row_contributions(
        scheme: &CodingScheme,
        iter: usize,
        epoch: usize,
        subset_grads: &[Vec<f64>],
        worker: usize,
        row: usize,
    ) -> Vec<WorkerEvent> {
        job_row_contributions(scheme, 0, iter, epoch, subset_grads, worker, row)
    }

    /// Identity-roster shorthand (row == worker id, job 0).
    fn contributions(
        scheme: &CodingScheme,
        iter: usize,
        epoch: usize,
        subset_grads: &[Vec<f64>],
        worker: usize,
    ) -> Vec<WorkerEvent> {
        row_contributions(scheme, iter, epoch, subset_grads, worker, worker)
    }

    fn random_subset_grads(n: usize, dim: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
        let grads: Vec<Vec<f64>> =
            (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
        let want: Vec<f64> =
            (0..dim).map(|d| grads.iter().map(|g| g[d]).sum()).collect();
        (grads, want)
    }

    fn install_identity(master: &mut Master, scheme: Arc<CodingScheme>, epoch: usize) {
        let n = scheme.n();
        let shards = Arc::new(identity_shards(n));
        master.install_scheme(scheme, epoch, (0..n).collect(), shards);
    }

    #[test]
    fn stale_epoch_contributions_never_mix_into_a_decode() {
        let (n, dim) = (4usize, 8usize);
        let mut rng = Rng::new(71);
        // Two schemes over the same dimensions but different random codes
        // (and different partitions): mixing their codewords would
        // corrupt the decode.
        let scheme_a =
            Arc::new(CodingScheme::new(BlockPartition::new(vec![0, 8, 0, 0]), &mut rng).unwrap());
        let scheme_b =
            Arc::new(CodingScheme::new(BlockPartition::new(vec![0, 4, 4, 0]), &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme_a.clone(), dim);
        install_identity(&mut master, scheme_b.clone(), 1);
        assert_eq!(master.epoch(), 1);

        let (tx, rx) = mpsc::channel();
        // A contribution encoded under the superseded epoch-0 scheme
        // arrives first, same iteration number.
        for ev in contributions(&scheme_a, 0, 0, &subset_grads, 0) {
            tx.send(ev).unwrap();
        }
        // Then the full epoch-1 traffic.
        for w in 0..n {
            for ev in contributions(&scheme_b, 0, 1, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.stale_epoch, 1, "the epoch-0 codeword must be dropped");
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn cross_job_contributions_are_dropped_like_stale_epochs() {
        // Two jobs share the pool. Job 7's master must refuse a codeword
        // stamped with job 3 — even one whose iter/epoch/binding all
        // match — and still decode job 7's traffic exactly.
        let (n, dim) = (4usize, 6usize);
        let mut rng = Rng::new(131);
        let part = BlockPartition::new(vec![0, 6, 0, 0]); // s=1, need 3
        let scheme_mine = Arc::new(CodingScheme::new(part.clone(), &mut rng).unwrap());
        let scheme_other = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::for_job(7, scheme_mine.clone(), dim, (0..n).collect());
        assert_eq!(master.job(), 7);
        let (tx, rx) = mpsc::channel();
        // A full worker's worth of job-3 codewords arrives first.
        for ev in job_row_contributions(&scheme_other, 3, 0, 0, &subset_grads, 0, 0) {
            tx.send(ev).unwrap();
        }
        for w in 0..n {
            for ev in job_row_contributions(&scheme_mine, 7, 0, 0, &subset_grads, w, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.cross_job, scheme_other.ranges().len());
        assert_eq!(out.stale_epoch, 0);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn current_epoch_traffic_decodes_exactly_after_a_swap() {
        // Same partition before and after the swap — only the code's
        // random coefficients change. The decode cache must not serve
        // epoch-0 decode vectors to epoch-1 survivor sets.
        let (n, dim) = (5usize, 10usize);
        let mut rng = Rng::new(73);
        let part = BlockPartition::new(vec![0, 0, 10, 0, 0]); // s=2, need 3
        let scheme_a = Arc::new(CodingScheme::new(part.clone(), &mut rng).unwrap());
        let scheme_b = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme_a.clone(), dim);
        let live = vec![true; n];

        // Epoch 0 round.
        let (tx, rx) = mpsc::channel();
        for w in 0..n {
            for ev in contributions(&scheme_a, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let out0 = master.collect(0, &rx, &live).unwrap();
        // Epoch 1 round with the new code, same survivor pattern.
        install_identity(&mut master, scheme_b.clone(), 1);
        let (tx, rx) = mpsc::channel();
        for w in 0..n {
            for ev in contributions(&scheme_b, 1, 1, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let out1 = master.collect(1, &rx, &live).unwrap();
        for d in 0..dim {
            assert!((out0.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()));
            assert!(
                (out1.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "epoch-1 decode used a stale cached vector: got {} want {}",
                out1.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn cache_stats_survive_install_scheme() {
        // Regression: a job's hit/miss counters must accumulate across
        // scheme epochs — `install_scheme` resets the cached vectors
        // (they belong to one code's coefficients) but never the
        // counters, so end-of-run statistics describe the whole run.
        let (n, dim) = (4usize, 8usize);
        let mut rng = Rng::new(137);
        let part = BlockPartition::new(vec![0, 8, 0, 0]); // s=1, need 3
        let scheme_a = Arc::new(CodingScheme::new(part.clone(), &mut rng).unwrap());
        let scheme_b = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);
        let live = vec![true; n];

        let mut master = Master::new(scheme_a.clone(), dim);
        // Two epoch-0 rounds: 1 miss (first solve) + 1 hit (same set).
        for iter in 0..2 {
            let (tx, rx) = mpsc::channel();
            for w in 0..n {
                for ev in contributions(&scheme_a, iter, 0, &subset_grads, w) {
                    tx.send(ev).unwrap();
                }
            }
            master.collect(iter, &rx, &live).unwrap();
        }
        let (h0, m0) = master.cache_stats();
        assert_eq!((h0, m0), (1, 1));

        install_identity(&mut master, scheme_b.clone(), 1);
        // Epoch 1 round: the same survivor set must MISS (vectors were
        // reset with the code) while the counters carry the epoch-0
        // history forward.
        let (tx, rx) = mpsc::channel();
        for w in 0..n {
            for ev in contributions(&scheme_b, 2, 1, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        master.collect(2, &rx, &live).unwrap();
        let (h1, m1) = master.cache_stats();
        assert_eq!(
            (h1, m1),
            (1, 2),
            "counters must survive the swap and the vectors must not"
        );
    }

    #[test]
    fn redimensioned_epoch_decodes_exactly_with_a_compacted_roster() {
        // N = 5 shrinks to N' = 3 (stable ids 0, 2, 4 survive): the
        // re-dimensioned scheme's rows are positions in the *new*
        // roster, and the decoded gradient is exactly the sum over the
        // new scheme's subsets.
        let (dim, n0, n1) = (6usize, 5usize, 3usize);
        let mut rng = Rng::new(97);
        let part0 = BlockPartition::new(vec![0, 6, 0, 0, 0]);
        let scheme0 = Arc::new(CodingScheme::new(part0, &mut rng).unwrap());
        let scheme1 =
            Arc::new(CodingScheme::new(BlockPartition::new(vec![0, 6, 0]), &mut rng).unwrap());
        let mut master = Master::new(scheme0, dim);
        let roster: Vec<usize> = vec![0, 2, 4];
        master.install_scheme(
            scheme1.clone(),
            1,
            roster.clone(),
            Arc::new(redistribute_shards(n1, n0)),
        );
        assert_eq!(master.roster(), &[0, 2, 4]);

        let (subset_grads, want) = random_subset_grads(n1, dim, &mut rng);
        let (tx, rx) = mpsc::channel();
        for (row, &worker) in roster.iter().enumerate() {
            for ev in row_contributions(&scheme1, 0, 1, &subset_grads, worker, row) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n1];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.mismatched_binding, 0);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn mismatched_binding_is_dropped_not_decoded() {
        // A contribution stamped with the current epoch but a row that
        // belongs to a different stable id must be dropped.
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(101);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        // Worker 9 falsely claims row 0 (bound to id 0).
        for ev in row_contributions(&scheme, 0, 0, &subset_grads, 9, 0) {
            tx.send(ev).unwrap();
        }
        for w in 0..3 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.mismatched_binding, 1);
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn unrecoverable_block_detected_per_worker_block() {
        // Regression for the satisfiability bug: block 0 (s=0) needs all
        // three workers. Workers 0 and 1 have already delivered it when
        // worker 2 fails — block 0 is unrecoverable even though worker 0
        // still owes a message to *block 1*. The old per-worker
        // outstanding count declared it recoverable and stalled into the
        // timeout.
        let (n, dim) = (3usize, 3usize);
        let mut rng = Rng::new(79);
        let part = BlockPartition::new(vec![2, 1, 0]); // block0 s=0 need 3, block1 s=1 need 2
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = Duration::from_secs(30); // the fix must not wait for this

        let (tx, rx) = mpsc::channel();
        // Worker 0 delivers only block 0.
        let mut evs0 = contributions(&scheme, 0, 0, &subset_grads, 0).into_iter();
        tx.send(evs0.next().unwrap()).unwrap();
        // Worker 1 delivers both blocks.
        for ev in contributions(&scheme, 0, 0, &subset_grads, 1) {
            tx.send(ev).unwrap();
        }
        // Worker 2 fails having delivered nothing.
        tx.send(WorkerEvent::Failed {
            worker: 2,
            job: 0,
            iter: 0,
            reason: "boom".into(),
            fatal: true,
        })
        .unwrap();

        let start = Instant::now();
        let live = vec![true; n];
        let err = master.collect(0, &rx, &live).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unrecoverable"), "{msg}");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "unrecoverability must be detected without waiting out the stall timeout"
        );
    }

    #[test]
    fn leave_mid_iteration_fail_fasts_like_a_fatal_straggler() {
        // Same shape as the fatal-failure case, but the worker departs
        // *cleanly* (a drain ack landing mid-iteration): block 0 (s=0)
        // becomes unrecoverable and the master must fail fast via
        // the satisfiability check instead of stalling into the timeout.
        let (n, dim) = (3usize, 3usize);
        let mut rng = Rng::new(103);
        let part = BlockPartition::new(vec![2, 1, 0]); // block0 s=0 need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = Duration::from_secs(30);

        let (tx, rx) = mpsc::channel();
        for ev in contributions(&scheme, 0, 0, &subset_grads, 0) {
            tx.send(ev).unwrap();
        }
        tx.send(WorkerEvent::Left { worker: 2 }).unwrap();

        let start = Instant::now();
        let live = vec![true; n];
        let err = master.collect(0, &rx, &live).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unrecoverable"), "{msg}");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "a mid-iteration Leave must fail fast, not stall into the timeout"
        );
    }

    #[test]
    fn leave_within_redundancy_still_decodes_and_is_reported() {
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(107);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        tx.send(WorkerEvent::Left { worker: 3 }).unwrap();
        for w in 0..3 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.left, vec![3]);
        assert!(out.failed.is_empty(), "a clean departure is not a failure");
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn satisfiable_despite_failure_keeps_collecting() {
        // Block tolerates one straggler: a failure after two deliveries
        // must NOT error, and the decode completes from the other three.
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(83);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        for ev in contributions(&scheme, 0, 0, &subset_grads, 0) {
            tx.send(ev).unwrap();
        }
        tx.send(WorkerEvent::Failed {
            worker: 3,
            job: 0,
            iter: 0,
            reason: "slow death".into(),
            fatal: true,
        })
        .unwrap();
        for w in 1..3 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.failed, vec![3]);
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn transient_failure_counts_this_iteration_but_not_the_worker() {
        // A grad-shards error is per-iteration: the worker contributes
        // nothing *now* (satisfiability must account for that), but it is
        // not reported in `failed`, so the pool keeps it in the quorum
        // accounting of future iterations — where it may well recover.
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(89);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        tx.send(WorkerEvent::Failed {
            worker: 2,
            job: 0,
            iter: 0,
            reason: "flaky executor".into(),
            fatal: false,
        })
        .unwrap();
        for w in [0usize, 1, 3] {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert!(out.failed.is_empty(), "transient failures must not be permanent");
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn transient_failure_for_another_job_does_not_void_this_jobs_row() {
        // Worker 3 reports a transient failure while serving job 5; job
        // 0's in-flight iteration must keep counting worker 3 toward its
        // own quorum (only fatal failures cross job boundaries).
        let (n, dim) = (3usize, 3usize);
        let mut rng = Rng::new(139);
        let part = BlockPartition::new(vec![3, 0, 0]); // s=0: needs everyone
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        tx.send(WorkerEvent::Failed {
            worker: 2,
            job: 5,
            iter: 0,
            reason: "other tenant's dataset".into(),
            fatal: false,
        })
        .unwrap();
        for w in 0..n {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert!(out.failed.is_empty());
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn wire_buffers_recycle_on_decode_late_and_drop_paths() {
        // Ownership contract: the master returns EVERY wire buffer it
        // receives to the pool — decoded arrivals, late contributions,
        // and the stale/cross-job/mismatched drop paths alike.
        let (n, dim) = (4usize, 8usize);
        let mut rng = Rng::new(149);
        let part = BlockPartition::new(vec![0, 8, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());

        // Drive offer() directly so the late contribution (arriving
        // after the block decoded) is still fed through the master.
        let mut events: Vec<WorkerEvent> = Vec::new();
        // Drop paths: a cross-job codeword, a stale-iteration one, and
        // a mismatched binding.
        events.extend(job_row_contributions(&scheme, 9, 0, 0, &subset_grads, 0, 0));
        events.extend(contributions(&scheme, 7, 0, &subset_grads, 1));
        events.extend(row_contributions(&scheme, 0, 0, &subset_grads, 8, 2));
        // Full current traffic: 3 decode the block, the 4th is late.
        for w in 0..n {
            events.extend(contributions(&scheme, 0, 0, &subset_grads, w));
        }
        let sent = events.len() as u64;
        let live = vec![true; n];
        master.begin_collect(0, &live).unwrap();
        for ev in events {
            master.offer(ev).unwrap();
        }
        assert!(master.collect_complete());
        let out = master.take_outcome();
        assert_eq!(out.cross_job, 1);
        assert_eq!(out.late_contributions, 1);
        let stats = master.wire_pool_stats();
        assert_eq!(
            stats.returned, sent,
            "every received wire buffer must be recycled into the pool"
        );
        assert!(pool.free_len() > 0);
    }

    #[test]
    fn shard_redistribution_covers_every_shard_exactly_once() {
        for (n, shards) in [(3usize, 8usize), (8, 8), (5, 3), (1, 4), (6, 4), (4, 10)] {
            let map = redistribute_shards(n, shards);
            assert_eq!(map.len(), n);
            let mut seen = vec![0usize; shards];
            for backing in &map {
                for &s in backing {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "n={n} shards={shards}: {seen:?}");
        }
        // More subsets than shards: exactly n − m subsets back nothing,
        // and the empties are spread rather than clustered at the end.
        let map = redistribute_shards(6, 4);
        let empties: Vec<usize> =
            (0..6).filter(|&k| map[k].is_empty()).collect();
        assert_eq!(empties.len(), 2, "{map:?}");
        assert!(empties.windows(2).all(|w| w[1] - w[0] > 1), "clustered: {empties:?}");
    }

    #[test]
    fn weighted_shard_split_covers_once_and_respects_quotas() {
        // 2-speed fleet, rate weights: every shard covered exactly once
        // and each subset within one shard of its exact quota.
        for (weights, m) in [
            (vec![1.0, 1.0, 0.25, 0.25], 4usize),
            (vec![1.0, 1.0, 1.0, 0.2, 0.2, 0.2], 24),
            (vec![3.0, 1.0], 7),
            (vec![5.0], 3),
        ] {
            let map = redistribute_shards_weighted(&weights, m);
            assert_eq!(map.len(), weights.len());
            let mut seen = vec![0usize; m];
            for backing in &map {
                for &s in backing {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "{weights:?} m={m}: {seen:?}");
            let total: f64 = weights.iter().sum();
            for (i, backing) in map.iter().enumerate() {
                let q = weights[i] * m as f64 / total;
                assert!(
                    (backing.len() as f64 - q).abs() < 1.0,
                    "subset {i}: count {} vs quota {q}",
                    backing.len()
                );
            }
        }
        // Fast workers get strictly more when granularity allows.
        let map = redistribute_shards_weighted(&[1.0, 1.0, 0.25, 0.25], 20);
        assert!(map[0].len() > map[2].len(), "{map:?}");
        assert!(map[1].len() > map[3].len(), "{map:?}");
        // The load multipliers mirror the counts.
        let rho = load_multipliers(&map, 20);
        assert!((rho.iter().sum::<f64>() - 4.0).abs() < 1e-12, "total work conserved");
        assert!(rho[0] > 1.0 && rho[2] < 1.0, "{rho:?}");
    }

    #[test]
    fn weighted_shard_split_degrades_gracefully() {
        // Degenerate weights (dead rows, NaNs, zero total) fall back to
        // a covering split instead of panicking.
        let map = redistribute_shards_weighted(&[0.0, f64::NAN, -1.0], 6);
        let counts: Vec<usize> = map.iter().map(Vec::len).collect();
        assert_eq!(counts.iter().sum::<usize>(), 6);
        let max = counts.iter().max().unwrap();
        let min = counts.iter().min().unwrap();
        assert!(max - min <= 1, "{counts:?}: zero-total weights split uniformly");
        // A single dead row among live ones backs nothing.
        let map = redistribute_shards_weighted(&[1.0, 0.0, 1.0], 6);
        assert!(map[1].is_empty(), "{map:?}");
        assert_eq!(map[0].len() + map[2].len(), 6);
        // Uniform weights reproduce the unweighted counts.
        let uni = redistribute_shards(5, 13);
        let wuni = redistribute_shards_weighted(&[2.0; 5], 13);
        let mut a: Vec<usize> = uni.iter().map(Vec::len).collect();
        let mut b: Vec<usize> = wuni.iter().map(Vec::len).collect();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        // load_multipliers guards the no-shard case.
        assert_eq!(load_multipliers(&vec![Vec::new(); 3], 0), vec![1.0; 3]);
    }

    #[test]
    fn weighted_shard_counts_are_permutation_equivariant() {
        // Distinct weights: permuting the workers permutes the counts
        // with them (the apportionment sees each worker only through
        // its own quota).
        let weights = vec![3.1, 0.7, 1.9, 5.3, 0.2, 2.6];
        let m = 17usize;
        let base = shard_quota_weighted(&weights, m);
        let perm = [4usize, 2, 0, 5, 1, 3];
        let permuted_w: Vec<f64> = perm.iter().map(|&i| weights[i]).collect();
        let permuted_c = shard_quota_weighted(&permuted_w, m);
        for (slot, &i) in perm.iter().enumerate() {
            assert_eq!(
                permuted_c[slot], base[i],
                "worker {i} must keep its count under permutation: {base:?} vs {permuted_c:?}"
            );
        }
    }

    #[test]
    fn shard_redistribution_balances_sample_load_and_spreads_the_remainder() {
        // Load balance (regression for the round-robin skew): per-subset
        // counts differ by at most one shard, i.e. with equal-size
        // shards the max/min sample ratio is ≤ 1 + 1/⌊m/n⌋.
        for (n, m) in [(4usize, 10usize), (24, 30), (6, 8), (7, 21), (5, 9)] {
            let map = redistribute_shards(n, m);
            let counts: Vec<usize> = map.iter().map(Vec::len).collect();
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(max - min <= 1, "n={n} m={m}: {counts:?}");
            let q = m / n;
            assert!(
                max as f64 / min as f64 <= 1.0 + 1.0 / q as f64 + 1e-12,
                "n={n} m={m}: ratio {}",
                max as f64 / min as f64
            );
        }
        // Remainder spread: 30 shards over 24 subsets leaves 6 subsets
        // with a double load. Round-robin parked them at subsets 0..6
        // (gap 1) — the contiguous windows low rows hold; the
        // largest-remainder split spaces them ≥ 3 apart.
        let map = redistribute_shards(24, 30);
        let heavy: Vec<usize> =
            (0..24).filter(|&k| map[k].len() == 2).collect();
        assert_eq!(heavy.len(), 6, "{map:?}");
        for w in heavy.windows(2) {
            assert!(w[1] - w[0] >= 3, "heavy subsets clustered: {heavy:?}");
        }
    }

    /// A lenient semi-async policy for tests: one-row shortfall, any
    /// residual accepted (the assertions check the tracked values).
    fn lenient_semi() -> SemiAsyncConfig {
        SemiAsyncConfig { max_shortfall: 1, backlog_factor: 2.0, max_residual: 10.0 }
    }

    #[test]
    fn approx_decode_fires_on_deep_rows_and_reconciles_to_exact() {
        // Single block, s=1, need 3 of 4. Rows 2 and 3 are flagged
        // deeply backlogged; after rows 0 and 1 deliver, the block is
        // short exactly one row and every missing row is deep — the
        // approximation fires. The straggler's exact quorum then lands
        // as a stale-iteration event and reconciles.
        let (n, dim) = (4usize, 8usize);
        let mut rng = Rng::new(211);
        let part = BlockPartition::new(vec![0, 8, 0, 0]); // one block, s=1
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());

        let live = vec![true; n];
        let deep = vec![false, false, true, true];
        master.begin_collect_async(0, &live, &deep, Some(lenient_semi())).unwrap();
        let mut sent = 0u64;
        for w in 0..2 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                sent += 1;
                master.offer(ev).unwrap();
            }
        }
        assert!(master.collect_complete(), "approx must complete the iteration");
        let out = master.take_outcome();
        assert_eq!(out.approx.len(), 1);
        let rec = &out.approx[0];
        assert_eq!((rec.used, rec.shortfall), (2, 1));
        assert!(rec.residual > 0.0, "a short quorum cannot be exact");
        assert!(rec.bound > 0.0 && rec.bound.is_finite());
        assert_eq!(master.pending_reconciles(), 1);

        // The deep row's contribution arrives for iteration 0 while
        // iteration 1 is already open → routed to the pending set.
        master.begin_collect(1, &live).unwrap();
        for ev in contributions(&scheme, 0, 0, &subset_grads, 2) {
            sent += 1;
            master.offer(ev).unwrap();
        }
        master.abort_collect();
        assert_eq!(master.pending_reconciles(), 0, "exact quorum landed");
        let rec = master.take_reconciled();
        assert_eq!(rec.len(), 1);
        // approx + delta == exact == the full-dataset gradient.
        for d in rec[0].start..rec[0].end {
            let fixed = out.gradient[d] + rec[0].delta[d - rec[0].start];
            assert!(
                (fixed - want[d]).abs() < 1e-4 * (1.0 + want[d].abs()),
                "coordinate {d}: reconciled {fixed} want {}",
                want[d]
            );
        }
        // Every wire buffer (two approx survivors + the reconciler)
        // was recycled once the reconcile closed.
        assert_eq!(master.wire_pool_stats().returned, sent);
    }

    #[test]
    fn exact_quorum_in_collect_upgrades_an_approximation_silently() {
        let (n, dim) = (4usize, 6usize);
        let mut rng = Rng::new(223);
        let part = BlockPartition::new(vec![0, 6, 0, 0]); // one block, s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());

        let live = vec![true; n];
        let deep = vec![false, false, true, true];
        master.begin_collect_async(0, &live, &deep, Some(lenient_semi())).unwrap();
        let mut sent = 0u64;
        // Rows 0, 1 → approximation fires; rows 2, 3 still deliver
        // in-collect: the exact decode overwrites it, the 4th is late.
        for w in 0..n {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                sent += 1;
                master.offer(ev).unwrap();
            }
        }
        let out = master.take_outcome();
        assert!(out.approx.is_empty(), "upgraded blocks owe no reconcile");
        assert_eq!(out.late_contributions, 1);
        assert_eq!(master.pending_reconciles(), 0);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "upgrade must land the exact decode: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
        assert_eq!(master.wire_pool_stats().returned, sent);
    }

    #[test]
    fn epoch_swap_discards_pending_reconciles_and_recycles_buffers() {
        let (n, dim) = (4usize, 8usize);
        let mut rng = Rng::new(227);
        let part = BlockPartition::new(vec![0, 8, 0, 0]);
        let scheme_a = Arc::new(CodingScheme::new(part.clone(), &mut rng).unwrap());
        let scheme_b = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme_a.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());

        let live = vec![true; n];
        let deep = vec![false, false, true, true];
        master.begin_collect_async(0, &live, &deep, Some(lenient_semi())).unwrap();
        let mut sent = 0u64;
        for w in 0..2 {
            for ev in contributions(&scheme_a, 0, 0, &subset_grads, w) {
                sent += 1;
                master.offer(ev).unwrap();
            }
        }
        let _ = master.take_outcome();
        assert_eq!(master.pending_reconciles(), 1);

        // A stale contribution that matches no pending entry is handed
        // back untouched (the caller recycles or reroutes it).
        let stray = job_row_contributions(&scheme_a, 0, 7, 0, &subset_grads, 3, 3);
        for ev in stray {
            if let WorkerEvent::Block(c) = ev {
                let back = master.offer_pending(c).expect("untracked event is handed back");
                sent += 1;
                pool.put(back.coded);
            }
        }

        // The swap invalidates the retained epoch-0 arrivals.
        install_identity(&mut master, scheme_b, 1);
        assert_eq!(master.pending_reconciles(), 0);
        assert_eq!(master.approx_discarded(), 1);
        assert!(master.take_reconciled().is_empty());
        assert_eq!(master.wire_pool_stats().returned, sent);
    }

    // ---- sample-granular apportionment (PR 10 satellite) ----

    #[test]
    fn sample_apportionment_rejects_bad_weights_where_the_shard_path_degrades() {
        // Strict sanitation on the NEW sample-granular variants: any
        // non-finite or negative weight is a loud error…
        for bad in [
            vec![1.0, f64::NAN, 1.0],
            vec![1.0, f64::INFINITY],
            vec![0.5, -0.1, 2.0],
            vec![f64::NEG_INFINITY],
        ] {
            assert!(sample_quota_weighted(&bad, 12).is_err(), "{bad:?}");
            assert!(redistribute_samples_weighted(&bad, 12).is_err(), "{bad:?}");
        }
        // …while the legacy shard path KEEPS its documented silent
        // degrade-to-uniform for the same inputs.
        let legacy = shard_quota_weighted(&[0.0, f64::NAN, -1.0], 6);
        assert_eq!(legacy.iter().sum::<usize>(), 6);
        // All-zero weights are legal (nothing to be proportional to):
        // degrade to the uniform split.
        let counts = sample_quota_weighted(&[0.0, 0.0, 0.0], 10).unwrap();
        assert_eq!(counts.iter().sum::<usize>(), 10);
        assert!(counts.iter().max().unwrap() - counts.iter().min().unwrap() <= 1, "{counts:?}");
        // A zero weight among live ones still gets the one-sample floor
        // whenever samples ≥ n: a live row holding a code row is never
        // assigned zero work.
        let counts = sample_quota_weighted(&[5.0, 0.0, 5.0], 11).unwrap();
        assert_eq!(counts.iter().sum::<usize>(), 11);
        assert!(counts[1] >= 1, "{counts:?}");
        let counts = sample_quota_weighted(&[1000.0, 1e-9, 1e-9], 10).unwrap();
        assert!(counts.iter().all(|&c| c >= 1), "{counts:?}");
        // With samples < n the floor cannot hold — the split still
        // covers exactly.
        let counts = sample_quota_weighted(&[1.0, 1.0, 1.0, 1.0, 1.0], 3).unwrap();
        assert_eq!(counts.iter().sum::<usize>(), 3);
        // Guards on the multiplier mirror.
        assert_eq!(sample_load_multipliers(&vec![(0, 0); 3], 0), vec![1.0; 3]);
    }

    #[test]
    fn sample_quota_is_exact_when_granularity_allows_and_within_one_otherwise() {
        // The tentpole claim: a 2.5:1 two-speed fleet is NOT a multiple
        // of 1/m at shard granularity, but 7000 samples split exactly.
        let weights = [2.5, 2.5, 2.5, 2.5, 2.5, 1.0, 1.0, 1.0, 1.0, 1.0];
        let counts = sample_quota_weighted(&weights, 7_000).unwrap();
        assert_eq!(counts, vec![1000, 1000, 1000, 1000, 1000, 400, 400, 400, 400, 400]);
        // Hamilton property: every count within one sample of its exact
        // quota (weights bounded away from the floor regime).
        let mut rng = Rng::new(4021);
        for _ in 0..200 {
            let n = 2 + rng.below(14) as usize;
            let samples = n * (10 + rng.below(90) as usize);
            let weights: Vec<f64> =
                (0..n).map(|_| 0.5 + 2.5 * rng.below(1000) as f64 / 1000.0).collect();
            let counts = sample_quota_weighted(&weights, samples).unwrap();
            assert_eq!(counts.iter().sum::<usize>(), samples);
            let total: f64 = weights.iter().sum();
            for (i, &c) in counts.iter().enumerate() {
                let q = weights[i] * samples as f64 / total;
                assert!(
                    (c as f64 - q).abs() < 1.0 + 1e-9,
                    "subset {i}: count {c} vs quota {q} ({weights:?}, {samples})"
                );
            }
            // The slice map partitions [0, samples) contiguously in
            // subset order with exactly those counts.
            let map = redistribute_samples_weighted(&weights, samples).unwrap();
            let mut cursor = 0usize;
            for (i, &(lo, hi)) in map.iter().enumerate() {
                assert_eq!(lo, cursor, "subset {i} must start where {i}−1 ended");
                assert_eq!(hi - lo, counts[i]);
                cursor = hi;
            }
            assert_eq!(cursor, samples);
            // Load multipliers conserve total work: Σρ = n.
            let rho = sample_load_multipliers(&map, samples);
            assert!((rho.iter().sum::<f64>() - n as f64).abs() < 1e-9, "{rho:?}");
        }
        // Permutation equivariance on distinct weights.
        let weights = vec![3.1, 0.7, 1.9, 5.3, 0.2, 2.6];
        let base = sample_quota_weighted(&weights, 173).unwrap();
        let perm = [4usize, 2, 0, 5, 1, 3];
        let permuted_w: Vec<f64> = perm.iter().map(|&i| weights[i]).collect();
        let permuted_c = sample_quota_weighted(&permuted_w, 173).unwrap();
        for (slot, &i) in perm.iter().enumerate() {
            assert_eq!(permuted_c[slot], base[i], "{base:?} vs {permuted_c:?}");
        }
    }

    // ---- partial-straggler streaming collect (PR 10 tentpole) ----

    /// Equal-span slice map over `n·span` virtual samples.
    fn uniform_slices(n: usize, span: usize) -> Arc<SliceMap> {
        Arc::new((0..n).map(|k| (k * span, (k + 1) * span)).collect())
    }

    /// Per-part random subset gradients (`grads[p][subset]` is the
    /// delta of data part `p` — the same samples no matter which row
    /// streams it) plus the whole-round total the decode must
    /// reproduce.
    fn random_part_grads(
        n: usize,
        dim: usize,
        parts: usize,
        rng: &mut Rng,
    ) -> (Vec<Vec<Vec<f64>>>, Vec<f64>) {
        let grads: Vec<Vec<Vec<f64>>> = (0..parts)
            .map(|_| (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect())
            .collect();
        let want: Vec<f64> = (0..dim)
            .map(|d| grads.iter().flat_map(|g| g.iter()).map(|v| v[d]).sum())
            .collect();
        (grads, want)
    }

    /// The rotation-part event row `row` emits at stride `j` for the
    /// (single-block) scheme. Mirrors the worker contract: stride `j`
    /// carries **data part** `(row + j) mod parts` of every held
    /// subset, so part-`p` deltas agree across rows and any quorum of
    /// them decodes exactly.
    fn partial_event(
        scheme: &CodingScheme,
        part_grads: &[Vec<Vec<f64>>],
        row: usize,
        j: usize,
        parts: usize,
    ) -> WorkerEvent {
        let part = (row + j) % parts;
        let held: Vec<Vec<f64>> = scheme
            .worker_subsets(row)
            .iter()
            .map(|&k| part_grads[part][k].clone())
            .collect();
        let r = &scheme.ranges()[0];
        WorkerEvent::Partial(PartialBlockContribution {
            job: 0,
            iter: 0,
            epoch: 0,
            worker: row,
            row,
            block_idx: 0,
            part,
            parts,
            samples_done: (j + 1) * 5,
            samples_total: parts * 5,
            virtual_time: 0.0,
            coded: scheme
                .encode_block_range(row, r, &held)
                .iter()
                .map(|&v| v as f32)
                .collect(),
        })
    }

    #[test]
    fn streamed_parts_decode_to_the_exact_gradient() {
        // 4 rows, one s=1 block (need 3), 3 rotation parts. Rows 0–2
        // streaming all their strides fills every part quorum; the
        // folded per-part decodes must sum to the whole-round gradient,
        // and row 3's late strides are pure overhead.
        let (n, dim, parts) = (4usize, 8usize, 3usize);
        let mut rng = Rng::new(233);
        let part = BlockPartition::new(vec![0, 8, 0, 0]);
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (grads, want) = random_part_grads(n, dim, parts, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());
        master.install_slices(Some(uniform_slices(n, 5)), parts);

        let live = vec![true; n];
        master.begin_collect(0, &live).unwrap();
        let mut done = false;
        for row in 0..3 {
            for j in 0..parts {
                done = master.offer(partial_event(&scheme, &grads, row, j, parts)).unwrap();
            }
        }
        assert!(done, "three full rows fill every rotation-part quorum");
        for j in 0..parts {
            master.offer(partial_event(&scheme, &grads, 3, j, parts)).unwrap();
        }
        let out = master.take_outcome();
        assert_eq!(out.partial_blocks, 1, "the block must complete part-wise");
        assert_eq!(out.partial_contributions, 9);
        assert_eq!(out.late_contributions, 3, "row 3's strides arrive after the fold");
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {} — per-part decodes must sum to the \
                 whole-block gradient",
                out.gradient[d],
                want[d]
            );
        }
        assert_eq!(
            master.wire_pool_stats().returned,
            12,
            "every streamed delta's wire buffer must recycle"
        );
    }

    #[test]
    fn part_quorums_decode_exactly_from_divergent_survivor_sets() {
        // Regression: part 0 folds from rows {0, 1, 2} while part 1
        // folds from rows {0, 1, 3}. Because the worker indexes each
        // stride's sub-span by the rotated part — not by the stride —
        // every row's part-`p` delta covers the same samples, so each
        // quorum decodes exactly on its own and no common survivor set
        // across parts is needed. (Stride-indexed data would decode to
        // garbage here; rotation makes divergent sets the common case
        // whenever streaming actually beats the whole-block quorum.)
        let (n, dim, parts) = (4usize, 8usize, 2usize);
        let mut rng = Rng::new(251);
        let part = BlockPartition::new(vec![0, 8, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (grads, want) = random_part_grads(n, dim, parts, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());
        master.install_slices(Some(uniform_slices(n, 5)), parts);

        let live = vec![true; n];
        master.begin_collect(0, &live).unwrap();
        let mut done = false;
        // Part 0 ← rows 0, 2 at stride 0 and row 1 at stride 1;
        // part 1 ← rows 1, 3 at stride 0 and row 0 at stride 1.
        for (row, j) in [(0usize, 0usize), (2, 0), (1, 1), (1, 0), (3, 0), (0, 1)] {
            done = master.offer(partial_event(&scheme, &grads, row, j, parts)).unwrap();
        }
        assert!(done, "both part quorums fill");
        let out = master.take_outcome();
        assert_eq!(out.partial_blocks, 1);
        assert_eq!(out.partial_contributions, 6);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {} — each part quorum must decode \
                 exactly under its own survivor set",
                out.gradient[d],
                want[d]
            );
        }
        assert_eq!(master.wire_pool_stats().returned, 6);
    }

    #[test]
    fn part_geometry_mismatches_are_refused_and_recycled() {
        // Every malformed streamed delta is dropped into the right
        // counter with its buffer recycled — and none of them corrupt
        // the decode that follows.
        let (n, dim, parts) = (4usize, 8usize, 3usize);
        let mut rng = Rng::new(239);
        let part = BlockPartition::new(vec![0, 8, 0, 0]);
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (grads, want) = random_part_grads(n, dim, parts, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());
        master.install_slices(Some(uniform_slices(n, 5)), parts);

        let live = vec![true; n];
        master.begin_collect(0, &live).unwrap();
        let mut sent = 0u64;
        let mut feed = |master: &mut Master, ev: WorkerEvent| {
            sent += 1;
            master.offer(ev).unwrap()
        };
        // Rotation geometry from another dispatch: parts = 2 ≠ 3.
        let stale_geom = match partial_event(&scheme, &grads, 0, 0, parts) {
            WorkerEvent::Partial(mut c) => {
                c.parts = 2;
                WorkerEvent::Partial(c)
            }
            _ => unreachable!(),
        };
        feed(&mut master, stale_geom);
        // Part index out of range.
        let bad_part = match partial_event(&scheme, &grads, 0, 0, parts) {
            WorkerEvent::Partial(mut c) => {
                c.part = 5;
                WorkerEvent::Partial(c)
            }
            _ => unreachable!(),
        };
        feed(&mut master, bad_part);
        // Binding mismatch: worker 8 claims row 2.
        let forged = match partial_event(&scheme, &grads, 2, 0, parts) {
            WorkerEvent::Partial(mut c) => {
                c.worker = 8;
                WorkerEvent::Partial(c)
            }
            _ => unreachable!(),
        };
        feed(&mut master, forged);
        // Cross-job and stale-iteration deltas.
        let cross = match partial_event(&scheme, &grads, 0, 0, parts) {
            WorkerEvent::Partial(mut c) => {
                c.job = 9;
                WorkerEvent::Partial(c)
            }
            _ => unreachable!(),
        };
        feed(&mut master, cross);
        let old_iter = match partial_event(&scheme, &grads, 0, 0, parts) {
            WorkerEvent::Partial(mut c) => {
                c.iter = 7;
                WorkerEvent::Partial(c)
            }
            _ => unreachable!(),
        };
        feed(&mut master, old_iter);
        // A genuine delta, then its exact duplicate (retry).
        feed(&mut master, partial_event(&scheme, &grads, 0, 0, parts));
        feed(&mut master, partial_event(&scheme, &grads, 0, 0, parts));
        // Fill every quorum with rows 0–2 (row 0's stride 0 is in).
        let mut done = false;
        for row in 0..3 {
            for j in 0..parts {
                if row == 0 && j == 0 {
                    continue;
                }
                done = feed(&mut master, partial_event(&scheme, &grads, row, j, parts));
            }
        }
        assert!(done);
        let out = master.take_outcome();
        assert_eq!(out.stale_epoch, 2, "bad geometry counts like a superseded epoch");
        assert_eq!(out.mismatched_binding, 1);
        assert_eq!(out.cross_job, 1);
        assert_eq!(out.late_contributions, 1, "the duplicate stride is late overhead");
        assert_eq!(out.partial_blocks, 1);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
        assert_eq!(master.wire_pool_stats().returned, sent, "every drop path recycles");
    }

    #[test]
    fn whole_quorum_overwrites_buffered_and_folded_parts() {
        // Parts 0's quorum folds first (3 rows' deltas accumulate into
        // the gradient slice); then a whole-contribution quorum lands.
        // The exact decode must OVERWRITE the partial sums — not add to
        // them — and later strides are late overhead.
        let (n, dim, parts) = (4usize, 8usize, 2usize);
        let mut rng = Rng::new(241);
        let part = BlockPartition::new(vec![0, 8, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (grads, want) = random_part_grads(n, dim, parts, &mut rng);
        // Whole-round per-subset gradients: sums over the data parts.
        let whole: Vec<Vec<f64>> = (0..n)
            .map(|k| {
                (0..dim).map(|d| grads.iter().map(|g| g[k][d]).sum()).collect()
            })
            .collect();
        let mut master = Master::new(scheme.clone(), dim);
        let pool = crate::util::buffers::BufferPool::new(64);
        master.set_wire_pool(pool.clone());
        master.install_slices(Some(uniform_slices(n, 5)), parts);

        let live = vec![true; n];
        master.begin_collect(0, &live).unwrap();
        let mut sent = 0u64;
        // Part 0 arrives from rows 0 (stride 0), 2 (stride 0) and 1
        // (stride 1): quorum of 3 → the fold lands in the slice.
        for (row, j) in [(0usize, 0usize), (2, 0), (1, 1)] {
            sent += 1;
            assert_eq!((row + j) % parts, 0, "rotation must address part 0");
            assert!(!master.offer(partial_event(&scheme, &grads, row, j, parts)).unwrap());
        }
        // One buffered (un-quorumed) part-1 delta from row 1's stride 0.
        sent += 1;
        assert!(!master.offer(partial_event(&scheme, &grads, 1, 0, parts)).unwrap());
        // Whole-block quorum from rows 0, 1, 2 overwrites everything.
        let mut done = false;
        for w in 0..3 {
            for ev in contributions(&scheme, 0, 0, &whole, w) {
                sent += 1;
                done = master.offer(ev).unwrap();
            }
        }
        assert!(done, "the whole quorum completes the block");
        // Any stride after the overwrite is late.
        sent += 1;
        master.offer(partial_event(&scheme, &grads, 0, 1, parts)).unwrap();
        let out = master.take_outcome();
        assert_eq!(out.partial_blocks, 0, "the block completed on the WHOLE path");
        assert_eq!(out.partial_contributions, 4);
        assert_eq!(out.late_contributions, 1);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-5 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {} — the exact decode must overwrite the \
                 folded parts, not stack on them",
                out.gradient[d],
                want[d]
            );
        }
        assert_eq!(
            master.wire_pool_stats().returned,
            sent,
            "folded, buffered and late buffers must all recycle"
        );
    }
}
