//! Master-side iteration engine: broadcast, collect, decode-on-arrival.
//!
//! The master owns the **current scheme epoch**: [`Master::install_scheme`]
//! swaps in a re-optimized — possibly re-*dimensioned* (different `N`) —
//! [`CodingScheme`] between iterations together with that epoch's roster
//! (row → stable worker id binding), and [`Master::collect`] rejects
//! contributions stamped with a superseded epoch exactly like
//! stale-iteration messages — coded blocks from two different codes must
//! never mix into one decode. Contributions whose id↔row binding does
//! not match the live roster are dropped the same way (a drained worker's
//! row may belong to someone else next epoch).
//!
//! All quorum accounting is **row**-indexed (rows are what the code's
//! survivor sets are made of); stable worker ids appear only at the
//! roster boundary and in the membership signals surfaced through
//! [`IterOutcome`].

use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::decoder::{decode, DecodeCache};
use crate::coding::scheme::CodingScheme;
use crate::coordinator::channel::{BlockContribution, ShardMap, WorkerEvent, WorkerTask};
use crate::{Error, Result};

/// Outcome of one collected iteration.
pub struct IterOutcome {
    /// The exact full gradient `Σ_n g_n`.
    pub gradient: Vec<f64>,
    /// Wall ns the master spent inside decode solves/combines.
    pub decode_ns: u64,
    /// Contributions that arrived after their block had decoded.
    pub late_contributions: usize,
    /// Contributions encoded under a superseded scheme epoch (dropped
    /// before they could touch a decode).
    pub stale_epoch: usize,
    /// Current-epoch contributions whose (worker id, row) stamp did not
    /// match the live roster binding (dropped).
    pub mismatched_binding: usize,
    /// Workers (stable ids) that reported a **fatal** failure (their
    /// thread exited; exclude them from future quorum accounting).
    /// Transient per-iteration failures only affect the current
    /// iteration's satisfiability bookkeeping.
    pub failed: Vec<usize>,
    /// Workers (stable ids) that announced a ready executor this
    /// iteration — joins the registry should confirm for the next
    /// epoch rebind.
    pub joined: Vec<usize>,
    /// Workers (stable ids) that drained cleanly this iteration;
    /// mid-iteration this was accounted like a fatal straggler.
    pub left: Vec<usize>,
}

/// Decode-on-arrival collector; owns the decode-vector cache across
/// iterations (survivor patterns repeat, so cached solves dominate).
pub struct Master {
    scheme: Arc<CodingScheme>,
    epoch: usize,
    dim: usize,
    /// Row → stable worker id for the current epoch.
    roster: Vec<usize>,
    /// Subset → dataset shards for the current epoch.
    shards: Arc<ShardMap>,
    cache: DecodeCache,
    /// Receive timeout before declaring the iteration stalled.
    pub timeout: Duration,
}

struct BlockState {
    need: usize,
    arrivals: Vec<(usize, Vec<f64>)>, // (row, coded)
    decoded: bool,
}

impl Master {
    /// A master whose epoch-0 roster binds row `r` to worker id `r` and
    /// whose subsets are backed 1:1 by dataset shards (the static-pool
    /// identity; elastic sessions install rebound rosters later).
    pub fn new(scheme: Arc<CodingScheme>, dim: usize) -> Self {
        let n = scheme.n();
        Self::with_roster(scheme, dim, (0..n).collect())
    }

    /// A master with an explicit epoch-0 roster (row → stable id).
    pub fn with_roster(scheme: Arc<CodingScheme>, dim: usize, roster: Vec<usize>) -> Self {
        assert_eq!(roster.len(), scheme.n(), "roster must bind every code row");
        let shards = Arc::new(identity_shards(scheme.n()));
        Self {
            scheme,
            epoch: 0,
            dim,
            roster,
            shards,
            cache: DecodeCache::new(4096),
            timeout: Duration::from_secs(30),
        }
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// The scheme epoch tasks are currently issued under.
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        &self.scheme
    }

    /// The current epoch's roster (row → stable worker id).
    pub fn roster(&self) -> &[usize] {
        &self.roster
    }

    /// The current epoch's subset → dataset shards mapping.
    pub fn shard_map(&self) -> &Arc<ShardMap> {
        &self.shards
    }

    fn row_of(&self, worker: usize) -> Option<usize> {
        self.roster.iter().position(|&id| id == worker)
    }

    /// Install a new scheme as epoch `epoch`, rebinding rows to
    /// `roster` and subsets to `shards` (pass the previous mappings for
    /// a same-`N` re-optimization). Decode vectors are specific to one
    /// code's coefficients (the cache keys only by `(s, survivor
    /// set)`), so the cache map is reset; hit/miss counters survive.
    pub fn install_scheme(
        &mut self,
        scheme: Arc<CodingScheme>,
        epoch: usize,
        roster: Vec<usize>,
        shards: Arc<ShardMap>,
    ) {
        assert!(epoch > self.epoch, "scheme epochs must be monotone");
        assert_eq!(roster.len(), scheme.n(), "roster must bind every code row");
        self.scheme = scheme;
        self.epoch = epoch;
        self.roster = roster;
        self.shards = shards;
        self.cache.reset();
    }

    /// Broadcast one iteration's tasks under the current scheme epoch.
    /// `tasks[row]` is the channel of the worker bound to that row
    /// (`None` for rows whose worker already departed — the coded
    /// scheme absorbs them like any straggler); `times[row]` its
    /// sampled cycle time; `unit_work` the epoch's `(M/N)·b`.
    pub fn broadcast(
        &self,
        iter: usize,
        theta: Arc<Vec<f32>>,
        times: &[f64],
        unit_work: f64,
        tasks: &[Option<Sender<WorkerTask>>],
    ) {
        debug_assert_eq!(tasks.len(), self.scheme.n());
        for (row, tx) in tasks.iter().enumerate() {
            let Some(tx) = tx else { continue };
            // A send error just means that worker died; the coded scheme
            // absorbs it like any straggler.
            let _ = tx.send(WorkerTask::Compute {
                iter,
                epoch: self.epoch,
                row,
                scheme: self.scheme.clone(),
                shards: self.shards.clone(),
                theta: theta.clone(),
                cycle_time: times[row],
                unit_work,
            });
        }
    }

    /// Collect events for iteration `iter` until every block decodes.
    ///
    /// Faithful to §III: block `b` (redundancy `s`) decodes using the
    /// first `N − s` contributions to arrive; later ones are counted as
    /// `late_contributions` and dropped. Contributions stamped with a
    /// superseded scheme epoch are dropped as `stale_epoch` — they are
    /// coded under different coefficients and would corrupt the decode.
    ///
    /// `live` flags which **rows** are up at iteration start (dead /
    /// previously failed / departed workers excluded); it seeds the
    /// per-(row, block) outstanding-message tracking used to detect
    /// unrecoverable blocks without waiting for the timeout. A
    /// [`WorkerEvent::Left`] arriving mid-iteration is accounted exactly
    /// like a fatal failure: the row goes dead and satisfiability is
    /// re-checked immediately.
    pub fn collect(
        &mut self,
        iter: usize,
        events: &Receiver<WorkerEvent>,
        live: &[bool],
    ) -> Result<IterOutcome> {
        let ranges = self.scheme.ranges();
        let n = self.scheme.n();
        debug_assert_eq!(live.len(), n);
        let mut blocks: Vec<BlockState> = ranges
            .iter()
            .map(|r| BlockState { need: n - r.s, arrivals: Vec::new(), decoded: false })
            .collect();
        let mut gradient = vec![0.0f64; self.dim];
        let mut decoded_count = 0usize;
        let mut late = 0usize;
        let mut stale_epoch = 0usize;
        let mut mismatched = 0usize;
        let mut decode_ns = 0u64;
        let mut failed: Vec<usize> = Vec::new();
        let mut joined: Vec<usize> = Vec::new();
        let mut left: Vec<usize> = Vec::new();
        // Per-(row, block) delivery state: `sent[row][b]` is true once
        // that row's contribution to block `b` was received this
        // iteration. Together with `alive` this tracks exactly which
        // messages are still outstanding, so satisfiability checks count
        // each row only toward blocks it can actually still deliver.
        let mut sent = vec![vec![false; ranges.len()]; n];
        let mut alive: Vec<bool> = live.to_vec();

        // Dead rows are known up front: fail fast when a block can
        // never reach quorum instead of waiting out the stall timeout.
        self.check_still_satisfiable(&blocks, &sent, &alive, iter)?;

        while decoded_count < blocks.len() {
            let ev = match events.recv_timeout(self.timeout) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::Runtime(format!(
                        "iteration {iter}: stalled ({decoded_count}/{} blocks decoded)",
                        blocks.len()
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Runtime(format!(
                        "iteration {iter}: all workers disconnected"
                    )));
                }
            };
            match ev {
                WorkerEvent::Joined { worker } => {
                    joined.push(worker);
                }
                WorkerEvent::Left { worker } => {
                    crate::log_info!("worker {worker} drained (iter {iter})");
                    left.push(worker);
                    if let Some(row) = self.row_of(worker) {
                        if alive[row] {
                            alive[row] = false;
                            self.check_still_satisfiable(&blocks, &sent, &alive, iter)?;
                        }
                    }
                }
                WorkerEvent::Failed { worker, iter: ev_iter, reason, fatal } => {
                    crate::log_warn!(
                        "worker {worker} failed in iter {ev_iter} (fatal={fatal}): {reason}"
                    );
                    if fatal {
                        failed.push(worker);
                    }
                    // A fatal failure kills the worker whenever its
                    // report arrives; a transient one only voids the
                    // iteration it happened in.
                    if fatal || ev_iter == iter {
                        if let Some(row) = self.row_of(worker) {
                            if alive[row] {
                                alive[row] = false;
                                self.check_still_satisfiable(&blocks, &sent, &alive, iter)?;
                            }
                        }
                    }
                }
                WorkerEvent::Block(c) => {
                    if c.iter != iter {
                        continue; // stale from a previous iteration
                    }
                    if c.epoch != self.epoch {
                        // Encoded under a superseded scheme: its block
                        // index and coefficients belong to another code.
                        stale_epoch += 1;
                        continue;
                    }
                    if c.row >= n || self.roster[c.row] != c.worker {
                        // The id↔row binding no longer matches the live
                        // roster (e.g. a drained worker's leftovers).
                        mismatched += 1;
                        continue;
                    }
                    self.on_block(
                        c,
                        &mut blocks,
                        &mut gradient,
                        &mut decoded_count,
                        &mut late,
                        &mut decode_ns,
                        &mut sent,
                    )?;
                }
            }
        }
        Ok(IterOutcome {
            gradient,
            decode_ns,
            late_contributions: late,
            stale_epoch,
            mismatched_binding: mismatched,
            failed,
            joined,
            left,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn on_block(
        &mut self,
        c: BlockContribution,
        blocks: &mut [BlockState],
        gradient: &mut [f64],
        decoded_count: &mut usize,
        late: &mut usize,
        decode_ns: &mut u64,
        sent: &mut [Vec<bool>],
    ) -> Result<()> {
        sent[c.row][c.block_idx] = true;
        let ranges = self.scheme.ranges();
        let b = &mut blocks[c.block_idx];
        if b.decoded {
            *late += 1;
            return Ok(());
        }
        b.arrivals.push((c.row, c.coded));
        if b.arrivals.len() < b.need {
            return Ok(());
        }
        // Decode now: the first `need` arrivals are the survivors.
        // Canonicalize to ascending row order — decode vectors are
        // order-aligned, and the cache keys by survivor *set*, so the
        // same set must always be presented in the same order.
        let t0 = Instant::now();
        let r = &ranges[c.block_idx];
        b.arrivals.sort_by_key(|(row, _)| *row);
        let survivors: Vec<usize> = b.arrivals.iter().map(|(row, _)| *row).collect();
        // Borrow the cached decode vector without copying it (§Perf opt 3):
        // the scheme handle is an independent Arc, so the cache's mutable
        // borrow of `self` does not conflict.
        let scheme = self.scheme.clone();
        let code = scheme.code(r.s);
        let a = self.cache.get(code, &survivors)?;
        let picked: Vec<&[f64]> = b.arrivals.iter().map(|(_, v)| v.as_slice()).collect();
        let block_grad = decode(a, &picked);
        gradient[r.start..r.end].copy_from_slice(&block_grad);
        b.decoded = true;
        b.arrivals.clear();
        b.arrivals.shrink_to_fit();
        *decoded_count += 1;
        *decode_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    /// After a failure, verify every undecoded block can still reach its
    /// quorum. A row counts toward a block only if it is alive *and*
    /// has not yet delivered that block — tracking outstanding status per
    /// (row, block) rather than per row, so an unrecoverable block is
    /// never declared recoverable just because some row still owes
    /// messages to *other* blocks.
    fn check_still_satisfiable(
        &self,
        blocks: &[BlockState],
        sent: &[Vec<bool>],
        alive: &[bool],
        iter: usize,
    ) -> Result<()> {
        for (idx, b) in blocks.iter().enumerate() {
            if b.decoded {
                continue;
            }
            let pending = alive
                .iter()
                .zip(sent.iter())
                .filter(|&(a, s)| *a && !s[idx])
                .count();
            let possible = b.arrivals.len() + pending;
            if possible < b.need {
                return Err(Error::Runtime(format!(
                    "iteration {iter}: block {idx} unrecoverable \
                     ({} arrivals, {} possible, need {})",
                    b.arrivals.len(),
                    possible,
                    b.need
                )));
            }
        }
        Ok(())
    }
}

/// The identity subset → shard mapping (subset `k` ↔ dataset shard `k`).
pub fn identity_shards(n: usize) -> ShardMap {
    (0..n).map(|k| vec![k]).collect()
}

/// Subset → dataset shards after re-dimensioning to `n` subsets over a
/// dataset sharded `num_shards` ways (equal-size shards —
/// `data::partition::equal_shards` enforces it). Every shard stays
/// covered by exactly one subset, so the decoded gradient still equals
/// the full-dataset gradient.
///
/// The split is **largest-remainder** (quota boundaries
/// `round(k·m/n)`): per-subset sample loads differ by at most one
/// shard — a max/min ratio of `1 + 1/⌊m/n⌋` — *and* the `+1`-loaded
/// subsets are spread evenly around the subset ring. The old
/// `shard % n` round-robin also kept the count gap at one, but piled
/// every remainder shard onto subsets `0..m mod n`; since a code row
/// holds a *contiguous window* of subsets, the surviving low-index rows
/// absorbed the whole overload, inflating their cycle times and biasing
/// the next online fit. Subsets beyond `num_shards` (a pool grown past
/// the data's sharding) back nothing and contribute exact zeros; the
/// empty subsets are spread evenly too.
pub fn redistribute_shards(n: usize, num_shards: usize) -> ShardMap {
    assert!(n >= 1, "need at least one subset");
    let mut map: ShardMap = vec![Vec::new(); n];
    let mut start = 0usize;
    for (k, backing) in map.iter_mut().enumerate() {
        // Largest-remainder quota boundary: after subset k, exactly
        // round((k+1)·m/n) shards are assigned.
        let end = (((k + 1) * num_shards + n / 2) / n).min(num_shards);
        backing.extend(start..end);
        start = end;
    }
    debug_assert_eq!(start, num_shards, "every shard must stay covered");
    map
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::blocks::BlockPartition;
    use crate::util::rng::Rng;
    use std::sync::mpsc;

    /// Build the full set of coded block events the worker bound to
    /// `row` (stable id `worker`) would emit for one iteration under
    /// `scheme`, from per-subset global gradients (`subset_grads[k]` is
    /// subset `k`'s full-dimension gradient).
    fn row_contributions(
        scheme: &CodingScheme,
        iter: usize,
        epoch: usize,
        subset_grads: &[Vec<f64>],
        worker: usize,
        row: usize,
    ) -> Vec<WorkerEvent> {
        let held: Vec<Vec<f64>> = scheme
            .worker_subsets(row)
            .iter()
            .map(|&k| subset_grads[k].clone())
            .collect();
        scheme
            .ranges()
            .iter()
            .enumerate()
            .map(|(block_idx, r)| {
                WorkerEvent::Block(BlockContribution {
                    iter,
                    epoch,
                    worker,
                    row,
                    block_idx,
                    virtual_time: 0.0,
                    coded: scheme.encode_block_range(row, r, &held),
                })
            })
            .collect()
    }

    /// Identity-roster shorthand (row == worker id).
    fn contributions(
        scheme: &CodingScheme,
        iter: usize,
        epoch: usize,
        subset_grads: &[Vec<f64>],
        worker: usize,
    ) -> Vec<WorkerEvent> {
        row_contributions(scheme, iter, epoch, subset_grads, worker, worker)
    }

    fn random_subset_grads(n: usize, dim: usize, rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>) {
        let grads: Vec<Vec<f64>> =
            (0..n).map(|_| (0..dim).map(|_| rng.normal()).collect()).collect();
        let want: Vec<f64> =
            (0..dim).map(|d| grads.iter().map(|g| g[d]).sum()).collect();
        (grads, want)
    }

    fn install_identity(master: &mut Master, scheme: Arc<CodingScheme>, epoch: usize) {
        let n = scheme.n();
        let shards = Arc::new(identity_shards(n));
        master.install_scheme(scheme, epoch, (0..n).collect(), shards);
    }

    #[test]
    fn stale_epoch_contributions_never_mix_into_a_decode() {
        let (n, dim) = (4usize, 8usize);
        let mut rng = Rng::new(71);
        // Two schemes over the same dimensions but different random codes
        // (and different partitions): mixing their codewords would
        // corrupt the decode.
        let scheme_a =
            Arc::new(CodingScheme::new(BlockPartition::new(vec![0, 8, 0, 0]), &mut rng).unwrap());
        let scheme_b =
            Arc::new(CodingScheme::new(BlockPartition::new(vec![0, 4, 4, 0]), &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme_a.clone(), dim);
        install_identity(&mut master, scheme_b.clone(), 1);
        assert_eq!(master.epoch(), 1);

        let (tx, rx) = mpsc::channel();
        // A contribution encoded under the superseded epoch-0 scheme
        // arrives first, same iteration number.
        for ev in contributions(&scheme_a, 0, 0, &subset_grads, 0) {
            tx.send(ev).unwrap();
        }
        // Then the full epoch-1 traffic.
        for w in 0..n {
            for ev in contributions(&scheme_b, 0, 1, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.stale_epoch, 1, "the epoch-0 codeword must be dropped");
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn current_epoch_traffic_decodes_exactly_after_a_swap() {
        // Same partition before and after the swap — only the code's
        // random coefficients change. The decode cache must not serve
        // epoch-0 decode vectors to epoch-1 survivor sets.
        let (n, dim) = (5usize, 10usize);
        let mut rng = Rng::new(73);
        let part = BlockPartition::new(vec![0, 0, 10, 0, 0]); // s=2, need 3
        let scheme_a = Arc::new(CodingScheme::new(part.clone(), &mut rng).unwrap());
        let scheme_b = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme_a.clone(), dim);
        let live = vec![true; n];

        // Epoch 0 round.
        let (tx, rx) = mpsc::channel();
        for w in 0..n {
            for ev in contributions(&scheme_a, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let out0 = master.collect(0, &rx, &live).unwrap();
        // Epoch 1 round with the new code, same survivor pattern.
        install_identity(&mut master, scheme_b.clone(), 1);
        let (tx, rx) = mpsc::channel();
        for w in 0..n {
            for ev in contributions(&scheme_b, 1, 1, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let out1 = master.collect(1, &rx, &live).unwrap();
        for d in 0..dim {
            assert!((out0.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()));
            assert!(
                (out1.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()),
                "epoch-1 decode used a stale cached vector: got {} want {}",
                out1.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn redimensioned_epoch_decodes_exactly_with_a_compacted_roster() {
        // N = 5 shrinks to N' = 3 (stable ids 0, 2, 4 survive): the
        // re-dimensioned scheme's rows are positions in the *new*
        // roster, and the decoded gradient is exactly the sum over the
        // new scheme's subsets.
        let (dim, n0, n1) = (6usize, 5usize, 3usize);
        let mut rng = Rng::new(97);
        let part0 = BlockPartition::new(vec![0, 6, 0, 0, 0]);
        let scheme0 = Arc::new(CodingScheme::new(part0, &mut rng).unwrap());
        let scheme1 =
            Arc::new(CodingScheme::new(BlockPartition::new(vec![0, 6, 0]), &mut rng).unwrap());
        let mut master = Master::new(scheme0, dim);
        let roster: Vec<usize> = vec![0, 2, 4];
        master.install_scheme(
            scheme1.clone(),
            1,
            roster.clone(),
            Arc::new(redistribute_shards(n1, n0)),
        );
        assert_eq!(master.roster(), &[0, 2, 4]);

        let (subset_grads, want) = random_subset_grads(n1, dim, &mut rng);
        let (tx, rx) = mpsc::channel();
        for (row, &worker) in roster.iter().enumerate() {
            for ev in row_contributions(&scheme1, 0, 1, &subset_grads, worker, row) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n1];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.mismatched_binding, 0);
        for d in 0..dim {
            assert!(
                (out.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()),
                "coordinate {d}: got {} want {}",
                out.gradient[d],
                want[d]
            );
        }
    }

    #[test]
    fn mismatched_binding_is_dropped_not_decoded() {
        // A contribution stamped with the current epoch but a row that
        // belongs to a different stable id must be dropped.
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(101);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        // Worker 9 falsely claims row 0 (bound to id 0).
        for ev in row_contributions(&scheme, 0, 0, &subset_grads, 9, 0) {
            tx.send(ev).unwrap();
        }
        for w in 0..3 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.mismatched_binding, 1);
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn unrecoverable_block_detected_per_worker_block() {
        // Regression for the satisfiability bug: block 0 (s=0) needs all
        // three workers. Workers 0 and 1 have already delivered it when
        // worker 2 fails — block 0 is unrecoverable even though worker 0
        // still owes a message to *block 1*. The old per-worker
        // outstanding count declared it recoverable and stalled into the
        // timeout.
        let (n, dim) = (3usize, 3usize);
        let mut rng = Rng::new(79);
        let part = BlockPartition::new(vec![2, 1, 0]); // block0 s=0 need 3, block1 s=1 need 2
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = Duration::from_secs(30); // the fix must not wait for this

        let (tx, rx) = mpsc::channel();
        // Worker 0 delivers only block 0.
        let mut evs0 = contributions(&scheme, 0, 0, &subset_grads, 0).into_iter();
        tx.send(evs0.next().unwrap()).unwrap();
        // Worker 1 delivers both blocks.
        for ev in contributions(&scheme, 0, 0, &subset_grads, 1) {
            tx.send(ev).unwrap();
        }
        // Worker 2 fails having delivered nothing.
        tx.send(WorkerEvent::Failed { worker: 2, iter: 0, reason: "boom".into(), fatal: true })
            .unwrap();

        let start = Instant::now();
        let live = vec![true; n];
        let err = master.collect(0, &rx, &live).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unrecoverable"), "{msg}");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "unrecoverability must be detected without waiting out the stall timeout"
        );
    }

    #[test]
    fn leave_mid_iteration_fail_fasts_like_a_fatal_straggler() {
        // Same shape as the fatal-failure case, but the worker departs
        // *cleanly* (a drain ack landing mid-iteration): block 0 (s=0)
        // becomes unrecoverable and the master must fail fast via
        // check_still_satisfiable instead of stalling into the timeout.
        let (n, dim) = (3usize, 3usize);
        let mut rng = Rng::new(103);
        let part = BlockPartition::new(vec![2, 1, 0]); // block0 s=0 need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, _) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = Duration::from_secs(30);

        let (tx, rx) = mpsc::channel();
        for ev in contributions(&scheme, 0, 0, &subset_grads, 0) {
            tx.send(ev).unwrap();
        }
        tx.send(WorkerEvent::Left { worker: 2 }).unwrap();

        let start = Instant::now();
        let live = vec![true; n];
        let err = master.collect(0, &rx, &live).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unrecoverable"), "{msg}");
        assert!(
            start.elapsed() < Duration::from_secs(5),
            "a mid-iteration Leave must fail fast, not stall into the timeout"
        );
    }

    #[test]
    fn leave_within_redundancy_still_decodes_and_is_reported() {
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(107);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);
        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        tx.send(WorkerEvent::Left { worker: 3 }).unwrap();
        for w in 0..3 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.left, vec![3]);
        assert!(out.failed.is_empty(), "a clean departure is not a failure");
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn satisfiable_despite_failure_keeps_collecting() {
        // Block tolerates one straggler: a failure after two deliveries
        // must NOT error, and the decode completes from the other three.
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(83);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        for ev in contributions(&scheme, 0, 0, &subset_grads, 0) {
            tx.send(ev).unwrap();
        }
        tx.send(WorkerEvent::Failed {
            worker: 3,
            iter: 0,
            reason: "slow death".into(),
            fatal: true,
        })
        .unwrap();
        for w in 1..3 {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert_eq!(out.failed, vec![3]);
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn transient_failure_counts_this_iteration_but_not_the_worker() {
        // A grad-shards error is per-iteration: the worker contributes
        // nothing *now* (satisfiability must account for that), but it is
        // not reported in `failed`, so the trainer keeps it in the quorum
        // accounting of future iterations — where it may well recover.
        let (n, dim) = (4usize, 4usize);
        let mut rng = Rng::new(89);
        let part = BlockPartition::new(vec![0, 4, 0, 0]); // s=1, need 3
        let scheme = Arc::new(CodingScheme::new(part, &mut rng).unwrap());
        let (subset_grads, want) = random_subset_grads(n, dim, &mut rng);

        let mut master = Master::new(scheme.clone(), dim);
        let (tx, rx) = mpsc::channel();
        tx.send(WorkerEvent::Failed {
            worker: 2,
            iter: 0,
            reason: "flaky executor".into(),
            fatal: false,
        })
        .unwrap();
        for w in [0usize, 1, 3] {
            for ev in contributions(&scheme, 0, 0, &subset_grads, w) {
                tx.send(ev).unwrap();
            }
        }
        let live = vec![true; n];
        let out = master.collect(0, &rx, &live).unwrap();
        assert!(out.failed.is_empty(), "transient failures must not be permanent");
        for d in 0..dim {
            assert!((out.gradient[d] - want[d]).abs() < 1e-8 * (1.0 + want[d].abs()));
        }
    }

    #[test]
    fn shard_redistribution_covers_every_shard_exactly_once() {
        for (n, shards) in [(3usize, 8usize), (8, 8), (5, 3), (1, 4), (6, 4), (4, 10)] {
            let map = redistribute_shards(n, shards);
            assert_eq!(map.len(), n);
            let mut seen = vec![0usize; shards];
            for backing in &map {
                for &s in backing {
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "n={n} shards={shards}: {seen:?}");
        }
        // More subsets than shards: exactly n − m subsets back nothing,
        // and the empties are spread rather than clustered at the end.
        let map = redistribute_shards(6, 4);
        let empties: Vec<usize> =
            (0..6).filter(|&k| map[k].is_empty()).collect();
        assert_eq!(empties.len(), 2, "{map:?}");
        assert!(empties.windows(2).all(|w| w[1] - w[0] > 1), "clustered: {empties:?}");
    }

    #[test]
    fn shard_redistribution_balances_sample_load_and_spreads_the_remainder() {
        // Load balance (regression for the round-robin skew): per-subset
        // counts differ by at most one shard, i.e. with equal-size
        // shards the max/min sample ratio is ≤ 1 + 1/⌊m/n⌋.
        for (n, m) in [(4usize, 10usize), (24, 30), (6, 8), (7, 21), (5, 9)] {
            let map = redistribute_shards(n, m);
            let counts: Vec<usize> = map.iter().map(Vec::len).collect();
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(max - min <= 1, "n={n} m={m}: {counts:?}");
            let q = m / n;
            assert!(
                max as f64 / min as f64 <= 1.0 + 1.0 / q as f64 + 1e-12,
                "n={n} m={m}: ratio {}",
                max as f64 / min as f64
            );
        }
        // Remainder spread: 30 shards over 24 subsets leaves 6 subsets
        // with a double load. Round-robin parked them at subsets 0..6
        // (gap 1) — the contiguous windows low rows hold; the
        // largest-remainder split spaces them ≥ 3 apart.
        let map = redistribute_shards(24, 30);
        let heavy: Vec<usize> =
            (0..24).filter(|&k| map[k].len() == 2).collect();
        assert_eq!(heavy.len(), 6, "{map:?}");
        for w in heavy.windows(2) {
            assert!(w[1] - w[0] >= 3, "heavy subsets clustered: {heavy:?}");
        }
    }
}
