//! Master-side iteration engine: broadcast, collect, decode-on-arrival.

use std::collections::HashMap;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::decoder::{decode, DecodeCache};
use crate::coding::scheme::CodingScheme;
use crate::coordinator::channel::{BlockContribution, WorkerEvent, WorkerTask};
use crate::{Error, Result};

/// Outcome of one collected iteration.
pub struct IterOutcome {
    /// The exact full gradient `Σ_n g_n`.
    pub gradient: Vec<f64>,
    /// Wall ns the master spent inside decode solves/combines.
    pub decode_ns: u64,
    /// Contributions that arrived after their block had decoded.
    pub late_contributions: usize,
    /// Workers that reported failure this iteration.
    pub failed: Vec<usize>,
}

/// Decode-on-arrival collector; owns the decode-vector cache across
/// iterations (survivor patterns repeat, so cached solves dominate).
pub struct Master {
    scheme: Arc<CodingScheme>,
    dim: usize,
    cache: DecodeCache,
    /// Receive timeout before declaring the iteration stalled.
    pub timeout: Duration,
}

struct BlockState {
    need: usize,
    arrivals: Vec<(usize, Vec<f64>)>, // (worker, coded)
    decoded: bool,
}

impl Master {
    pub fn new(scheme: Arc<CodingScheme>, dim: usize) -> Self {
        Self { scheme, dim, cache: DecodeCache::new(4096), timeout: Duration::from_secs(30) }
    }

    pub fn cache_stats(&self) -> (u64, u64) {
        (self.cache.hits, self.cache.misses)
    }

    /// Broadcast one iteration's tasks.
    pub fn broadcast(
        &self,
        iter: usize,
        theta: Arc<Vec<f32>>,
        times: &[f64],
        tasks: &[Sender<WorkerTask>],
    ) {
        for (w, tx) in tasks.iter().enumerate() {
            // A send error just means that worker died; the coded scheme
            // absorbs it like any straggler.
            let _ = tx.send(WorkerTask::Compute {
                iter,
                theta: theta.clone(),
                cycle_time: times[w],
            });
        }
    }

    /// Collect events for iteration `iter` until every block decodes.
    ///
    /// Faithful to §III: block `b` (redundancy `s`) decodes using the
    /// first `N − s` contributions to arrive; later ones are counted as
    /// `late_contributions` and dropped.
    pub fn collect(
        &mut self,
        iter: usize,
        events: &Receiver<WorkerEvent>,
        live_workers: usize,
    ) -> Result<IterOutcome> {
        let ranges = self.scheme.ranges();
        let n = self.scheme.n();
        let mut blocks: Vec<BlockState> = ranges
            .iter()
            .map(|r| BlockState { need: n - r.s, arrivals: Vec::new(), decoded: false })
            .collect();
        let mut gradient = vec![0.0f64; self.dim];
        let mut decoded_count = 0usize;
        let mut late = 0usize;
        let mut decode_ns = 0u64;
        let mut failed: Vec<usize> = Vec::new();
        // Messages still expected from live workers (used to detect
        // unrecoverable stalls without waiting for the timeout).
        let mut outstanding: HashMap<usize, usize> =
            (0..n).map(|w| (w, ranges.len())).collect();
        let mut live = live_workers;

        while decoded_count < blocks.len() {
            let ev = match events.recv_timeout(self.timeout) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::Runtime(format!(
                        "iteration {iter}: stalled ({decoded_count}/{} blocks decoded)",
                        blocks.len()
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Runtime(format!(
                        "iteration {iter}: all workers disconnected"
                    )));
                }
            };
            match ev {
                WorkerEvent::Failed { worker, iter: ev_iter, reason } => {
                    if ev_iter == iter {
                        log::warn!("worker {worker} failed in iter {iter}: {reason}");
                        failed.push(worker);
                        outstanding.remove(&worker);
                        live = live.saturating_sub(1);
                        self.check_still_satisfiable(&blocks, &outstanding, iter)?;
                    }
                }
                WorkerEvent::Block(c) => {
                    if c.iter != iter {
                        continue; // stale from a previous iteration
                    }
                    self.on_block(
                        c,
                        &mut blocks,
                        &mut gradient,
                        &mut decoded_count,
                        &mut late,
                        &mut decode_ns,
                        &mut outstanding,
                    )?;
                }
            }
            let _ = live;
        }
        Ok(IterOutcome { gradient, decode_ns, late_contributions: late, failed })
    }

    #[allow(clippy::too_many_arguments)]
    fn on_block(
        &mut self,
        c: BlockContribution,
        blocks: &mut [BlockState],
        gradient: &mut [f64],
        decoded_count: &mut usize,
        late: &mut usize,
        decode_ns: &mut u64,
        outstanding: &mut HashMap<usize, usize>,
    ) -> Result<()> {
        if let Some(left) = outstanding.get_mut(&c.worker) {
            *left -= 1;
            if *left == 0 {
                outstanding.remove(&c.worker);
            }
        }
        let ranges = self.scheme.ranges();
        let b = &mut blocks[c.block_idx];
        if b.decoded {
            *late += 1;
            return Ok(());
        }
        b.arrivals.push((c.worker, c.coded));
        if b.arrivals.len() < b.need {
            return Ok(());
        }
        // Decode now: the first `need` arrivals are the survivors.
        // Canonicalize to ascending worker order — decode vectors are
        // order-aligned, and the cache keys by survivor *set*, so the
        // same set must always be presented in the same order.
        let t0 = Instant::now();
        let r = &ranges[c.block_idx];
        b.arrivals.sort_by_key(|(w, _)| *w);
        let survivors: Vec<usize> = b.arrivals.iter().map(|(w, _)| *w).collect();
        // Borrow the cached decode vector without copying it (§Perf opt 3):
        // the scheme handle is an independent Arc, so the cache's mutable
        // borrow of `self` does not conflict.
        let scheme = self.scheme.clone();
        let code = scheme.code(r.s);
        let a = self.cache.get(code, &survivors)?;
        let picked: Vec<&[f64]> = b.arrivals.iter().map(|(_, v)| v.as_slice()).collect();
        let block_grad = decode(a, &picked);
        gradient[r.start..r.end].copy_from_slice(&block_grad);
        b.decoded = true;
        b.arrivals.clear();
        b.arrivals.shrink_to_fit();
        *decoded_count += 1;
        *decode_ns += t0.elapsed().as_nanos() as u64;
        Ok(())
    }

    /// After a failure, verify every undecoded block can still reach its
    /// quorum from arrivals + outstanding messages.
    fn check_still_satisfiable(
        &self,
        blocks: &[BlockState],
        outstanding: &HashMap<usize, usize>,
        iter: usize,
    ) -> Result<()> {
        for (idx, b) in blocks.iter().enumerate() {
            if b.decoded {
                continue;
            }
            // Workers that can still deliver this block: have not failed
            // and have not yet sent it.
            let possible = b.arrivals.len()
                + outstanding
                    .values()
                    .filter(|&&left| left > 0)
                    .count();
            if possible < b.need {
                return Err(Error::Runtime(format!(
                    "iteration {iter}: block {idx} unrecoverable \
                     ({} arrivals, {} possible, need {})",
                    b.arrivals.len(),
                    possible,
                    b.need
                )));
            }
        }
        Ok(())
    }
}
