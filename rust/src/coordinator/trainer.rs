//! The end-to-end trainer, decomposed into a setup phase and an
//! iteration loop so the coding scheme can be **hot-swapped between
//! iterations** (adaptive coding engine) and the worker pool itself can
//! **change size mid-run** (elastic pool).
//!
//! [`Trainer::run`] = [`TrainSession::start`] (validate, build the
//! epoch-0 scheme, spawn the worker topology) + a loop of
//! [`TrainSession::apply_scheduled_churn`] (config-driven joins/leaves),
//! [`TrainSession::adapt`] (poll the drift detector, install a
//! re-optimized scheme as a new epoch),
//! [`TrainSession::maybe_redimension`] (membership epochs: once churn
//! passes the threshold — or departures exceed what the live scheme's
//! redundancy absorbs — re-solve with the live roster's `N'` and install
//! the re-dimensioned scheme as a fresh epoch) and [`TrainSession::step`]
//! (one coded GD iteration) + [`TrainSession::finish`] (shutdown +
//! report). Embedders that need custom control flow (manual scheme
//! installs, interleaved evaluation, explicit
//! [`TrainSession::add_worker`] / [`TrainSession::remove_worker`]
//! calls…) can drive a [`TrainSession`] directly.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::coding::scheme::CodingScheme;
use crate::coordinator::adaptive::{self, AdaptiveConfig, AdaptiveController, ResolveStrategy};
use crate::coordinator::channel::{WorkerEvent, WorkerTask};
use crate::coordinator::master::{redistribute_shards, Master};
use crate::coordinator::membership::{MemberStatus, WorkerId, WorkerRegistry};
use crate::coordinator::metrics::{
    IterMetrics, MembershipEvent, MembershipRecord, SchemeEpoch, TrainReport,
};
use crate::coordinator::state::ModelState;
use crate::coordinator::straggler::{virtual_runtime, StragglerSampler, StragglerSchedule};
use crate::coordinator::worker::{self, WorkerContext};
use crate::coordinator::PacingMode;
use crate::distribution::fit::{FittedModel, ShiftedExpEstimate};
use crate::distribution::CycleTimeDistribution;
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::{ExecutorFactory, GradExecutor};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Elastic worker-pool policy: when membership changes, when to
/// re-dimension the scheme around the new roster.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Re-dimension once this many membership changes (confirmed joins
    /// + leaves) accumulated since the last rebind. Departures that
    /// exceed the live scheme's redundancy always force an immediate
    /// re-dimension regardless of this threshold. Clamped to ≥ 1.
    pub churn_threshold: usize,
    /// Scheduled departures `(iter, count)`: before iteration `iter`,
    /// drain `count` workers (highest-row live workers first).
    pub departures: Vec<(usize, usize)>,
    /// Scheduled arrivals `(iter, count)`: before iteration `iter`,
    /// spawn `count` new workers (assigned work from the next epoch).
    pub arrivals: Vec<(usize, usize)>,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self { churn_threshold: 1, departures: Vec::new(), arrivals: Vec::new() }
    }
}

/// Training configuration.
pub struct TrainConfig {
    pub spec: ProblemSpec,
    /// The initial (epoch-0) block partition.
    pub blocks: BlockPartition,
    pub steps: usize,
    pub lr: f64,
    /// Evaluate the loss every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub pacing: PacingMode,
    pub seed: u64,
    /// Worker ids that are never spawned — failure injection. The coded
    /// scheme must tolerate up to `min_s` of them.
    pub dead_workers: Vec<usize>,
    /// θ init scale (Gaussian); 0 = zeros.
    pub init_scale: f64,
    /// How long the master waits on an empty event channel before
    /// declaring the iteration stalled.
    pub stall_timeout: std::time::Duration,
    /// Online re-optimization policy (None = the scheme stays fixed).
    pub adaptive: Option<AdaptiveConfig>,
    /// Elastic worker-pool policy (None = `N` frozen at spawn, the
    /// paper's setting).
    pub elastic: Option<ElasticConfig>,
}

impl TrainConfig {
    pub fn new(spec: ProblemSpec, blocks: BlockPartition) -> Self {
        Self {
            spec,
            blocks,
            steps: 100,
            lr: 1e-2,
            eval_every: 10,
            pacing: PacingMode::Virtual,
            seed: 2021,
            dead_workers: Vec::new(),
            init_scale: 0.05,
            stall_timeout: std::time::Duration::from_secs(30),
            adaptive: None,
            elastic: None,
        }
    }
}

/// Coded distributed GD driver.
pub struct Trainer {
    cfg: TrainConfig,
    schedule: StragglerSchedule,
    factory: ExecutorFactory,
}

impl Trainer {
    /// Stationary straggler model (the paper's setting).
    pub fn new(
        cfg: TrainConfig,
        dist: Box<dyn CycleTimeDistribution>,
        factory: ExecutorFactory,
    ) -> Self {
        Self::with_schedule(cfg, StragglerSchedule::stationary(dist), factory)
    }

    /// Piecewise-stationary straggler model: the distribution may shift
    /// mid-training (what the adaptive engine is for).
    pub fn with_schedule(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        factory: ExecutorFactory,
    ) -> Self {
        Self { cfg, schedule, factory }
    }

    /// Run the full training loop.
    pub fn run(self) -> Result<TrainReport> {
        let steps = self.cfg.steps;
        let mut session = TrainSession::start(self.cfg, self.schedule, self.factory)?;
        for iter in 0..steps {
            session.apply_scheduled_churn(iter)?;
            session.adapt(iter)?;
            session.maybe_redimension(iter)?;
            session.step(iter)?;
        }
        session.finish()
    }
}

/// A live worker topology plus all per-run mutable state.
pub struct TrainSession {
    cfg: TrainConfig,
    dim: usize,
    /// Dataset shard count (fixed at spawn; elastic subsets are
    /// re-mapped onto these shards when `N` changes).
    num_data_shards: usize,
    scheme: Arc<CodingScheme>,
    epoch: usize,
    master: Master,
    registry: WorkerRegistry,
    /// Task channel per worker **id** (None once drained/dead/never
    /// spawned). Indexed by stable id, not row.
    task_txs: Vec<Option<Sender<WorkerTask>>>,
    /// Kept for spawning late joiners; the channel therefore never
    /// disconnects while the session lives (stalls still time out).
    event_tx: Sender<WorkerEvent>,
    event_rx: Receiver<WorkerEvent>,
    handles: Vec<std::thread::JoinHandle<()>>,
    factory: ExecutorFactory,
    sampler: StragglerSampler,
    state: ModelState,
    eval_exec: Option<Box<dyn GradExecutor>>,
    /// Row-indexed liveness for the current epoch's roster.
    live_mask: Vec<bool>,
    failed_set: Vec<usize>,
    controller: Option<AdaptiveController>,
    rng: Rng,
    report: TrainReport,
}

impl TrainSession {
    /// Setup phase: validate the config, build the epoch-0 scheme and
    /// spawn the worker topology.
    pub fn start(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        factory: ExecutorFactory,
    ) -> Result<Self> {
        let n = cfg.spec.n;
        if cfg.blocks.n() != n {
            return Err(Error::InvalidArgument("blocks.n() != spec.n".into()));
        }
        let mut rng = Rng::new(cfg.seed);
        let scheme = Arc::new(CodingScheme::new(cfg.blocks.clone(), &mut rng)?);

        // Master-side executor for loss evaluation (worker id n = master).
        let mut eval_exec = if cfg.eval_every > 0 { Some(factory(n)?) } else { None };
        let dim = if let Some(e) = &eval_exec {
            e.dim()
        } else {
            factory(n)?.dim()
        };
        if dim != cfg.spec.coords {
            crate::log_warn!(
                "model dim {} != spec.coords {} — virtual-runtime accounting uses the model dim",
                dim,
                cfg.spec.coords
            );
        }
        if cfg.blocks.total() != dim {
            return Err(Error::InvalidArgument(format!(
                "block partition covers {} coordinates but the model has {dim}",
                cfg.blocks.total()
            )));
        }

        // Topology: per-worker task channels + one shared event channel.
        let mut registry = WorkerRegistry::new(n);
        let (event_tx, event_rx) = mpsc::channel::<WorkerEvent>();
        let mut task_txs: Vec<Option<Sender<WorkerTask>>> = Vec::with_capacity(n);
        let mut handles = Vec::new();
        let mut live_mask = vec![false; n];
        for w in 0..n {
            if cfg.dead_workers.contains(&w) {
                // Injected failure: worker never comes up. It keeps its
                // epoch-0 row (the scheme must absorb it) and is dropped
                // at the first rebind, like any departure.
                task_txs.push(None);
                registry.leave(w);
                continue;
            }
            let (tx, rx) = mpsc::channel::<WorkerTask>();
            task_txs.push(Some(tx));
            live_mask[w] = true;
            let ctx = WorkerContext {
                id: w,
                factory: factory.clone(),
                tasks: rx,
                events: event_tx.clone(),
                pacing: cfg.pacing,
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bcgc-worker-{w}"))
                    .spawn(move || worker::run(ctx))
                    .map_err(|e| Error::Runtime(format!("spawn: {e}")))?,
            );
        }

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = cfg.stall_timeout;

        // Seed the drift detector with the parameters the initial scheme
        // is presumed optimal for (when the phase-0 model is shifted-exp).
        let controller = cfg.adaptive.clone().map(|acfg| match schedule.dist_at(0).as_shifted_exp()
        {
            Some(d) => AdaptiveController::with_reference(acfg, d.mu, d.t0),
            None => AdaptiveController::new(acfg),
        });
        let sampler = StragglerSampler::from_schedule(schedule, rng.next_u64());
        let state = if cfg.init_scale > 0.0 {
            ModelState::random(dim, cfg.init_scale, &mut rng)
        } else {
            ModelState::zeros(dim)
        };

        let mut report = TrainReport::default();
        report.scheme_epochs.push(SchemeEpoch {
            epoch: 0,
            installed_at_iter: 0,
            block_sizes: cfg.blocks.sizes().to_vec(),
            estimated_mu: None,
            estimated_t0: None,
            estimated_mean: None,
            family: None,
            drift: 0.0,
        });
        let failed_set = cfg.dead_workers.clone();

        let mut session = Self {
            cfg,
            dim,
            num_data_shards: n,
            scheme,
            epoch: 0,
            master,
            registry,
            task_txs,
            event_tx,
            event_rx,
            handles,
            factory,
            sampler,
            state,
            eval_exec: None,
            live_mask,
            failed_set,
            controller,
            rng,
            report,
        };
        if session.cfg.eval_every > 0 {
            if let Some(e) = eval_exec.as_mut() {
                let l = e.loss(session.state.as_slice())?;
                session.report.loss_curve.push((0, l));
            }
        }
        session.eval_exec = eval_exec;
        Ok(session)
    }

    /// The current scheme epoch (0-based, monotone).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        &self.scheme
    }

    /// The membership registry (id ↔ row bindings, churn counters).
    pub fn registry(&self) -> &WorkerRegistry {
        &self.registry
    }

    /// Spawn a new worker thread into the pool. It is registered as
    /// pending and **receives no work until the next epoch swap**: its
    /// `Joined` event confirms the executor came up, and the following
    /// [`Self::maybe_redimension`] binds it to a code row of a fresh,
    /// re-dimensioned scheme epoch.
    pub fn add_worker(&mut self, iter: usize) -> Result<WorkerId> {
        if self.cfg.elastic.is_none() {
            return Err(Error::InvalidArgument(
                "add_worker requires an elastic pool (TrainConfig::elastic)".into(),
            ));
        }
        let id = self.registry.join();
        let (tx, rx) = mpsc::channel::<WorkerTask>();
        if self.task_txs.len() <= id {
            self.task_txs.resize_with(id + 1, || None);
        }
        self.task_txs[id] = Some(tx);
        let ctx = WorkerContext {
            id,
            factory: self.factory.clone(),
            tasks: rx,
            events: self.event_tx.clone(),
            pacing: self.cfg.pacing,
        };
        self.handles.push(
            std::thread::Builder::new()
                .name(format!("bcgc-worker-{id}"))
                .spawn(move || worker::run(ctx))
                .map_err(|e| Error::Runtime(format!("spawn: {e}")))?,
        );
        crate::log_info!("iter {iter}: worker {id} joined (pending next epoch)");
        self.report
            .membership
            .push(MembershipRecord { iter, event: MembershipEvent::Join { worker: id } });
        Ok(id)
    }

    /// Drain a worker out of the pool without dropping an iteration:
    /// its thread finishes cleanly, its row counts as a fatal straggler
    /// for the remainder of the current epoch, and the next
    /// [`Self::maybe_redimension`] drops it from the roster.
    pub fn remove_worker(&mut self, id: WorkerId, iter: usize) -> Result<()> {
        if self.cfg.elastic.is_none() {
            return Err(Error::InvalidArgument(
                "remove_worker requires an elastic pool (TrainConfig::elastic)".into(),
            ));
        }
        if self.registry.status(id) != Some(MemberStatus::Active)
            && self.registry.status(id) != Some(MemberStatus::Pending)
        {
            return Err(Error::InvalidArgument(format!(
                "worker {id} is not a live pool member"
            )));
        }
        if let Some(tx) = self.task_txs.get_mut(id).and_then(Option::take) {
            let _ = tx.send(WorkerTask::Drain);
        }
        self.mark_departed(id);
        crate::log_info!("iter {iter}: worker {id} draining out of the pool");
        self.report
            .membership
            .push(MembershipRecord { iter, event: MembershipEvent::Leave { worker: id } });
        Ok(())
    }

    /// Shared departure bookkeeping (clean drain and fatal failure):
    /// the registry marks the id departed — keeping its row for the
    /// rest of the epoch — its task channel is dropped, and its row, if
    /// any, goes dead in the live mask.
    fn mark_departed(&mut self, id: WorkerId) {
        self.registry.leave(id);
        if let Some(tx) = self.task_txs.get_mut(id) {
            *tx = None;
        }
        if let Some(row) = self.registry.row_of(id) {
            if row < self.live_mask.len() {
                self.live_mask[row] = false;
            }
        }
    }

    /// Apply the config's scheduled churn for iteration `iter`
    /// (arrivals first, then departures of the highest-row live
    /// workers). No-op without an elastic config.
    pub fn apply_scheduled_churn(&mut self, iter: usize) -> Result<()> {
        let (arrive, depart) = match &self.cfg.elastic {
            None => return Ok(()),
            Some(e) => (
                e.arrivals.iter().filter(|&&(at, _)| at == iter).map(|&(_, c)| c).sum::<usize>(),
                e.departures.iter().filter(|&&(at, _)| at == iter).map(|&(_, c)| c).sum::<usize>(),
            ),
        };
        for _ in 0..arrive {
            self.add_worker(iter)?;
        }
        for _ in 0..depart {
            let victim = self
                .registry
                .roster()
                .iter()
                .rev()
                .copied()
                .find(|&id| self.registry.status(id) == Some(MemberStatus::Active));
            match victim {
                Some(id) => self.remove_worker(id, iter)?,
                None => {
                    return Err(Error::Runtime(format!(
                        "iter {iter}: scheduled departure but no live worker remains"
                    )))
                }
            }
        }
        Ok(())
    }

    /// Poll the adaptive policy before iteration `iter`; on a triggered
    /// re-plan, install the re-optimized scheme as a new epoch.
    pub fn adapt(&mut self, iter: usize) -> Result<()> {
        if self.controller.is_none() {
            return Ok(());
        }
        let warm = self.scheme.blocks().as_f64();
        let plan = {
            let ctrl = self.controller.as_mut().unwrap();
            ctrl.maybe_replan(iter, &self.cfg.spec, &warm, &mut self.rng)?
        };
        if let Some(plan) = plan {
            crate::log_info!(
                "iter {iter}: drift {:.2} → installing scheme epoch {} (fit {})",
                plan.drift,
                self.epoch + 1,
                plan.estimate.label()
            );
            self.install_scheme(plan.blocks, iter, Some(&plan.estimate), plan.drift)?;
        }
        Ok(())
    }

    /// Membership epochs: once churn since the last rebind reaches the
    /// threshold — or immediately when departures exceed what the live
    /// scheme's redundancy can absorb — re-solve the partition for the
    /// live roster's `N'` (the existing adaptive re-solve, wired to the
    /// new worker count), rebind rows, and install the re-dimensioned
    /// scheme as a fresh epoch. Returns whether a re-dimension happened.
    pub fn maybe_redimension(&mut self, iter: usize) -> Result<bool> {
        let Some(threshold) = self.cfg.elastic.as_ref().map(|e| e.churn_threshold.max(1))
        else {
            return Ok(false);
        };
        let dead_rows = self.registry.departed_in_roster();
        let min_s = self.scheme.ranges().iter().map(|r| r.s).min().unwrap_or(0);
        let forced = dead_rows > min_s;
        if !forced && self.registry.churn_since_rebind() < threshold {
            return Ok(false);
        }
        let from_n = self.cfg.spec.n;
        let to_n = self.registry.next_n();
        if to_n == 0 {
            return Err(Error::Runtime(format!(
                "iter {iter}: elastic pool drained to zero workers"
            )));
        }
        // Re-solve with the *new* N. Evidence, in order of preference:
        // the online estimator's live family-selected fit, then the
        // schedule's current phase (when shifted-exp), else a uniform
        // level-1 fallback.
        let spec_new = self.cfg.spec.with_n(to_n);
        let estimate: Option<FittedModel> = self
            .controller
            .as_ref()
            .and_then(|c| c.current_fit())
            .or_else(|| {
                self.sampler.distribution_at(iter).as_shifted_exp().map(|d| {
                    FittedModel::ShiftedExp(ShiftedExpEstimate {
                        mu: d.mu,
                        t0: d.t0,
                        samples: 0,
                    })
                })
            });
        let strategy = self
            .cfg
            .adaptive
            .as_ref()
            .map(|a| a.strategy.clone())
            .unwrap_or(ResolveStrategy::ClosedFormFreq);
        let warm = self.scheme.blocks().as_f64();
        let blocks = match &estimate {
            Some(est) => {
                let dist = est.build();
                adaptive::resolve_partition(
                    &strategy,
                    &spec_new,
                    dist.as_ref(),
                    Some(warm.as_slice()),
                    self.dim,
                    &mut self.rng,
                )?
            }
            None => {
                let s = if to_n > 1 { 1 } else { 0 };
                BlockPartition::single_level(to_n, s, self.dim)
            }
        };

        // Rebind rows and install the re-dimensioned scheme atomically
        // (from the workers' point of view: with their next task).
        let roster = self.registry.rebind().to_vec();
        debug_assert_eq!(roster.len(), to_n);
        self.cfg.spec.n = to_n;
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        self.master.install_scheme(
            scheme,
            self.epoch,
            roster,
            Arc::new(redistribute_shards(to_n, self.num_data_shards)),
        );
        self.live_mask = vec![true; to_n];
        crate::log_info!(
            "iter {iter}: re-dimensioned N {from_n}→{to_n} as scheme epoch {}",
            self.epoch
        );
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.as_ref().and_then(|e| e.mu_hint()),
            estimated_t0: estimate.as_ref().and_then(|e| e.t0_hint()),
            estimated_mean: estimate.as_ref().map(|e| e.mean()),
            family: estimate.as_ref().map(|e| e.family().name().to_string()),
            drift: 0.0,
        });
        self.report.membership.push(MembershipRecord {
            iter,
            event: MembershipEvent::Redimension { from_n, to_n, epoch: self.epoch },
        });
        // The re-dimension changed N (and with it the per-coordinate
        // unit of work): observations recorded under the old epoch are
        // no longer comparable, so flush the estimator window and
        // rebase the drift reference on the model this scheme was
        // solved for.
        if let Some(ctrl) = self.controller.as_mut() {
            ctrl.rebase(estimate);
        }
        Ok(true)
    }

    /// Install a new same-`N` partition as the next scheme epoch. Safe
    /// between iterations: workers receive the new scheme with their
    /// next task, and the master rejects contributions encoded under any
    /// previous epoch like stale-iteration messages. (Re-dimensioning to
    /// a different `N` goes through [`Self::maybe_redimension`].)
    pub fn install_scheme(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
    ) -> Result<()> {
        if blocks.n() != self.cfg.spec.n {
            return Err(Error::InvalidArgument("new scheme: blocks.n() != spec.n".into()));
        }
        if blocks.total() != self.dim {
            return Err(Error::InvalidArgument(format!(
                "new scheme covers {} coordinates but the model has {}",
                blocks.total(),
                self.dim
            )));
        }
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        let roster = self.master.roster().to_vec();
        let shards = self.master.shard_map().clone();
        self.master.install_scheme(scheme, self.epoch, roster, shards);
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.and_then(|e| e.mu_hint()),
            estimated_t0: estimate.and_then(|e| e.t0_hint()),
            estimated_mean: estimate.map(|e| e.mean()),
            family: estimate.map(|e| e.family().name().to_string()),
            drift,
        });
        Ok(())
    }

    /// One coded GD iteration under the current scheme epoch.
    pub fn step(&mut self, iter: usize) -> Result<()> {
        let t_iter = Instant::now();
        let n = self.cfg.spec.n;
        debug_assert_eq!(n, self.registry.n());
        let times = self.sampler.sample(iter, n);
        if let Some(ctrl) = self.controller.as_mut() {
            ctrl.observe(&times);
        }
        // Row-ordered task channels for the current roster (None where
        // the bound worker already departed).
        let senders: Vec<Option<Sender<WorkerTask>>> = self
            .registry
            .roster()
            .iter()
            .map(|&id| self.task_txs.get(id).cloned().flatten())
            .collect();
        self.master.broadcast(
            iter,
            self.state.shared(),
            &times,
            self.cfg.spec.unit_work(),
            &senders,
        );
        let outcome = self.master.collect(iter, &self.event_rx, &self.live_mask)?;
        for id in outcome.joined {
            self.registry.confirm(id);
        }
        for id in outcome.left {
            // Clean departures observed mid-iteration (their Leave was
            // already logged by remove_worker); keep masks in sync.
            self.mark_departed(id);
        }
        for id in outcome.failed {
            if !self.failed_set.contains(&id) {
                self.failed_set.push(id);
                // Elastic pools treat a fatal failure as a departure; a
                // static run's membership log stays empty by contract.
                if self.cfg.elastic.is_some() {
                    self.report.membership.push(MembershipRecord {
                        iter,
                        event: MembershipEvent::Leave { worker: id },
                    });
                }
            }
            // A fatal failure is a departure the worker never got to
            // announce: same bookkeeping as a drain.
            self.mark_departed(id);
        }
        let grad_norm = outcome.gradient.iter().map(|g| g * g).sum::<f64>().sqrt();
        self.state.step(&outcome.gradient, self.cfg.lr);
        self.report.iters.push(IterMetrics {
            iter,
            epoch: self.epoch,
            workers: n,
            virtual_runtime: virtual_runtime(&self.cfg.spec, &self.scheme, &times),
            wall_ns: t_iter.elapsed().as_nanos() as u64,
            decode_ns: outcome.decode_ns,
            blocks_decoded: self.scheme.ranges().len(),
            late_contributions: outcome.late_contributions,
            stale_epoch_contributions: outcome.stale_epoch + outcome.mismatched_binding,
            grad_norm,
        });
        if self.cfg.eval_every > 0 && (iter + 1) % self.cfg.eval_every == 0 {
            if let Some(e) = self.eval_exec.as_mut() {
                let l = e.loss(self.state.as_slice())?;
                self.report.loss_curve.push((iter + 1, l));
            }
        }
        Ok(())
    }

    /// Shut the topology down and produce the report.
    pub fn finish(mut self) -> Result<TrainReport> {
        for tx in self.task_txs.iter().flatten() {
            let _ = tx.send(WorkerTask::Shutdown);
        }
        self.task_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let (hits, misses) = self.master.cache_stats();
        self.report.decode_cache_hits = hits;
        self.report.decode_cache_misses = misses;
        self.report.failed_workers = self.failed_set;
        Ok(self.report)
    }
}
