//! Single-job training facade over the multi-job worker pool.
//!
//! The coordinator's real engine lives in [`crate::coordinator::pool`]:
//! a [`WorkerPool`] owns the threads, registry and channels, and any
//! number of [`JobSpec`]-submitted jobs run interleaved on it. Most
//! callers train exactly one model, so this module keeps the classic
//! one-job surface:
//!
//! * [`train`] / [`train_stationary`] — run a [`TrainConfig`] to
//!   completion and return its [`TrainReport`] (what `Trainer::run` used
//!   to do);
//! * [`TrainSession`] — a driveable session (per-iteration `step`,
//!   `adapt`, `maybe_redimension`, explicit `add_worker` /
//!   `remove_worker`, manual `install_scheme`) for embedders that need
//!   custom control flow. It is a thin veneer over a single-job
//!   [`WorkerPool`]: pool rounds and job iterations coincide.
//!
//! Multi-job callers go to the pool directly:
//!
//! ```ignore
//! let mut pool = WorkerPool::new(PoolConfig::new(n), schedule)?;
//! JobSpec::new(spec_a, blocks_a).executor(fac_a).submit(&mut pool)?;
//! JobSpec::new(spec_b, blocks_b).executor(fac_b).submit(&mut pool)?;
//! let reports = pool.run_to_completion()?;
//! ```
//!
//! The pre-pool [`Trainer`] struct survives as a deprecated shim for
//! one release; all in-repo callers have been migrated.

use std::sync::Arc;

use crate::coding::scheme::CodingScheme;
use crate::coordinator::membership::{WorkerId, WorkerRegistry};
use crate::coordinator::metrics::TrainReport;
use crate::coordinator::pool::{JobHandle, JobSpec, PoolConfig, WorkerPool};
// Re-exported from the pool (membership is a pool-level concern now);
// kept importable from `trainer` for source compatibility.
pub use crate::coordinator::pool::ElasticConfig;
use crate::coordinator::adaptive::AdaptiveConfig;
use crate::coordinator::straggler::StragglerSchedule;
use crate::coordinator::PacingMode;
use crate::distribution::fit::FittedModel;
use crate::distribution::CycleTimeDistribution;
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::ExecutorFactory;
use crate::Result;

/// Training configuration for a single job on its own pool.
pub struct TrainConfig {
    pub spec: ProblemSpec,
    /// The initial (epoch-0) block partition.
    pub blocks: BlockPartition,
    pub steps: usize,
    pub lr: f64,
    /// Evaluate the loss every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub pacing: PacingMode,
    pub seed: u64,
    /// Worker ids that are never spawned — failure injection. The coded
    /// scheme must tolerate up to `min_s` of them.
    pub dead_workers: Vec<usize>,
    /// θ init scale (Gaussian); 0 = zeros.
    pub init_scale: f64,
    /// How long the master waits on an empty event channel before
    /// declaring the iteration stalled.
    pub stall_timeout: std::time::Duration,
    /// Online re-optimization policy (None = the scheme stays fixed).
    pub adaptive: Option<AdaptiveConfig>,
    /// Elastic worker-pool policy (None = `N` frozen at spawn, the
    /// paper's setting).
    pub elastic: Option<ElasticConfig>,
    /// How workers are reached (in-process threads by default; remote
    /// TCP peers under `--features tcp` — see [`crate::transport`]).
    pub transport: crate::transport::TransportConfig,
}

impl TrainConfig {
    pub fn new(spec: ProblemSpec, blocks: BlockPartition) -> Self {
        Self {
            spec,
            blocks,
            steps: 100,
            lr: 1e-2,
            eval_every: 10,
            pacing: PacingMode::Virtual,
            seed: 2021,
            dead_workers: Vec::new(),
            init_scale: 0.05,
            stall_timeout: std::time::Duration::from_secs(30),
            adaptive: None,
            elastic: None,
            transport: crate::transport::TransportConfig::default(),
        }
    }
}

/// Run a [`TrainConfig`] to completion under a (possibly
/// non-stationary) straggler schedule and return the job's report —
/// the whole churn → adapt → re-dimension → step loop per iteration.
pub fn train(
    cfg: TrainConfig,
    schedule: StragglerSchedule,
    factory: ExecutorFactory,
) -> Result<TrainReport> {
    let steps = cfg.steps;
    let mut session = TrainSession::start(cfg, schedule, factory)?;
    for iter in 0..steps {
        session.apply_scheduled_churn(iter)?;
        session.adapt(iter)?;
        session.maybe_redimension(iter)?;
        session.step(iter)?;
    }
    session.finish()
}

/// [`train`] under the paper's stationary straggler model.
pub fn train_stationary(
    cfg: TrainConfig,
    dist: Box<dyn CycleTimeDistribution>,
    factory: ExecutorFactory,
) -> Result<TrainReport> {
    train(cfg, StragglerSchedule::stationary(dist), factory)
}

/// [`train`] on a **heterogeneous fleet**: worker id `w` draws its
/// cycle times from `fleet[w]`'s own model (non-i.i.d. workers — what
/// the `[hetero]` engine senses and actuates against); `schedule`
/// stays the pooled fallback/prior.
pub fn train_fleet(
    cfg: TrainConfig,
    schedule: StragglerSchedule,
    fleet: Vec<Box<dyn CycleTimeDistribution>>,
    factory: ExecutorFactory,
) -> Result<TrainReport> {
    let steps = cfg.steps;
    let mut session = TrainSession::start_fleet(cfg, schedule, fleet, factory)?;
    for iter in 0..steps {
        session.apply_scheduled_churn(iter)?;
        session.adapt(iter)?;
        session.maybe_redimension(iter)?;
        session.step(iter)?;
    }
    session.finish()
}

/// A live single-job topology: one [`WorkerPool`] carrying exactly one
/// job, exposed through the classic per-iteration driving surface.
/// Pool rounds and job iterations coincide, so the `iter` arguments
/// below are the job's 0-based iteration counter.
pub struct TrainSession {
    pool: WorkerPool,
    job: usize,
}

impl TrainSession {
    /// Setup phase: spawn the pool and submit the one job (validates
    /// the config, builds the epoch-0 scheme).
    pub fn start(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        factory: ExecutorFactory,
    ) -> Result<Self> {
        Self::start_inner(cfg, schedule, None, factory)
    }

    /// [`Self::start`] on a heterogeneous fleet: worker id `w`'s cycle
    /// times come from `fleet[w]`'s own model (see
    /// [`WorkerPool::new_fleet`]).
    pub fn start_fleet(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        fleet: Vec<Box<dyn CycleTimeDistribution>>,
        factory: ExecutorFactory,
    ) -> Result<Self> {
        Self::start_inner(cfg, schedule, Some(fleet), factory)
    }

    fn start_inner(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        fleet: Option<Vec<Box<dyn CycleTimeDistribution>>>,
        factory: ExecutorFactory,
    ) -> Result<Self> {
        let mut pcfg = PoolConfig::new(cfg.spec.n);
        pcfg.pacing = cfg.pacing;
        pcfg.seed = cfg.seed;
        pcfg.stall_timeout = cfg.stall_timeout;
        pcfg.dead_workers = cfg.dead_workers.clone();
        pcfg.elastic = cfg.elastic.clone();
        pcfg.transport = cfg.transport.clone();
        let mut pool = match fleet {
            Some(fleet) => WorkerPool::new_fleet(pcfg, schedule, fleet)?,
            None => WorkerPool::new(pcfg, schedule)?,
        };
        let mut js = JobSpec::new(cfg.spec, cfg.blocks)
            .steps(cfg.steps)
            .lr(cfg.lr)
            .eval_every(cfg.eval_every)
            .seed(cfg.seed)
            .init_scale(cfg.init_scale)
            .executor(factory);
        if let Some(a) = cfg.adaptive {
            js = js.adaptive(a);
        }
        let job = js.submit(&mut pool)?;
        Ok(Self { pool, job })
    }

    /// The job's live state on the pool.
    pub fn job(&self) -> &JobHandle {
        self.pool.job(self.job)
    }

    /// The underlying pool (registry, rounds, makespan accounting).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// The current scheme epoch (0-based, monotone).
    pub fn epoch(&self) -> usize {
        self.job().epoch()
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        self.pool.job(self.job).scheme()
    }

    /// The membership registry (id ↔ row bindings, churn counters).
    pub fn registry(&self) -> &WorkerRegistry {
        self.pool.registry()
    }

    /// Spawn a new worker thread into the pool (see
    /// [`WorkerPool::add_worker`]); it waits unassigned until the next
    /// epoch swap.
    pub fn add_worker(&mut self, iter: usize) -> Result<WorkerId> {
        let _ = iter; // rounds == iterations on a single-job pool
        self.pool.add_worker()
    }

    /// Drain a worker out of the pool (see
    /// [`WorkerPool::remove_worker`]).
    pub fn remove_worker(&mut self, id: WorkerId, iter: usize) -> Result<()> {
        let _ = iter;
        self.pool.remove_worker(id)
    }

    /// Apply the config's scheduled churn for iteration `iter`
    /// (arrivals first, then departures). No-op without an elastic
    /// config.
    pub fn apply_scheduled_churn(&mut self, iter: usize) -> Result<()> {
        self.pool.apply_scheduled_churn_at(iter)
    }

    /// Poll the adaptive policy before iteration `iter`; on a triggered
    /// re-plan, install the re-optimized scheme as a new epoch.
    pub fn adapt(&mut self, iter: usize) -> Result<()> {
        debug_assert_eq!(iter, self.job().iters_done(), "sessions step contiguously");
        self.pool.adapt_job(self.job)
    }

    /// Membership epochs (see [`WorkerPool::maybe_redimension`]).
    /// Returns whether a re-dimension happened.
    pub fn maybe_redimension(&mut self, iter: usize) -> Result<bool> {
        let _ = iter;
        self.pool.maybe_redimension()
    }

    /// Install a new same-`N` partition as the next scheme epoch (see
    /// [`JobHandle::install_scheme`]).
    pub fn install_scheme(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
    ) -> Result<()> {
        self.pool.install_scheme(self.job, blocks, iter, estimate, drift)
    }

    /// One coded GD iteration under the current scheme epoch.
    pub fn step(&mut self, iter: usize) -> Result<()> {
        debug_assert_eq!(iter, self.job().iters_done(), "sessions step contiguously");
        self.pool.step_job(self.job)
    }

    /// Shut the topology down and produce the report.
    pub fn finish(self) -> Result<TrainReport> {
        let job = self.job;
        let mut reports = self.pool.finish()?;
        Ok(reports.remove(job))
    }
}

/// Pre-pool driver, kept as a thin shim for one release.
#[deprecated(
    since = "0.3.0",
    note = "use coordinator::pool::{WorkerPool, JobSpec} (multi-job) or \
            coordinator::trainer::train / TrainSession (single job)"
)]
pub struct Trainer {
    cfg: TrainConfig,
    schedule: StragglerSchedule,
    factory: ExecutorFactory,
}

#[allow(deprecated)]
impl Trainer {
    /// Stationary straggler model (the paper's setting).
    pub fn new(
        cfg: TrainConfig,
        dist: Box<dyn CycleTimeDistribution>,
        factory: ExecutorFactory,
    ) -> Self {
        Self::with_schedule(cfg, StragglerSchedule::stationary(dist), factory)
    }

    /// Piecewise-stationary straggler model: the distribution may shift
    /// mid-training (what the adaptive engine is for).
    pub fn with_schedule(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        factory: ExecutorFactory,
    ) -> Self {
        Self { cfg, schedule, factory }
    }

    /// Run the full training loop.
    pub fn run(self) -> Result<TrainReport> {
        train(self.cfg, self.schedule, self.factory)
    }
}
