//! The end-to-end trainer, decomposed into a setup phase and an
//! iteration loop so the coding scheme can be **hot-swapped between
//! iterations** (adaptive coding engine).
//!
//! [`Trainer::run`] = [`TrainSession::start`] (validate, build the
//! epoch-0 scheme, spawn the worker topology) + a loop of
//! [`TrainSession::adapt`] (poll the drift detector, install a
//! re-optimized scheme as a new epoch) and [`TrainSession::step`] (one
//! coded GD iteration) + [`TrainSession::finish`] (shutdown + report).
//! Embedders that need custom control flow (manual scheme installs,
//! interleaved evaluation…) can drive a [`TrainSession`] directly.

use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::coding::scheme::CodingScheme;
use crate::coordinator::adaptive::{AdaptiveConfig, AdaptiveController};
use crate::coordinator::channel::{WorkerEvent, WorkerTask};
use crate::coordinator::master::Master;
use crate::coordinator::metrics::{IterMetrics, SchemeEpoch, TrainReport};
use crate::coordinator::state::ModelState;
use crate::coordinator::straggler::{virtual_runtime, StragglerSampler, StragglerSchedule};
use crate::coordinator::worker::{self, WorkerContext};
use crate::coordinator::PacingMode;
use crate::distribution::fit::ShiftedExpEstimate;
use crate::distribution::CycleTimeDistribution;
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::{ExecutorFactory, GradExecutor};
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Training configuration.
pub struct TrainConfig {
    pub spec: ProblemSpec,
    /// The initial (epoch-0) block partition.
    pub blocks: BlockPartition,
    pub steps: usize,
    pub lr: f64,
    /// Evaluate the loss every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub pacing: PacingMode,
    pub seed: u64,
    /// Worker ids that are never spawned — failure injection. The coded
    /// scheme must tolerate up to `min_s` of them.
    pub dead_workers: Vec<usize>,
    /// θ init scale (Gaussian); 0 = zeros.
    pub init_scale: f64,
    /// How long the master waits on an empty event channel before
    /// declaring the iteration stalled.
    pub stall_timeout: std::time::Duration,
    /// Online re-optimization policy (None = the scheme stays fixed).
    pub adaptive: Option<AdaptiveConfig>,
}

impl TrainConfig {
    pub fn new(spec: ProblemSpec, blocks: BlockPartition) -> Self {
        Self {
            spec,
            blocks,
            steps: 100,
            lr: 1e-2,
            eval_every: 10,
            pacing: PacingMode::Virtual,
            seed: 2021,
            dead_workers: Vec::new(),
            init_scale: 0.05,
            stall_timeout: std::time::Duration::from_secs(30),
            adaptive: None,
        }
    }
}

/// Coded distributed GD driver.
pub struct Trainer {
    cfg: TrainConfig,
    schedule: StragglerSchedule,
    factory: ExecutorFactory,
}

impl Trainer {
    /// Stationary straggler model (the paper's setting).
    pub fn new(
        cfg: TrainConfig,
        dist: Box<dyn CycleTimeDistribution>,
        factory: ExecutorFactory,
    ) -> Self {
        Self::with_schedule(cfg, StragglerSchedule::stationary(dist), factory)
    }

    /// Piecewise-stationary straggler model: the distribution may shift
    /// mid-training (what the adaptive engine is for).
    pub fn with_schedule(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        factory: ExecutorFactory,
    ) -> Self {
        Self { cfg, schedule, factory }
    }

    /// Run the full training loop.
    pub fn run(self) -> Result<TrainReport> {
        let steps = self.cfg.steps;
        let mut session = TrainSession::start(self.cfg, self.schedule, self.factory)?;
        for iter in 0..steps {
            session.adapt(iter)?;
            session.step(iter)?;
        }
        session.finish()
    }
}

/// A live worker topology plus all per-run mutable state.
pub struct TrainSession {
    cfg: TrainConfig,
    dim: usize,
    scheme: Arc<CodingScheme>,
    epoch: usize,
    master: Master,
    task_txs: Vec<Sender<WorkerTask>>,
    event_rx: Receiver<WorkerEvent>,
    handles: Vec<std::thread::JoinHandle<()>>,
    sampler: StragglerSampler,
    state: ModelState,
    eval_exec: Option<Box<dyn GradExecutor>>,
    live_mask: Vec<bool>,
    failed_set: Vec<usize>,
    controller: Option<AdaptiveController>,
    rng: Rng,
    report: TrainReport,
}

impl TrainSession {
    /// Setup phase: validate the config, build the epoch-0 scheme and
    /// spawn the worker topology.
    pub fn start(
        cfg: TrainConfig,
        schedule: StragglerSchedule,
        factory: ExecutorFactory,
    ) -> Result<Self> {
        let n = cfg.spec.n;
        if cfg.blocks.n() != n {
            return Err(Error::InvalidArgument("blocks.n() != spec.n".into()));
        }
        let mut rng = Rng::new(cfg.seed);
        let scheme = Arc::new(CodingScheme::new(cfg.blocks.clone(), &mut rng)?);

        // Master-side executor for loss evaluation (worker id n = master).
        let mut eval_exec = if cfg.eval_every > 0 { Some(factory(n)?) } else { None };
        let dim = if let Some(e) = &eval_exec {
            e.dim()
        } else {
            factory(n)?.dim()
        };
        if dim != cfg.spec.coords {
            crate::log_warn!(
                "model dim {} != spec.coords {} — virtual-runtime accounting uses the model dim",
                dim,
                cfg.spec.coords
            );
        }
        if cfg.blocks.total() != dim {
            return Err(Error::InvalidArgument(format!(
                "block partition covers {} coordinates but the model has {dim}",
                cfg.blocks.total()
            )));
        }

        // Topology: per-worker task channels + one shared event channel.
        let (event_tx, event_rx) = mpsc::channel::<WorkerEvent>();
        let mut task_txs = Vec::with_capacity(n);
        let mut handles = Vec::new();
        let mut live_mask = vec![false; n];
        for w in 0..n {
            let (tx, rx) = mpsc::channel::<WorkerTask>();
            task_txs.push(tx);
            if cfg.dead_workers.contains(&w) {
                continue; // injected failure: worker never comes up
            }
            live_mask[w] = true;
            let ctx = WorkerContext {
                id: w,
                spec: cfg.spec,
                factory: factory.clone(),
                tasks: rx,
                events: event_tx.clone(),
                pacing: cfg.pacing,
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bcgc-worker-{w}"))
                    .spawn(move || worker::run(ctx))
                    .map_err(|e| Error::Runtime(format!("spawn: {e}")))?,
            );
        }
        drop(event_tx);

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = cfg.stall_timeout;

        // Seed the drift detector with the parameters the initial scheme
        // is presumed optimal for (when the phase-0 model is shifted-exp).
        let controller = cfg.adaptive.clone().map(|acfg| match schedule.dist_at(0).as_shifted_exp()
        {
            Some(d) => AdaptiveController::with_reference(acfg, d.mu, d.t0),
            None => AdaptiveController::new(acfg),
        });
        let sampler = StragglerSampler::from_schedule(schedule, rng.next_u64());
        let state = if cfg.init_scale > 0.0 {
            ModelState::random(dim, cfg.init_scale, &mut rng)
        } else {
            ModelState::zeros(dim)
        };

        let mut report = TrainReport::default();
        report.scheme_epochs.push(SchemeEpoch {
            epoch: 0,
            installed_at_iter: 0,
            block_sizes: cfg.blocks.sizes().to_vec(),
            estimated_mu: None,
            estimated_t0: None,
            drift: 0.0,
        });
        let failed_set = cfg.dead_workers.clone();

        let mut session = Self {
            cfg,
            dim,
            scheme,
            epoch: 0,
            master,
            task_txs,
            event_rx,
            handles,
            sampler,
            state,
            eval_exec: None,
            live_mask,
            failed_set,
            controller,
            rng,
            report,
        };
        if session.cfg.eval_every > 0 {
            if let Some(e) = eval_exec.as_mut() {
                let l = e.loss(session.state.as_slice())?;
                session.report.loss_curve.push((0, l));
            }
        }
        session.eval_exec = eval_exec;
        Ok(session)
    }

    /// The current scheme epoch (0-based, monotone).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        &self.scheme
    }

    /// Poll the adaptive policy before iteration `iter`; on a triggered
    /// re-plan, install the re-optimized scheme as a new epoch.
    pub fn adapt(&mut self, iter: usize) -> Result<()> {
        if self.controller.is_none() {
            return Ok(());
        }
        let warm = self.scheme.blocks().as_f64();
        let plan = {
            let ctrl = self.controller.as_mut().unwrap();
            ctrl.maybe_replan(iter, &self.cfg.spec, &warm, &mut self.rng)?
        };
        if let Some(plan) = plan {
            crate::log_info!(
                "iter {iter}: drift {:.2} → installing scheme epoch {} (fit mu={:.3e}, t0={:.1})",
                plan.drift,
                self.epoch + 1,
                plan.estimate.mu,
                plan.estimate.t0
            );
            self.install_scheme(plan.blocks, iter, Some(&plan.estimate), plan.drift)?;
        }
        Ok(())
    }

    /// Install a new partition as the next scheme epoch. Safe between
    /// iterations: workers receive the new scheme with their next task,
    /// and the master rejects contributions encoded under any previous
    /// epoch like stale-iteration messages.
    pub fn install_scheme(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&ShiftedExpEstimate>,
        drift: f64,
    ) -> Result<()> {
        if blocks.n() != self.cfg.spec.n {
            return Err(Error::InvalidArgument("new scheme: blocks.n() != spec.n".into()));
        }
        if blocks.total() != self.dim {
            return Err(Error::InvalidArgument(format!(
                "new scheme covers {} coordinates but the model has {}",
                blocks.total(),
                self.dim
            )));
        }
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        self.master.install_scheme(scheme, self.epoch);
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.map(|e| e.mu),
            estimated_t0: estimate.map(|e| e.t0),
            drift,
        });
        Ok(())
    }

    /// One coded GD iteration under the current scheme epoch.
    pub fn step(&mut self, iter: usize) -> Result<()> {
        let t_iter = Instant::now();
        let times = self.sampler.sample(iter, self.cfg.spec.n);
        if let Some(ctrl) = self.controller.as_mut() {
            ctrl.observe(&times);
        }
        self.master.broadcast(iter, self.state.shared(), &times, &self.task_txs);
        let outcome = self.master.collect(iter, &self.event_rx, &self.live_mask)?;
        for w in outcome.failed {
            if self.live_mask[w] {
                self.live_mask[w] = false;
                self.failed_set.push(w);
            }
        }
        let grad_norm = outcome.gradient.iter().map(|g| g * g).sum::<f64>().sqrt();
        self.state.step(&outcome.gradient, self.cfg.lr);
        self.report.iters.push(IterMetrics {
            iter,
            epoch: self.epoch,
            virtual_runtime: virtual_runtime(&self.cfg.spec, &self.scheme, &times),
            wall_ns: t_iter.elapsed().as_nanos() as u64,
            decode_ns: outcome.decode_ns,
            blocks_decoded: self.scheme.ranges().len(),
            late_contributions: outcome.late_contributions,
            stale_epoch_contributions: outcome.stale_epoch,
            grad_norm,
        });
        if self.cfg.eval_every > 0 && (iter + 1) % self.cfg.eval_every == 0 {
            if let Some(e) = self.eval_exec.as_mut() {
                let l = e.loss(self.state.as_slice())?;
                self.report.loss_curve.push((iter + 1, l));
            }
        }
        Ok(())
    }

    /// Shut the topology down and produce the report.
    pub fn finish(mut self) -> Result<TrainReport> {
        for tx in &self.task_txs {
            let _ = tx.send(WorkerTask::Shutdown);
        }
        self.task_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let (hits, misses) = self.master.cache_stats();
        self.report.decode_cache_hits = hits;
        self.report.decode_cache_misses = misses;
        self.report.failed_workers = self.failed_set;
        Ok(self.report)
    }
}
