//! The end-to-end trainer: spawns the worker topology, runs coded
//! gradient descent, and produces a [`TrainReport`].

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

use crate::coding::scheme::CodingScheme;
use crate::coordinator::channel::{WorkerEvent, WorkerTask};
use crate::coordinator::master::Master;
use crate::coordinator::metrics::{IterMetrics, TrainReport};
use crate::coordinator::state::ModelState;
use crate::coordinator::straggler::{virtual_runtime, StragglerSampler};
use crate::coordinator::worker::{self, WorkerContext};
use crate::coordinator::PacingMode;
use crate::distribution::CycleTimeDistribution;
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::ExecutorFactory;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Training configuration.
pub struct TrainConfig {
    pub spec: ProblemSpec,
    pub blocks: BlockPartition,
    pub steps: usize,
    pub lr: f64,
    /// Evaluate the loss every `eval_every` steps (0 = never).
    pub eval_every: usize,
    pub pacing: PacingMode,
    pub seed: u64,
    /// Worker ids that are never spawned — failure injection. The coded
    /// scheme must tolerate up to `min_s` of them.
    pub dead_workers: Vec<usize>,
    /// θ init scale (Gaussian); 0 = zeros.
    pub init_scale: f64,
    /// How long the master waits on an empty event channel before
    /// declaring the iteration stalled.
    pub stall_timeout: std::time::Duration,
}

impl TrainConfig {
    pub fn new(spec: ProblemSpec, blocks: BlockPartition) -> Self {
        Self {
            spec,
            blocks,
            steps: 100,
            lr: 1e-2,
            eval_every: 10,
            pacing: PacingMode::Virtual,
            seed: 2021,
            dead_workers: Vec::new(),
            init_scale: 0.05,
            stall_timeout: std::time::Duration::from_secs(30),
        }
    }
}

/// Coded distributed GD driver.
pub struct Trainer {
    cfg: TrainConfig,
    dist: Box<dyn CycleTimeDistribution>,
    factory: ExecutorFactory,
}

impl Trainer {
    pub fn new(
        cfg: TrainConfig,
        dist: Box<dyn CycleTimeDistribution>,
        factory: ExecutorFactory,
    ) -> Self {
        Self { cfg, dist, factory }
    }

    /// Run the full training loop.
    pub fn run(self) -> Result<TrainReport> {
        let Trainer { cfg, dist, factory } = self;
        let n = cfg.spec.n;
        if cfg.blocks.n() != n {
            return Err(Error::InvalidArgument("blocks.n() != spec.n".into()));
        }
        let mut rng = Rng::new(cfg.seed);
        let scheme = Arc::new(CodingScheme::new(cfg.blocks.clone(), &mut rng)?);

        // Master-side executor for loss evaluation (worker id n = master).
        let mut eval_exec = if cfg.eval_every > 0 { Some(factory(n)?) } else { None };
        let dim = if let Some(e) = &eval_exec {
            e.dim()
        } else {
            factory(n)?.dim()
        };
        if dim != cfg.spec.coords {
            log::warn!(
                "model dim {} != spec.coords {} — virtual-runtime accounting uses the model dim",
                dim,
                cfg.spec.coords
            );
        }
        if cfg.blocks.total() != dim {
            return Err(Error::InvalidArgument(format!(
                "block partition covers {} coordinates but the model has {dim}",
                cfg.blocks.total()
            )));
        }

        // Topology: per-worker task channels + one shared event channel.
        let (event_tx, event_rx) = mpsc::channel::<WorkerEvent>();
        let mut task_txs = Vec::with_capacity(n);
        let mut handles = Vec::new();
        let mut live = 0usize;
        for w in 0..n {
            let (tx, rx) = mpsc::channel::<WorkerTask>();
            task_txs.push(tx);
            if cfg.dead_workers.contains(&w) {
                continue; // injected failure: worker never comes up
            }
            live += 1;
            let ctx = WorkerContext {
                id: w,
                spec: cfg.spec,
                scheme: scheme.clone(),
                factory: factory.clone(),
                tasks: rx,
                events: event_tx.clone(),
                pacing: cfg.pacing,
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("bcgc-worker-{w}"))
                    .spawn(move || worker::run(ctx))
                    .map_err(|e| Error::Runtime(format!("spawn: {e}")))?,
            );
        }
        drop(event_tx);

        let mut master = Master::new(scheme.clone(), dim);
        master.timeout = cfg.stall_timeout;
        let mut sampler = StragglerSampler::new(dist, rng.next_u64());
        let mut state = if cfg.init_scale > 0.0 {
            ModelState::random(dim, cfg.init_scale, &mut rng)
        } else {
            ModelState::zeros(dim)
        };

        let mut report = TrainReport::default();
        let mut failed_set: Vec<usize> = cfg.dead_workers.clone();

        if cfg.eval_every > 0 {
            if let Some(e) = eval_exec.as_mut() {
                report.loss_curve.push((0, e.loss(state.as_slice())?));
            }
        }

        for iter in 0..cfg.steps {
            let t_iter = Instant::now();
            let times = sampler.sample(n);
            master.broadcast(iter, state.shared(), &times, &task_txs);
            let outcome = master.collect(iter, &event_rx, live)?;
            for w in outcome.failed {
                if !failed_set.contains(&w) {
                    failed_set.push(w);
                    live -= 1;
                }
            }
            let grad_norm = outcome.gradient.iter().map(|g| g * g).sum::<f64>().sqrt();
            state.step(&outcome.gradient, cfg.lr);
            report.iters.push(IterMetrics {
                iter,
                virtual_runtime: virtual_runtime(&cfg.spec, &scheme, &times),
                wall_ns: t_iter.elapsed().as_nanos() as u64,
                decode_ns: outcome.decode_ns,
                blocks_decoded: scheme.ranges().len(),
                late_contributions: outcome.late_contributions,
                grad_norm,
            });
            if cfg.eval_every > 0 && (iter + 1) % cfg.eval_every == 0 {
                if let Some(e) = eval_exec.as_mut() {
                    report.loss_curve.push((iter + 1, e.loss(state.as_slice())?));
                }
            }
        }

        // Shutdown.
        for tx in &task_txs {
            let _ = tx.send(WorkerTask::Shutdown);
        }
        drop(task_txs);
        for h in handles {
            let _ = h.join();
        }
        let (hits, misses) = master.cache_stats();
        report.decode_cache_hits = hits;
        report.decode_cache_misses = misses;
        report.failed_workers = failed_set;
        Ok(report)
    }
}
