//! Model parameter state owned by the master.

use std::sync::Arc;

use crate::util::rng::Rng;

/// The master's copy of θ, broadcast to workers each iteration.
#[derive(Debug, Clone)]
pub struct ModelState {
    theta: Arc<Vec<f32>>,
}

impl ModelState {
    /// Zero initialization.
    pub fn zeros(dim: usize) -> Self {
        Self { theta: Arc::new(vec![0.0; dim]) }
    }

    /// He-style Gaussian init scaled by `scale`.
    pub fn random(dim: usize, scale: f64, rng: &mut Rng) -> Self {
        Self { theta: Arc::new((0..dim).map(|_| (rng.normal() * scale) as f32).collect()) }
    }

    pub fn dim(&self) -> usize {
        self.theta.len()
    }

    /// Shared read-only handle for broadcast.
    pub fn shared(&self) -> Arc<Vec<f32>> {
        self.theta.clone()
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.theta
    }

    /// Gradient-descent step `θ ← θ − lr·g` (gradient in f64 from decode).
    pub fn step(&mut self, grad: &[f64], lr: f64) {
        assert_eq!(grad.len(), self.theta.len());
        let theta = Arc::make_mut(&mut self.theta);
        for (t, &g) in theta.iter_mut().zip(grad.iter()) {
            *t -= (lr * g) as f32;
        }
    }

    /// Semi-async reconciliation: re-apply the step for one block range
    /// with the *correction* `delta = exact − approximate`, i.e.
    /// `θ[offset+i] ← θ[offset+i] − lr·delta[i]`. Equivalent to having
    /// stepped with the exact block gradient in the first place, applied
    /// retroactively once the exact quorum lands.
    pub fn correct(&mut self, offset: usize, delta: &[f64], lr: f64) {
        assert!(offset + delta.len() <= self.theta.len());
        let theta = Arc::make_mut(&mut self.theta);
        for (t, &d) in theta[offset..offset + delta.len()].iter_mut().zip(delta.iter()) {
            *t -= (lr * d) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_updates_in_place() {
        let mut st = ModelState::zeros(3);
        let broadcast = st.shared(); // outstanding reference
        st.step(&[1.0, -2.0, 0.5], 0.1);
        assert_eq!(st.as_slice(), &[-0.1, 0.2, -0.05]);
        // The broadcast copy is unaffected (copy-on-write).
        assert_eq!(broadcast.as_slice(), &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn correct_matches_having_stepped_exactly() {
        // step(approx) then correct(exact − approx) over the block's
        // range lands where step(exact) would have, up to one extra
        // f32 rounding per corrected coordinate.
        let grad_exact = [1.0, -2.0, 0.5, 3.0];
        let grad_approx = [1.0, -1.5, 0.75, 3.0]; // block = coords 1..3
        let lr = 0.1;
        let mut direct = ModelState::zeros(4);
        direct.step(&grad_exact, lr);
        let mut reconciled = ModelState::zeros(4);
        reconciled.step(&grad_approx, lr);
        let delta: Vec<f64> = (1..3).map(|i| grad_exact[i] - grad_approx[i]).collect();
        reconciled.correct(1, &delta, lr);
        for (a, b) in direct.as_slice().iter().zip(reconciled.as_slice()) {
            assert!((a - b).abs() < 1e-7, "{a} vs {b}");
        }
    }

    #[test]
    fn random_init_uses_scale() {
        let mut rng = Rng::new(5);
        let st = ModelState::random(1000, 0.01, &mut rng);
        let max = st.as_slice().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        assert!(max < 0.1);
        assert!(max > 0.0);
    }
}
