//! The shared worker pool and its multi-job coordinator.
//!
//! PRs 1–3 made the coding scheme an epoch-versioned artifact over a
//! stable [`WorkerId`] registry — but the public API still hard-wired
//! one training job to one thread pool. This module finishes the
//! decoupling: a [`WorkerPool`] owns the threads, the
//! [`WorkerRegistry`], the channels and the pooled cycle-time feed, and
//! any number of **jobs** — each a [`JobHandle`] with its own scheme
//! epochs, decode state ([`Master`] keyed by `(job, epoch)`), model
//! state and adapt/re-dimension loop — are multiplexed over it. This is
//! how production straggler-mitigation systems amortize stragglers
//! across tenants: redundancy is priced per cluster, not per job, and
//! straggler statistics are pooled.
//!
//! ## Submitting work
//!
//! Jobs are described by a builder-style [`JobSpec`] and submitted to a
//! live pool:
//!
//! ```ignore
//! let mut pool = WorkerPool::new(PoolConfig::new(8), schedule)?;
//! let a = JobSpec::new(spec_a, blocks_a).executor(factory_a).steps(150).submit(&mut pool)?;
//! let b = JobSpec::new(spec_b, blocks_b).executor(factory_b).steps(50)
//!     .adaptive(AdaptiveConfig::default()).submit(&mut pool)?;
//! let reports = pool.run_to_completion()?;
//! ```
//!
//! ## Scheduling
//!
//! The pool interleaves **per-iteration broadcasts**: each round, the
//! scheduler picks one unfinished job, broadcasts its iteration to every
//! worker, and decodes it to completion before the next round
//! (synchronous GD needs the decoded gradient before its next
//! broadcast anyway). [`ScheduleMode::RoundRobin`] cycles fairly over
//! unfinished jobs; [`ScheduleMode::WeightedUnitWork`] is deficit-fair
//! in *work*: it always picks the job that has consumed the least total
//! coded work (`unit_work × Σ(s+1)x` per iteration), so cheap jobs get
//! proportionally more turns and no tenant can starve the others with
//! huge iterations.
//!
//! ## Isolation
//!
//! Every task and contribution is stamped with its [`JobId`]. The pool
//! routes the shared event channel by job: the active job's master
//! consumes its own traffic; another job's late blocks are counted
//! against *that* job (off-cycle arrivals — late or stale by
//! definition, since the job is not collecting); blocks for unknown
//! jobs are dropped and counted. A job's quorum only ever contains its
//! own codewords ([`Master`] refuses cross-job contributions like
//! stale epochs), and a straggling job cannot stall a healthy one
//! beyond the worker-FIFO delay its own redundancy already absorbs.
//!
//! ## Membership
//!
//! Churn is a **pool-level** event: joins/leaves update the one shared
//! registry, and once churn passes the elastic threshold — or
//! departures exceed what the most fragile live scheme absorbs — the
//! pool rebinds rows **once** and every job re-solves its partition for
//! the new `N'` (each from its own family-selected fit, all off the
//! shared membership epoch) and installs it as a fresh scheme epoch.
//!
//! ## Asynchronous rounds
//!
//! [`WorkerPool::run_all_async`] replaces the decode-to-completion
//! barrier with a **pipelined** dispatcher ([`AsyncConfig`]): up to
//! `max_inflight` jobs have a broadcast iteration open at once, so job
//! B's iteration `t+1` goes out while job A's tail blocks are still in
//! flight. The engine keeps a per-worker **virtual-time queue** of
//! compute segments; at each dispatch, a row's queued-but-unfinished
//! work is its *backlog*, which
//!
//! 1. **prices the scheme** — each row's backlog divided by the round's
//!    unit work becomes an added shift on its fitted cycle-time model
//!    (Eq. (2) and the subgradient solver then price queue position
//!    natively), and a sufficiently skewed backlog triggers a re-solve
//!    ([`AsyncConfig::reprice_threshold`]);
//! 2. **marks deep rows** — rows whose backlog exceeds
//!    `backlog_factor ×` one average round feed the master's
//!    semi-asynchronous decode ([`SemiAsyncConfig`]): a block short only
//!    of deeply-backlogged rows is decoded approximately
//!    (least-squares, with a tracked error bound) and reconciled — or
//!    discarded — when the exact quorum lands.
//!
//! A finalized round **truncates** its segments at the decode's virtual
//! completion (tail compute past the quorum is abandoned, exactly like
//! the serialized barrier) and reflows the queues behind it, so with
//! `max_inflight = 1` the async engine reproduces the serialized
//! schedule bit-for-bit — pipelining only ever adds overlap, never
//! accounting drift.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coding::scheme::CodingScheme;
use crate::coordinator::adaptive::{
    self, AdaptiveConfig, AdaptiveController, ObservationStore, ResolveStrategy,
};
use crate::coordinator::channel::{JobId, ShardMap, SliceMap, WorkerEvent, WorkerTask};
use crate::coordinator::master::{
    load_multipliers, redistribute_samples_weighted, redistribute_shards,
    redistribute_shards_weighted, sample_load_multipliers, IterOutcome, Master, SemiAsyncConfig,
    MAX_STREAM_PARTS,
};
use crate::coordinator::membership::{MemberStatus, WorkerId, WorkerRegistry};
use crate::coordinator::metrics::{
    IterMetrics, MembershipEvent, MembershipRecord, SchemeEpoch, TrainReport,
};
use crate::coordinator::state::ModelState;
use crate::coordinator::straggler::{virtual_runtime, StragglerSampler, StragglerSchedule};
use crate::coordinator::PacingMode;
use crate::distribution::fit::{FittedModel, ShiftedExpEstimate};
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::{ExecutorFactory, GradExecutor};
use crate::transport::{TaskSender, Transport, TransportConfig, WireSnapshot};
use crate::util::buffers::BufferPool;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Elastic worker-pool policy: when membership changes, when to
/// re-dimension the jobs' schemes around the new roster.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Re-dimension once this many membership changes (confirmed joins
    /// + leaves) accumulated since the last rebind. Departures that
    /// exceed a live scheme's redundancy always force an immediate
    /// re-dimension regardless of this threshold. Clamped to ≥ 1.
    pub churn_threshold: usize,
    /// Scheduled departures `(round, count)`: before pool round
    /// `round`, drain `count` workers (highest-id live workers first).
    /// For a single-job pool, rounds and job iterations coincide.
    pub departures: Vec<(usize, usize)>,
    /// Scheduled arrivals `(round, count)`: before pool round `round`,
    /// spawn `count` new workers (assigned work from the next epoch).
    pub arrivals: Vec<(usize, usize)>,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self { churn_threshold: 1, departures: Vec::new(), arrivals: Vec::new() }
    }
}

/// How the pool interleaves per-iteration broadcasts across jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScheduleMode {
    /// Fair rotation over unfinished jobs: every job gets one
    /// iteration per cycle.
    #[default]
    RoundRobin,
    /// Deficit-fair in work: each round goes to the job that has
    /// consumed the least total coded work so far (`unit_work ×
    /// Σ(s+1)x` per iteration), so per-iteration cost differences
    /// between tenants even out.
    WeightedUnitWork,
}

impl ScheduleMode {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round_robin" | "round-robin" | "rr" => Some(Self::RoundRobin),
            "weighted" | "weighted_unit_work" => Some(Self::WeightedUnitWork),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "round_robin",
            Self::WeightedUnitWork => "weighted",
        }
    }
}

/// Asynchronous round engine policy (see the module docs): how deep the
/// broadcast pipeline runs and how queue backlog feeds scheme selection
/// and semi-asynchronous decoding.
#[derive(Debug, Clone)]
pub struct AsyncConfig {
    /// Maximum simultaneously open collects (clamped to ≥ 1; a job
    /// never has two of its own iterations open — synchronous GD needs
    /// the decoded gradient before the next broadcast — so depth beyond
    /// the job count buys nothing).
    pub max_inflight: usize,
    /// Fold each row's queued virtual time into its cycle-time model as
    /// an added shift before solving the partition (the position-aware
    /// part of position-aware rounds).
    pub backlog_pricing: bool,
    /// Re-solve the dispatching job's partition when the rows' backlog
    /// skew (max − min, in cycle-time units) exceeds this multiple of
    /// the fitted mean cycle time. Requires an adaptive controller on
    /// the job; 0 re-prices on any skew.
    pub reprice_threshold: f64,
    /// Enable semi-asynchronous decoding for blocks short only of
    /// deeply-backlogged rows (None = exact quorums only).
    pub semi_async: Option<SemiAsyncConfig>,
}

impl Default for AsyncConfig {
    fn default() -> Self {
        Self { max_inflight: 2, backlog_pricing: true, reprice_threshold: 0.25, semi_async: None }
    }
}

/// Pool-wide configuration (everything that is a property of the
/// worker fleet rather than of any one job).
#[derive(Clone)]
pub struct PoolConfig {
    /// Initial worker count `N` (ids `0..N`).
    pub workers: usize,
    pub pacing: PacingMode,
    /// Seeds the pooled cycle-time sampler.
    pub seed: u64,
    /// How long a collect waits on an empty event channel before
    /// declaring the iteration stalled.
    pub stall_timeout: Duration,
    /// Worker ids that are never spawned — failure injection. Every
    /// job's coded scheme must tolerate them.
    pub dead_workers: Vec<usize>,
    /// Elastic membership policy (None = `N` frozen at spawn).
    pub elastic: Option<ElasticConfig>,
    /// How rounds are interleaved across jobs.
    pub schedule: ScheduleMode,
    /// Pooled estimator feed: when true (default), every job's drift
    /// controller observes **every** round's sampled cycle times —
    /// worker speeds are a pool property, so tenants share straggler
    /// statistics and windows fill `K×` faster on a `K`-job pool.
    pub shared_observations: bool,
    /// Pipelined dispatch policy for [`WorkerPool::run_all_async`]
    /// (None = that entry point falls back to the serialized
    /// [`WorkerPool::run_all`]).
    pub async_rounds: Option<AsyncConfig>,
    /// How workers are reached: in-process threads (default) or remote
    /// peers over the framed TCP codec ([`crate::transport`]).
    pub transport: TransportConfig,
}

impl PoolConfig {
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            pacing: PacingMode::Virtual,
            seed: 2021,
            stall_timeout: Duration::from_secs(30),
            dead_workers: Vec::new(),
            elastic: None,
            schedule: ScheduleMode::RoundRobin,
            shared_observations: true,
            async_rounds: None,
            transport: TransportConfig::default(),
        }
    }
}

/// Builder-style description of one training job, submitted to a
/// [`WorkerPool`]. The problem spec's `n` must match the pool's
/// current worker count (solve the partition for the pool you are
/// joining).
pub struct JobSpec {
    spec: ProblemSpec,
    blocks: BlockPartition,
    steps: usize,
    lr: f64,
    eval_every: usize,
    seed: u64,
    init_scale: f64,
    adaptive: Option<AdaptiveConfig>,
    elastic: Option<ElasticConfig>,
    factory: Option<ExecutorFactory>,
    stream_parts: usize,
}

impl JobSpec {
    /// A job over `spec` dimensions with an initial (epoch-0) block
    /// partition.
    pub fn new(spec: ProblemSpec, blocks: BlockPartition) -> Self {
        Self {
            spec,
            blocks,
            steps: 100,
            lr: 1e-2,
            eval_every: 10,
            seed: 2021,
            init_scale: 0.05,
            adaptive: None,
            elastic: None,
            factory: None,
            stream_parts: 0,
        }
    }

    /// GD iterations to run.
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Learning rate.
    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    /// Evaluate the loss every `k` steps (0 = never).
    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k;
        self
    }

    /// Seed for the job's scheme construction and θ init.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// θ init scale (Gaussian); 0 = zeros.
    pub fn init_scale(mut self, scale: f64) -> Self {
        self.init_scale = scale;
        self
    }

    /// Online re-optimization policy (drift-triggered re-solves).
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    /// Elastic membership policy. Membership is pool-level, so this is
    /// a convenience that installs the policy on the pool at submit
    /// time; submitting a second elastic policy to a pool that already
    /// has one is an error.
    pub fn elastic(mut self, cfg: ElasticConfig) -> Self {
        self.elastic = Some(cfg);
        self
    }

    /// The executor factory backing this job's gradient compute
    /// (required).
    pub fn executor(mut self, factory: ExecutorFactory) -> Self {
        self.factory = Some(factory);
        self
    }

    /// Sample-granular dispatch and partial-straggler streaming. `0`
    /// (the default) keeps shard-granular tasks; `1` assigns each code
    /// row an exact sample-count load (continuous ratios — a two-speed
    /// fleet whose speed ratio is not a multiple of `1/m` gets its
    /// exact proportional split) without streaming; `p ≥ 2`
    /// additionally checkpoints each row's compute at `p` sample
    /// strides and streams rotated per-part coded deltas, so a block
    /// can decode part-wise before any single worker finishes its whole
    /// load. Requires an executor with span support
    /// ([`crate::runtime::GradExecutor::grad_span_into`]); submit
    /// rejects the combination otherwise.
    pub fn stream_parts(mut self, parts: usize) -> Self {
        self.stream_parts = parts;
        self
    }

    /// Submit to a pool; the job starts receiving broadcast rounds on
    /// the next scheduler pass.
    pub fn submit(self, pool: &mut WorkerPool) -> Result<JobId> {
        pool.submit(self)
    }
}

/// Per-job state on the pool: scheme epochs, decode state, adaptive
/// controller, model parameters and the job's training report — the
/// surface `TrainSession` used to expose for exactly one job.
pub struct JobHandle {
    id: JobId,
    spec: ProblemSpec,
    dim: usize,
    /// Dataset shard count (fixed at submit; elastic subsets are
    /// re-mapped onto these shards when `N` changes).
    num_data_shards: usize,
    steps: usize,
    lr: f64,
    eval_every: usize,
    factory: ExecutorFactory,
    scheme: Arc<CodingScheme>,
    epoch: usize,
    master: Master,
    controller: Option<AdaptiveController>,
    /// Re-solve strategy for elastic re-dimensions (the adaptive
    /// strategy when configured, closed-form `x^(f)` otherwise).
    resolve_strategy: ResolveStrategy,
    state: ModelState,
    eval_exec: Option<Box<dyn GradExecutor>>,
    /// Per-row data-load multipliers of the installed shard map
    /// (`c_row·N/m`; all ones until a speed-weighted re-shard). The
    /// virtual-time layer scales each row's cycle time by its
    /// multiplier so Eq. (2) accounting reflects the weighted
    /// placement.
    load_mult: Vec<f64>,
    /// Dataset sample count reported by the job's executor (0 when
    /// unknown; sample-granular dispatch is rejected at submit then).
    samples: usize,
    /// Rotation parts for sample-granular dispatch (0 = shard-granular
    /// legacy tasks, 1 = exact sample loads without streaming, ≥ 2 =
    /// rotated partial-delta streaming). See [`JobSpec::stream_parts`].
    stream_parts: usize,
    /// Live per-row sample weights (ones until a speed-weighted
    /// re-plan); every scheme install re-derives the slice map from
    /// these, since installs reset the master's dispatch plan.
    sample_weights: Vec<f64>,
    iters_done: usize,
    /// Total coded work consumed, in cycles (`unit_work × Σ(s+1)x` per
    /// iteration) — the deficit counter behind
    /// [`ScheduleMode::WeightedUnitWork`].
    issued_work: f64,
    /// Contributions that arrived while this job was **not** collecting
    /// (tail blocks outrun by the decode quorum, delivered during some
    /// other job's round), split by whether they were also stale-epoch.
    offcycle_late: usize,
    offcycle_stale: usize,
    rng: Rng,
    report: TrainReport,
}

impl JobHandle {
    /// The job's id on its pool.
    pub fn id(&self) -> JobId {
        self.id
    }

    /// The current scheme epoch (0-based, monotone).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        &self.scheme
    }

    /// The job's problem spec (`n` tracks membership epochs).
    pub fn spec(&self) -> &ProblemSpec {
        &self.spec
    }

    /// Iterations completed so far.
    pub fn iters_done(&self) -> usize {
        self.iters_done
    }

    /// Iterations the job was submitted for.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Whether the job has completed all its steps.
    pub fn done(&self) -> bool {
        self.iters_done >= self.steps
    }

    /// Live view of the job's training report (finalized counters —
    /// cache stats, failed workers — land at pool finish).
    pub fn report(&self) -> &TrainReport {
        &self.report
    }

    /// Decode-vector cache statistics, accumulated across **all** of
    /// this job's scheme epochs.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.master.cache_stats()
    }

    /// Contributions that arrived while the job was not collecting
    /// (late tail blocks routed during other jobs' rounds), as
    /// `(late, stale_epoch)`.
    pub fn offcycle_contributions(&self) -> (usize, usize) {
        (self.offcycle_late, self.offcycle_stale)
    }

    /// The live subset → dataset-shard mapping (identity until an
    /// elastic or speed-weighted re-shard).
    pub fn shard_map(&self) -> &Arc<ShardMap> {
        self.master.shard_map()
    }

    /// Per-row data-load multipliers of the live shard map (all ones
    /// until a speed-weighted re-shard).
    pub fn load_multipliers(&self) -> &[f64] {
        &self.load_mult
    }

    /// Rotation parts configured for sample-granular dispatch (0 =
    /// shard-granular legacy tasks; see [`JobSpec::stream_parts`]).
    pub fn stream_parts(&self) -> usize {
        self.stream_parts
    }

    /// The live sample-granular slice map (None for shard-granular
    /// jobs): `slices[k]` is subset `k`'s contiguous sample span.
    pub fn slice_map(&self) -> Option<&Arc<SliceMap>> {
        self.master.slice_map()
    }

    /// Count a contribution (whole block or streamed part) that arrived
    /// outside the job's own collect window, by its encoding epoch.
    fn note_offcycle(&mut self, epoch: usize) {
        if epoch == self.epoch {
            self.offcycle_late += 1;
        } else {
            self.offcycle_stale += 1;
        }
    }

    /// (Re-)derive the sample-granular slice map from the live weights
    /// and install it on the master. Called after every scheme install
    /// — installs reset the master's dispatch plan — and after a weight
    /// update; a no-op for shard-granular jobs. The slice map is also
    /// the job's load accounting: each row's multiplier is its sample
    /// share relative to a uniform split.
    fn reinstall_slices(&mut self) -> Result<()> {
        if self.stream_parts == 0 {
            return Ok(());
        }
        let map = Arc::new(redistribute_samples_weighted(&self.sample_weights, self.samples)?);
        self.load_mult = sample_load_multipliers(&map, self.samples);
        self.master.install_slices(Some(map), self.stream_parts);
        Ok(())
    }

    /// Install a new same-`N` partition as the job's next scheme epoch.
    /// Safe between iterations: workers receive the new scheme with
    /// their next task, and the master rejects contributions encoded
    /// under any previous epoch like stale-iteration messages.
    /// (Re-dimensioning to a different `N` goes through the pool's
    /// [`WorkerPool::maybe_redimension`].)
    pub fn install_scheme(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
    ) -> Result<()> {
        self.install_scheme_with_shards(blocks, iter, estimate, drift, None)
    }

    /// [`Self::install_scheme`] with an optional subset → shard
    /// re-mapping installed alongside the new epoch (the speed-weighted
    /// actuation path; `None` keeps the live mapping).
    fn install_scheme_with_shards(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
        shards: Option<Arc<ShardMap>>,
    ) -> Result<()> {
        if blocks.n() != self.spec.n {
            return Err(Error::InvalidArgument("new scheme: blocks.n() != spec.n".into()));
        }
        if blocks.total() != self.dim {
            return Err(Error::InvalidArgument(format!(
                "new scheme covers {} coordinates but the model has {}",
                blocks.total(),
                self.dim
            )));
        }
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        let roster = self.master.roster().to_vec();
        let shards = shards.unwrap_or_else(|| self.master.shard_map().clone());
        self.load_mult = load_multipliers(&shards, self.num_data_shards);
        self.master.install_scheme(scheme, self.epoch, roster, shards);
        // The install reset the master's dispatch plan; sample-granular
        // jobs re-derive their slice map from the live weights.
        self.reinstall_slices()?;
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.and_then(|e| e.mu_hint()),
            estimated_t0: estimate.and_then(|e| e.t0_hint()),
            estimated_mean: estimate.map(|e| e.mean()),
            family: estimate.map(|e| e.family().name().to_string()),
            drift,
        });
        Ok(())
    }

    /// Poll the job's adaptive policy; on a triggered re-plan, install
    /// the re-optimized scheme as a new epoch.
    fn adapt(&mut self) -> Result<()> {
        if self.done() {
            return Ok(());
        }
        let iter = self.iters_done;
        let warm = self.scheme.blocks().as_f64();
        let plan = {
            let Some(ctrl) = self.controller.as_mut() else {
                return Ok(()); // non-adaptive job: nothing to poll
            };
            ctrl.maybe_replan(iter, &self.spec, &warm, &mut self.rng)?
        };
        if let Some(plan) = plan {
            crate::log_info!(
                "job {}: iter {iter}: drift {:.2} → installing scheme epoch {} (fit {}{})",
                self.id,
                plan.drift,
                self.epoch + 1,
                plan.estimate.label(),
                if plan.fleet_rates.is_some() { ", hetero speed-weighted" } else { "" }
            );
            // Speed-weighted actuation: a hetero re-plan re-shards the
            // dataset proportionally to the fitted per-row rates, so
            // fast workers carry more data instead of idling at the
            // quorum barrier. Sample-granular jobs re-cut the *sample*
            // spans instead (shard quanta would round the ratio to a
            // multiple of 1/m); the shard map stays as-is and the new
            // weights flow into the slice map via the install's
            // `reinstall_slices`.
            let shards = if self.stream_parts > 0 {
                if let Some(r) = plan.fleet_rates.as_ref() {
                    if r.len() == self.spec.n && r.iter().all(|v| v.is_finite() && *v >= 0.0) {
                        self.sample_weights = r.clone();
                    }
                }
                None
            } else {
                plan.fleet_rates
                    .as_ref()
                    .map(|r| Arc::new(redistribute_shards_weighted(r, self.num_data_shards)))
            };
            self.install_scheme_with_shards(
                plan.blocks,
                iter,
                Some(&plan.estimate),
                plan.drift,
                shards,
            )?;
        }
        Ok(())
    }

    /// Re-dimension this job onto a rebound roster of `to_n` rows:
    /// re-solve the partition for `N' = to_n` from the job's own
    /// family-selected fit (falling back to `fallback`, then to a
    /// uniform level-1 partition), install it as a fresh scheme epoch,
    /// and flush/rebase the drift estimator (observations under the old
    /// `N`'s unit work are not comparable).
    fn redimension(
        &mut self,
        to_n: usize,
        roster: &[WorkerId],
        fallback: Option<FittedModel>,
    ) -> Result<()> {
        let from_n = self.spec.n;
        let iter = self.iters_done;
        let spec_new = self.spec.with_n(to_n);
        let estimate: Option<FittedModel> =
            self.controller.as_ref().and_then(|c| c.current_fit()).or(fallback);
        let warm = self.scheme.blocks().as_f64();
        // Heterogeneity-aware re-dimension: with per-worker evidence
        // for the surviving roster (the windows are id-keyed, so
        // survivors keep their histories through the rebind), the
        // partition is solved against the load-adjusted fleet AND the
        // shards are re-split by fitted rate — one consistent plan,
        // like the drift path. Otherwise the pooled estimate shapes x
        // and the split stays uniform.
        let fleet_plan = self.controller.as_ref().and_then(|c| c.fleet_plan_for(roster));
        let blocks = match &fleet_plan {
            Some((fleet, _)) => adaptive::resolve_partition(
                &self.resolve_strategy,
                &spec_new,
                fleet,
                Some(warm.as_slice()),
                self.dim,
                &mut self.rng,
            )?,
            None => match &estimate {
                Some(est) => {
                    let dist = est.build();
                    adaptive::resolve_partition(
                        &self.resolve_strategy,
                        &spec_new,
                        dist.as_ref(),
                        Some(warm.as_slice()),
                        self.dim,
                        &mut self.rng,
                    )?
                }
                None => {
                    let s = if to_n > 1 { 1 } else { 0 };
                    BlockPartition::single_level(to_n, s, self.dim)
                }
            },
        };
        self.spec.n = to_n;
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        let shards = match fleet_plan.as_ref().and_then(|(_, rates)| rates.as_ref()) {
            Some(rates) => Arc::new(redistribute_shards_weighted(rates, self.num_data_shards)),
            None => Arc::new(redistribute_shards(to_n, self.num_data_shards)),
        };
        self.load_mult = load_multipliers(&shards, self.num_data_shards);
        self.master.install_scheme(scheme, self.epoch, roster.to_vec(), shards);
        if self.stream_parts > 0 {
            // Weights are per-row: the rebind re-bases them on the new
            // roster (fitted rates when the fleet plan has them, ones
            // otherwise) before the slice map is re-cut for `to_n`.
            self.sample_weights = match fleet_plan.as_ref().and_then(|(_, rates)| rates.as_ref())
            {
                Some(r) if r.len() == to_n && r.iter().all(|v| v.is_finite() && *v >= 0.0) => {
                    r.clone()
                }
                _ => vec![1.0; to_n],
            };
            self.reinstall_slices()?;
        }
        crate::log_info!(
            "job {}: iter {iter}: re-dimensioned N {from_n}→{to_n} as scheme epoch {}",
            self.id,
            self.epoch
        );
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.as_ref().and_then(|e| e.mu_hint()),
            estimated_t0: estimate.as_ref().and_then(|e| e.t0_hint()),
            estimated_mean: estimate.as_ref().map(|e| e.mean()),
            family: estimate.as_ref().map(|e| e.family().name().to_string()),
            drift: 0.0,
        });
        self.report.membership.push(MembershipRecord {
            iter,
            event: MembershipEvent::Redimension { from_n, to_n, epoch: self.epoch },
        });
        if let Some(ctrl) = self.controller.as_mut() {
            ctrl.set_roster(roster);
            ctrl.rebase(estimate);
        }
        Ok(())
    }

    /// The smallest redundancy any live block of this job's scheme has
    /// (how many dead rows the job absorbs without re-dimensioning).
    fn min_redundancy(&self) -> usize {
        self.scheme.ranges().iter().map(|r| r.s).min().unwrap_or(0)
    }

    fn record_membership(&mut self, event: MembershipEvent) {
        self.report.membership.push(MembershipRecord { iter: self.iters_done, event });
    }

    fn finalize(&mut self, failed: &[usize]) {
        // Un-reconciled semi-async approximations die with the run:
        // their retained arrival buffers go back to the pool before the
        // wire stats are snapshotted, and they count as discarded.
        self.master.discard_pending();
        self.report.approx_discarded = self.master.approx_discarded();
        let (hits, misses) = self.master.cache_stats();
        self.report.decode_cache_hits = hits;
        self.report.decode_cache_misses = misses;
        // Wire-pool counters are pool-wide (the freelist is shared by
        // every worker and job on the pool), snapshotted at job finish.
        let ws = self.master.wire_pool_stats();
        self.report.wire_pool_hits = ws.hits;
        self.report.wire_pool_misses = ws.misses;
        self.report.wire_pool_returned = ws.returned;
        self.report.failed_workers = failed.to_vec();
    }
}

/// The shared worker fleet and the jobs multiplexed over it.
pub struct WorkerPool {
    cfg: PoolConfig,
    registry: WorkerRegistry,
    /// Task lane per worker **id** (None once drained/dead/never
    /// attached). Indexed by stable id, not row.
    task_txs: Vec<Option<TaskSender>>,
    /// Row-ordered task lanes for the current roster, cached per
    /// membership epoch (rebuilding this per iteration was measurable
    /// broadcast overhead). Invalidated on rebind, join and departure.
    row_senders: Vec<Option<TaskSender>>,
    row_senders_dirty: bool,
    /// Kept for spawning late joiners; the channel therefore never
    /// disconnects while the pool lives (stalls still time out).
    event_tx: Sender<WorkerEvent>,
    event_rx: Receiver<WorkerEvent>,
    handles: Vec<std::thread::JoinHandle<()>>,
    sampler: StragglerSampler,
    /// Row-indexed liveness for the current membership epoch's roster.
    live_mask: Vec<bool>,
    failed_set: Vec<usize>,
    jobs: Vec<JobHandle>,
    /// Pool-level broadcast rounds completed (one job iteration each).
    rounds: usize,
    rr_cursor: usize,
    /// Sum of every round's virtual runtime — rounds serialize on the
    /// shared pool, so this is the pool's virtual **makespan**.
    virtual_makespan: f64,
    /// Contributions stamped with a job id the pool has never seen.
    cross_job_dropped: usize,
    /// Shared wire-buffer freelist: workers take coded-block buffers
    /// from it, every job's master recycles arrivals back into it (see
    /// the data-plane notes in [`crate::coordinator`]).
    wire_pool: BufferPool,
    /// How worker lanes are realized (threads or sockets); also owns
    /// the transport's service threads and wire counters.
    transport: Box<dyn Transport>,
}

impl WorkerPool {
    /// Spawn a pool of `cfg.workers` threads whose cycle times follow
    /// `schedule` (sampled per round at broadcast).
    pub fn new(cfg: PoolConfig, schedule: StragglerSchedule) -> Result<Self> {
        Self::build(cfg, schedule, None)
    }

    /// Spawn a **heterogeneous** pool: worker id `w`'s cycle times come
    /// from `fleet[w]`'s own model (ids beyond the list — elastic joins
    /// — fall back to `schedule`, which also remains the pool's prior
    /// for seeding drift references).
    pub fn new_fleet(
        cfg: PoolConfig,
        schedule: StragglerSchedule,
        fleet: Vec<Box<dyn crate::distribution::CycleTimeDistribution>>,
    ) -> Result<Self> {
        Self::build(cfg, schedule, Some(fleet))
    }

    fn build(
        cfg: PoolConfig,
        schedule: StragglerSchedule,
        fleet: Option<Vec<Box<dyn crate::distribution::CycleTimeDistribution>>>,
    ) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(Error::InvalidArgument("the pool needs at least one worker".into()));
        }
        let n = cfg.workers;
        let mut registry = WorkerRegistry::new(n);
        let (event_tx, event_rx) = mpsc::channel::<WorkerEvent>();
        let mut task_txs: Vec<Option<TaskSender>> = Vec::with_capacity(n);
        let mut handles = Vec::new();
        let mut live_mask = vec![false; n];
        let wire_pool = BufferPool::default();
        let mut transport = cfg.transport.build(event_tx.clone(), cfg.pacing, wire_pool.clone())?;
        for w in 0..n {
            if cfg.dead_workers.contains(&w) {
                // Injected failure: worker never comes up. It keeps its
                // epoch-0 row (every scheme must absorb it) and is
                // dropped at the first rebind, like any departure.
                task_txs.push(None);
                registry.leave(w);
                continue;
            }
            let lane = transport.attach_worker(w)?;
            task_txs.push(Some(lane.tasks));
            if let Some(h) = lane.handle {
                handles.push(h);
            }
            live_mask[w] = true;
        }
        let mut rng = Rng::new(cfg.seed);
        let mut sampler = StragglerSampler::from_schedule(schedule, rng.next_u64());
        if let Some(fleet) = fleet {
            sampler = sampler.with_fleet(fleet);
        }
        // Injected-dead workers are permanent failures from round 0
        // (they also never get a Leave record re-logged per job).
        let failed_set = cfg.dead_workers.clone();
        Ok(Self {
            cfg,
            registry,
            task_txs,
            row_senders: Vec::new(),
            row_senders_dirty: true,
            event_tx,
            event_rx,
            handles,
            sampler,
            live_mask,
            failed_set,
            jobs: Vec::new(),
            rounds: 0,
            rr_cursor: 0,
            virtual_makespan: 0.0,
            cross_job_dropped: 0,
            wire_pool,
            transport,
        })
    }

    /// Current worker count (rows in the live membership epoch).
    pub fn n(&self) -> usize {
        self.registry.n()
    }

    /// The membership registry (id ↔ row bindings, churn counters).
    pub fn registry(&self) -> &WorkerRegistry {
        &self.registry
    }

    /// Broadcast rounds completed so far (one job iteration each).
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Number of jobs ever submitted.
    pub fn num_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// A submitted job's live state.
    pub fn job(&self, id: JobId) -> &JobHandle {
        &self.jobs[id]
    }

    /// Sum of every round's virtual runtime — the shared pool's virtual
    /// makespan (rounds serialize on the fleet).
    pub fn virtual_makespan(&self) -> f64 {
        self.virtual_makespan
    }

    /// Contributions dropped because they were stamped with a job id
    /// this pool has never issued.
    pub fn cross_job_dropped(&self) -> usize {
        self.cross_job_dropped
    }

    /// Register and start a job (see [`JobSpec`]). The job's `spec.n`
    /// and partition must be dimensioned for the pool's **current**
    /// worker count.
    pub fn submit(&mut self, js: JobSpec) -> Result<JobId> {
        let id = self.jobs.len();
        let n = self.registry.n();
        if js.spec.n != n {
            return Err(Error::InvalidArgument(format!(
                "job spec is dimensioned for N={} but the pool has {n} workers",
                js.spec.n
            )));
        }
        if js.blocks.n() != js.spec.n {
            return Err(Error::InvalidArgument("blocks.n() != spec.n".into()));
        }
        let factory = js.factory.ok_or_else(|| {
            Error::InvalidArgument("JobSpec needs an executor factory (JobSpec::executor)".into())
        })?;
        if let Some(elastic) = js.elastic {
            if self.cfg.elastic.is_some() {
                return Err(Error::InvalidArgument(
                    "the pool already has an elastic policy; configure it on PoolConfig".into(),
                ));
            }
            self.cfg.elastic = Some(elastic);
        }
        let mut rng = Rng::new(js.seed);
        let scheme = Arc::new(CodingScheme::new(js.blocks.clone(), &mut rng)?);

        // Master-side executor for loss evaluation (worker id n = master).
        let mut eval_exec = if js.eval_every > 0 { Some(factory(n)?) } else { None };
        let (dim, samples, spans_ok) = if let Some(e) = &eval_exec {
            (e.dim(), e.num_samples(), e.supports_spans())
        } else {
            let probe = factory(n)?;
            (probe.dim(), probe.num_samples(), probe.supports_spans())
        };
        if js.stream_parts > 0 {
            if !spans_ok || samples == 0 {
                return Err(Error::InvalidArgument(
                    "stream_parts needs an executor with sample-span support \
                     (GradExecutor::grad_span_into / num_samples)"
                        .into(),
                ));
            }
            if js.stream_parts > MAX_STREAM_PARTS {
                return Err(Error::InvalidArgument(format!(
                    "stream_parts {} exceeds the wire limit of {MAX_STREAM_PARTS}",
                    js.stream_parts
                )));
            }
        }
        if dim != js.spec.coords {
            crate::log_warn!(
                "job {id}: model dim {} != spec.coords {} — virtual-runtime accounting uses \
                 the model dim",
                dim,
                js.spec.coords
            );
        }
        if js.blocks.total() != dim {
            return Err(Error::InvalidArgument(format!(
                "block partition covers {} coordinates but the model has {dim}",
                js.blocks.total()
            )));
        }

        let mut master = Master::for_job(id, scheme.clone(), dim, self.registry.roster().to_vec());
        master.timeout = self.cfg.stall_timeout;
        // Decoded arrival buffers cycle back to the pool's encoders.
        master.set_wire_pool(self.wire_pool.clone());
        // Sample-granular jobs dispatch with a slice map from round 0:
        // a uniform split until a speed-weighted re-plan updates the
        // weights. The map doubles as the load accounting.
        let mut load_mult = vec![1.0; n];
        if js.stream_parts > 0 {
            let map = Arc::new(redistribute_samples_weighted(&vec![1.0; n], samples)?);
            load_mult = sample_load_multipliers(&map, samples);
            master.install_slices(Some(map), js.stream_parts);
        }

        // Seed the drift detector with the parameters the initial scheme
        // is presumed optimal for (when the current phase is shifted-exp).
        let resolve_strategy = js
            .adaptive
            .as_ref()
            .map(|a| a.strategy.clone())
            .unwrap_or(ResolveStrategy::ClosedFormFreq);
        let controller = js.adaptive.map(|acfg| {
            let mut c = match self.sampler.distribution_at(self.rounds).as_shifted_exp() {
                Some(d) => AdaptiveController::with_reference(acfg, d.mu, d.t0),
                None => AdaptiveController::new(acfg),
            };
            c.set_roster(self.registry.roster());
            // Pool-level shared observation store: a compatible tenant
            // borrows the first existing tenant's store instead of
            // keeping its own copy of the same per-machine evidence —
            // one write and one memoized fit per machine per round,
            // however many jobs share the pool.
            if self.cfg.shared_observations {
                for existing in &self.jobs {
                    if let Some(other) = existing.controller.as_ref() {
                        if c.attach_store(&other.shared_store()) {
                            break;
                        }
                    }
                }
            }
            c
        });
        let state = if js.init_scale > 0.0 {
            ModelState::random(dim, js.init_scale, &mut rng)
        } else {
            ModelState::zeros(dim)
        };

        let mut report = TrainReport::default();
        report.scheme_epochs.push(SchemeEpoch {
            epoch: 0,
            installed_at_iter: 0,
            block_sizes: js.blocks.sizes().to_vec(),
            estimated_mu: None,
            estimated_t0: None,
            estimated_mean: None,
            family: None,
            drift: 0.0,
        });
        if js.eval_every > 0 {
            if let Some(e) = eval_exec.as_mut() {
                let l = e.loss(state.as_slice())?;
                report.loss_curve.push((0, l));
            }
        }

        self.jobs.push(JobHandle {
            id,
            spec: js.spec,
            dim,
            num_data_shards: js.spec.n,
            steps: js.steps,
            lr: js.lr,
            eval_every: js.eval_every,
            factory,
            scheme,
            epoch: 0,
            master,
            controller,
            resolve_strategy,
            state,
            eval_exec,
            load_mult,
            samples,
            stream_parts: js.stream_parts,
            sample_weights: vec![1.0; n],
            iters_done: 0,
            issued_work: 0.0,
            offcycle_late: 0,
            offcycle_stale: 0,
            rng,
            report,
        });
        Ok(id)
    }

    /// Spawn a new worker thread into the pool. It is registered as
    /// pending and **receives no work until the next epoch swap**: its
    /// `Joined` event confirms the thread came up, and the following
    /// [`Self::maybe_redimension`] binds it to a code row of every
    /// job's fresh, re-dimensioned scheme epoch.
    pub fn add_worker(&mut self) -> Result<WorkerId> {
        if self.cfg.elastic.is_none() {
            return Err(Error::InvalidArgument(
                "add_worker requires an elastic pool (PoolConfig::elastic)".into(),
            ));
        }
        let id = self.registry.join();
        let lane = self.transport.attach_worker(id)?;
        if let Some(h) = lane.handle {
            self.handles.push(h);
        }
        if self.task_txs.len() <= id {
            self.task_txs.resize_with(id + 1, || None);
        }
        self.task_txs[id] = Some(lane.tasks);
        self.row_senders_dirty = true;
        crate::log_info!("round {}: worker {id} joined (pending next epoch)", self.rounds);
        for job in &mut self.jobs {
            job.record_membership(MembershipEvent::Join { worker: id });
        }
        Ok(id)
    }

    /// Drain a worker out of the pool without dropping an iteration:
    /// its thread finishes cleanly, its row counts as a fatal straggler
    /// for the remainder of every job's current epoch, and the next
    /// [`Self::maybe_redimension`] drops it from the roster.
    pub fn remove_worker(&mut self, id: WorkerId) -> Result<()> {
        if self.cfg.elastic.is_none() {
            return Err(Error::InvalidArgument(
                "remove_worker requires an elastic pool (PoolConfig::elastic)".into(),
            ));
        }
        if self.registry.status(id) != Some(MemberStatus::Active)
            && self.registry.status(id) != Some(MemberStatus::Pending)
        {
            return Err(Error::InvalidArgument(format!(
                "worker {id} is not a live pool member"
            )));
        }
        if let Some(tx) = self.task_txs.get_mut(id).and_then(Option::take) {
            let _ = tx.send(WorkerTask::Drain);
        }
        self.mark_departed(id);
        crate::log_info!("round {}: worker {id} draining out of the pool", self.rounds);
        for job in &mut self.jobs {
            job.record_membership(MembershipEvent::Leave { worker: id });
        }
        Ok(())
    }

    /// Shared departure bookkeeping (clean drain and fatal failure):
    /// the registry marks the id departed — keeping its row for the
    /// rest of the membership epoch — its task channel is dropped, and
    /// its row, if any, goes dead in the shared live mask.
    fn mark_departed(&mut self, id: WorkerId) {
        self.registry.leave(id);
        if let Some(tx) = self.task_txs.get_mut(id) {
            *tx = None;
        }
        self.row_senders_dirty = true;
        if let Some(row) = self.registry.row_of(id) {
            if row < self.live_mask.len() {
                self.live_mask[row] = false;
            }
        }
    }

    /// Apply the elastic config's scheduled churn for pool round `at`
    /// (arrivals first, then departures of the highest-id live
    /// workers). No-op without an elastic config.
    pub fn apply_scheduled_churn_at(&mut self, at: usize) -> Result<()> {
        let (arrive, depart) = match &self.cfg.elastic {
            None => return Ok(()),
            Some(e) => (
                e.arrivals.iter().filter(|&&(t, _)| t == at).map(|&(_, c)| c).sum::<usize>(),
                e.departures.iter().filter(|&&(t, _)| t == at).map(|&(_, c)| c).sum::<usize>(),
            ),
        };
        for _ in 0..arrive {
            self.add_worker()?;
        }
        for _ in 0..depart {
            let victim = self
                .registry
                .roster()
                .iter()
                .rev()
                .copied()
                .find(|&id| self.registry.status(id) == Some(MemberStatus::Active));
            match victim {
                Some(id) => self.remove_worker(id)?,
                None => {
                    return Err(Error::Runtime(format!(
                        "round {at}: scheduled departure but no live worker remains"
                    )))
                }
            }
        }
        Ok(())
    }

    /// Poll one job's adaptive policy (see [`JobHandle::install_scheme`]).
    pub fn adapt_job(&mut self, id: JobId) -> Result<()> {
        self.jobs[id].adapt()
    }

    /// Install a same-`N` scheme for one job (manual hot-swap).
    pub fn install_scheme(
        &mut self,
        id: JobId,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
    ) -> Result<()> {
        self.jobs[id].install_scheme(blocks, iter, estimate, drift)
    }

    /// Membership epochs, pool-wide: once churn since the last rebind
    /// reaches the elastic threshold — or immediately when departures
    /// exceed what the most fragile live scheme's redundancy absorbs —
    /// rebind rows **once** and re-dimension **every** unfinished job
    /// onto the new roster (each re-solving with its own fit). Returns
    /// whether a re-dimension happened.
    pub fn maybe_redimension(&mut self) -> Result<bool> {
        let Some(threshold) = self.cfg.elastic.as_ref().map(|e| e.churn_threshold.max(1))
        else {
            return Ok(false);
        };
        if self.jobs.iter().all(|j| j.done()) {
            return Ok(false);
        }
        let dead_rows = self.registry.departed_in_roster();
        let min_s = self
            .jobs
            .iter()
            .filter(|j| !j.done())
            .map(|j| j.min_redundancy())
            .min()
            .unwrap_or(0);
        let forced = dead_rows > min_s;
        if !forced && self.registry.churn_since_rebind() < threshold {
            return Ok(false);
        }
        let to_n = self.registry.next_n();
        if to_n == 0 {
            return Err(Error::Runtime(format!(
                "round {}: elastic pool drained to zero workers",
                self.rounds
            )));
        }
        // The fallback evidence when a job has no live fit: the
        // schedule's current phase, when shifted-exponential.
        let fallback: Option<FittedModel> =
            self.sampler.distribution_at(self.rounds).as_shifted_exp().map(|d| {
                FittedModel::ShiftedExp(ShiftedExpEstimate { mu: d.mu, t0: d.t0, samples: 0 })
            });
        let roster = self.registry.rebind().to_vec();
        debug_assert_eq!(roster.len(), to_n);
        self.live_mask = vec![true; to_n];
        self.row_senders_dirty = true;
        for job in &mut self.jobs {
            if job.done() {
                continue;
            }
            job.redimension(to_n, &roster, fallback.clone())?;
        }
        Ok(true)
    }

    /// Feed one round's sampled cycle times to the drift estimators.
    /// Pooled feed (`shared_observations`): worker speeds are a pool
    /// property, so every tenant's window may learn from every round —
    /// but tenants attached to the same shared [`ObservationStore`]
    /// get **one** write (and one memoized fit) per machine per round,
    /// not `K` copies; only controllers whose configs were incompatible
    /// at submit keep (and feed) their own stores. Every observation is
    /// stamped with the worker's stable id, so per-worker windows never
    /// blend identities across rebinds.
    fn observe_round(&mut self, id: JobId, times: &[f64], roster: &[WorkerId]) {
        if self.cfg.shared_observations {
            let mut seen: Vec<Arc<Mutex<ObservationStore>>> = Vec::new();
            for job in self.jobs.iter_mut() {
                if let Some(ctrl) = job.controller.as_mut() {
                    let store = ctrl.shared_store();
                    if seen.iter().any(|s| Arc::ptr_eq(s, &store)) {
                        // Another tenant already fed this store this
                        // round; just refresh the roster binding.
                        ctrl.set_roster(roster);
                    } else {
                        ctrl.observe_rows(times, roster);
                        seen.push(store);
                    }
                }
            }
        } else if let Some(ctrl) = self.jobs[id].controller.as_mut() {
            ctrl.observe_rows(times, roster);
        }
    }

    /// Rebuild the cached row → task-channel table if membership moved
    /// since the last broadcast (None where the bound worker already
    /// departed).
    fn refresh_row_senders(&mut self) {
        if !self.row_senders_dirty {
            return;
        }
        self.row_senders = self
            .registry
            .roster()
            .iter()
            .map(|&wid| self.task_txs.get(wid).cloned().flatten())
            .collect();
        self.row_senders_dirty = false;
    }

    /// One GD iteration for job `id`: sample the round's pool-wide
    /// cycle times, broadcast, route the shared event channel until the
    /// job's every block decodes, then step its model.
    pub fn step_job(&mut self, id: JobId) -> Result<()> {
        if id >= self.jobs.len() {
            return Err(Error::InvalidArgument(format!("no such job {id}")));
        }
        if self.jobs[id].done() {
            return Err(Error::InvalidArgument(format!(
                "job {id} already ran its {} steps",
                self.jobs[id].steps
            )));
        }
        // lint: allow(determinism) — wall_ns metric only; round control flow is virtual-time
        let t_iter = Instant::now();
        let n = self.registry.n();
        debug_assert_eq!(self.jobs[id].spec.n, n, "job not re-dimensioned to the live roster");
        let roster = self.registry.roster().to_vec();
        // Cycle times are drawn per stable id (a machine keeps its
        // speed across rebinds); `times[row]` belongs to `roster[row]`.
        let times = self.sampler.sample_roster(self.rounds, &roster);
        self.observe_round(id, &times, &roster);
        self.refresh_row_senders();
        let iter = self.jobs[id].iters_done;
        // Effective per-row cycle times: a speed-weighted re-shard
        // changes each row's per-unit data load, so its compute pace
        // scales by the load multiplier (raw times keep feeding the
        // estimators — the model tracks the machine, not its load).
        let eff: Vec<f64> = times
            .iter()
            .enumerate()
            .map(|(row, &t)| t * self.jobs[id].load_mult.get(row).copied().unwrap_or(1.0))
            .collect();
        {
            let job = &self.jobs[id];
            job.master.broadcast(
                iter,
                job.state.shared(),
                &eff,
                job.spec.unit_work(),
                &job.factory,
                &self.row_senders,
            );
        }
        let outcome = self.collect_for(id, iter)?;
        let approx_blocks = outcome.approx.len();

        for w in outcome.joined {
            self.registry.confirm(w);
        }
        for w in outcome.left {
            // Clean departures observed mid-iteration (their Leave was
            // already logged by remove_worker); keep masks in sync.
            self.mark_departed(w);
        }
        for w in outcome.failed {
            if !self.failed_set.contains(&w) {
                self.failed_set.push(w);
                // Elastic pools treat a fatal failure as a departure; a
                // static run's membership log stays empty by contract.
                if self.cfg.elastic.is_some() {
                    for job in &mut self.jobs {
                        job.record_membership(MembershipEvent::Leave { worker: w });
                    }
                }
            }
            // A fatal failure is a departure the worker never got to
            // announce: same bookkeeping as a drain.
            self.mark_departed(w);
        }

        let job = &mut self.jobs[id];
        let grad_norm = outcome.gradient.iter().map(|g| g * g).sum::<f64>().sqrt();
        job.state.step(&outcome.gradient, job.lr);
        let vr = virtual_runtime(&job.spec, &job.scheme, &eff);
        self.virtual_makespan += vr;
        job.issued_work += job.spec.unit_work() * job.scheme.work_units_per_worker();
        // Run-level partial-decode ledger, bumped beside the outcome
        // handoff (the lint's ledger-discipline pair).
        job.report.partial_decodes += outcome.partial_blocks;
        job.report.iters.push(IterMetrics {
            iter,
            epoch: job.epoch,
            workers: n,
            virtual_runtime: vr,
            wall_ns: t_iter.elapsed().as_nanos() as u64,
            decode_ns: outcome.decode_ns,
            blocks_decoded: job.scheme.ranges().len(),
            late_contributions: outcome.late_contributions,
            stale_epoch_contributions: outcome.stale_epoch
                + outcome.mismatched_binding
                + outcome.cross_job,
            grad_norm,
            approx_blocks,
            partial_contributions: outcome.partial_contributions,
            partial_blocks: outcome.partial_blocks,
            // The serialized barrier never dispatches into a backlog.
            queue_wait: 0.0,
        });
        job.iters_done += 1;
        if job.eval_every > 0 && job.iters_done % job.eval_every == 0 {
            if let Some(e) = job.eval_exec.as_mut() {
                let l = e.loss(job.state.as_slice())?;
                job.report.loss_curve.push((job.iters_done, l));
            }
        }
        self.rounds += 1;
        Ok(())
    }

    /// Route the shared event channel until job `id`'s iteration
    /// decodes completely. Foreign jobs' stray blocks are charged to
    /// their own off-cycle counters; unknown job ids are dropped.
    fn collect_for(&mut self, id: JobId, iter: usize) -> Result<IterOutcome> {
        self.jobs[id].master.begin_collect(iter, &self.live_mask)?;
        if self.jobs[id].master.collect_complete() {
            // Degenerate scheme with nothing to decode: don't wait on
            // events that will never come.
            return Ok(self.jobs[id].master.take_outcome());
        }
        loop {
            let ev = match self.event_rx.recv_timeout(self.cfg.stall_timeout) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    self.jobs[id].master.abort_collect();
                    return Err(Error::Runtime(format!(
                        "job {id}: iteration {iter}: stalled waiting for contributions"
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.jobs[id].master.abort_collect();
                    return Err(Error::Runtime(format!(
                        "job {id}: iteration {iter}: all workers disconnected"
                    )));
                }
            };
            // Route blocks by job: only the active job's master consumes
            // its traffic; a non-active job's tail blocks are by
            // definition late (or stale-epoch) for that job.
            let ev = match ev {
                WorkerEvent::Block(c) if c.job != id => {
                    match self.jobs.get_mut(c.job) {
                        Some(other) => other.note_offcycle(c.epoch),
                        None => self.cross_job_dropped += 1,
                    }
                    // The router dropped this contribution, so the
                    // router recycles its wire buffer.
                    self.wire_pool.put(c.coded);
                    continue;
                }
                WorkerEvent::Partial(c) if c.job != id => {
                    // Streamed deltas are late by definition off-cycle
                    // (they never feed pending reconciliations); same
                    // router-recycles-what-it-drops contract as blocks.
                    match self.jobs.get_mut(c.job) {
                        Some(other) => other.note_offcycle(c.epoch),
                        None => self.cross_job_dropped += 1,
                    }
                    self.wire_pool.put(c.coded);
                    continue;
                }
                ev => ev,
            };
            if self.jobs[id].master.offer(ev)? {
                return Ok(self.jobs[id].master.take_outcome());
            }
        }
    }

    /// Pick the next job to broadcast (None when every job is done).
    pub fn next_job(&mut self) -> Option<JobId> {
        self.pick_job(|j| !j.done())
    }

    /// The async dispatcher's eligibility: unfinished and not already
    /// collecting an in-flight iteration (synchronous GD needs the
    /// decoded gradient before its next broadcast).
    fn pick_ready_job(&mut self) -> Option<JobId> {
        self.pick_job(|j| !j.done() && !j.master.is_collecting())
    }

    /// Scheduler core shared by the serialized and async drivers: the
    /// schedule mode picks among `eligible` jobs.
    fn pick_job(&mut self, eligible: impl Fn(&JobHandle) -> bool) -> Option<JobId> {
        let k = self.jobs.len();
        if k == 0 {
            return None;
        }
        match self.cfg.schedule {
            ScheduleMode::RoundRobin => {
                for off in 0..k {
                    let id = (self.rr_cursor + off) % k;
                    if eligible(&self.jobs[id]) {
                        self.rr_cursor = (id + 1) % k;
                        return Some(id);
                    }
                }
                None
            }
            ScheduleMode::WeightedUnitWork => self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| eligible(j))
                .min_by(|a, b| {
                    a.1.issued_work
                        .partial_cmp(&b.1.issued_work)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(i, _)| i),
        }
    }

    /// Drive every submitted job to completion under the pool's
    /// scheduler: per round — scheduled churn, the picked job's adapt
    /// poll, a pool-wide re-dimension check, one broadcast+collect.
    pub fn run_all(&mut self) -> Result<()> {
        while let Some(id) = self.next_job() {
            self.apply_scheduled_churn_at(self.rounds)?;
            self.adapt_job(id)?;
            self.maybe_redimension()?;
            self.step_job(id)?;
        }
        Ok(())
    }

    /// Shut the fleet down and produce every job's report (indexed by
    /// [`JobId`]).
    pub fn finish(mut self) -> Result<Vec<TrainReport>> {
        for tx in self.task_txs.iter().flatten() {
            let _ = tx.send(WorkerTask::Shutdown);
        }
        self.task_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        // Reap transport service threads (socket readers, lease
        // sweeper) after the workers themselves, then snapshot the
        // final wire counters into every report.
        self.transport.shutdown();
        let wire: WireSnapshot = self.transport.wire_stats();
        let failed = std::mem::take(&mut self.failed_set);
        Ok(self
            .jobs
            .drain(..)
            .map(|mut job| {
                job.finalize(&failed);
                job.report.wire = wire;
                job.report
            })
            .collect())
    }

    /// [`Self::run_all`] + [`Self::finish`].
    pub fn run_to_completion(mut self) -> Result<Vec<TrainReport>> {
        self.run_all()?;
        self.finish()
    }

    /// Drive every job to completion with **pipelined** broadcasts (see
    /// the module docs): up to [`AsyncConfig::max_inflight`] collects
    /// stay open at once, dispatches price each row's queue backlog
    /// into the scheme, and semi-asynchronous decodes (when configured)
    /// trade a tracked approximation error for not waiting on
    /// deeply-backlogged rows. Falls back to the serialized
    /// [`Self::run_all`] when `PoolConfig::async_rounds` is unset.
    pub fn run_all_async(&mut self) -> Result<()> {
        let Some(cfg) = self.cfg.async_rounds.clone() else {
            return self.run_all();
        };
        let mut eng = AsyncEngine::new(cfg, self.task_txs.len());
        let out = self.drive_async(&mut eng);
        if out.is_err() {
            // Recycle what the open collects held before surfacing.
            self.abort_open(&mut eng);
        }
        if eng.makespan > self.virtual_makespan {
            self.virtual_makespan = eng.makespan;
        }
        out
    }

    /// [`Self::run_all_async`] + [`Self::finish`].
    pub fn run_to_completion_async(mut self) -> Result<Vec<TrainReport>> {
        self.run_all_async()?;
        self.finish()
    }

    fn drive_async(&mut self, eng: &mut AsyncEngine) -> Result<()> {
        let max_inflight = eng.cfg.max_inflight.max(1);
        loop {
            // Fill the pipeline: dispatch every ready job up to depth.
            while eng.open.len() < max_inflight {
                let Some(id) = self.pick_ready_job() else { break };
                self.dispatch_round(eng, id)?;
            }
            // Finalize whatever completed (including degenerate rounds
            // that were complete at dispatch); freed slots re-enter the
            // dispatch loop before we block on the channel.
            if self.finalize_complete(eng)? > 0 {
                continue;
            }
            if eng.open.is_empty() {
                // Nothing open and nothing dispatchable: all jobs done.
                return Ok(());
            }
            let ev = match self.event_rx.recv_timeout(self.cfg.stall_timeout) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(Error::Runtime(format!(
                        "async rounds: stalled with {} open collect(s)",
                        eng.open.len()
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(Error::Runtime("async rounds: all workers disconnected".into()));
                }
            };
            self.route_event_async(ev)?;
        }
    }

    /// Abort every open collect (error path), recycling master-held
    /// buffers.
    fn abort_open(&mut self, eng: &mut AsyncEngine) {
        for open in eng.open.drain(..) {
            self.jobs[open.job].master.abort_collect();
        }
    }

    /// Dispatch one pipelined iteration of job `id`. Mirrors the
    /// serialized per-round order exactly — scheduled churn, the job's
    /// adapt poll, the pool-wide re-dimension check (deferred to
    /// pipeline-drain points: a rebind swaps every job's epoch and must
    /// not land under an open collect), then broadcast — plus the
    /// position-aware parts: backlog pricing and the deep-row mask.
    fn dispatch_round(&mut self, eng: &mut AsyncEngine, id: JobId) -> Result<()> {
        self.apply_scheduled_churn_at(self.rounds)?;
        self.adapt_job(id)?;
        if eng.open.is_empty() {
            self.maybe_redimension()?;
        }
        // lint: allow(determinism) — wall_ns metric only; round control flow is virtual-time
        let t_wall = Instant::now();
        let n = self.registry.n();
        debug_assert_eq!(self.jobs[id].spec.n, n, "job not re-dimensioned to the live roster");
        let roster = self.registry.roster().to_vec();
        let times = self.sampler.sample_roster(self.rounds, &roster);
        self.observe_round(id, &times, &roster);
        let iter = self.jobs[id].iters_done;
        let eff: Vec<f64> = times
            .iter()
            .enumerate()
            .map(|(row, &t)| t * self.jobs[id].load_mult.get(row).copied().unwrap_or(1.0))
            .collect();

        // Dispatch stamp: the job's own GD dependency (θ needs the
        // previous iteration's gradient) and, when the pipeline was
        // full, the finalize that freed this slot.
        let t_b = eng.avail(id).max(eng.slot_gate);
        // Per-row backlog: queued-but-unfinished virtual work at t_b.
        let q: Vec<f64> = roster.iter().map(|&wid| (eng.wfree(wid) - t_b).max(0.0)).collect();
        let queue_wait = q.iter().cloned().fold(0.0, f64::max);

        if eng.cfg.backlog_pricing {
            self.maybe_reprice(eng, id, iter, &q)?;
        }

        self.refresh_row_senders();
        {
            let job = &self.jobs[id];
            job.master.broadcast(
                iter,
                job.state.shared(),
                &eff,
                job.spec.unit_work(),
                &job.factory,
                &self.row_senders,
            );
        }
        // Deep-row mask for the semi-async decode: a row whose backlog
        // exceeds `backlog_factor ×` one average round of this job's
        // work is not worth waiting on.
        let semi = eng.cfg.semi_async.clone();
        let deep: Vec<bool> = match &semi {
            Some(cfg) => {
                let job = &self.jobs[id];
                let mean_t = eff.iter().sum::<f64>() / eff.len().max(1) as f64;
                let round_v = job.spec.unit_work() * job.scheme.work_units_per_worker() * mean_t;
                q.iter().map(|&b| b > cfg.backlog_factor * round_v).collect()
            }
            None => vec![false; n],
        };
        self.jobs[id].master.begin_collect_async(iter, &self.live_mask, &deep, semi)?;

        // Enqueue the round's compute segments on the virtual-time
        // queues and open the round.
        let job = &mut self.jobs[id];
        let unit = job.spec.unit_work();
        let ranges = job.scheme.ranges();
        let mut cum = Vec::with_capacity(ranges.len());
        let mut ks = Vec::with_capacity(ranges.len());
        let mut acc = 0.0f64;
        for r in &ranges {
            acc += ((r.s + 1) * r.len()) as f64;
            cum.push(acc);
            ks.push(n - 1 - r.s);
        }
        for (row, &wid) in roster.iter().enumerate() {
            eng.push_seg(wid, id, iter, t_b, unit * (eff[row] * acc));
        }
        job.issued_work += unit * job.scheme.work_units_per_worker();
        eng.open.push(OpenRound {
            job: id,
            iter,
            t_b,
            roster,
            eff,
            unit,
            cum,
            ks,
            queue_wait,
            t_wall,
        });
        self.rounds += 1;
        Ok(())
    }

    /// Backlog-aware scheme selection: express each row's queued
    /// virtual time as an added shift on its fitted cycle-time model
    /// (`delay = backlog / (unit·W)` cycles — Eq. (2) and the
    /// subgradient solver then price queue position natively) and
    /// re-solve the partition when the backlog skew across rows exceeds
    /// [`AsyncConfig::reprice_threshold`] mean cycle times. No-op for
    /// jobs without an adaptive controller or without fit evidence.
    fn maybe_reprice(
        &mut self,
        eng: &AsyncEngine,
        id: JobId,
        iter: usize,
        q: &[f64],
    ) -> Result<()> {
        let job = &self.jobs[id];
        let Some(ctrl) = job.controller.as_ref() else { return Ok(()) };
        let w = job.spec.unit_work() * job.scheme.work_units_per_worker();
        if w <= 0.0 || q.is_empty() {
            return Ok(());
        }
        let Some(fit) = ctrl.current_fit() else { return Ok(()) };
        let mean = fit.mean();
        if !mean.is_finite() || mean <= 0.0 {
            return Ok(());
        }
        let max_q = q.iter().cloned().fold(0.0f64, f64::max);
        let min_q = q.iter().cloned().fold(f64::INFINITY, f64::min);
        // Backlog skew in cycle-time units: a uniform backlog shifts
        // every row equally and leaves the optimal partition unchanged.
        let skew = (max_q - min_q) / w;
        if !skew.is_finite() || skew <= eng.cfg.reprice_threshold * mean {
            return Ok(());
        }
        let delays: Vec<f64> = q.iter().map(|&v| v / w).collect();
        let roster = self.registry.roster().to_vec();
        let Some(fleet) = ctrl.delay_priced_fleet(&roster, &delays) else { return Ok(()) };
        let warm = job.scheme.blocks().as_f64();
        let spec = job.spec;
        let strategy = job.resolve_strategy.clone();
        let dim = job.dim;
        let job = &mut self.jobs[id];
        let blocks = adaptive::resolve_partition(
            &strategy,
            &spec,
            &fleet,
            Some(warm.as_slice()),
            dim,
            &mut job.rng,
        )?;
        crate::log_info!(
            "job {id}: iter {iter}: backlog skew {:.2}× mean → repricing scheme epoch {}",
            skew / mean,
            job.epoch + 1
        );
        job.install_scheme(blocks, iter, Some(&fit), skew / mean)
    }

    /// Route one shared-channel event while async rounds are open.
    /// Blocks go to their own job's master — its open collect when it
    /// has one (stale-iteration arrivals feed pending reconciliations
    /// internally), the reconciliation path otherwise, the off-cycle
    /// counters as a last resort. Membership events fan out to every
    /// open collect; the registry reconciles once per finalize (its
    /// transitions are idempotent).
    fn route_event_async(&mut self, ev: WorkerEvent) -> Result<()> {
        match ev {
            WorkerEvent::Block(c) => {
                let jid = c.job;
                match self.jobs.get_mut(jid) {
                    None => {
                        self.cross_job_dropped += 1;
                        self.wire_pool.put(c.coded);
                    }
                    Some(job) => {
                        if job.master.is_collecting() {
                            job.master.offer(WorkerEvent::Block(c))?;
                        } else if let Some(c) = job.master.offer_pending(c) {
                            // Not a pending reconciliation either: a
                            // plain off-cycle tail block.
                            job.note_offcycle(c.epoch);
                            self.wire_pool.put(c.coded);
                        }
                        self.apply_reconciles(jid);
                    }
                }
            }
            WorkerEvent::Partial(c) => {
                match self.jobs.get_mut(c.job) {
                    None => {
                        self.cross_job_dropped += 1;
                        self.wire_pool.put(c.coded);
                    }
                    Some(job) => {
                        if job.master.is_collecting() {
                            job.master.offer(WorkerEvent::Partial(c))?;
                        } else {
                            // Streamed deltas never feed pending
                            // reconciliations: an off-cycle part is a
                            // plain late tail, recycled by the router
                            // that dropped it.
                            job.note_offcycle(c.epoch);
                            self.wire_pool.put(c.coded);
                        }
                    }
                }
            }
            WorkerEvent::Joined { worker } => {
                for job in self.jobs.iter_mut() {
                    if job.master.is_collecting() {
                        job.master.offer(WorkerEvent::Joined { worker })?;
                    }
                }
            }
            WorkerEvent::Left { worker } => {
                for job in self.jobs.iter_mut() {
                    if job.master.is_collecting() {
                        job.master.offer(WorkerEvent::Left { worker })?;
                    }
                }
            }
            WorkerEvent::Failed { worker, job, iter, reason, fatal } => {
                for j in self.jobs.iter_mut() {
                    if j.master.is_collecting() {
                        j.master.offer(WorkerEvent::Failed {
                            worker,
                            job,
                            iter,
                            reason: reason.clone(),
                            fatal,
                        })?;
                    }
                }
            }
        }
        Ok(())
    }

    /// Land any completed semi-async reconciliations for job `id`:
    /// `θ[start..end] −= lr·(exact − approx)` retroactively re-bases
    /// each block on its exact decode.
    fn apply_reconciles(&mut self, id: JobId) {
        let job = &mut self.jobs[id];
        for rec in job.master.take_reconciled() {
            if rec.bound > job.report.max_approx_bound {
                job.report.max_approx_bound = rec.bound;
            }
            job.state.correct(rec.start, &rec.delta, job.lr);
            job.report.approx_reconciled += 1;
        }
    }

    /// Finalize every open round whose collect completed; returns how
    /// many were closed. Finalization order is dispatch order among the
    /// complete set, so accounting is deterministic given the same
    /// completion pattern.
    fn finalize_complete(&mut self, eng: &mut AsyncEngine) -> Result<usize> {
        let mut closed = 0;
        loop {
            let Some(pos) =
                eng.open.iter().position(|o| self.jobs[o.job].master.collect_complete())
            else {
                return Ok(closed);
            };
            let open = eng.open.remove(pos);
            self.finalize_round(eng, open)?;
            closed += 1;
        }
    }

    /// Close one round: take the decode outcome, reconcile pool-level
    /// membership, settle the round's virtual-time accounting (truncate
    /// + reflow the queues), step the model and record metrics.
    fn finalize_round(&mut self, eng: &mut AsyncEngine, open: OpenRound) -> Result<()> {
        let id = open.job;
        let outcome = self.jobs[id].master.take_outcome();
        let approx_blocks = outcome.approx.len();
        for a in &outcome.approx {
            if a.bound > self.jobs[id].report.max_approx_bound {
                self.jobs[id].report.max_approx_bound = a.bound;
            }
        }
        for w in outcome.joined {
            self.registry.confirm(w);
        }
        for w in outcome.left {
            self.mark_departed(w);
        }
        for w in outcome.failed {
            if !self.failed_set.contains(&w) {
                self.failed_set.push(w);
                if self.cfg.elastic.is_some() {
                    for job in &mut self.jobs {
                        job.record_membership(MembershipEvent::Leave { worker: w });
                    }
                }
            }
            self.mark_departed(w);
        }

        let vr = eng.complete(&open);
        let v = open.t_b + vr;
        if eng.open.len() + 1 >= eng.cfg.max_inflight.max(1) {
            // This finalize freed a slot in a full pipeline: the next
            // dispatch could not have gone out before it.
            eng.slot_gate = v;
        }
        eng.set_avail(id, v);
        if v > eng.makespan {
            eng.makespan = v;
        }

        let job = &mut self.jobs[id];
        let grad_norm = outcome.gradient.iter().map(|g| g * g).sum::<f64>().sqrt();
        job.state.step(&outcome.gradient, job.lr);
        job.report.approx_decodes += approx_blocks;
        job.report.partial_decodes += outcome.partial_blocks;
        job.report.iters.push(IterMetrics {
            iter: open.iter,
            epoch: job.epoch,
            workers: open.roster.len(),
            virtual_runtime: vr,
            wall_ns: open.t_wall.elapsed().as_nanos() as u64,
            decode_ns: outcome.decode_ns,
            blocks_decoded: job.scheme.ranges().len(),
            late_contributions: outcome.late_contributions,
            stale_epoch_contributions: outcome.stale_epoch
                + outcome.mismatched_binding
                + outcome.cross_job,
            grad_norm,
            approx_blocks,
            partial_contributions: outcome.partial_contributions,
            partial_blocks: outcome.partial_blocks,
            queue_wait: open.queue_wait,
        });
        job.iters_done += 1;
        if job.eval_every > 0 && job.iters_done % job.eval_every == 0 {
            if let Some(e) = job.eval_exec.as_mut() {
                let l = e.loss(job.state.as_slice())?;
                job.report.loss_curve.push((job.iters_done, l));
            }
        }
        self.apply_reconciles(id);
        Ok(())
    }
}

/// One queued compute segment on a worker's virtual-time schedule.
#[derive(Debug, Clone)]
struct Seg {
    job: JobId,
    iter: usize,
    /// Virtual time the broadcast was issued (the segment can never
    /// start earlier).
    dispatch: f64,
    /// Natural compute duration, `unit·T_eff·Σ(s+1)x`.
    cost: f64,
    start: f64,
    end: f64,
    /// Finalized: the interval is settled; reflow moves only live
    /// segments.
    frozen: bool,
}

/// One broadcast whose collect is still open.
struct OpenRound {
    job: JobId,
    iter: usize,
    /// Dispatch virtual time (`max(job ready, slot gate)`).
    t_b: f64,
    roster: Vec<WorkerId>,
    /// Effective per-row cycle times sampled at dispatch.
    eff: Vec<f64>,
    unit: f64,
    /// Per-block cumulative work prefix `Σ_{b'≤b}(s+1)·x`.
    cum: Vec<f64>,
    /// Per-block quorum order-statistic index (`n−1−s`).
    ks: Vec<usize>,
    /// Largest row backlog priced at dispatch (metrics).
    queue_wait: f64,
    t_wall: Instant,
}

/// Virtual-time state of the pipelined dispatcher: per-worker segment
/// queues, open rounds, and the dispatch gates.
struct AsyncEngine {
    cfg: AsyncConfig,
    /// Per-worker-**id** queues of in-flight compute segments.
    queues: Vec<Vec<Seg>>,
    /// Per-worker completion floor of the collapsed finalized prefix.
    floor: Vec<f64>,
    open: Vec<OpenRound>,
    /// Per-job virtual time its previous iteration finalized at.
    job_avail: Vec<f64>,
    /// Virtual time the most recent full-pipeline finalize freed a
    /// dispatch slot.
    slot_gate: f64,
    makespan: f64,
}

impl AsyncEngine {
    fn new(cfg: AsyncConfig, workers: usize) -> Self {
        Self {
            cfg,
            queues: vec![Vec::new(); workers],
            floor: vec![0.0; workers],
            open: Vec::new(),
            job_avail: Vec::new(),
            slot_gate: 0.0,
            makespan: 0.0,
        }
    }

    fn ensure(&mut self, wid: WorkerId) {
        if self.queues.len() <= wid {
            self.queues.resize_with(wid + 1, Vec::new);
            self.floor.resize(wid + 1, 0.0);
        }
    }

    /// Virtual time worker `wid`'s queue drains (its next segment can
    /// start no earlier).
    fn wfree(&self, wid: WorkerId) -> f64 {
        match self.queues.get(wid).and_then(|q| q.last()) {
            Some(seg) => seg.end,
            None => self.floor.get(wid).copied().unwrap_or(0.0),
        }
    }

    fn avail(&self, job: JobId) -> f64 {
        self.job_avail.get(job).copied().unwrap_or(0.0)
    }

    fn set_avail(&mut self, job: JobId, v: f64) {
        if self.job_avail.len() <= job {
            self.job_avail.resize(job + 1, 0.0);
        }
        self.job_avail[job] = v;
    }

    fn push_seg(&mut self, wid: WorkerId, job: JobId, iter: usize, dispatch: f64, cost: f64) {
        self.ensure(wid);
        let start = self.wfree(wid).max(dispatch);
        let end = start + cost;
        self.queues[wid].push(Seg { job, iter, dispatch, cost, start, end, frozen: false });
    }

    /// Settle a finalized round's virtual-time accounting and return
    /// its virtual runtime **relative to its dispatch stamp**.
    ///
    /// Each row's decode-relevant completion is its queue offset at
    /// dispatch plus its natural block-completion stamp; per block, the
    /// quorum lands at the `(n−1−s)`-th order statistic, and the round
    /// completes at the slowest block (Eq. (2) with per-row shifts —
    /// with empty queues the offsets are exactly 0 and this reproduces
    /// [`virtual_runtime`] bit-for-bit). The round's segments are then
    /// **truncated** at the decode time — tail compute past the quorum
    /// is abandoned, exactly like the serialized barrier — queued
    /// segments behind them reflow, and the finalized prefix collapses
    /// into each worker's completion floor.
    fn complete(&mut self, open: &OpenRound) -> f64 {
        let n = open.roster.len();
        let offs: Vec<f64> = open
            .roster
            .iter()
            .map(|&wid| {
                self.queues
                    .get(wid)
                    .and_then(|q| q.iter().find(|s| s.job == open.job && s.iter == open.iter))
                    .map(|s| s.start - open.t_b)
                    .unwrap_or(0.0)
            })
            .collect();
        let mut vr = 0.0f64;
        let mut vals = vec![0.0f64; n];
        for (b, &cum) in open.cum.iter().enumerate() {
            for (row, v) in vals.iter_mut().enumerate() {
                *v = offs[row] + open.unit * (open.eff[row] * cum);
            }
            vals.sort_by(f64::total_cmp);
            let v = vals[open.ks[b]];
            if v > vr {
                vr = v;
            }
        }
        let v_abs = open.t_b + vr;
        for &wid in &open.roster {
            let Some(q) = self.queues.get_mut(wid) else { continue };
            let Some(i) = q.iter().position(|s| s.job == open.job && s.iter == open.iter) else {
                continue;
            };
            q[i].end = q[i].end.min(q[i].start.max(v_abs));
            q[i].frozen = true;
            let mut prev = q[i].end;
            for seg in q.iter_mut().skip(i + 1) {
                if seg.frozen {
                    prev = seg.end;
                    continue;
                }
                seg.start = prev.max(seg.dispatch);
                seg.end = seg.start + seg.cost;
                prev = seg.end;
            }
            while q.first().is_some_and(|s| s.frozen) {
                let e = q.remove(0).end;
                if e > self.floor[wid] {
                    self.floor[wid] = e;
                }
            }
        }
        vr
    }
}

