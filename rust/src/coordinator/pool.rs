//! The shared worker pool and its multi-job coordinator.
//!
//! PRs 1–3 made the coding scheme an epoch-versioned artifact over a
//! stable [`WorkerId`] registry — but the public API still hard-wired
//! one training job to one thread pool. This module finishes the
//! decoupling: a [`WorkerPool`] owns the threads, the
//! [`WorkerRegistry`], the channels and the pooled cycle-time feed, and
//! any number of **jobs** — each a [`JobHandle`] with its own scheme
//! epochs, decode state ([`Master`] keyed by `(job, epoch)`), model
//! state and adapt/re-dimension loop — are multiplexed over it. This is
//! how production straggler-mitigation systems amortize stragglers
//! across tenants: redundancy is priced per cluster, not per job, and
//! straggler statistics are pooled.
//!
//! ## Submitting work
//!
//! Jobs are described by a builder-style [`JobSpec`] and submitted to a
//! live pool:
//!
//! ```ignore
//! let mut pool = WorkerPool::new(PoolConfig::new(8), schedule)?;
//! let a = JobSpec::new(spec_a, blocks_a).executor(factory_a).steps(150).submit(&mut pool)?;
//! let b = JobSpec::new(spec_b, blocks_b).executor(factory_b).steps(50)
//!     .adaptive(AdaptiveConfig::default()).submit(&mut pool)?;
//! let reports = pool.run_to_completion()?;
//! ```
//!
//! ## Scheduling
//!
//! The pool interleaves **per-iteration broadcasts**: each round, the
//! scheduler picks one unfinished job, broadcasts its iteration to every
//! worker, and decodes it to completion before the next round
//! (synchronous GD needs the decoded gradient before its next
//! broadcast anyway). [`ScheduleMode::RoundRobin`] cycles fairly over
//! unfinished jobs; [`ScheduleMode::WeightedUnitWork`] is deficit-fair
//! in *work*: it always picks the job that has consumed the least total
//! coded work (`unit_work × Σ(s+1)x` per iteration), so cheap jobs get
//! proportionally more turns and no tenant can starve the others with
//! huge iterations.
//!
//! ## Isolation
//!
//! Every task and contribution is stamped with its [`JobId`]. The pool
//! routes the shared event channel by job: the active job's master
//! consumes its own traffic; another job's late blocks are counted
//! against *that* job (off-cycle arrivals — late or stale by
//! definition, since the job is not collecting); blocks for unknown
//! jobs are dropped and counted. A job's quorum only ever contains its
//! own codewords ([`Master`] refuses cross-job contributions like
//! stale epochs), and a straggling job cannot stall a healthy one
//! beyond the worker-FIFO delay its own redundancy already absorbs.
//!
//! ## Membership
//!
//! Churn is a **pool-level** event: joins/leaves update the one shared
//! registry, and once churn passes the elastic threshold — or
//! departures exceed what the most fragile live scheme absorbs — the
//! pool rebinds rows **once** and every job re-solves its partition for
//! the new `N'` (each from its own family-selected fit, all off the
//! shared membership epoch) and installs it as a fresh scheme epoch.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coding::scheme::CodingScheme;
use crate::coordinator::adaptive::{self, AdaptiveConfig, AdaptiveController, ResolveStrategy};
use crate::coordinator::channel::{BlockContribution, JobId, ShardMap, WorkerEvent, WorkerTask};
use crate::coordinator::master::{
    load_multipliers, redistribute_shards, redistribute_shards_weighted, IterOutcome, Master,
};
use crate::coordinator::membership::{MemberStatus, WorkerId, WorkerRegistry};
use crate::coordinator::metrics::{
    IterMetrics, MembershipEvent, MembershipRecord, SchemeEpoch, TrainReport,
};
use crate::coordinator::state::ModelState;
use crate::coordinator::straggler::{virtual_runtime, StragglerSampler, StragglerSchedule};
use crate::coordinator::worker::{self, WorkerContext};
use crate::coordinator::PacingMode;
use crate::distribution::fit::{FittedModel, ShiftedExpEstimate};
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::{ExecutorFactory, GradExecutor};
use crate::util::buffers::BufferPool;
use crate::util::rng::Rng;
use crate::{Error, Result};

/// Elastic worker-pool policy: when membership changes, when to
/// re-dimension the jobs' schemes around the new roster.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Re-dimension once this many membership changes (confirmed joins
    /// + leaves) accumulated since the last rebind. Departures that
    /// exceed a live scheme's redundancy always force an immediate
    /// re-dimension regardless of this threshold. Clamped to ≥ 1.
    pub churn_threshold: usize,
    /// Scheduled departures `(round, count)`: before pool round
    /// `round`, drain `count` workers (highest-id live workers first).
    /// For a single-job pool, rounds and job iterations coincide.
    pub departures: Vec<(usize, usize)>,
    /// Scheduled arrivals `(round, count)`: before pool round `round`,
    /// spawn `count` new workers (assigned work from the next epoch).
    pub arrivals: Vec<(usize, usize)>,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        Self { churn_threshold: 1, departures: Vec::new(), arrivals: Vec::new() }
    }
}

/// How the pool interleaves per-iteration broadcasts across jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ScheduleMode {
    /// Fair rotation over unfinished jobs: every job gets one
    /// iteration per cycle.
    #[default]
    RoundRobin,
    /// Deficit-fair in work: each round goes to the job that has
    /// consumed the least total coded work so far (`unit_work ×
    /// Σ(s+1)x` per iteration), so per-iteration cost differences
    /// between tenants even out.
    WeightedUnitWork,
}

impl ScheduleMode {
    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round_robin" | "round-robin" | "rr" => Some(Self::RoundRobin),
            "weighted" | "weighted_unit_work" => Some(Self::WeightedUnitWork),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Self::RoundRobin => "round_robin",
            Self::WeightedUnitWork => "weighted",
        }
    }
}

/// Pool-wide configuration (everything that is a property of the
/// worker fleet rather than of any one job).
#[derive(Clone)]
pub struct PoolConfig {
    /// Initial worker count `N` (ids `0..N`).
    pub workers: usize,
    pub pacing: PacingMode,
    /// Seeds the pooled cycle-time sampler.
    pub seed: u64,
    /// How long a collect waits on an empty event channel before
    /// declaring the iteration stalled.
    pub stall_timeout: Duration,
    /// Worker ids that are never spawned — failure injection. Every
    /// job's coded scheme must tolerate them.
    pub dead_workers: Vec<usize>,
    /// Elastic membership policy (None = `N` frozen at spawn).
    pub elastic: Option<ElasticConfig>,
    /// How rounds are interleaved across jobs.
    pub schedule: ScheduleMode,
    /// Pooled estimator feed: when true (default), every job's drift
    /// controller observes **every** round's sampled cycle times —
    /// worker speeds are a pool property, so tenants share straggler
    /// statistics and windows fill `K×` faster on a `K`-job pool.
    pub shared_observations: bool,
}

impl PoolConfig {
    pub fn new(workers: usize) -> Self {
        Self {
            workers,
            pacing: PacingMode::Virtual,
            seed: 2021,
            stall_timeout: Duration::from_secs(30),
            dead_workers: Vec::new(),
            elastic: None,
            schedule: ScheduleMode::RoundRobin,
            shared_observations: true,
        }
    }
}

/// Builder-style description of one training job, submitted to a
/// [`WorkerPool`]. The problem spec's `n` must match the pool's
/// current worker count (solve the partition for the pool you are
/// joining).
pub struct JobSpec {
    spec: ProblemSpec,
    blocks: BlockPartition,
    steps: usize,
    lr: f64,
    eval_every: usize,
    seed: u64,
    init_scale: f64,
    adaptive: Option<AdaptiveConfig>,
    elastic: Option<ElasticConfig>,
    factory: Option<ExecutorFactory>,
}

impl JobSpec {
    /// A job over `spec` dimensions with an initial (epoch-0) block
    /// partition.
    pub fn new(spec: ProblemSpec, blocks: BlockPartition) -> Self {
        Self {
            spec,
            blocks,
            steps: 100,
            lr: 1e-2,
            eval_every: 10,
            seed: 2021,
            init_scale: 0.05,
            adaptive: None,
            elastic: None,
            factory: None,
        }
    }

    /// GD iterations to run.
    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = steps;
        self
    }

    /// Learning rate.
    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = lr;
        self
    }

    /// Evaluate the loss every `k` steps (0 = never).
    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k;
        self
    }

    /// Seed for the job's scheme construction and θ init.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// θ init scale (Gaussian); 0 = zeros.
    pub fn init_scale(mut self, scale: f64) -> Self {
        self.init_scale = scale;
        self
    }

    /// Online re-optimization policy (drift-triggered re-solves).
    pub fn adaptive(mut self, cfg: AdaptiveConfig) -> Self {
        self.adaptive = Some(cfg);
        self
    }

    /// Elastic membership policy. Membership is pool-level, so this is
    /// a convenience that installs the policy on the pool at submit
    /// time; submitting a second elastic policy to a pool that already
    /// has one is an error.
    pub fn elastic(mut self, cfg: ElasticConfig) -> Self {
        self.elastic = Some(cfg);
        self
    }

    /// The executor factory backing this job's gradient compute
    /// (required).
    pub fn executor(mut self, factory: ExecutorFactory) -> Self {
        self.factory = Some(factory);
        self
    }

    /// Submit to a pool; the job starts receiving broadcast rounds on
    /// the next scheduler pass.
    pub fn submit(self, pool: &mut WorkerPool) -> Result<JobId> {
        pool.submit(self)
    }
}

/// Per-job state on the pool: scheme epochs, decode state, adaptive
/// controller, model parameters and the job's training report — the
/// surface `TrainSession` used to expose for exactly one job.
pub struct JobHandle {
    id: JobId,
    spec: ProblemSpec,
    dim: usize,
    /// Dataset shard count (fixed at submit; elastic subsets are
    /// re-mapped onto these shards when `N` changes).
    num_data_shards: usize,
    steps: usize,
    lr: f64,
    eval_every: usize,
    factory: ExecutorFactory,
    scheme: Arc<CodingScheme>,
    epoch: usize,
    master: Master,
    controller: Option<AdaptiveController>,
    /// Re-solve strategy for elastic re-dimensions (the adaptive
    /// strategy when configured, closed-form `x^(f)` otherwise).
    resolve_strategy: ResolveStrategy,
    state: ModelState,
    eval_exec: Option<Box<dyn GradExecutor>>,
    /// Per-row data-load multipliers of the installed shard map
    /// (`c_row·N/m`; all ones until a speed-weighted re-shard). The
    /// virtual-time layer scales each row's cycle time by its
    /// multiplier so Eq. (2) accounting reflects the weighted
    /// placement.
    load_mult: Vec<f64>,
    iters_done: usize,
    /// Total coded work consumed, in cycles (`unit_work × Σ(s+1)x` per
    /// iteration) — the deficit counter behind
    /// [`ScheduleMode::WeightedUnitWork`].
    issued_work: f64,
    /// Contributions that arrived while this job was **not** collecting
    /// (tail blocks outrun by the decode quorum, delivered during some
    /// other job's round), split by whether they were also stale-epoch.
    offcycle_late: usize,
    offcycle_stale: usize,
    rng: Rng,
    report: TrainReport,
}

impl JobHandle {
    /// The job's id on its pool.
    pub fn id(&self) -> JobId {
        self.id
    }

    /// The current scheme epoch (0-based, monotone).
    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// The currently installed scheme.
    pub fn scheme(&self) -> &Arc<CodingScheme> {
        &self.scheme
    }

    /// The job's problem spec (`n` tracks membership epochs).
    pub fn spec(&self) -> &ProblemSpec {
        &self.spec
    }

    /// Iterations completed so far.
    pub fn iters_done(&self) -> usize {
        self.iters_done
    }

    /// Iterations the job was submitted for.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Whether the job has completed all its steps.
    pub fn done(&self) -> bool {
        self.iters_done >= self.steps
    }

    /// Live view of the job's training report (finalized counters —
    /// cache stats, failed workers — land at pool finish).
    pub fn report(&self) -> &TrainReport {
        &self.report
    }

    /// Decode-vector cache statistics, accumulated across **all** of
    /// this job's scheme epochs.
    pub fn cache_stats(&self) -> (u64, u64) {
        self.master.cache_stats()
    }

    /// Contributions that arrived while the job was not collecting
    /// (late tail blocks routed during other jobs' rounds), as
    /// `(late, stale_epoch)`.
    pub fn offcycle_contributions(&self) -> (usize, usize) {
        (self.offcycle_late, self.offcycle_stale)
    }

    /// The live subset → dataset-shard mapping (identity until an
    /// elastic or speed-weighted re-shard).
    pub fn shard_map(&self) -> &Arc<ShardMap> {
        self.master.shard_map()
    }

    /// Per-row data-load multipliers of the live shard map (all ones
    /// until a speed-weighted re-shard).
    pub fn load_multipliers(&self) -> &[f64] {
        &self.load_mult
    }

    /// Count a contribution that arrived outside the job's own collect
    /// window.
    fn note_offcycle(&mut self, c: &BlockContribution) {
        if c.epoch == self.epoch {
            self.offcycle_late += 1;
        } else {
            self.offcycle_stale += 1;
        }
    }

    /// Install a new same-`N` partition as the job's next scheme epoch.
    /// Safe between iterations: workers receive the new scheme with
    /// their next task, and the master rejects contributions encoded
    /// under any previous epoch like stale-iteration messages.
    /// (Re-dimensioning to a different `N` goes through the pool's
    /// [`WorkerPool::maybe_redimension`].)
    pub fn install_scheme(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
    ) -> Result<()> {
        self.install_scheme_with_shards(blocks, iter, estimate, drift, None)
    }

    /// [`Self::install_scheme`] with an optional subset → shard
    /// re-mapping installed alongside the new epoch (the speed-weighted
    /// actuation path; `None` keeps the live mapping).
    fn install_scheme_with_shards(
        &mut self,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
        shards: Option<Arc<ShardMap>>,
    ) -> Result<()> {
        if blocks.n() != self.spec.n {
            return Err(Error::InvalidArgument("new scheme: blocks.n() != spec.n".into()));
        }
        if blocks.total() != self.dim {
            return Err(Error::InvalidArgument(format!(
                "new scheme covers {} coordinates but the model has {}",
                blocks.total(),
                self.dim
            )));
        }
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        let roster = self.master.roster().to_vec();
        let shards = shards.unwrap_or_else(|| self.master.shard_map().clone());
        self.load_mult = load_multipliers(&shards, self.num_data_shards);
        self.master.install_scheme(scheme, self.epoch, roster, shards);
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.and_then(|e| e.mu_hint()),
            estimated_t0: estimate.and_then(|e| e.t0_hint()),
            estimated_mean: estimate.map(|e| e.mean()),
            family: estimate.map(|e| e.family().name().to_string()),
            drift,
        });
        Ok(())
    }

    /// Poll the job's adaptive policy; on a triggered re-plan, install
    /// the re-optimized scheme as a new epoch.
    fn adapt(&mut self) -> Result<()> {
        if self.controller.is_none() || self.done() {
            return Ok(());
        }
        let iter = self.iters_done;
        let warm = self.scheme.blocks().as_f64();
        let plan = {
            let ctrl = self.controller.as_mut().unwrap();
            ctrl.maybe_replan(iter, &self.spec, &warm, &mut self.rng)?
        };
        if let Some(plan) = plan {
            crate::log_info!(
                "job {}: iter {iter}: drift {:.2} → installing scheme epoch {} (fit {}{})",
                self.id,
                plan.drift,
                self.epoch + 1,
                plan.estimate.label(),
                if plan.fleet_rates.is_some() { ", hetero speed-weighted" } else { "" }
            );
            // Speed-weighted actuation: a hetero re-plan re-shards the
            // dataset proportionally to the fitted per-row rates, so
            // fast workers carry more data instead of idling at the
            // quorum barrier.
            let shards = plan
                .fleet_rates
                .as_ref()
                .map(|r| Arc::new(redistribute_shards_weighted(r, self.num_data_shards)));
            self.install_scheme_with_shards(
                plan.blocks,
                iter,
                Some(&plan.estimate),
                plan.drift,
                shards,
            )?;
        }
        Ok(())
    }

    /// Re-dimension this job onto a rebound roster of `to_n` rows:
    /// re-solve the partition for `N' = to_n` from the job's own
    /// family-selected fit (falling back to `fallback`, then to a
    /// uniform level-1 partition), install it as a fresh scheme epoch,
    /// and flush/rebase the drift estimator (observations under the old
    /// `N`'s unit work are not comparable).
    fn redimension(
        &mut self,
        to_n: usize,
        roster: &[WorkerId],
        fallback: Option<FittedModel>,
    ) -> Result<()> {
        let from_n = self.spec.n;
        let iter = self.iters_done;
        let spec_new = self.spec.with_n(to_n);
        let estimate: Option<FittedModel> =
            self.controller.as_ref().and_then(|c| c.current_fit()).or(fallback);
        let warm = self.scheme.blocks().as_f64();
        // Heterogeneity-aware re-dimension: with per-worker evidence
        // for the surviving roster (the windows are id-keyed, so
        // survivors keep their histories through the rebind), the
        // partition is solved against the load-adjusted fleet AND the
        // shards are re-split by fitted rate — one consistent plan,
        // like the drift path. Otherwise the pooled estimate shapes x
        // and the split stays uniform.
        let fleet_plan = self.controller.as_ref().and_then(|c| c.fleet_plan_for(roster));
        let blocks = match &fleet_plan {
            Some((fleet, _)) => adaptive::resolve_partition(
                &self.resolve_strategy,
                &spec_new,
                fleet,
                Some(warm.as_slice()),
                self.dim,
                &mut self.rng,
            )?,
            None => match &estimate {
                Some(est) => {
                    let dist = est.build();
                    adaptive::resolve_partition(
                        &self.resolve_strategy,
                        &spec_new,
                        dist.as_ref(),
                        Some(warm.as_slice()),
                        self.dim,
                        &mut self.rng,
                    )?
                }
                None => {
                    let s = if to_n > 1 { 1 } else { 0 };
                    BlockPartition::single_level(to_n, s, self.dim)
                }
            },
        };
        self.spec.n = to_n;
        let scheme = Arc::new(CodingScheme::new(blocks, &mut self.rng)?);
        self.epoch += 1;
        self.scheme = scheme.clone();
        let shards = match fleet_plan.as_ref().and_then(|(_, rates)| rates.as_ref()) {
            Some(rates) => Arc::new(redistribute_shards_weighted(rates, self.num_data_shards)),
            None => Arc::new(redistribute_shards(to_n, self.num_data_shards)),
        };
        self.load_mult = load_multipliers(&shards, self.num_data_shards);
        self.master.install_scheme(scheme, self.epoch, roster.to_vec(), shards);
        crate::log_info!(
            "job {}: iter {iter}: re-dimensioned N {from_n}→{to_n} as scheme epoch {}",
            self.id,
            self.epoch
        );
        self.report.scheme_epochs.push(SchemeEpoch {
            epoch: self.epoch,
            installed_at_iter: iter,
            block_sizes: self.scheme.blocks().sizes().to_vec(),
            estimated_mu: estimate.as_ref().and_then(|e| e.mu_hint()),
            estimated_t0: estimate.as_ref().and_then(|e| e.t0_hint()),
            estimated_mean: estimate.as_ref().map(|e| e.mean()),
            family: estimate.as_ref().map(|e| e.family().name().to_string()),
            drift: 0.0,
        });
        self.report.membership.push(MembershipRecord {
            iter,
            event: MembershipEvent::Redimension { from_n, to_n, epoch: self.epoch },
        });
        if let Some(ctrl) = self.controller.as_mut() {
            ctrl.set_roster(roster);
            ctrl.rebase(estimate);
        }
        Ok(())
    }

    /// The smallest redundancy any live block of this job's scheme has
    /// (how many dead rows the job absorbs without re-dimensioning).
    fn min_redundancy(&self) -> usize {
        self.scheme.ranges().iter().map(|r| r.s).min().unwrap_or(0)
    }

    fn record_membership(&mut self, event: MembershipEvent) {
        self.report.membership.push(MembershipRecord { iter: self.iters_done, event });
    }

    fn finalize(&mut self, failed: &[usize]) {
        let (hits, misses) = self.master.cache_stats();
        self.report.decode_cache_hits = hits;
        self.report.decode_cache_misses = misses;
        // Wire-pool counters are pool-wide (the freelist is shared by
        // every worker and job on the pool), snapshotted at job finish.
        let ws = self.master.wire_pool_stats();
        self.report.wire_pool_hits = ws.hits;
        self.report.wire_pool_misses = ws.misses;
        self.report.wire_pool_returned = ws.returned;
        self.report.failed_workers = failed.to_vec();
    }
}

/// The shared worker fleet and the jobs multiplexed over it.
pub struct WorkerPool {
    cfg: PoolConfig,
    registry: WorkerRegistry,
    /// Task channel per worker **id** (None once drained/dead/never
    /// spawned). Indexed by stable id, not row.
    task_txs: Vec<Option<Sender<WorkerTask>>>,
    /// Kept for spawning late joiners; the channel therefore never
    /// disconnects while the pool lives (stalls still time out).
    event_tx: Sender<WorkerEvent>,
    event_rx: Receiver<WorkerEvent>,
    handles: Vec<std::thread::JoinHandle<()>>,
    sampler: StragglerSampler,
    /// Row-indexed liveness for the current membership epoch's roster.
    live_mask: Vec<bool>,
    failed_set: Vec<usize>,
    jobs: Vec<JobHandle>,
    /// Pool-level broadcast rounds completed (one job iteration each).
    rounds: usize,
    rr_cursor: usize,
    /// Sum of every round's virtual runtime — rounds serialize on the
    /// shared pool, so this is the pool's virtual **makespan**.
    virtual_makespan: f64,
    /// Contributions stamped with a job id the pool has never seen.
    cross_job_dropped: usize,
    /// Shared wire-buffer freelist: workers take coded-block buffers
    /// from it, every job's master recycles arrivals back into it (see
    /// the data-plane notes in [`crate::coordinator`]).
    wire_pool: BufferPool,
}

impl WorkerPool {
    /// Spawn a pool of `cfg.workers` threads whose cycle times follow
    /// `schedule` (sampled per round at broadcast).
    pub fn new(cfg: PoolConfig, schedule: StragglerSchedule) -> Result<Self> {
        Self::build(cfg, schedule, None)
    }

    /// Spawn a **heterogeneous** pool: worker id `w`'s cycle times come
    /// from `fleet[w]`'s own model (ids beyond the list — elastic joins
    /// — fall back to `schedule`, which also remains the pool's prior
    /// for seeding drift references).
    pub fn new_fleet(
        cfg: PoolConfig,
        schedule: StragglerSchedule,
        fleet: Vec<Box<dyn crate::distribution::CycleTimeDistribution>>,
    ) -> Result<Self> {
        Self::build(cfg, schedule, Some(fleet))
    }

    fn build(
        cfg: PoolConfig,
        schedule: StragglerSchedule,
        fleet: Option<Vec<Box<dyn crate::distribution::CycleTimeDistribution>>>,
    ) -> Result<Self> {
        if cfg.workers == 0 {
            return Err(Error::InvalidArgument("the pool needs at least one worker".into()));
        }
        let n = cfg.workers;
        let mut registry = WorkerRegistry::new(n);
        let (event_tx, event_rx) = mpsc::channel::<WorkerEvent>();
        let mut task_txs: Vec<Option<Sender<WorkerTask>>> = Vec::with_capacity(n);
        let mut handles = Vec::new();
        let mut live_mask = vec![false; n];
        let wire_pool = BufferPool::default();
        for w in 0..n {
            if cfg.dead_workers.contains(&w) {
                // Injected failure: worker never comes up. It keeps its
                // epoch-0 row (every scheme must absorb it) and is
                // dropped at the first rebind, like any departure.
                task_txs.push(None);
                registry.leave(w);
                continue;
            }
            let tx = spawn_worker(w, &event_tx, cfg.pacing, &wire_pool, &mut handles)?;
            task_txs.push(Some(tx));
            live_mask[w] = true;
        }
        let mut rng = Rng::new(cfg.seed);
        let mut sampler = StragglerSampler::from_schedule(schedule, rng.next_u64());
        if let Some(fleet) = fleet {
            sampler = sampler.with_fleet(fleet);
        }
        // Injected-dead workers are permanent failures from round 0
        // (they also never get a Leave record re-logged per job).
        let failed_set = cfg.dead_workers.clone();
        Ok(Self {
            cfg,
            registry,
            task_txs,
            event_tx,
            event_rx,
            handles,
            sampler,
            live_mask,
            failed_set,
            jobs: Vec::new(),
            rounds: 0,
            rr_cursor: 0,
            virtual_makespan: 0.0,
            cross_job_dropped: 0,
            wire_pool,
        })
    }

    /// Current worker count (rows in the live membership epoch).
    pub fn n(&self) -> usize {
        self.registry.n()
    }

    /// The membership registry (id ↔ row bindings, churn counters).
    pub fn registry(&self) -> &WorkerRegistry {
        &self.registry
    }

    /// Broadcast rounds completed so far (one job iteration each).
    pub fn rounds(&self) -> usize {
        self.rounds
    }

    /// Number of jobs ever submitted.
    pub fn num_jobs(&self) -> usize {
        self.jobs.len()
    }

    /// A submitted job's live state.
    pub fn job(&self, id: JobId) -> &JobHandle {
        &self.jobs[id]
    }

    /// Sum of every round's virtual runtime — the shared pool's virtual
    /// makespan (rounds serialize on the fleet).
    pub fn virtual_makespan(&self) -> f64 {
        self.virtual_makespan
    }

    /// Contributions dropped because they were stamped with a job id
    /// this pool has never issued.
    pub fn cross_job_dropped(&self) -> usize {
        self.cross_job_dropped
    }

    /// Register and start a job (see [`JobSpec`]). The job's `spec.n`
    /// and partition must be dimensioned for the pool's **current**
    /// worker count.
    pub fn submit(&mut self, js: JobSpec) -> Result<JobId> {
        let id = self.jobs.len();
        let n = self.registry.n();
        if js.spec.n != n {
            return Err(Error::InvalidArgument(format!(
                "job spec is dimensioned for N={} but the pool has {n} workers",
                js.spec.n
            )));
        }
        if js.blocks.n() != js.spec.n {
            return Err(Error::InvalidArgument("blocks.n() != spec.n".into()));
        }
        let factory = js.factory.ok_or_else(|| {
            Error::InvalidArgument("JobSpec needs an executor factory (JobSpec::executor)".into())
        })?;
        if let Some(elastic) = js.elastic {
            if self.cfg.elastic.is_some() {
                return Err(Error::InvalidArgument(
                    "the pool already has an elastic policy; configure it on PoolConfig".into(),
                ));
            }
            self.cfg.elastic = Some(elastic);
        }
        let mut rng = Rng::new(js.seed);
        let scheme = Arc::new(CodingScheme::new(js.blocks.clone(), &mut rng)?);

        // Master-side executor for loss evaluation (worker id n = master).
        let mut eval_exec = if js.eval_every > 0 { Some(factory(n)?) } else { None };
        let dim = if let Some(e) = &eval_exec { e.dim() } else { factory(n)?.dim() };
        if dim != js.spec.coords {
            crate::log_warn!(
                "job {id}: model dim {} != spec.coords {} — virtual-runtime accounting uses \
                 the model dim",
                dim,
                js.spec.coords
            );
        }
        if js.blocks.total() != dim {
            return Err(Error::InvalidArgument(format!(
                "block partition covers {} coordinates but the model has {dim}",
                js.blocks.total()
            )));
        }

        let mut master = Master::for_job(id, scheme.clone(), dim, self.registry.roster().to_vec());
        master.timeout = self.cfg.stall_timeout;
        // Decoded arrival buffers cycle back to the pool's encoders.
        master.set_wire_pool(self.wire_pool.clone());

        // Seed the drift detector with the parameters the initial scheme
        // is presumed optimal for (when the current phase is shifted-exp).
        let resolve_strategy = js
            .adaptive
            .as_ref()
            .map(|a| a.strategy.clone())
            .unwrap_or(ResolveStrategy::ClosedFormFreq);
        let controller = js.adaptive.map(|acfg| {
            let mut c = match self.sampler.distribution_at(self.rounds).as_shifted_exp() {
                Some(d) => AdaptiveController::with_reference(acfg, d.mu, d.t0),
                None => AdaptiveController::new(acfg),
            };
            c.set_roster(self.registry.roster());
            c
        });
        let state = if js.init_scale > 0.0 {
            ModelState::random(dim, js.init_scale, &mut rng)
        } else {
            ModelState::zeros(dim)
        };

        let mut report = TrainReport::default();
        report.scheme_epochs.push(SchemeEpoch {
            epoch: 0,
            installed_at_iter: 0,
            block_sizes: js.blocks.sizes().to_vec(),
            estimated_mu: None,
            estimated_t0: None,
            estimated_mean: None,
            family: None,
            drift: 0.0,
        });
        if js.eval_every > 0 {
            if let Some(e) = eval_exec.as_mut() {
                let l = e.loss(state.as_slice())?;
                report.loss_curve.push((0, l));
            }
        }

        self.jobs.push(JobHandle {
            id,
            spec: js.spec,
            dim,
            num_data_shards: js.spec.n,
            steps: js.steps,
            lr: js.lr,
            eval_every: js.eval_every,
            factory,
            scheme,
            epoch: 0,
            master,
            controller,
            resolve_strategy,
            state,
            eval_exec,
            load_mult: vec![1.0; n],
            iters_done: 0,
            issued_work: 0.0,
            offcycle_late: 0,
            offcycle_stale: 0,
            rng,
            report,
        });
        Ok(id)
    }

    /// Spawn a new worker thread into the pool. It is registered as
    /// pending and **receives no work until the next epoch swap**: its
    /// `Joined` event confirms the thread came up, and the following
    /// [`Self::maybe_redimension`] binds it to a code row of every
    /// job's fresh, re-dimensioned scheme epoch.
    pub fn add_worker(&mut self) -> Result<WorkerId> {
        if self.cfg.elastic.is_none() {
            return Err(Error::InvalidArgument(
                "add_worker requires an elastic pool (PoolConfig::elastic)".into(),
            ));
        }
        let id = self.registry.join();
        let tx =
            spawn_worker(id, &self.event_tx, self.cfg.pacing, &self.wire_pool, &mut self.handles)?;
        if self.task_txs.len() <= id {
            self.task_txs.resize_with(id + 1, || None);
        }
        self.task_txs[id] = Some(tx);
        crate::log_info!("round {}: worker {id} joined (pending next epoch)", self.rounds);
        for job in &mut self.jobs {
            job.record_membership(MembershipEvent::Join { worker: id });
        }
        Ok(id)
    }

    /// Drain a worker out of the pool without dropping an iteration:
    /// its thread finishes cleanly, its row counts as a fatal straggler
    /// for the remainder of every job's current epoch, and the next
    /// [`Self::maybe_redimension`] drops it from the roster.
    pub fn remove_worker(&mut self, id: WorkerId) -> Result<()> {
        if self.cfg.elastic.is_none() {
            return Err(Error::InvalidArgument(
                "remove_worker requires an elastic pool (PoolConfig::elastic)".into(),
            ));
        }
        if self.registry.status(id) != Some(MemberStatus::Active)
            && self.registry.status(id) != Some(MemberStatus::Pending)
        {
            return Err(Error::InvalidArgument(format!(
                "worker {id} is not a live pool member"
            )));
        }
        if let Some(tx) = self.task_txs.get_mut(id).and_then(Option::take) {
            let _ = tx.send(WorkerTask::Drain);
        }
        self.mark_departed(id);
        crate::log_info!("round {}: worker {id} draining out of the pool", self.rounds);
        for job in &mut self.jobs {
            job.record_membership(MembershipEvent::Leave { worker: id });
        }
        Ok(())
    }

    /// Shared departure bookkeeping (clean drain and fatal failure):
    /// the registry marks the id departed — keeping its row for the
    /// rest of the membership epoch — its task channel is dropped, and
    /// its row, if any, goes dead in the shared live mask.
    fn mark_departed(&mut self, id: WorkerId) {
        self.registry.leave(id);
        if let Some(tx) = self.task_txs.get_mut(id) {
            *tx = None;
        }
        if let Some(row) = self.registry.row_of(id) {
            if row < self.live_mask.len() {
                self.live_mask[row] = false;
            }
        }
    }

    /// Apply the elastic config's scheduled churn for pool round `at`
    /// (arrivals first, then departures of the highest-id live
    /// workers). No-op without an elastic config.
    pub fn apply_scheduled_churn_at(&mut self, at: usize) -> Result<()> {
        let (arrive, depart) = match &self.cfg.elastic {
            None => return Ok(()),
            Some(e) => (
                e.arrivals.iter().filter(|&&(t, _)| t == at).map(|&(_, c)| c).sum::<usize>(),
                e.departures.iter().filter(|&&(t, _)| t == at).map(|&(_, c)| c).sum::<usize>(),
            ),
        };
        for _ in 0..arrive {
            self.add_worker()?;
        }
        for _ in 0..depart {
            let victim = self
                .registry
                .roster()
                .iter()
                .rev()
                .copied()
                .find(|&id| self.registry.status(id) == Some(MemberStatus::Active));
            match victim {
                Some(id) => self.remove_worker(id)?,
                None => {
                    return Err(Error::Runtime(format!(
                        "round {at}: scheduled departure but no live worker remains"
                    )))
                }
            }
        }
        Ok(())
    }

    /// Poll one job's adaptive policy (see [`JobHandle::install_scheme`]).
    pub fn adapt_job(&mut self, id: JobId) -> Result<()> {
        self.jobs[id].adapt()
    }

    /// Install a same-`N` scheme for one job (manual hot-swap).
    pub fn install_scheme(
        &mut self,
        id: JobId,
        blocks: BlockPartition,
        iter: usize,
        estimate: Option<&FittedModel>,
        drift: f64,
    ) -> Result<()> {
        self.jobs[id].install_scheme(blocks, iter, estimate, drift)
    }

    /// Membership epochs, pool-wide: once churn since the last rebind
    /// reaches the elastic threshold — or immediately when departures
    /// exceed what the most fragile live scheme's redundancy absorbs —
    /// rebind rows **once** and re-dimension **every** unfinished job
    /// onto the new roster (each re-solving with its own fit). Returns
    /// whether a re-dimension happened.
    pub fn maybe_redimension(&mut self) -> Result<bool> {
        let Some(threshold) = self.cfg.elastic.as_ref().map(|e| e.churn_threshold.max(1))
        else {
            return Ok(false);
        };
        if self.jobs.iter().all(|j| j.done()) {
            return Ok(false);
        }
        let dead_rows = self.registry.departed_in_roster();
        let min_s = self
            .jobs
            .iter()
            .filter(|j| !j.done())
            .map(|j| j.min_redundancy())
            .min()
            .unwrap_or(0);
        let forced = dead_rows > min_s;
        if !forced && self.registry.churn_since_rebind() < threshold {
            return Ok(false);
        }
        let to_n = self.registry.next_n();
        if to_n == 0 {
            return Err(Error::Runtime(format!(
                "round {}: elastic pool drained to zero workers",
                self.rounds
            )));
        }
        // The fallback evidence when a job has no live fit: the
        // schedule's current phase, when shifted-exponential.
        let fallback: Option<FittedModel> =
            self.sampler.distribution_at(self.rounds).as_shifted_exp().map(|d| {
                FittedModel::ShiftedExp(ShiftedExpEstimate { mu: d.mu, t0: d.t0, samples: 0 })
            });
        let roster = self.registry.rebind().to_vec();
        debug_assert_eq!(roster.len(), to_n);
        self.live_mask = vec![true; to_n];
        for job in &mut self.jobs {
            if job.done() {
                continue;
            }
            job.redimension(to_n, &roster, fallback.clone())?;
        }
        Ok(true)
    }

    /// One GD iteration for job `id`: sample the round's pool-wide
    /// cycle times, broadcast, route the shared event channel until the
    /// job's every block decodes, then step its model.
    pub fn step_job(&mut self, id: JobId) -> Result<()> {
        if id >= self.jobs.len() {
            return Err(Error::InvalidArgument(format!("no such job {id}")));
        }
        if self.jobs[id].done() {
            return Err(Error::InvalidArgument(format!(
                "job {id} already ran its {} steps",
                self.jobs[id].steps
            )));
        }
        let t_iter = Instant::now();
        let n = self.registry.n();
        debug_assert_eq!(self.jobs[id].spec.n, n, "job not re-dimensioned to the live roster");
        let roster = self.registry.roster().to_vec();
        // Cycle times are drawn per stable id (a machine keeps its
        // speed across rebinds); `times[row]` belongs to `roster[row]`.
        let times = self.sampler.sample_roster(self.rounds, &roster);
        // Pooled estimator feed: worker speeds are a pool property, so
        // every tenant's window may learn from every round. Every
        // observation is stamped with the worker's stable id, so
        // per-worker windows never blend identities across rebinds.
        if self.cfg.shared_observations {
            for job in self.jobs.iter_mut() {
                if let Some(ctrl) = job.controller.as_mut() {
                    ctrl.observe_rows(&times, &roster);
                }
            }
        } else if let Some(ctrl) = self.jobs[id].controller.as_mut() {
            ctrl.observe_rows(&times, &roster);
        }

        // Row-ordered task channels for the current roster (None where
        // the bound worker already departed).
        let senders: Vec<Option<Sender<WorkerTask>>> = roster
            .iter()
            .map(|&wid| self.task_txs.get(wid).cloned().flatten())
            .collect();
        let iter = self.jobs[id].iters_done;
        // Effective per-row cycle times: a speed-weighted re-shard
        // changes each row's per-unit data load, so its compute pace
        // scales by the load multiplier (raw times keep feeding the
        // estimators — the model tracks the machine, not its load).
        let eff: Vec<f64> = times
            .iter()
            .enumerate()
            .map(|(row, &t)| t * self.jobs[id].load_mult.get(row).copied().unwrap_or(1.0))
            .collect();
        {
            let job = &self.jobs[id];
            job.master.broadcast(
                iter,
                job.state.shared(),
                &eff,
                job.spec.unit_work(),
                &job.factory,
                &senders,
            );
        }
        let outcome = self.collect_for(id, iter)?;

        for w in outcome.joined {
            self.registry.confirm(w);
        }
        for w in outcome.left {
            // Clean departures observed mid-iteration (their Leave was
            // already logged by remove_worker); keep masks in sync.
            self.mark_departed(w);
        }
        for w in outcome.failed {
            if !self.failed_set.contains(&w) {
                self.failed_set.push(w);
                // Elastic pools treat a fatal failure as a departure; a
                // static run's membership log stays empty by contract.
                if self.cfg.elastic.is_some() {
                    for job in &mut self.jobs {
                        job.record_membership(MembershipEvent::Leave { worker: w });
                    }
                }
            }
            // A fatal failure is a departure the worker never got to
            // announce: same bookkeeping as a drain.
            self.mark_departed(w);
        }

        let job = &mut self.jobs[id];
        let grad_norm = outcome.gradient.iter().map(|g| g * g).sum::<f64>().sqrt();
        job.state.step(&outcome.gradient, job.lr);
        let vr = virtual_runtime(&job.spec, &job.scheme, &eff);
        self.virtual_makespan += vr;
        job.issued_work += job.spec.unit_work() * job.scheme.work_units_per_worker();
        job.report.iters.push(IterMetrics {
            iter,
            epoch: job.epoch,
            workers: n,
            virtual_runtime: vr,
            wall_ns: t_iter.elapsed().as_nanos() as u64,
            decode_ns: outcome.decode_ns,
            blocks_decoded: job.scheme.ranges().len(),
            late_contributions: outcome.late_contributions,
            stale_epoch_contributions: outcome.stale_epoch
                + outcome.mismatched_binding
                + outcome.cross_job,
            grad_norm,
        });
        job.iters_done += 1;
        if job.eval_every > 0 && job.iters_done % job.eval_every == 0 {
            if let Some(e) = job.eval_exec.as_mut() {
                let l = e.loss(job.state.as_slice())?;
                job.report.loss_curve.push((job.iters_done, l));
            }
        }
        self.rounds += 1;
        Ok(())
    }

    /// Route the shared event channel until job `id`'s iteration
    /// decodes completely. Foreign jobs' stray blocks are charged to
    /// their own off-cycle counters; unknown job ids are dropped.
    fn collect_for(&mut self, id: JobId, iter: usize) -> Result<IterOutcome> {
        self.jobs[id].master.begin_collect(iter, &self.live_mask)?;
        if self.jobs[id].master.collect_complete() {
            // Degenerate scheme with nothing to decode: don't wait on
            // events that will never come.
            return Ok(self.jobs[id].master.take_outcome());
        }
        loop {
            let ev = match self.event_rx.recv_timeout(self.cfg.stall_timeout) {
                Ok(ev) => ev,
                Err(RecvTimeoutError::Timeout) => {
                    self.jobs[id].master.abort_collect();
                    return Err(Error::Runtime(format!(
                        "job {id}: iteration {iter}: stalled waiting for contributions"
                    )));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    self.jobs[id].master.abort_collect();
                    return Err(Error::Runtime(format!(
                        "job {id}: iteration {iter}: all workers disconnected"
                    )));
                }
            };
            // Route blocks by job: only the active job's master consumes
            // its traffic; a non-active job's tail blocks are by
            // definition late (or stale-epoch) for that job.
            let ev = match ev {
                WorkerEvent::Block(c) if c.job != id => {
                    match self.jobs.get_mut(c.job) {
                        Some(other) => other.note_offcycle(&c),
                        None => self.cross_job_dropped += 1,
                    }
                    // The router dropped this contribution, so the
                    // router recycles its wire buffer.
                    self.wire_pool.put(c.coded);
                    continue;
                }
                ev => ev,
            };
            if self.jobs[id].master.offer(ev)? {
                return Ok(self.jobs[id].master.take_outcome());
            }
        }
    }

    /// Pick the next job to broadcast (None when every job is done).
    pub fn next_job(&mut self) -> Option<JobId> {
        let k = self.jobs.len();
        if k == 0 {
            return None;
        }
        match self.cfg.schedule {
            ScheduleMode::RoundRobin => {
                for off in 0..k {
                    let id = (self.rr_cursor + off) % k;
                    if !self.jobs[id].done() {
                        self.rr_cursor = (id + 1) % k;
                        return Some(id);
                    }
                }
                None
            }
            ScheduleMode::WeightedUnitWork => self
                .jobs
                .iter()
                .enumerate()
                .filter(|(_, j)| !j.done())
                .min_by(|a, b| {
                    a.1.issued_work
                        .partial_cmp(&b.1.issued_work)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .map(|(i, _)| i),
        }
    }

    /// Drive every submitted job to completion under the pool's
    /// scheduler: per round — scheduled churn, the picked job's adapt
    /// poll, a pool-wide re-dimension check, one broadcast+collect.
    pub fn run_all(&mut self) -> Result<()> {
        while let Some(id) = self.next_job() {
            self.apply_scheduled_churn_at(self.rounds)?;
            self.adapt_job(id)?;
            self.maybe_redimension()?;
            self.step_job(id)?;
        }
        Ok(())
    }

    /// Shut the fleet down and produce every job's report (indexed by
    /// [`JobId`]).
    pub fn finish(mut self) -> Result<Vec<TrainReport>> {
        for tx in self.task_txs.iter().flatten() {
            let _ = tx.send(WorkerTask::Shutdown);
        }
        self.task_txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        let failed = std::mem::take(&mut self.failed_set);
        Ok(self
            .jobs
            .drain(..)
            .map(|mut job| {
                job.finalize(&failed);
                job.report
            })
            .collect())
    }

    /// [`Self::run_all`] + [`Self::finish`].
    pub fn run_to_completion(mut self) -> Result<Vec<TrainReport>> {
        self.run_all()?;
        self.finish()
    }
}

/// Spawn one worker thread (shared by initial spawn and elastic joins).
fn spawn_worker(
    id: WorkerId,
    event_tx: &Sender<WorkerEvent>,
    pacing: PacingMode,
    wire_pool: &BufferPool,
    handles: &mut Vec<std::thread::JoinHandle<()>>,
) -> Result<Sender<WorkerTask>> {
    let (tx, rx) = mpsc::channel::<WorkerTask>();
    let ctx = WorkerContext {
        id,
        tasks: rx,
        events: event_tx.clone(),
        pacing,
        wire_pool: wire_pool.clone(),
    };
    handles.push(
        std::thread::Builder::new()
            .name(format!("bcgc-worker-{id}"))
            .spawn(move || worker::run(ctx))
            .map_err(|e| Error::Runtime(format!("spawn: {e}")))?,
    );
    Ok(tx)
}
