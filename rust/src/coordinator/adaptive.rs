//! The adaptive re-optimization policy: *when* to re-solve the block
//! partition and *how*.
//!
//! The controller consumes every iteration's observed cycle times
//! ([`AdaptiveController::observe`]) into a sliding-window
//! shifted-exponential estimator ([`crate::distribution::fit`]). Every
//! `check_every` iterations (outside a post-swap cooldown) it fits the
//! window and measures the relative parameter drift against the
//! parameters the live scheme was optimized for. Past the threshold it
//! re-solves:
//!
//! * [`ResolveStrategy::ClosedFormFreq`] — Theorem 3's `x^(f)` closed
//!   form on the *exact* order statistics of the fitted distribution.
//!   O(N²) quadratures, microseconds at paper scale; the default.
//! * [`ResolveStrategy::Subgradient`] — the full stochastic projected
//!   subgradient method, warm-started from the live partition so a mild
//!   drift converges in a fraction of the cold-start iterations.
//!
//! The caller (threaded trainer or the multi-iteration simulator)
//! installs the returned partition as a new **scheme epoch**.

use crate::distribution::fit::{FitMethod, OnlineEstimator, ShiftedExpEstimate};
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::closed_form;
use crate::optimizer::rounding::round_to_blocks;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::optimizer::subgradient::{self, SubgradientOptions};
use crate::util::rng::Rng;
use crate::Result;

/// How a triggered re-solve computes the new partition.
#[derive(Debug, Clone)]
pub enum ResolveStrategy {
    /// Theorem 3 closed form `x^(f)` for the fitted parameters (cheap).
    ClosedFormFreq,
    /// Stochastic projected subgradient, warm-started from the live
    /// partition (heavier, slightly better optima).
    Subgradient { iters: usize, playoff_trials: usize },
}

/// Tuning knobs for the adaptive engine.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Sliding-window size in *observations* (N per iteration).
    pub window: usize,
    /// Poll the drift detector every this many iterations.
    pub check_every: usize,
    /// Minimum iterations between scheme swaps.
    pub cooldown: usize,
    /// Minimum observations before the first fit is trusted.
    pub min_samples: usize,
    /// Relative drift (max over mean and scale) that triggers a re-solve.
    pub drift_threshold: f64,
    /// Estimator family.
    pub method: FitMethod,
    /// Re-solve strategy.
    pub strategy: ResolveStrategy,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            window: 512,
            check_every: 10,
            cooldown: 20,
            min_samples: 64,
            drift_threshold: 0.2,
            method: FitMethod::Mle,
            strategy: ResolveStrategy::ClosedFormFreq,
        }
    }
}

/// A triggered re-plan: the new partition plus the evidence behind it.
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    pub blocks: BlockPartition,
    /// The fitted parameters the new partition is optimal for.
    pub estimate: ShiftedExpEstimate,
    /// The relative drift that tripped the threshold.
    pub drift: f64,
}

/// Online drift detector + re-solver.
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    window: OnlineEstimator,
    /// Parameters the live scheme was optimized for (None until known —
    /// with no reference, the first trustworthy fit triggers a re-plan).
    reference: Option<ShiftedExpEstimate>,
    last_swap: Option<usize>,
    /// Number of re-plans issued so far.
    pub swaps: usize,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        // Defensive floors: the estimator needs at least two samples to
        // fit, whatever the config layer let through.
        let mut cfg = cfg;
        cfg.window = cfg.window.max(2);
        cfg.min_samples = cfg.min_samples.max(2);
        let window = OnlineEstimator::new(cfg.window, cfg.method);
        Self { cfg, window, reference: None, last_swap: None, swaps: 0 }
    }

    /// Seed the reference with the parameters the initial scheme was
    /// optimized for (so a stationary run never re-plans spuriously).
    pub fn with_reference(cfg: AdaptiveConfig, mu: f64, t0: f64) -> Self {
        let mut c = Self::new(cfg);
        c.reference = Some(ShiftedExpEstimate { mu, t0, samples: 0 });
        c
    }

    /// Feed one iteration's observed cycle times.
    pub fn observe(&mut self, times: &[f64]) {
        self.window.extend(times);
    }

    /// Observations currently in the window.
    pub fn observations(&self) -> usize {
        self.window.len()
    }

    /// The current windowed fit, if the window supports one.
    pub fn current_fit(&self) -> Option<ShiftedExpEstimate> {
        self.window.fit()
    }

    /// Relative drift of `fit` against the live reference
    /// (infinite when no reference exists yet).
    pub fn drift(&self, fit: &ShiftedExpEstimate) -> f64 {
        match &self.reference {
            Some(r) => fit.drift_from(r),
            None => f64::INFINITY,
        }
    }

    /// Poll the policy at iteration `iter`. Returns a re-plan when the
    /// schedule allows a check, the window holds enough evidence, and the
    /// fitted parameters drifted past the threshold. `warm_x` is the live
    /// (continuous) partition used to warm-start the subgradient path.
    pub fn maybe_replan(
        &mut self,
        iter: usize,
        spec: &ProblemSpec,
        warm_x: &[f64],
        rng: &mut Rng,
    ) -> Result<Option<ReplanDecision>> {
        if iter == 0 || self.cfg.check_every == 0 || iter % self.cfg.check_every != 0 {
            return Ok(None);
        }
        if let Some(last) = self.last_swap {
            if iter - last < self.cfg.cooldown {
                return Ok(None);
            }
        }
        if self.window.len() < self.cfg.min_samples {
            return Ok(None);
        }
        let Some(fit) = self.window.fit() else {
            return Ok(None);
        };
        let drift = self.drift(&fit);
        if drift <= self.cfg.drift_threshold {
            return Ok(None);
        }
        let dist = fit.to_distribution();
        // The new scheme must cover exactly the coordinates the live one
        // does — the deployed model's dim may legitimately differ from
        // `spec.coords` (the trainer only warns on that mismatch), so the
        // rounding target comes from the live partition, not the spec.
        let target = warm_x.iter().sum::<f64>().round().max(1.0) as usize;
        let blocks =
            resolve_partition(&self.cfg.strategy, spec, &dist, Some(warm_x), target, rng)?;
        self.reference = Some(fit.clone());
        self.last_swap = Some(iter);
        self.swaps += 1;
        Ok(Some(ReplanDecision { blocks, estimate: fit, drift }))
    }
}

/// Re-solve the block partition under `strategy` for `spec` — the
/// shared re-solve primitive behind both drift-triggered re-plans and
/// elastic re-**dimensioning** (`spec.n` is whatever the live roster
/// says; both the closed form and the subgradient method take `N` as an
/// input). `target` is the coordinate count the partition must cover;
/// `warm_x` (any length — it is resized to `spec.n`) warm-starts the
/// subgradient path.
pub fn resolve_partition(
    strategy: &ResolveStrategy,
    spec: &ProblemSpec,
    dist: &crate::distribution::shifted_exp::ShiftedExponential,
    warm_x: Option<&[f64]>,
    target: usize,
    rng: &mut Rng,
) -> Result<BlockPartition> {
    match strategy {
        ResolveStrategy::ClosedFormFreq => closed_form::x_freq_blocks(spec, dist, target),
        ResolveStrategy::Subgradient { iters, playoff_trials } => {
            let opts = SubgradientOptions {
                iters: *iters,
                playoff_trials: *playoff_trials,
                ..Default::default()
            };
            let warm = warm_x.map(|w| resize_warm(w, spec.n));
            let mut x = subgradient::solve(spec, dist, warm, &opts, rng)?.x;
            if target != spec.coords {
                let scale = target as f64 / spec.coords as f64;
                for v in x.iter_mut() {
                    *v *= scale;
                }
            }
            Ok(round_to_blocks(&x, target))
        }
    }
}

/// Adapt a warm-start vector to a different worker count: unchanged
/// when the length already matches; otherwise truncated/zero-padded to
/// `n` rows with the original mass preserved (rescaled), so a mild
/// re-dimension still warm-starts near the old optimum.
fn resize_warm(w: &[f64], n: usize) -> Vec<f64> {
    if w.len() == n {
        return w.to_vec();
    }
    let total: f64 = w.iter().sum();
    let mut out = vec![0.0f64; n];
    for (o, &v) in out.iter_mut().zip(w.iter()) {
        *o = v;
    }
    let kept: f64 = out.iter().sum();
    if kept > 0.0 && total > 0.0 {
        let scale = total / kept;
        for v in out.iter_mut() {
            *v *= scale;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::shifted_exp::ShiftedExponential;
    use crate::distribution::CycleTimeDistribution;

    fn observe_from(ctrl: &mut AdaptiveController, d: &ShiftedExponential, iters: usize, n: usize, rng: &mut Rng) {
        for _ in 0..iters {
            let t = d.sample_vec(n, rng);
            ctrl.observe(&t);
        }
    }

    #[test]
    fn stationary_run_never_replans() {
        let spec = ProblemSpec::paper_default(20, 20_000);
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl = AdaptiveController::with_reference(AdaptiveConfig::default(), d.mu, d.t0);
        let mut rng = Rng::new(5);
        observe_from(&mut ctrl, &d, 40, spec.n, &mut rng);
        let warm = vec![spec.coords as f64 / spec.n as f64; spec.n];
        for iter in [10usize, 20, 30, 40] {
            let plan = ctrl.maybe_replan(iter, &spec, &warm, &mut rng).unwrap();
            assert!(plan.is_none(), "spurious re-plan at iter {iter}");
        }
        assert_eq!(ctrl.swaps, 0);
    }

    #[test]
    fn large_drift_triggers_one_replan_then_cooldown() {
        let spec = ProblemSpec::paper_default(20, 20_000);
        let before = ShiftedExponential::new(1e-2, 50.0); // mean 150
        let after = ShiftedExponential::new(1e-3, 50.0); // mean 1050
        let mut ctrl =
            AdaptiveController::with_reference(AdaptiveConfig::default(), before.mu, before.t0);
        let mut rng = Rng::new(7);
        observe_from(&mut ctrl, &after, 40, spec.n, &mut rng);
        let warm = vec![spec.coords as f64 / spec.n as f64; spec.n];
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("6x mean drift must trigger a re-plan");
        assert!(plan.drift > 1.0, "drift={}", plan.drift);
        assert_eq!(plan.blocks.total(), spec.coords);
        assert_eq!(plan.blocks.n(), spec.n);
        assert!((plan.estimate.mean() - after.mean()).abs() / after.mean() < 0.2);
        assert_eq!(ctrl.swaps, 1);
        // Inside the cooldown window nothing fires, and once the fit
        // matches the new reference nothing fires either.
        assert!(ctrl.maybe_replan(20, &spec, &warm, &mut rng).unwrap().is_none());
        observe_from(&mut ctrl, &after, 40, spec.n, &mut rng);
        assert!(ctrl.maybe_replan(50, &spec, &warm, &mut rng).unwrap().is_none());
        assert_eq!(ctrl.swaps, 1);
    }

    #[test]
    fn off_schedule_and_underfilled_windows_do_not_fire() {
        let spec = ProblemSpec::paper_default(10, 1_000);
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl = AdaptiveController::new(AdaptiveConfig::default());
        let mut rng = Rng::new(9);
        let warm = vec![100.0; 10];
        // iter 0 and off-multiples never check.
        assert!(ctrl.maybe_replan(0, &spec, &warm, &mut rng).unwrap().is_none());
        assert!(ctrl.maybe_replan(7, &spec, &warm, &mut rng).unwrap().is_none());
        // On-schedule but with an empty window: no evidence, no plan.
        assert!(ctrl.maybe_replan(10, &spec, &warm, &mut rng).unwrap().is_none());
        // With no reference, the first trustworthy fit triggers.
        observe_from(&mut ctrl, &d, 20, spec.n, &mut rng);
        let plan = ctrl.maybe_replan(20, &spec, &warm, &mut rng).unwrap();
        assert!(plan.is_some(), "no-reference controller must adopt the first fit");
    }

    #[test]
    fn replan_targets_the_live_partition_not_the_spec() {
        // The deployed model's dim (= sum of the live partition) differs
        // from spec.coords — the trainer only warns on that mismatch, so
        // a re-solved scheme must cover the model's dim, not the spec's.
        let spec = ProblemSpec::paper_default(10, 2_000);
        let before = ShiftedExponential::new(1e-2, 50.0);
        let after = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl =
            AdaptiveController::with_reference(AdaptiveConfig::default(), before.mu, before.t0);
        let mut rng = Rng::new(13);
        observe_from(&mut ctrl, &after, 20, spec.n, &mut rng);
        let warm = vec![173.1; 10]; // live model dim = 1731
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("drift fires");
        assert_eq!(plan.blocks.total(), 1731);
    }

    #[test]
    fn tiny_window_configs_are_clamped_not_panicking() {
        let cfg = AdaptiveConfig { window: 0, min_samples: 0, ..Default::default() };
        let ctrl = AdaptiveController::new(cfg);
        assert_eq!(ctrl.observations(), 0);
    }

    #[test]
    fn resolve_partition_accepts_a_different_n_than_the_warm_start() {
        // Elastic re-dimensioning: the warm start comes from an N=10
        // partition but the live roster shrank to N=8 (and grew to 12).
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut rng = Rng::new(17);
        let warm = vec![100.0; 10];
        for (n_new, strategy) in [
            (8usize, ResolveStrategy::ClosedFormFreq),
            (12, ResolveStrategy::ClosedFormFreq),
            (8, ResolveStrategy::Subgradient { iters: 200, playoff_trials: 100 }),
        ] {
            let spec = ProblemSpec::paper_default(n_new, 1_000);
            let p = resolve_partition(&strategy, &spec, &d, Some(warm.as_slice()), 1_000, &mut rng)
                .unwrap();
            assert_eq!(p.n(), n_new, "{strategy:?}");
            assert_eq!(p.total(), 1_000, "{strategy:?}");
        }
    }

    #[test]
    fn subgradient_strategy_produces_a_feasible_partition() {
        let spec = ProblemSpec::paper_default(8, 400);
        let before = ShiftedExponential::new(1e-2, 50.0);
        let after = ShiftedExponential::new(1e-3, 50.0);
        let cfg = AdaptiveConfig {
            strategy: ResolveStrategy::Subgradient { iters: 300, playoff_trials: 200 },
            ..Default::default()
        };
        let mut ctrl = AdaptiveController::with_reference(cfg, before.mu, before.t0);
        let mut rng = Rng::new(11);
        observe_from(&mut ctrl, &after, 20, spec.n, &mut rng);
        let warm = vec![50.0; 8];
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("drift must trigger");
        assert_eq!(plan.blocks.total(), 400);
        assert_eq!(plan.blocks.n(), 8);
    }
}
