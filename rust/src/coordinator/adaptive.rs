//! The adaptive re-optimization policy: *when* to re-solve the block
//! partition and *how* — and under **which straggler model**.
//!
//! The controller consumes every iteration's observed cycle times
//! ([`AdaptiveController::observe`]) into a sliding window. Every
//! `check_every` iterations (outside a post-swap cooldown) it runs
//! **family selection** over the window
//! ([`crate::distribution::fit::select_model`], governed by
//! [`AdaptiveConfig::family`]): under `auto` both parametric families
//! (shifted-exp, shifted-Weibull) are fitted and scored by windowed KS
//! distance, with the window's own ECDF as the fall-back when neither
//! fits. The winning [`FittedModel`]'s moments are compared against the
//! model the live scheme was optimized for; past the drift threshold it
//! re-solves **for the selected model**:
//!
//! * [`ResolveStrategy::ClosedFormFreq`] — Theorem 3's `x^(f)` shape on
//!   the selected model's order-stat moments
//!   ([`crate::distribution::runtime_dist::RuntimeDistribution`]): exact
//!   quadrature for shifted-exp, exact ECDF sums for empirical,
//!   CRN-seeded Monte Carlo for Weibull. The default.
//! * [`ResolveStrategy::Subgradient`] — the full stochastic projected
//!   subgradient method sampling the selected model, warm-started from
//!   the live partition (re-projected onto the feasible simplex first —
//!   see [`resize_warm`]).
//!
//! The caller (threaded trainer or the multi-iteration simulator)
//! installs the returned partition as a new **scheme epoch**. On an
//! elastic re-**dimension** the caller should also [`AdaptiveController::rebase`]
//! the controller: the window is flushed (observations from the old
//! epoch's `N` / unit work are not comparable) and the drift reference
//! becomes the model the re-dimensioned scheme was solved for.

use crate::distribution::fit::{
    FamilyPolicy, FitMethod, FittedModel, OnlineEstimator, ShiftedExpEstimate,
};
use crate::distribution::runtime_dist::{OrderStatConfig, RuntimeDistribution};
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::closed_form;
use crate::optimizer::projection::project_simplex;
use crate::optimizer::rounding::round_to_blocks;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::optimizer::subgradient::{self, SubgradientOptions};
use crate::util::rng::Rng;
use crate::Result;

/// How a triggered re-solve computes the new partition.
#[derive(Debug, Clone)]
pub enum ResolveStrategy {
    /// Theorem 3 closed form `x^(f)` for the fitted parameters (cheap).
    ClosedFormFreq,
    /// Stochastic projected subgradient, warm-started from the live
    /// partition (heavier, slightly better optima).
    Subgradient { iters: usize, playoff_trials: usize },
}

/// Tuning knobs for the adaptive engine.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Sliding-window size in *observations* (N per iteration).
    pub window: usize,
    /// Poll the drift detector every this many iterations.
    pub check_every: usize,
    /// Minimum iterations between scheme swaps.
    pub cooldown: usize,
    /// Minimum observations before the first fit is trusted.
    pub min_samples: usize,
    /// Relative drift (max over mean and scale) that triggers a re-solve.
    pub drift_threshold: f64,
    /// Shifted-exp estimator flavor (MLE or moments) — also the location
    /// estimator the Weibull fit shares.
    pub method: FitMethod,
    /// Straggler-model family the window is fitted to (`Auto` = KS-gated
    /// selection between shifted-exp, Weibull and the empirical ECDF).
    pub family: FamilyPolicy,
    /// Re-solve strategy.
    pub strategy: ResolveStrategy,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            window: 512,
            check_every: 10,
            cooldown: 20,
            min_samples: 64,
            drift_threshold: 0.2,
            method: FitMethod::Mle,
            family: FamilyPolicy::Auto,
            strategy: ResolveStrategy::ClosedFormFreq,
        }
    }
}

/// A triggered re-plan: the new partition plus the evidence behind it.
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    pub blocks: BlockPartition,
    /// The fitted model the new partition is optimal for.
    pub estimate: FittedModel,
    /// The relative drift that tripped the threshold.
    pub drift: f64,
}

/// Online drift detector + re-solver.
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    window: OnlineEstimator,
    /// Model the live scheme was optimized for (None until known —
    /// with no reference, the first trustworthy fit triggers a re-plan).
    reference: Option<FittedModel>,
    last_swap: Option<usize>,
    /// Number of re-plans issued so far.
    pub swaps: usize,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        // Defensive floors: the estimator needs at least two samples to
        // fit, whatever the config layer let through.
        let mut cfg = cfg;
        cfg.window = cfg.window.max(2);
        cfg.min_samples = cfg.min_samples.max(2);
        let window = OnlineEstimator::new(cfg.window, cfg.method);
        Self { cfg, window, reference: None, last_swap: None, swaps: 0 }
    }

    /// Seed the reference with the shifted-exp parameters the initial
    /// scheme was optimized for (so a stationary run never re-plans
    /// spuriously).
    pub fn with_reference(cfg: AdaptiveConfig, mu: f64, t0: f64) -> Self {
        Self::with_reference_model(
            cfg,
            FittedModel::ShiftedExp(ShiftedExpEstimate { mu, t0, samples: 0 }),
        )
    }

    /// Seed the reference with an arbitrary fitted model.
    pub fn with_reference_model(cfg: AdaptiveConfig, model: FittedModel) -> Self {
        let mut c = Self::new(cfg);
        c.reference = Some(model);
        c
    }

    /// Feed one iteration's observed cycle times.
    pub fn observe(&mut self, times: &[f64]) {
        self.window.extend(times);
    }

    /// Observations currently in the window.
    pub fn observations(&self) -> usize {
        self.window.len()
    }

    /// The current windowed family-selected fit, if the window supports
    /// one.
    pub fn current_fit(&self) -> Option<FittedModel> {
        self.window.fit_model(self.cfg.family)
    }

    /// Epoch-swap hook for elastic re-dimensions: flushes the window —
    /// observations recorded under the previous epoch's `N` / unit work
    /// would bias the first post-churn fits toward the old regime — and
    /// rebases the drift reference on the model the re-dimensioned
    /// scheme was solved for (kept unchanged when `None`).
    pub fn rebase(&mut self, reference: Option<FittedModel>) {
        self.window.clear();
        if reference.is_some() {
            self.reference = reference;
        }
    }

    /// Relative drift of `fit` against the live reference
    /// (infinite when no reference exists yet).
    pub fn drift(&self, fit: &FittedModel) -> f64 {
        match &self.reference {
            Some(r) => fit.drift_from(r),
            None => f64::INFINITY,
        }
    }

    /// Poll the policy at iteration `iter`. Returns a re-plan when the
    /// schedule allows a check, the window holds enough evidence, and the
    /// fitted parameters drifted past the threshold. `warm_x` is the live
    /// (continuous) partition used to warm-start the subgradient path.
    pub fn maybe_replan(
        &mut self,
        iter: usize,
        spec: &ProblemSpec,
        warm_x: &[f64],
        rng: &mut Rng,
    ) -> Result<Option<ReplanDecision>> {
        if iter == 0 || self.cfg.check_every == 0 || iter % self.cfg.check_every != 0 {
            return Ok(None);
        }
        if let Some(last) = self.last_swap {
            if iter - last < self.cfg.cooldown {
                return Ok(None);
            }
        }
        if self.window.len() < self.cfg.min_samples {
            return Ok(None);
        }
        let Some(fit) = self.current_fit() else {
            return Ok(None);
        };
        let drift = self.drift(&fit);
        if drift <= self.cfg.drift_threshold {
            return Ok(None);
        }
        let dist = fit.build();
        // The new scheme must cover exactly the coordinates the live one
        // does — the deployed model's dim may legitimately differ from
        // `spec.coords` (the trainer only warns on that mismatch), so the
        // rounding target comes from the live partition, not the spec.
        let target = warm_x.iter().sum::<f64>().round().max(1.0) as usize;
        let blocks =
            resolve_partition(&self.cfg.strategy, spec, dist.as_ref(), Some(warm_x), target, rng)?;
        self.reference = Some(fit.clone());
        self.last_swap = Some(iter);
        self.swaps += 1;
        Ok(Some(ReplanDecision { blocks, estimate: fit, drift }))
    }
}

/// Re-solve the block partition under `strategy` for `spec` — the
/// shared re-solve primitive behind both drift-triggered re-plans and
/// elastic re-**dimensioning** (`spec.n` is whatever the live roster
/// says; both the closed form and the subgradient method take `N` as an
/// input). `dist` is whichever [`RuntimeDistribution`] family the model
/// selection picked — the `x^(f)` shape is computed from *its*
/// order-stat moments, not a hard-wired shifted exponential. `target`
/// is the coordinate count the partition must cover; `warm_x` (any
/// length — it is resized and re-projected onto the feasible simplex,
/// see [`resize_warm`]) warm-starts the subgradient path.
pub fn resolve_partition(
    strategy: &ResolveStrategy,
    spec: &ProblemSpec,
    dist: &dyn RuntimeDistribution,
    warm_x: Option<&[f64]>,
    target: usize,
    rng: &mut Rng,
) -> Result<BlockPartition> {
    match strategy {
        ResolveStrategy::ClosedFormFreq => {
            // CRN: one seed per re-solve, so a Monte-Carlo family yields
            // a reproducible partition for this decision.
            let os_cfg = OrderStatConfig { seed: rng.next_u64(), ..Default::default() };
            closed_form::x_freq_blocks_model(spec, dist, target, &os_cfg)
        }
        ResolveStrategy::Subgradient { iters, playoff_trials } => {
            let opts = SubgradientOptions {
                iters: *iters,
                playoff_trials: *playoff_trials,
                ..Default::default()
            };
            let warm = warm_x.map(|w| resize_warm(w, spec.n, spec.coords as f64));
            let mut x = subgradient::solve(spec, dist.as_cycle_time(), warm, &opts, rng)?.x;
            if target != spec.coords {
                let scale = target as f64 / spec.coords as f64;
                for v in x.iter_mut() {
                    *v *= scale;
                }
            }
            Ok(round_to_blocks(&x, target))
        }
    }
}

/// Adapt a warm-start vector to a different worker count, then project
/// it onto Problem 3's feasible set `{x ≥ 0, Σx = l}`: truncated or
/// zero-padded to `n` rows, negatives/non-finites clamped, and
/// Euclidean-projected onto the scaled simplex. A shrink that drops
/// most of the old mass (the high-redundancy tail blocks are large —
/// Fig. 3) still yields a feasible start, and an all-zero truncation
/// projects to the uniform point instead of handing the subgradient
/// method an infeasible `Σx = 0` vector.
pub fn resize_warm(w: &[f64], n: usize, l: f64) -> Vec<f64> {
    let mut out = vec![0.0f64; n];
    for (o, &v) in out.iter_mut().zip(w.iter()) {
        *o = if v.is_finite() { v.max(0.0) } else { 0.0 };
    }
    project_simplex(&out, l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::shifted_exp::ShiftedExponential;
    use crate::distribution::CycleTimeDistribution;

    fn observe_from(ctrl: &mut AdaptiveController, d: &ShiftedExponential, iters: usize, n: usize, rng: &mut Rng) {
        for _ in 0..iters {
            let t = d.sample_vec(n, rng);
            ctrl.observe(&t);
        }
    }

    #[test]
    fn stationary_run_never_replans() {
        let spec = ProblemSpec::paper_default(20, 20_000);
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl = AdaptiveController::with_reference(AdaptiveConfig::default(), d.mu, d.t0);
        let mut rng = Rng::new(5);
        observe_from(&mut ctrl, &d, 40, spec.n, &mut rng);
        let warm = vec![spec.coords as f64 / spec.n as f64; spec.n];
        for iter in [10usize, 20, 30, 40] {
            let plan = ctrl.maybe_replan(iter, &spec, &warm, &mut rng).unwrap();
            assert!(plan.is_none(), "spurious re-plan at iter {iter}");
        }
        assert_eq!(ctrl.swaps, 0);
    }

    #[test]
    fn large_drift_triggers_one_replan_then_cooldown() {
        let spec = ProblemSpec::paper_default(20, 20_000);
        let before = ShiftedExponential::new(1e-2, 50.0); // mean 150
        let after = ShiftedExponential::new(1e-3, 50.0); // mean 1050
        let mut ctrl =
            AdaptiveController::with_reference(AdaptiveConfig::default(), before.mu, before.t0);
        let mut rng = Rng::new(7);
        observe_from(&mut ctrl, &after, 40, spec.n, &mut rng);
        let warm = vec![spec.coords as f64 / spec.n as f64; spec.n];
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("6x mean drift must trigger a re-plan");
        assert!(plan.drift > 1.0, "drift={}", plan.drift);
        assert_eq!(plan.blocks.total(), spec.coords);
        assert_eq!(plan.blocks.n(), spec.n);
        assert!((plan.estimate.mean() - after.mean()).abs() / after.mean() < 0.2);
        assert_eq!(ctrl.swaps, 1);
        // Inside the cooldown window nothing fires, and once the fit
        // matches the new reference nothing fires either.
        assert!(ctrl.maybe_replan(20, &spec, &warm, &mut rng).unwrap().is_none());
        observe_from(&mut ctrl, &after, 40, spec.n, &mut rng);
        assert!(ctrl.maybe_replan(50, &spec, &warm, &mut rng).unwrap().is_none());
        assert_eq!(ctrl.swaps, 1);
    }

    #[test]
    fn off_schedule_and_underfilled_windows_do_not_fire() {
        let spec = ProblemSpec::paper_default(10, 1_000);
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl = AdaptiveController::new(AdaptiveConfig::default());
        let mut rng = Rng::new(9);
        let warm = vec![100.0; 10];
        // iter 0 and off-multiples never check.
        assert!(ctrl.maybe_replan(0, &spec, &warm, &mut rng).unwrap().is_none());
        assert!(ctrl.maybe_replan(7, &spec, &warm, &mut rng).unwrap().is_none());
        // On-schedule but with an empty window: no evidence, no plan.
        assert!(ctrl.maybe_replan(10, &spec, &warm, &mut rng).unwrap().is_none());
        // With no reference, the first trustworthy fit triggers.
        observe_from(&mut ctrl, &d, 20, spec.n, &mut rng);
        let plan = ctrl.maybe_replan(20, &spec, &warm, &mut rng).unwrap();
        assert!(plan.is_some(), "no-reference controller must adopt the first fit");
    }

    #[test]
    fn replan_targets_the_live_partition_not_the_spec() {
        // The deployed model's dim (= sum of the live partition) differs
        // from spec.coords — the trainer only warns on that mismatch, so
        // a re-solved scheme must cover the model's dim, not the spec's.
        let spec = ProblemSpec::paper_default(10, 2_000);
        let before = ShiftedExponential::new(1e-2, 50.0);
        let after = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl =
            AdaptiveController::with_reference(AdaptiveConfig::default(), before.mu, before.t0);
        let mut rng = Rng::new(13);
        observe_from(&mut ctrl, &after, 20, spec.n, &mut rng);
        let warm = vec![173.1; 10]; // live model dim = 1731
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("drift fires");
        assert_eq!(plan.blocks.total(), 1731);
    }

    #[test]
    fn tiny_window_configs_are_clamped_not_panicking() {
        let cfg = AdaptiveConfig { window: 0, min_samples: 0, ..Default::default() };
        let ctrl = AdaptiveController::new(cfg);
        assert_eq!(ctrl.observations(), 0);
    }

    #[test]
    fn resolve_partition_accepts_a_different_n_than_the_warm_start() {
        // Elastic re-dimensioning: the warm start comes from an N=10
        // partition but the live roster shrank to N=8 (and grew to 12).
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut rng = Rng::new(17);
        let warm = vec![100.0; 10];
        for (n_new, strategy) in [
            (8usize, ResolveStrategy::ClosedFormFreq),
            (12, ResolveStrategy::ClosedFormFreq),
            (8, ResolveStrategy::Subgradient { iters: 200, playoff_trials: 100 }),
        ] {
            let spec = ProblemSpec::paper_default(n_new, 1_000);
            let p = resolve_partition(&strategy, &spec, &d, Some(warm.as_slice()), 1_000, &mut rng)
                .unwrap();
            assert_eq!(p.n(), n_new, "{strategy:?}");
            assert_eq!(p.total(), 1_000, "{strategy:?}");
        }
    }

    #[test]
    fn resized_warm_start_is_feasible_after_a_shrink() {
        // N = 10 → 4: the old optimum keeps most of its mass in the
        // high-redundancy tail, which the truncation drops entirely.
        let warm = vec![10.0, 10.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 380.0, 600.0];
        let l = 1_000.0;
        for n_new in [4usize, 7, 10, 13] {
            let x = resize_warm(&warm, n_new, l);
            assert_eq!(x.len(), n_new);
            assert!(x.iter().all(|&v| v >= 0.0 && v.is_finite()), "{x:?}");
            let sum: f64 = x.iter().sum();
            assert!((sum - l).abs() < 1e-6, "n={n_new}: sum={sum}");
        }
        // All kept mass zero: the projection falls back to uniform
        // rather than an infeasible all-zero vector.
        let x = resize_warm(&warm[2..8], 4, 100.0);
        assert!(x.iter().all(|&v| (v - 25.0).abs() < 1e-9), "{x:?}");
        // Garbage entries are clamped, not propagated.
        let x = resize_warm(&[f64::NAN, -5.0, 30.0], 3, 60.0);
        assert!(x.iter().all(|&v| v.is_finite() && v >= 0.0));
        assert!((x.iter().sum::<f64>() - 60.0).abs() < 1e-6);
    }

    #[test]
    fn rebase_flushes_the_window_so_post_churn_fits_are_unbiased() {
        // Regression for the cross-epoch window bug: observations from
        // the previous scheme epoch must not blend into the first
        // post-re-dimension fits.
        let a = ShiftedExponential::new(1e-2, 50.0); // mean 150
        let b = ShiftedExponential::new(1e-3, 50.0); // mean 1050
        let mut ctrl = AdaptiveController::with_reference(
            AdaptiveConfig { window: 400, ..Default::default() },
            a.mu,
            a.t0,
        );
        let mut rng = Rng::new(21);
        observe_from(&mut ctrl, &a, 50, 8, &mut rng); // window full of regime A
        assert_eq!(ctrl.observations(), 400);
        // Re-dimension: flush + rebase on the estimate the new scheme
        // was solved for.
        let basis = ctrl.current_fit().unwrap();
        ctrl.rebase(Some(basis.clone()));
        assert_eq!(ctrl.observations(), 0);
        // 120 post-churn observations of regime B. A blended 400-window
        // would average ~(280·150 + 120·1050)/400 ≈ 420 — 60% off; the
        // flushed window must track B directly.
        observe_from(&mut ctrl, &b, 15, 8, &mut rng);
        let fit = ctrl.current_fit().expect("120 fresh samples fit");
        assert!(
            (fit.mean() - b.mean()).abs() / b.mean() < 0.2,
            "post-churn fit mean {} should track {} (not a cross-epoch blend)",
            fit.mean(),
            b.mean()
        );
        // The drift reference moved with the rebase.
        assert!(ctrl.drift(&basis) < 1e-12);
        // rebase(None) flushes but keeps the reference.
        ctrl.rebase(None);
        assert_eq!(ctrl.observations(), 0);
        assert!(ctrl.drift(&basis) < 1e-12);
    }

    #[test]
    fn closed_form_resolve_follows_the_selected_family() {
        // The same re-solve primitive must produce family-appropriate
        // partitions: a heavy-tailed Weibull model asks for a different
        // x^(f) shape than a shifted exponential of equal mean/spread.
        use crate::distribution::weibull::Weibull;
        let spec = ProblemSpec::paper_default(12, 6_000);
        let mut rng = Rng::new(23);
        let exp = ShiftedExponential::new(1e-3, 50.0);
        let weib = Weibull::new(0.6, 800.0, 50.0);
        let p_exp = resolve_partition(
            &ResolveStrategy::ClosedFormFreq,
            &spec,
            &exp,
            None,
            6_000,
            &mut rng,
        )
        .unwrap();
        let p_weib = resolve_partition(
            &ResolveStrategy::ClosedFormFreq,
            &spec,
            &weib,
            None,
            6_000,
            &mut rng,
        )
        .unwrap();
        for p in [&p_exp, &p_weib] {
            assert_eq!(p.n(), 12);
            assert_eq!(p.total(), 6_000);
        }
        assert_ne!(
            p_exp.sizes(),
            p_weib.sizes(),
            "the model family must shape the partition"
        );
    }

    #[test]
    fn subgradient_strategy_produces_a_feasible_partition() {
        let spec = ProblemSpec::paper_default(8, 400);
        let before = ShiftedExponential::new(1e-2, 50.0);
        let after = ShiftedExponential::new(1e-3, 50.0);
        let cfg = AdaptiveConfig {
            strategy: ResolveStrategy::Subgradient { iters: 300, playoff_trials: 200 },
            ..Default::default()
        };
        let mut ctrl = AdaptiveController::with_reference(cfg, before.mu, before.t0);
        let mut rng = Rng::new(11);
        observe_from(&mut ctrl, &after, 20, spec.n, &mut rng);
        let warm = vec![50.0; 8];
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("drift must trigger");
        assert_eq!(plan.blocks.total(), 400);
        assert_eq!(plan.blocks.n(), 8);
    }
}
