//! The adaptive re-optimization policy: *when* to re-solve the block
//! partition and *how* — and under **which straggler model**.
//!
//! The controller consumes every iteration's observed cycle times
//! ([`AdaptiveController::observe`]) into a sliding window. Every
//! `check_every` iterations (outside a post-swap cooldown) it runs
//! **family selection** over the window
//! ([`crate::distribution::fit::select_model`], governed by
//! [`AdaptiveConfig::family`]): under `auto` both parametric families
//! (shifted-exp, shifted-Weibull) are fitted and scored by windowed KS
//! distance, with the window's own ECDF as the fall-back when neither
//! fits. The winning [`FittedModel`]'s moments are compared against the
//! model the live scheme was optimized for; past the drift threshold it
//! re-solves **for the selected model**:
//!
//! * [`ResolveStrategy::ClosedFormFreq`] — Theorem 3's `x^(f)` shape on
//!   the selected model's order-stat moments
//!   ([`crate::distribution::runtime_dist::RuntimeDistribution`]): exact
//!   quadrature for shifted-exp, exact ECDF sums for empirical,
//!   CRN-seeded Monte Carlo for Weibull. The default.
//! * [`ResolveStrategy::Subgradient`] — the full stochastic projected
//!   subgradient method sampling the selected model, warm-started from
//!   the live partition (re-projected onto the feasible simplex first —
//!   see [`resize_warm`]).
//!
//! **Heterogeneity-aware sensing** ([`HeteroConfig`], the `[hetero]`
//! config section): on top of the pooled window, every observation can
//! be stamped with the stable [`WorkerId`] that produced it
//! ([`AdaptiveController::observe_rows`]) and kept in that worker's own
//! window. A triggered re-solve then optimizes against a
//! [`HeteroFleet`] of per-worker family-selected fits (workers below
//! `min_worker_samples` fall back to the pooled fit) — the expected
//! order statistics of *non-identically* distributed draws — and, with
//! `speed_weighted_shards` on, reports per-row mean rates so the caller
//! re-shards the dataset proportionally (fast workers carry more data).
//!
//! The caller (threaded trainer or the multi-iteration simulator)
//! installs the returned partition as a new **scheme epoch**. On an
//! elastic re-**dimension** the caller should also [`AdaptiveController::rebase`]
//! the controller: the pooled and per-worker windows are flushed
//! (observations from the old epoch's `N` / unit work are not
//! comparable) and the drift reference becomes the model the
//! re-dimensioned scheme was solved for.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::coordinator::membership::WorkerId;
use crate::distribution::fit::{
    FamilyPolicy, FitMethod, FittedModel, OnlineEstimator, ShiftedExpEstimate,
};
use crate::distribution::hetero::HeteroFleet;
use crate::distribution::runtime_dist::{OrderStatConfig, RuntimeDistribution};
use crate::optimizer::blocks::BlockPartition;
use crate::optimizer::closed_form;
use crate::optimizer::projection::project_simplex;
use crate::optimizer::rounding::round_to_blocks;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::optimizer::subgradient::{self, SubgradientOptions};
use crate::util::rng::Rng;
use crate::Result;

/// How a triggered re-solve computes the new partition.
#[derive(Debug, Clone)]
pub enum ResolveStrategy {
    /// Theorem 3 closed form `x^(f)` for the fitted parameters (cheap).
    ClosedFormFreq,
    /// Stochastic projected subgradient, warm-started from the live
    /// partition (heavier, slightly better optima).
    Subgradient { iters: usize, playoff_trials: usize },
}

/// Heterogeneity-aware sensing/actuation knobs: per-worker cycle-time
/// models on top of the pooled window, and speed-weighted shard loads.
#[derive(Debug, Clone)]
pub struct HeteroConfig {
    /// Sliding-window capacity **per worker**, in observations (one per
    /// round per worker).
    pub per_worker_window: usize,
    /// Below this many samples a worker's model falls back to the
    /// pooled fit (its row behaves i.i.d. until evidence accumulates).
    pub min_worker_samples: usize,
    /// Re-shard the dataset proportionally to fitted mean rates on
    /// every hetero re-solve, so fast workers carry more data instead
    /// of idling at the quorum barrier.
    pub speed_weighted_shards: bool,
}

impl Default for HeteroConfig {
    fn default() -> Self {
        Self { per_worker_window: 128, min_worker_samples: 24, speed_weighted_shards: true }
    }
}

/// Tuning knobs for the adaptive engine.
#[derive(Debug, Clone)]
pub struct AdaptiveConfig {
    /// Sliding-window size in *observations* (N per iteration).
    pub window: usize,
    /// Poll the drift detector every this many iterations.
    pub check_every: usize,
    /// Minimum iterations between scheme swaps.
    pub cooldown: usize,
    /// Minimum observations before the first fit is trusted.
    pub min_samples: usize,
    /// Relative drift (max over mean and scale) that triggers a re-solve.
    pub drift_threshold: f64,
    /// Shifted-exp estimator flavor (MLE or moments) — also the location
    /// estimator the Weibull fit shares.
    pub method: FitMethod,
    /// Straggler-model family the window is fitted to (`Auto` = KS-gated
    /// selection between shifted-exp, Weibull and the empirical ECDF).
    pub family: FamilyPolicy,
    /// Re-solve strategy.
    pub strategy: ResolveStrategy,
    /// Heterogeneity-aware sensing (`None` = the pooled i.i.d. model,
    /// the paper's assumption): per-worker windows keyed by stable
    /// [`WorkerId`], fleet-model re-solves, speed-weighted shard loads.
    pub hetero: Option<HeteroConfig>,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self {
            window: 512,
            check_every: 10,
            cooldown: 20,
            min_samples: 64,
            drift_threshold: 0.2,
            method: FitMethod::Mle,
            family: FamilyPolicy::Auto,
            strategy: ResolveStrategy::ClosedFormFreq,
            hetero: None,
        }
    }
}

/// A triggered re-plan: the new partition plus the evidence behind it.
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    pub blocks: BlockPartition,
    /// The fitted (pooled) model the drift detector tripped on.
    pub estimate: FittedModel,
    /// The relative drift that tripped the threshold.
    pub drift: f64,
    /// Per-row fitted mean rates (`1/E[T]`, roster order) when the
    /// re-solve was heterogeneity-aware with speed-weighted shards on —
    /// the caller re-shards the dataset proportionally
    /// ([`crate::coordinator::master::redistribute_shards_weighted`]).
    pub fleet_rates: Option<Vec<f64>>,
}

/// The sensing half of the adaptive engine, split out of the
/// controller so a pool can hold it **once per fleet** instead of once
/// per tenant: the pooled sliding window, the per-worker id-keyed
/// windows, and round-memoized family-selected fits. In a K-job pool
/// under `shared_observations`, every tenant observes the same machines
/// produce the same cycle times — K private copies meant K identical
/// windows and K identical fits per round. Controllers now hold an
/// `Arc<Mutex<ObservationStore>>`; compatible tenants attach to one
/// store ([`AdaptiveController::attach_store`]), the pool feeds it once
/// per round, and every fit query in the same round returns the same
/// memoized [`Arc<FittedModel>`] snapshot.
pub struct ObservationStore {
    method: FitMethod,
    family: FamilyPolicy,
    window_cap: usize,
    /// `(per_worker_window, min_worker_samples)` when hetero sensing is
    /// on — actuation knobs like `speed_weighted_shards` are per-tenant
    /// policy and deliberately not part of the store.
    hetero: Option<(usize, usize)>,
    window: OnlineEstimator,
    /// Per-worker windows keyed by **stable id** (not row position), so
    /// a churn rebind never blends one machine's history into another's.
    per_worker: HashMap<WorkerId, OnlineEstimator>,
    /// Bumped on every observe/clear — the memo epoch for fits.
    round: u64,
    pooled_memo: Option<(u64, Option<Arc<FittedModel>>)>,
    worker_memo: HashMap<WorkerId, (u64, Option<Arc<FittedModel>>)>,
}

impl ObservationStore {
    /// Build a store for `cfg`'s sensing parameters (window sizes are
    /// clamped to the estimator's ≥ 2 floor, mirroring the controller).
    pub fn new(cfg: &AdaptiveConfig) -> Self {
        let window_cap = cfg.window.max(2);
        let hetero = cfg
            .hetero
            .as_ref()
            .map(|h| (h.per_worker_window.max(2), h.min_worker_samples.max(2)));
        Self {
            method: cfg.method,
            family: cfg.family,
            window_cap,
            hetero,
            window: OnlineEstimator::new(window_cap, cfg.method),
            per_worker: HashMap::new(),
            round: 0,
            pooled_memo: None,
            worker_memo: HashMap::new(),
        }
    }

    /// Whether a controller configured with `cfg` can share this store:
    /// every **sensing** parameter must match (window capacity, fit
    /// method, family policy, hetero window/min-samples). Actuation and
    /// policy knobs (drift threshold, cadence, strategy, shard
    /// weighting) stay per-tenant and don't gate sharing.
    pub fn compatible(&self, cfg: &AdaptiveConfig) -> bool {
        let hetero = cfg
            .hetero
            .as_ref()
            .map(|h| (h.per_worker_window.max(2), h.min_worker_samples.max(2)));
        self.method == cfg.method
            && self.family == cfg.family
            && self.window_cap == cfg.window.max(2)
            && self.hetero == hetero
    }

    /// Feed cycle times with no worker identity (pooled sensing only).
    pub fn observe(&mut self, times: &[f64]) {
        self.window.extend(times);
        self.round += 1;
    }

    /// Feed one round's cycle times stamped with the stable ids that
    /// produced them: `times[row]` was measured on `roster[row]`.
    pub fn observe_rows(&mut self, times: &[f64], roster: &[WorkerId]) {
        debug_assert_eq!(times.len(), roster.len(), "one cycle time per rostered row");
        self.window.extend(times);
        self.round += 1;
        let Some((cap, _)) = self.hetero else { return };
        let method = self.method;
        for (&t, &id) in times.iter().zip(roster.iter()) {
            self.per_worker
                .entry(id)
                .or_insert_with(|| OnlineEstimator::new(cap, method))
                .push(t);
        }
    }

    /// Observations currently in the pooled window.
    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }

    /// Observations in worker `id`'s own window (0 when never observed
    /// or hetero sensing is off).
    pub fn worker_len(&self, id: WorkerId) -> usize {
        self.per_worker.get(&id).map(OnlineEstimator::len).unwrap_or(0)
    }

    /// The windowed family-selected pooled fit, memoized per observe
    /// round: however many tenants ask, the window is fitted once.
    pub fn pooled_fit(&mut self) -> Option<Arc<FittedModel>> {
        if let Some((round, memo)) = &self.pooled_memo {
            if *round == self.round {
                return memo.clone();
            }
        }
        let fit = self.window.fit_model(self.family).map(Arc::new);
        self.pooled_memo = Some((self.round, fit.clone()));
        fit
    }

    /// Worker `id`'s own family-selected fit (requires hetero sensing
    /// and ≥ `min_worker_samples` observations), memoized per round —
    /// one fit per machine per round, shared by every tenant.
    pub fn worker_fit(&mut self, id: WorkerId) -> Option<Arc<FittedModel>> {
        let (_, min_samples) = self.hetero?;
        if let Some((round, memo)) = self.worker_memo.get(&id) {
            if *round == self.round {
                return memo.clone();
            }
        }
        let fit = self
            .per_worker
            .get(&id)
            .filter(|est| est.len() >= min_samples)
            .and_then(|est| est.fit_model(self.family))
            .map(Arc::new);
        self.worker_memo.insert(id, (self.round, fit.clone()));
        fit
    }

    /// Flush every window and memo (elastic re-dimension). Idempotent,
    /// so K tenants rebasing one shared store at the same epoch swap is
    /// harmless.
    pub fn clear(&mut self) {
        self.window.clear();
        for est in self.per_worker.values_mut() {
            est.clear();
        }
        self.pooled_memo = None;
        self.worker_memo.clear();
        self.round += 1;
    }
}

/// Online drift detector + re-solver.
pub struct AdaptiveController {
    cfg: AdaptiveConfig,
    /// The sensing state — possibly shared with other tenants on the
    /// same pool (see [`ObservationStore`]).
    store: Arc<Mutex<ObservationStore>>,
    /// Latest row → stable-id binding (kept by [`Self::observe_rows`] /
    /// [`Self::set_roster`]); orders the fleet fit by code row.
    roster: Vec<WorkerId>,
    /// Model the live scheme was optimized for (None until known —
    /// with no reference, the first trustworthy fit triggers a re-plan).
    reference: Option<FittedModel>,
    last_swap: Option<usize>,
    /// Number of re-plans issued so far.
    pub swaps: usize,
}

impl AdaptiveController {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        // Defensive floors: the estimator needs at least two samples to
        // fit, whatever the config layer let through.
        let mut cfg = cfg;
        cfg.window = cfg.window.max(2);
        cfg.min_samples = cfg.min_samples.max(2);
        if let Some(h) = cfg.hetero.as_mut() {
            h.per_worker_window = h.per_worker_window.max(2);
            h.min_worker_samples = h.min_worker_samples.max(2);
        }
        let store = Arc::new(Mutex::new(ObservationStore::new(&cfg)));
        Self { cfg, store, roster: Vec::new(), reference: None, last_swap: None, swaps: 0 }
    }

    /// The controller's observation store handle — hand this to other
    /// compatible tenants ([`Self::attach_store`]) or feed it directly.
    pub fn shared_store(&self) -> Arc<Mutex<ObservationStore>> {
        self.store.clone()
    }

    /// Adopt `store` as this controller's sensing state when its
    /// sensing parameters match ([`ObservationStore::compatible`]).
    /// Returns whether the attach happened; on `false` the controller
    /// keeps its private store (mismatched tenants must not blend
    /// incomparable windows).
    pub fn attach_store(&mut self, store: &Arc<Mutex<ObservationStore>>) -> bool {
        let ok = lock_store(store).compatible(&self.cfg);
        if ok {
            self.store = store.clone();
        }
        ok
    }

    fn store_mut(&self) -> MutexGuard<'_, ObservationStore> {
        lock_store(&self.store)
    }

    /// Seed the reference with the shifted-exp parameters the initial
    /// scheme was optimized for (so a stationary run never re-plans
    /// spuriously).
    pub fn with_reference(cfg: AdaptiveConfig, mu: f64, t0: f64) -> Self {
        Self::with_reference_model(
            cfg,
            FittedModel::ShiftedExp(ShiftedExpEstimate { mu, t0, samples: 0 }),
        )
    }

    /// Seed the reference with an arbitrary fitted model.
    pub fn with_reference_model(cfg: AdaptiveConfig, model: FittedModel) -> Self {
        let mut c = Self::new(cfg);
        c.reference = Some(model);
        c
    }

    /// Feed one iteration's observed cycle times with no worker
    /// identity — pooled sensing only (the pre-hetero behavior; the
    /// per-worker windows see nothing).
    pub fn observe(&mut self, times: &[f64]) {
        self.store_mut().observe(times);
    }

    /// Feed one iteration's observed cycle times **stamped with the
    /// stable worker ids** that produced them: `times[row]` was
    /// measured on worker `roster[row]`. The pooled window sees every
    /// sample; under `[hetero]` each sample also lands in its worker's
    /// own id-keyed window, so a churn rebind that hands row `r` to a
    /// different machine never blends the two histories.
    pub fn observe_rows(&mut self, times: &[f64], roster: &[WorkerId]) {
        self.store_mut().observe_rows(times, roster);
        self.roster.clear();
        self.roster.extend_from_slice(roster);
    }

    /// Record the live row → stable-id binding without feeding samples
    /// (e.g. right after a rebind, before the first post-churn round).
    pub fn set_roster(&mut self, roster: &[WorkerId]) {
        self.roster.clear();
        self.roster.extend_from_slice(roster);
    }

    /// Observations currently in the pooled window.
    pub fn observations(&self) -> usize {
        self.store_mut().len()
    }

    /// Observations currently in worker `id`'s own window (0 when the
    /// id was never observed or hetero sensing is off).
    pub fn worker_observations(&self, id: WorkerId) -> usize {
        self.store_mut().worker_len(id)
    }

    /// Family-selected fit of worker `id`'s own window, when it holds
    /// at least `[hetero].min_worker_samples` observations.
    pub fn worker_fit(&self, id: WorkerId) -> Option<FittedModel> {
        self.cfg.hetero.as_ref()?;
        self.store_mut().worker_fit(id).map(|m| (*m).clone())
    }

    /// The current windowed family-selected fit, if the window supports
    /// one.
    pub fn current_fit(&self) -> Option<FittedModel> {
        self.current_fit_shared().map(|m| (*m).clone())
    }

    /// The current pooled fit as the store's memoized shared snapshot —
    /// every tenant asking in the same round gets the same `Arc`.
    pub fn current_fit_shared(&self) -> Option<Arc<FittedModel>> {
        self.store_mut().pooled_fit()
    }

    /// Row-ordered per-worker fitted models for `roster`: each worker's
    /// own family-selected fit once its window passes
    /// `[hetero].min_worker_samples`, the pooled fit below that. `None`
    /// unless hetero sensing is on and at least the pooled fallback (or
    /// every per-worker fit) is available.
    pub fn fleet_models_for(&self, roster: &[WorkerId]) -> Option<Vec<FittedModel>> {
        self.fleet_models_inner(roster).map(|(models, _)| models)
    }

    /// The one implementation of the per-worker-or-pooled fallback
    /// policy; the bool reports whether ANY row carried its own fit
    /// (false = the fleet is the pooled i.i.d. special case).
    fn fleet_models_inner(&self, roster: &[WorkerId]) -> Option<(Vec<FittedModel>, bool)> {
        self.cfg.hetero.as_ref()?;
        if roster.is_empty() {
            return None;
        }
        // One lock for the whole fleet build: the store memoizes each
        // fit per round, so repeat queries (other tenants, repeated
        // rows) cost an Arc clone, not a re-fit.
        let mut store = self.store_mut();
        let pooled = store.pooled_fit();
        let mut models = Vec::with_capacity(roster.len());
        let mut any_worker_fit = false;
        for &id in roster {
            match store.worker_fit(id) {
                Some(m) => {
                    any_worker_fit = true;
                    models.push((*m).clone());
                }
                None => match &pooled {
                    Some(p) => models.push((**p).clone()),
                    None => return None,
                },
            }
        }
        Some((models, any_worker_fit))
    }

    /// Per-row fitted mean rates `1/E[T]` for speed-weighted shard
    /// actuation, in `roster` order. `None` unless `[hetero]` is on
    /// with `speed_weighted_shards` and at least one worker carries its
    /// own fit (an all-pooled fleet is i.i.d. — nothing to weight).
    pub fn fleet_rates_for(&self, roster: &[WorkerId]) -> Option<Vec<f64>> {
        self.fleet_plan_for(roster).and_then(|(_, rates)| rates)
    }

    /// The full heterogeneity-aware re-solve plan for `roster`: the
    /// fleet model to optimize against, plus (when speed-weighted shard
    /// actuation is on and per-worker evidence exists) the raw per-row
    /// rates the caller re-shards with. `None` when hetero sensing is
    /// off or no fit is available — callers fall back to the pooled
    /// path.
    ///
    /// When **every** row fell back to the pooled fit, the fleet is the
    /// i.i.d. special case: one shared model handle (so
    /// [`HeteroFleet::order_stat_moments`] keeps the exact
    /// quadrature/ECDF routes instead of Monte Carlo) and no actuation
    /// rates (uniform rates would only re-derive the uniform split).
    /// With actuation on and real per-worker evidence, each model is
    /// pre-scaled by its *planned* load multiplier `ρ_w = N·r_w/Σr`
    /// (the ideal proportional share; the shard split quantizes it), so
    /// the partition is optimal for the cycle times the fleet will
    /// exhibit *after* the re-shard, not before.
    pub fn fleet_plan_for(
        &self,
        roster: &[WorkerId],
    ) -> Option<(HeteroFleet, Option<Vec<f64>>)> {
        let h = self.cfg.hetero.as_ref()?;
        let (models, any_worker_fit) = self.fleet_models_inner(roster)?;
        if !any_worker_fit {
            // All rows share the pooled fit: one handle, exact moments.
            let fleet = HeteroFleet::homogeneous(Arc::from(models[0].build()), roster.len());
            return Some((fleet, None));
        }
        if !h.speed_weighted_shards {
            return Some((HeteroFleet::from_fits(&models), None));
        }
        let rates: Vec<f64> = models.iter().map(|m| rate_of(m.mean())).collect();
        let rho = planned_loads(&rates);
        let scaled: Vec<FittedModel> = models
            .iter()
            .zip(rho.iter())
            // A degenerate (zero-rate) fit gets rho = 0: keep its model
            // UNscaled — pricing a broken fit as near-instant would
            // invert the intent; unscaled stays conservative.
            .map(|(m, &r)| if r > 0.0 { m.scaled(r) } else { m.clone() })
            .collect();
        Some((HeteroFleet::from_fits(&scaled), Some(rates)))
    }

    /// Epoch-swap hook for elastic re-dimensions: flushes the pooled
    /// **and** every per-worker window — observations recorded under
    /// the previous scheme epoch must never blend into post-churn
    /// fits — and rebases the drift reference on the model the
    /// re-dimensioned scheme was solved for (kept unchanged when
    /// `None`).
    pub fn rebase(&mut self, reference: Option<FittedModel>) {
        self.store_mut().clear();
        if reference.is_some() {
            self.reference = reference;
        }
    }

    /// Relative drift of `fit` against the live reference
    /// (infinite when no reference exists yet).
    pub fn drift(&self, fit: &FittedModel) -> f64 {
        match &self.reference {
            Some(r) => fit.drift_from(r),
            None => f64::INFINITY,
        }
    }

    /// Poll the policy at iteration `iter`. Returns a re-plan when the
    /// schedule allows a check, the window holds enough evidence, and the
    /// fitted parameters drifted past the threshold. `warm_x` is the live
    /// (continuous) partition used to warm-start the subgradient path.
    pub fn maybe_replan(
        &mut self,
        iter: usize,
        spec: &ProblemSpec,
        warm_x: &[f64],
        rng: &mut Rng,
    ) -> Result<Option<ReplanDecision>> {
        if iter == 0 || self.cfg.check_every == 0 || iter % self.cfg.check_every != 0 {
            return Ok(None);
        }
        if let Some(last) = self.last_swap {
            if iter - last < self.cfg.cooldown {
                return Ok(None);
            }
        }
        if self.observations() < self.cfg.min_samples {
            return Ok(None);
        }
        let Some(fit) = self.current_fit() else {
            return Ok(None);
        };
        let drift = self.drift(&fit);
        if drift <= self.cfg.drift_threshold {
            return Ok(None);
        }
        // The new scheme must cover exactly the coordinates the live one
        // does — the deployed model's dim may legitimately differ from
        // `spec.coords` (the trainer only warns on that mismatch), so the
        // rounding target comes from the live partition, not the spec.
        let target = warm_x.iter().sum::<f64>().round().max(1.0) as usize;
        // Heterogeneity-aware path: with per-worker evidence for the
        // live roster, the re-solve optimizes against the fleet of
        // per-worker models (load-adjusted when speed-weighted shard
        // actuation is on) instead of the pooled i.i.d. fiction.
        let mut fleet_rates = None;
        let blocks = match self.hetero_fleet_for_resolve(spec.n) {
            Some((fleet, rates)) => {
                let b =
                    resolve_partition(&self.cfg.strategy, spec, &fleet, Some(warm_x), target, rng)?;
                fleet_rates = rates;
                b
            }
            None => {
                let dist = fit.build();
                let d = dist.as_ref();
                resolve_partition(&self.cfg.strategy, spec, d, Some(warm_x), target, rng)?
            }
        };
        self.reference = Some(fit.clone());
        self.last_swap = Some(iter);
        self.swaps += 1;
        Ok(Some(ReplanDecision { blocks, estimate: fit, drift, fleet_rates }))
    }

    /// [`Self::fleet_plan_for`] on the stored roster, when it covers
    /// exactly `n` rows — the drift path's entry point.
    fn hetero_fleet_for_resolve(&self, n: usize) -> Option<(HeteroFleet, Option<Vec<f64>>)> {
        if self.roster.len() != n {
            return None;
        }
        self.fleet_plan_for(&self.roster)
    }

    /// The backlog-priced cycle-time model for an async dispatch:
    /// row `r`'s fitted model translated by `delays[r]` units of queued
    /// virtual time per unit work ([`FittedModel::delayed`]). Feeding
    /// this fleet to [`resolve_partition`] makes Eq. (2) and the
    /// subgradient solver price queue position natively — a row stuck
    /// behind a deep backlog looks like a slow-shift machine, so the
    /// planner steers low-redundancy blocks away from waiting on it.
    /// Uses per-worker fits when hetero sensing has them, else the
    /// pooled fit on every row; `None` when no fit exists yet.
    pub fn delay_priced_fleet(
        &self,
        roster: &[WorkerId],
        delays: &[f64],
    ) -> Option<HeteroFleet> {
        debug_assert_eq!(roster.len(), delays.len(), "one queued delay per rostered row");
        let base: Vec<FittedModel> = match self.fleet_models_for(roster) {
            Some(models) => models,
            None => {
                let pooled = self.current_fit()?;
                vec![pooled; roster.len()]
            }
        };
        let priced: Vec<FittedModel> = base
            .iter()
            .zip(delays.iter())
            .map(|(m, &d)| m.delayed(if d.is_finite() { d.max(0.0) } else { 0.0 }))
            .collect();
        Some(HeteroFleet::from_fits(&priced))
    }
}

/// Lock an observation store, surviving a poisoned mutex: the store
/// holds plain sample windows, which stay internally consistent even if
/// another tenant's thread panicked mid-observe.
fn lock_store(store: &Arc<Mutex<ObservationStore>>) -> MutexGuard<'_, ObservationStore> {
    store.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// `1/mean`, guarded against degenerate fits (0 for an infinite or
/// non-positive mean — such a worker gets no speed-weighted load).
fn rate_of(mean: f64) -> f64 {
    if mean.is_finite() && mean > 0.0 {
        1.0 / mean
    } else {
        0.0
    }
}

/// Ideal per-worker load multipliers under rate-proportional sharding:
/// `ρ_w = N·r_w/Σr` (uniform share ⇒ 1). All-ones when the rates are
/// degenerate (non-positive sum).
pub fn planned_loads(rates: &[f64]) -> Vec<f64> {
    let n = rates.len();
    let total: f64 = rates.iter().copied().filter(|r| r.is_finite() && *r > 0.0).sum();
    if n == 0 || total <= 0.0 || !total.is_finite() {
        return vec![1.0; n];
    }
    rates
        .iter()
        .map(|&r| if r.is_finite() && r > 0.0 { n as f64 * r / total } else { 0.0 })
        .collect()
}

/// Re-solve the block partition under `strategy` for `spec` — the
/// shared re-solve primitive behind both drift-triggered re-plans and
/// elastic re-**dimensioning** (`spec.n` is whatever the live roster
/// says; both the closed form and the subgradient method take `N` as an
/// input). `dist` is whichever [`RuntimeDistribution`] family the model
/// selection picked — the `x^(f)` shape is computed from *its*
/// order-stat moments, not a hard-wired shifted exponential. `target`
/// is the coordinate count the partition must cover; `warm_x` (any
/// length — it is resized and re-projected onto the feasible simplex,
/// see [`resize_warm`]) warm-starts the subgradient path.
pub fn resolve_partition(
    strategy: &ResolveStrategy,
    spec: &ProblemSpec,
    dist: &dyn RuntimeDistribution,
    warm_x: Option<&[f64]>,
    target: usize,
    rng: &mut Rng,
) -> Result<BlockPartition> {
    match strategy {
        ResolveStrategy::ClosedFormFreq => {
            // CRN: one seed per re-solve, so a Monte-Carlo family yields
            // a reproducible partition for this decision.
            let os_cfg = OrderStatConfig { seed: rng.next_u64(), ..Default::default() };
            closed_form::x_freq_blocks_model(spec, dist, target, &os_cfg)
        }
        ResolveStrategy::Subgradient { iters, playoff_trials } => {
            let opts = SubgradientOptions {
                iters: *iters,
                playoff_trials: *playoff_trials,
                ..Default::default()
            };
            let warm = warm_x.map(|w| resize_warm(w, spec.n, spec.coords as f64));
            let mut x = subgradient::solve(spec, dist.as_cycle_time(), warm, &opts, rng)?.x;
            if target != spec.coords {
                let scale = target as f64 / spec.coords as f64;
                for v in x.iter_mut() {
                    *v *= scale;
                }
            }
            Ok(round_to_blocks(&x, target))
        }
    }
}

/// Adapt a warm-start vector to a different worker count, then project
/// it onto Problem 3's feasible set `{x ≥ 0, Σx = l}`: truncated or
/// zero-padded to `n` rows, negatives/non-finites clamped, and
/// Euclidean-projected onto the scaled simplex. A shrink that drops
/// most of the old mass (the high-redundancy tail blocks are large —
/// Fig. 3) still yields a feasible start, and an all-zero truncation
/// projects to the uniform point instead of handing the subgradient
/// method an infeasible `Σx = 0` vector.
pub fn resize_warm(w: &[f64], n: usize, l: f64) -> Vec<f64> {
    let mut out = vec![0.0f64; n];
    for (o, &v) in out.iter_mut().zip(w.iter()) {
        *o = if v.is_finite() { v.max(0.0) } else { 0.0 };
    }
    project_simplex(&out, l)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::shifted_exp::ShiftedExponential;
    use crate::distribution::CycleTimeDistribution;

    fn observe_from(ctrl: &mut AdaptiveController, d: &ShiftedExponential, iters: usize, n: usize, rng: &mut Rng) {
        for _ in 0..iters {
            let t = d.sample_vec(n, rng);
            ctrl.observe(&t);
        }
    }

    #[test]
    fn stationary_run_never_replans() {
        let spec = ProblemSpec::paper_default(20, 20_000);
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl = AdaptiveController::with_reference(AdaptiveConfig::default(), d.mu, d.t0);
        let mut rng = Rng::new(5);
        observe_from(&mut ctrl, &d, 40, spec.n, &mut rng);
        let warm = vec![spec.coords as f64 / spec.n as f64; spec.n];
        for iter in [10usize, 20, 30, 40] {
            let plan = ctrl.maybe_replan(iter, &spec, &warm, &mut rng).unwrap();
            assert!(plan.is_none(), "spurious re-plan at iter {iter}");
        }
        assert_eq!(ctrl.swaps, 0);
    }

    #[test]
    fn large_drift_triggers_one_replan_then_cooldown() {
        let spec = ProblemSpec::paper_default(20, 20_000);
        let before = ShiftedExponential::new(1e-2, 50.0); // mean 150
        let after = ShiftedExponential::new(1e-3, 50.0); // mean 1050
        let mut ctrl =
            AdaptiveController::with_reference(AdaptiveConfig::default(), before.mu, before.t0);
        let mut rng = Rng::new(7);
        observe_from(&mut ctrl, &after, 40, spec.n, &mut rng);
        let warm = vec![spec.coords as f64 / spec.n as f64; spec.n];
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("6x mean drift must trigger a re-plan");
        assert!(plan.drift > 1.0, "drift={}", plan.drift);
        assert_eq!(plan.blocks.total(), spec.coords);
        assert_eq!(plan.blocks.n(), spec.n);
        assert!((plan.estimate.mean() - after.mean()).abs() / after.mean() < 0.2);
        assert_eq!(ctrl.swaps, 1);
        // Inside the cooldown window nothing fires, and once the fit
        // matches the new reference nothing fires either.
        assert!(ctrl.maybe_replan(20, &spec, &warm, &mut rng).unwrap().is_none());
        observe_from(&mut ctrl, &after, 40, spec.n, &mut rng);
        assert!(ctrl.maybe_replan(50, &spec, &warm, &mut rng).unwrap().is_none());
        assert_eq!(ctrl.swaps, 1);
    }

    #[test]
    fn off_schedule_and_underfilled_windows_do_not_fire() {
        let spec = ProblemSpec::paper_default(10, 1_000);
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl = AdaptiveController::new(AdaptiveConfig::default());
        let mut rng = Rng::new(9);
        let warm = vec![100.0; 10];
        // iter 0 and off-multiples never check.
        assert!(ctrl.maybe_replan(0, &spec, &warm, &mut rng).unwrap().is_none());
        assert!(ctrl.maybe_replan(7, &spec, &warm, &mut rng).unwrap().is_none());
        // On-schedule but with an empty window: no evidence, no plan.
        assert!(ctrl.maybe_replan(10, &spec, &warm, &mut rng).unwrap().is_none());
        // With no reference, the first trustworthy fit triggers.
        observe_from(&mut ctrl, &d, 20, spec.n, &mut rng);
        let plan = ctrl.maybe_replan(20, &spec, &warm, &mut rng).unwrap();
        assert!(plan.is_some(), "no-reference controller must adopt the first fit");
    }

    #[test]
    fn replan_targets_the_live_partition_not_the_spec() {
        // The deployed model's dim (= sum of the live partition) differs
        // from spec.coords — the trainer only warns on that mismatch, so
        // a re-solved scheme must cover the model's dim, not the spec's.
        let spec = ProblemSpec::paper_default(10, 2_000);
        let before = ShiftedExponential::new(1e-2, 50.0);
        let after = ShiftedExponential::new(1e-3, 50.0);
        let mut ctrl =
            AdaptiveController::with_reference(AdaptiveConfig::default(), before.mu, before.t0);
        let mut rng = Rng::new(13);
        observe_from(&mut ctrl, &after, 20, spec.n, &mut rng);
        let warm = vec![173.1; 10]; // live model dim = 1731
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("drift fires");
        assert_eq!(plan.blocks.total(), 1731);
    }

    #[test]
    fn tiny_window_configs_are_clamped_not_panicking() {
        let cfg = AdaptiveConfig { window: 0, min_samples: 0, ..Default::default() };
        let ctrl = AdaptiveController::new(cfg);
        assert_eq!(ctrl.observations(), 0);
    }

    #[test]
    fn resolve_partition_accepts_a_different_n_than_the_warm_start() {
        // Elastic re-dimensioning: the warm start comes from an N=10
        // partition but the live roster shrank to N=8 (and grew to 12).
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut rng = Rng::new(17);
        let warm = vec![100.0; 10];
        for (n_new, strategy) in [
            (8usize, ResolveStrategy::ClosedFormFreq),
            (12, ResolveStrategy::ClosedFormFreq),
            (8, ResolveStrategy::Subgradient { iters: 200, playoff_trials: 100 }),
        ] {
            let spec = ProblemSpec::paper_default(n_new, 1_000);
            let p = resolve_partition(&strategy, &spec, &d, Some(warm.as_slice()), 1_000, &mut rng)
                .unwrap();
            assert_eq!(p.n(), n_new, "{strategy:?}");
            assert_eq!(p.total(), 1_000, "{strategy:?}");
        }
    }

    #[test]
    fn resized_warm_start_is_feasible_after_a_shrink() {
        // N = 10 → 4: the old optimum keeps most of its mass in the
        // high-redundancy tail, which the truncation drops entirely.
        let warm = vec![10.0, 10.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 380.0, 600.0];
        let l = 1_000.0;
        for n_new in [4usize, 7, 10, 13] {
            let x = resize_warm(&warm, n_new, l);
            assert_eq!(x.len(), n_new);
            assert!(x.iter().all(|&v| v >= 0.0 && v.is_finite()), "{x:?}");
            let sum: f64 = x.iter().sum();
            assert!((sum - l).abs() < 1e-6, "n={n_new}: sum={sum}");
        }
        // All kept mass zero: the projection falls back to uniform
        // rather than an infeasible all-zero vector.
        let x = resize_warm(&warm[2..8], 4, 100.0);
        assert!(x.iter().all(|&v| (v - 25.0).abs() < 1e-9), "{x:?}");
        // Garbage entries are clamped, not propagated.
        let x = resize_warm(&[f64::NAN, -5.0, 30.0], 3, 60.0);
        assert!(x.iter().all(|&v| v.is_finite() && v >= 0.0));
        assert!((x.iter().sum::<f64>() - 60.0).abs() < 1e-6);
    }

    #[test]
    fn rebase_flushes_the_window_so_post_churn_fits_are_unbiased() {
        // Regression for the cross-epoch window bug: observations from
        // the previous scheme epoch must not blend into the first
        // post-re-dimension fits.
        let a = ShiftedExponential::new(1e-2, 50.0); // mean 150
        let b = ShiftedExponential::new(1e-3, 50.0); // mean 1050
        let mut ctrl = AdaptiveController::with_reference(
            AdaptiveConfig { window: 400, ..Default::default() },
            a.mu,
            a.t0,
        );
        let mut rng = Rng::new(21);
        observe_from(&mut ctrl, &a, 50, 8, &mut rng); // window full of regime A
        assert_eq!(ctrl.observations(), 400);
        // Re-dimension: flush + rebase on the estimate the new scheme
        // was solved for.
        let basis = ctrl.current_fit().unwrap();
        ctrl.rebase(Some(basis.clone()));
        assert_eq!(ctrl.observations(), 0);
        // 120 post-churn observations of regime B. A blended 400-window
        // would average ~(280·150 + 120·1050)/400 ≈ 420 — 60% off; the
        // flushed window must track B directly.
        observe_from(&mut ctrl, &b, 15, 8, &mut rng);
        let fit = ctrl.current_fit().expect("120 fresh samples fit");
        assert!(
            (fit.mean() - b.mean()).abs() / b.mean() < 0.2,
            "post-churn fit mean {} should track {} (not a cross-epoch blend)",
            fit.mean(),
            b.mean()
        );
        // The drift reference moved with the rebase.
        assert!(ctrl.drift(&basis) < 1e-12);
        // rebase(None) flushes but keeps the reference.
        ctrl.rebase(None);
        assert_eq!(ctrl.observations(), 0);
        assert!(ctrl.drift(&basis) < 1e-12);
    }

    #[test]
    fn closed_form_resolve_follows_the_selected_family() {
        // The same re-solve primitive must produce family-appropriate
        // partitions: a heavy-tailed Weibull model asks for a different
        // x^(f) shape than a shifted exponential of equal mean/spread.
        use crate::distribution::weibull::Weibull;
        let spec = ProblemSpec::paper_default(12, 6_000);
        let mut rng = Rng::new(23);
        let exp = ShiftedExponential::new(1e-3, 50.0);
        let weib = Weibull::new(0.6, 800.0, 50.0);
        let p_exp = resolve_partition(
            &ResolveStrategy::ClosedFormFreq,
            &spec,
            &exp,
            None,
            6_000,
            &mut rng,
        )
        .unwrap();
        let p_weib = resolve_partition(
            &ResolveStrategy::ClosedFormFreq,
            &spec,
            &weib,
            None,
            6_000,
            &mut rng,
        )
        .unwrap();
        for p in [&p_exp, &p_weib] {
            assert_eq!(p.n(), 12);
            assert_eq!(p.total(), 6_000);
        }
        assert_ne!(
            p_exp.sizes(),
            p_weib.sizes(),
            "the model family must shape the partition"
        );
    }

    fn hetero_cfg() -> AdaptiveConfig {
        AdaptiveConfig {
            hetero: Some(HeteroConfig {
                per_worker_window: 64,
                min_worker_samples: 8,
                speed_weighted_shards: true,
            }),
            ..Default::default()
        }
    }

    /// Feed `iters` rounds of a 3-row roster where each row's times come
    /// from its own distribution.
    fn observe_fleet_rows(
        ctrl: &mut AdaptiveController,
        dists: &[&ShiftedExponential],
        roster: &[usize],
        iters: usize,
        rng: &mut Rng,
    ) {
        for _ in 0..iters {
            let times: Vec<f64> = dists.iter().map(|d| d.sample(rng)).collect();
            ctrl.observe_rows(&times, roster);
        }
    }

    #[test]
    fn per_worker_windows_are_keyed_by_stable_id_not_row() {
        // Regression for the row-attribution bug: after a churn rebind
        // hands a worker's old row to someone else, the two histories
        // must never blend — observations are stamped with WorkerId.
        let fast = ShiftedExponential::new(1e-2, 50.0); // mean 150
        let slow = ShiftedExponential::new(1e-3, 200.0); // mean 1200
        let mut ctrl = AdaptiveController::new(hetero_cfg());
        let mut rng = Rng::new(31);

        // Epoch 0: roster [0, 1, 2]; id 2 (row 2) is the slow machine.
        observe_fleet_rows(&mut ctrl, &[&fast, &fast, &slow], &[0, 1, 2], 30, &mut rng);
        assert_eq!(ctrl.worker_observations(2), 30);
        let slow_fit = ctrl.worker_fit(2).expect("30 samples fit");
        assert!((slow_fit.mean() - slow.mean()).abs() / slow.mean() < 0.35);

        // Rebind: id 1 left, id 3 joined → roster [0, 2, 3]. Row 1 now
        // belongs to the slow id 2 and row 2 to the fresh fast id 3.
        observe_fleet_rows(&mut ctrl, &[&fast, &slow, &fast], &[0, 2, 3], 30, &mut rng);

        // Id 2's window kept ONLY its own (slow) samples across the
        // rebind — a row-keyed window would now be half fast.
        let f2 = ctrl.worker_fit(2).expect("id 2 fit");
        assert!(
            (f2.mean() - slow.mean()).abs() / slow.mean() < 0.35,
            "id 2 mean {} must track the slow machine ({}), not a row blend",
            f2.mean(),
            slow.mean()
        );
        // Id 3 never inherits the slow history that lived in its row.
        let f3 = ctrl.worker_fit(3).expect("id 3 fit");
        assert!(
            (f3.mean() - fast.mean()).abs() / fast.mean() < 0.35,
            "id 3 mean {} must track the fast machine ({})",
            f3.mean(),
            fast.mean()
        );
        // Id 1 departed mid-history: its window holds only epoch-0 rounds.
        assert_eq!(ctrl.worker_observations(1), 30);
    }

    #[test]
    fn rebase_flushes_per_worker_windows_so_epochs_never_mix() {
        let fast = ShiftedExponential::new(1e-2, 50.0);
        let slow = ShiftedExponential::new(1e-3, 200.0);
        let mut ctrl = AdaptiveController::new(hetero_cfg());
        let mut rng = Rng::new(33);
        observe_fleet_rows(&mut ctrl, &[&fast, &fast, &slow], &[0, 1, 2], 20, &mut rng);
        assert!(ctrl.worker_observations(2) > 0);
        // Re-dimension: every window flushes — per-worker included.
        ctrl.rebase(None);
        assert_eq!(ctrl.observations(), 0);
        for id in 0..3 {
            assert_eq!(
                ctrl.worker_observations(id),
                0,
                "id {id}: per-worker windows must not leak across scheme epochs"
            );
        }
        // Fresh post-epoch evidence stands alone: id 2 is now FAST
        // (machine rebooted), and its fit must not remember the old slow
        // regime.
        observe_fleet_rows(&mut ctrl, &[&fast, &fast, &fast], &[0, 1, 2], 30, &mut rng);
        let f2 = ctrl.worker_fit(2).unwrap();
        assert!((f2.mean() - fast.mean()).abs() / fast.mean() < 0.35, "mean {}", f2.mean());
    }

    #[test]
    fn fleet_fit_falls_back_to_the_pooled_model_below_min_samples() {
        let fast = ShiftedExponential::new(1e-2, 50.0);
        let slow = ShiftedExponential::new(1e-3, 200.0);
        let mut ctrl = AdaptiveController::new(hetero_cfg());
        let mut rng = Rng::new(37);
        observe_fleet_rows(&mut ctrl, &[&fast, &fast, &slow], &[0, 1, 2], 30, &mut rng);
        // Id 9 was never observed: its slot uses the pooled fit, whose
        // mean sits between the two speeds.
        let models = ctrl.fleet_models_for(&[0, 2, 9]).expect("pooled fallback covers id 9");
        assert_eq!(models.len(), 3);
        assert!(models[1].mean() > 2.0 * models[0].mean(), "row 1 is the slow machine");
        let pooled = ctrl.current_fit().unwrap();
        assert!((models[2].mean() - pooled.mean()).abs() < 1e-9);
        // Rates follow: fast row > pooled row > slow row.
        let rates = ctrl.fleet_rates_for(&[0, 2, 9]).unwrap();
        assert!(rates[0] > rates[2] && rates[2] > rates[1], "{rates:?}");
        // Without hetero sensing there is no fleet fit at all.
        let mut plain = AdaptiveController::new(AdaptiveConfig::default());
        plain.observe_rows(&[1.0, 2.0, 3.0], &[0, 1, 2]);
        assert!(plain.fleet_models_for(&[0, 1, 2]).is_none());
        assert_eq!(plain.worker_observations(0), 0, "no per-worker windows without [hetero]");
    }

    #[test]
    fn all_pooled_fleet_plan_is_the_exact_iid_special_case() {
        // Regression: when NO worker has reached min_worker_samples,
        // every row falls back to the pooled fit — the plan must be a
        // shared-handle (exact-moments) fleet with no actuation rates,
        // not n value-clones forced through Monte Carlo.
        let fast = ShiftedExponential::new(1e-2, 50.0);
        let slow = ShiftedExponential::new(1e-3, 200.0);
        let cfg = AdaptiveConfig {
            hetero: Some(HeteroConfig {
                per_worker_window: 64,
                min_worker_samples: 1_000, // unreachable in this test
                speed_weighted_shards: true,
            }),
            ..Default::default()
        };
        let mut ctrl = AdaptiveController::new(cfg);
        let mut rng = Rng::new(39);
        observe_fleet_rows(&mut ctrl, &[&fast, &fast, &slow], &[0, 1, 2], 30, &mut rng);
        let (fleet, rates) = ctrl.fleet_plan_for(&[0, 1, 2]).expect("pooled fallback plan");
        assert!(
            fleet.is_homogeneous(),
            "an all-pooled fleet must share one model handle (exact order-stat route)"
        );
        assert_eq!(fleet.n(), 3);
        assert!(rates.is_none(), "uniform evidence must not trigger a re-shard");
        // And the companion helpers agree.
        assert!(ctrl.fleet_rates_for(&[0, 1, 2]).is_none());
    }

    #[test]
    fn hetero_replan_resolves_on_the_fleet_and_reports_rates() {
        // A 2-speed fleet: the hetero re-plan must (a) trigger off the
        // pooled drift, (b) return per-row actuation rates with the
        // slow rows strictly below the fast rows, and (c) shape the
        // partition differently from the pooled i.i.d. re-solve on the
        // same evidence.
        let spec = ProblemSpec::paper_default(8, 4_000);
        let fast = ShiftedExponential::new(1e-2, 50.0);
        let slow = ShiftedExponential::new(2e-3, 250.0); // 5× slower
        let mk = |hetero: Option<HeteroConfig>| AdaptiveConfig {
            min_samples: 64,
            check_every: 10,
            hetero,
            ..Default::default()
        };
        let run = |hetero: Option<HeteroConfig>| {
            let mut ctrl = AdaptiveController::with_reference(mk(hetero), fast.mu, fast.t0);
            let mut rng = Rng::new(41);
            let roster: Vec<usize> = (0..8).collect();
            for _ in 0..30 {
                let times: Vec<f64> = (0..8)
                    .map(|w| if w < 4 { fast.sample(&mut rng) } else { slow.sample(&mut rng) })
                    .collect();
                ctrl.observe_rows(&times, &roster);
            }
            let warm = vec![500.0; 8];
            let mut rng = Rng::new(43);
            ctrl.maybe_replan(10, &spec, &warm, &mut rng).unwrap().expect("drift fires")
        };
        let hetero = run(Some(HeteroConfig {
            per_worker_window: 64,
            min_worker_samples: 8,
            speed_weighted_shards: true,
        }));
        let pooled = run(None);
        assert!(pooled.fleet_rates.is_none());
        let rates = hetero.fleet_rates.expect("hetero replan carries actuation rates");
        assert_eq!(rates.len(), 8);
        let min_fast = rates[..4].iter().cloned().fold(f64::INFINITY, f64::min);
        let max_slow = rates[4..].iter().cloned().fold(0.0, f64::max);
        assert!(
            max_slow < min_fast,
            "slow rows must rate strictly below fast rows: {rates:?}"
        );
        assert_eq!(hetero.blocks.total(), 4_000);
        assert_eq!(hetero.blocks.n(), 8);
        assert_ne!(
            hetero.blocks.sizes(),
            pooled.blocks.sizes(),
            "the fleet model must shape the partition differently from the pooled fit"
        );
    }

    #[test]
    fn shared_store_feeds_every_attached_tenant_with_one_fit() {
        // Two tenants with identical sensing attach to one store: a
        // single pool-level observe round is visible to both, and both
        // get the SAME memoized Arc snapshot instead of fitting twice.
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut a = AdaptiveController::new(AdaptiveConfig::default());
        let mut b = AdaptiveController::new(AdaptiveConfig::default());
        assert!(b.attach_store(&a.shared_store()), "identical sensing must attach");
        let mut rng = Rng::new(51);
        let roster: Vec<usize> = (0..8).collect();
        for _ in 0..20 {
            let t = d.sample_vec(8, &mut rng);
            // Pool-level: observed once, not once per tenant.
            a.observe_rows(&t, &roster);
            b.set_roster(&roster);
        }
        assert_eq!(a.observations(), 160);
        assert_eq!(b.observations(), 160, "tenant B sees the shared window");
        let fa = a.current_fit_shared().expect("fit");
        let fb = b.current_fit_shared().expect("fit");
        assert!(Arc::ptr_eq(&fa, &fb), "same round must return one memoized snapshot");
        // A fresh observation invalidates the memo.
        a.observe(&[100.0]);
        let fa2 = a.current_fit_shared().unwrap();
        assert!(!Arc::ptr_eq(&fa, &fa2), "new evidence must re-fit");
        // Rebase through either tenant flushes the one shared store.
        b.rebase(None);
        assert_eq!(a.observations(), 0);
    }

    #[test]
    fn incompatible_sensing_refuses_to_share_a_store() {
        let a = AdaptiveController::new(AdaptiveConfig::default());
        let mut b = AdaptiveController::new(AdaptiveConfig {
            window: 99, // different pooled window capacity
            ..Default::default()
        });
        assert!(!b.attach_store(&a.shared_store()));
        let mut c = AdaptiveController::new(AdaptiveConfig {
            hetero: Some(HeteroConfig::default()), // hetero vs pooled sensing
            ..Default::default()
        });
        assert!(!c.attach_store(&a.shared_store()));
        // Policy-only differences (threshold, cadence, strategy) DO share.
        let mut e = AdaptiveController::new(AdaptiveConfig {
            drift_threshold: 0.9,
            check_every: 3,
            cooldown: 1,
            strategy: ResolveStrategy::Subgradient { iters: 10, playoff_trials: 5 },
            ..Default::default()
        });
        assert!(e.attach_store(&a.shared_store()), "policy knobs must not gate sharing");
    }

    #[test]
    fn delay_priced_fleet_shifts_each_row_by_its_backlog() {
        let d = ShiftedExponential::new(1e-3, 50.0);
        // Pooled (no hetero) controller: every row starts from the same
        // pooled fit; the delays alone differentiate the rows.
        let mut ctrl = AdaptiveController::new(AdaptiveConfig::default());
        let mut rng = Rng::new(53);
        observe_from(&mut ctrl, &d, 20, 4, &mut rng);
        let base_mean = ctrl.current_fit().unwrap().mean();
        let delays = [0.0, 250.0, 0.0, 1000.0];
        let fleet = ctrl.delay_priced_fleet(&[0, 1, 2, 3], &delays).expect("fit exists");
        assert_eq!(fleet.n(), 4);
        let means = fleet.means();
        for (row, &q) in delays.iter().enumerate() {
            assert!(
                (means[row] - (base_mean + q)).abs() < 1e-9 * (1.0 + base_mean + q),
                "row {row}: mean {} should be base {base_mean} + queue {q}",
                means[row]
            );
        }
        // Garbage delays are clamped, not propagated.
        let fleet = ctrl.delay_priced_fleet(&[0, 1], &[f64::NAN, -3.0]).unwrap();
        assert!(fleet.means().iter().all(|m| (m - base_mean).abs() < 1e-9 * base_mean));
        // No evidence at all → no priced fleet.
        let empty = AdaptiveController::new(AdaptiveConfig::default());
        assert!(empty.delay_priced_fleet(&[0, 1], &[0.0, 0.0]).is_none());
    }

    #[test]
    fn planned_loads_are_proportional_and_guarded() {
        let rho = planned_loads(&[2.0, 1.0, 1.0]);
        assert!((rho.iter().sum::<f64>() - 3.0).abs() < 1e-12, "loads preserve total work");
        assert!((rho[0] - 1.5).abs() < 1e-12 && (rho[1] - 0.75).abs() < 1e-12);
        assert_eq!(planned_loads(&[0.0, 0.0]), vec![1.0, 1.0], "degenerate rates → uniform");
        let with_dead = planned_loads(&[1.0, 0.0, f64::NAN]);
        assert_eq!(with_dead[1], 0.0);
        assert_eq!(with_dead[2], 0.0);
        assert!((with_dead[0] - 3.0).abs() < 1e-12);
        assert!(planned_loads(&[]).is_empty());
    }

    #[test]
    fn subgradient_strategy_produces_a_feasible_partition() {
        let spec = ProblemSpec::paper_default(8, 400);
        let before = ShiftedExponential::new(1e-2, 50.0);
        let after = ShiftedExponential::new(1e-3, 50.0);
        let cfg = AdaptiveConfig {
            strategy: ResolveStrategy::Subgradient { iters: 300, playoff_trials: 200 },
            ..Default::default()
        };
        let mut ctrl = AdaptiveController::with_reference(cfg, before.mu, before.t0);
        let mut rng = Rng::new(11);
        observe_from(&mut ctrl, &after, 20, spec.n, &mut rng);
        let warm = vec![50.0; 8];
        let plan = ctrl
            .maybe_replan(10, &spec, &warm, &mut rng)
            .unwrap()
            .expect("drift must trigger");
        assert_eq!(plan.blocks.total(), 400);
        assert_eq!(plan.blocks.n(), 8);
    }
}
