//! Worker-pool membership: stable **worker ids** decoupled from per-epoch
//! **code row positions**.
//!
//! The paper (and PR 1's adaptive engine) treat the worker count `N` as a
//! construction-time constant: worker `n` *is* row `n` of the encoding
//! matrix for the whole run. At production scale workers join, leave and
//! die mid-training, so the coordinator instead gives every worker thread
//! a stable [`WorkerId`] for its whole lifetime and binds ids to code
//! rows **per scheme epoch** through a [`WorkerRegistry`]:
//!
//! * a *join* registers a new id as `Pending`; it is assigned no work
//!   (and no row) until the next epoch rebind — and only once its
//!   executor has come up ([`WorkerRegistry::confirm`], driven by the
//!   worker's `Joined` event);
//! * a *leave* (clean drain, fatal failure, or — over the `tcp`
//!   transport — an expired heartbeat lease, which
//!   [`crate::transport::tcp`] surfaces as the same `Left` event) marks
//!   the id `Departed`;
//!   it keeps its row for the remainder of the current epoch — the
//!   master treats it exactly like a fatal straggler — and is dropped at
//!   the next rebind;
//! * [`WorkerRegistry::rebind`] starts a membership epoch: confirmed
//!   pending ids become `Active`, departed ids are dropped, and rows
//!   `0..N'` are assigned to the active ids in ascending id order. The
//!   caller re-dimensions the coding scheme to the new `N'` and installs
//!   it as a fresh scheme epoch, so within any epoch decoding stays
//!   exact.
//!
//! The registry tracks *churn* (confirmed joins + leaves) since the last
//! rebind; the trainer re-dimensions once churn passes a threshold, or
//! immediately when departures exceed what the live scheme's redundancy
//! can absorb.

/// Stable worker identity: allocated monotonically, never reused.
pub type WorkerId = usize;

/// Lifecycle state of a registered worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemberStatus {
    /// Joined but not yet bound to a code row (waiting for the next
    /// epoch rebind).
    Pending,
    /// Bound to a row in the current epoch's roster.
    Active,
    /// Left (drained, died, or never came up); dropped at the next
    /// rebind.
    Departed,
}

/// Id ↔ row bookkeeping for the elastic worker pool.
#[derive(Debug, Clone)]
pub struct WorkerRegistry {
    /// Status per worker id (ids are indices; never reused).
    status: Vec<MemberStatus>,
    /// Whether the worker's executor is known to be up (its `Joined`
    /// event was observed). Initial members are presumed up.
    confirmed: Vec<bool>,
    /// Current epoch's roster: row → worker id.
    roster: Vec<WorkerId>,
    /// Inverse map: worker id → row in the current roster.
    rows: Vec<Option<usize>>,
    /// Membership changes (confirmed joins + leaves of rostered or
    /// confirmed members) since the last [`Self::rebind`].
    churn: usize,
}

impl WorkerRegistry {
    /// A registry for an initial pool of `n0` workers (ids `0..n0`),
    /// all active and bound to rows `0..n0` (row = id for epoch 0).
    pub fn new(n0: usize) -> Self {
        assert!(n0 >= 1, "the pool needs at least one worker");
        Self {
            status: vec![MemberStatus::Active; n0],
            confirmed: vec![true; n0],
            roster: (0..n0).collect(),
            rows: (0..n0).map(Some).collect(),
            churn: 0,
        }
    }

    /// Register a new worker. It stays `Pending` — unassigned to any
    /// row — until it is [confirmed](Self::confirm) and the next
    /// [rebind](Self::rebind) runs.
    pub fn join(&mut self) -> WorkerId {
        let id = self.status.len();
        self.status.push(MemberStatus::Pending);
        self.confirmed.push(false);
        self.rows.push(None);
        id
    }

    /// Mark a pending worker's executor as up (its `Joined` event was
    /// observed). Counts toward churn: a confirmed join is a membership
    /// change the next rebind must absorb. Idempotent.
    pub fn confirm(&mut self, id: WorkerId) {
        if id < self.status.len()
            && self.status[id] == MemberStatus::Pending
            && !self.confirmed[id]
        {
            self.confirmed[id] = true;
            self.churn += 1;
        }
    }

    /// Mark a worker as departed (clean drain or fatal failure). It
    /// keeps its current row — the master accounts for it like a fatal
    /// straggler — until the next rebind drops it. Idempotent.
    pub fn leave(&mut self, id: WorkerId) {
        if id >= self.status.len() || self.status[id] == MemberStatus::Departed {
            return;
        }
        match self.status[id] {
            MemberStatus::Active => self.churn += 1,
            // A confirmed-but-unbound join cancels out: it never held a
            // row, so its arrival and departure are a net no-op.
            MemberStatus::Pending => {
                if self.confirmed[id] {
                    self.churn = self.churn.saturating_sub(1);
                }
            }
            MemberStatus::Departed => unreachable!(),
        }
        self.status[id] = MemberStatus::Departed;
    }

    /// Start a membership epoch: promote confirmed pending workers,
    /// drop departed ones, and bind rows `0..N'` to the active ids in
    /// ascending id order. Returns the new roster. Resets churn.
    pub fn rebind(&mut self) -> &[WorkerId] {
        for (s, &confirmed) in self.status.iter_mut().zip(self.confirmed.iter()) {
            if *s == MemberStatus::Pending && confirmed {
                *s = MemberStatus::Active;
            }
        }
        self.roster = (0..self.status.len())
            .filter(|&id| self.status[id] == MemberStatus::Active)
            .collect();
        for r in self.rows.iter_mut() {
            *r = None;
        }
        for (row, &id) in self.roster.iter().enumerate() {
            self.rows[id] = Some(row);
        }
        self.churn = 0;
        &self.roster
    }

    /// The current epoch's roster (row → worker id).
    pub fn roster(&self) -> &[WorkerId] {
        &self.roster
    }

    /// Rows in the current roster, i.e. the live scheme's `N`.
    pub fn n(&self) -> usize {
        self.roster.len()
    }

    /// The roster size a rebind would produce *now*: active members not
    /// yet departed, plus confirmed pending joins.
    pub fn next_n(&self) -> usize {
        self.status
            .iter()
            .zip(self.confirmed.iter())
            .filter(|&(s, c)| {
                *s == MemberStatus::Active || (*s == MemberStatus::Pending && *c)
            })
            .count()
    }

    /// The row worker `id` holds in the current roster (None while
    /// pending, after departure + rebind, or for unknown ids).
    pub fn row_of(&self, id: WorkerId) -> Option<usize> {
        self.rows.get(id).copied().flatten()
    }

    /// The worker id bound to `row` in the current roster.
    pub fn id_at(&self, row: usize) -> Option<WorkerId> {
        self.roster.get(row).copied()
    }

    /// Lifecycle state of `id` (None for unknown ids).
    pub fn status(&self, id: WorkerId) -> Option<MemberStatus> {
        self.status.get(id).copied()
    }

    /// Membership changes since the last rebind.
    pub fn churn_since_rebind(&self) -> usize {
        self.churn
    }

    /// Rostered workers that have departed this epoch — dead rows the
    /// live scheme's redundancy must currently absorb.
    pub fn departed_in_roster(&self) -> usize {
        self.roster
            .iter()
            .filter(|&&id| self.status[id] == MemberStatus::Departed)
            .count()
    }

    /// Total ids ever allocated (capacity of id-indexed side tables).
    pub fn capacity(&self) -> usize {
        self.status.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_pool_is_identity_bound() {
        let reg = WorkerRegistry::new(4);
        assert_eq!(reg.n(), 4);
        assert_eq!(reg.roster(), &[0, 1, 2, 3]);
        for id in 0..4 {
            assert_eq!(reg.row_of(id), Some(id));
            assert_eq!(reg.id_at(id), Some(id));
            assert_eq!(reg.status(id), Some(MemberStatus::Active));
        }
        assert_eq!(reg.churn_since_rebind(), 0);
        assert_eq!(reg.next_n(), 4);
    }

    #[test]
    fn join_is_unbound_until_confirmed_and_rebound() {
        let mut reg = WorkerRegistry::new(3);
        let id = reg.join();
        assert_eq!(id, 3);
        assert_eq!(reg.status(id), Some(MemberStatus::Pending));
        assert_eq!(reg.row_of(id), None);
        // Unconfirmed joins neither count as churn nor survive a rebind
        // into the roster.
        assert_eq!(reg.churn_since_rebind(), 0);
        assert_eq!(reg.next_n(), 3);
        reg.rebind();
        assert_eq!(reg.n(), 3);
        assert_eq!(reg.row_of(id), None);
        // Confirmation makes it churn; the next rebind binds a row.
        reg.confirm(id);
        reg.confirm(id); // idempotent
        assert_eq!(reg.churn_since_rebind(), 1);
        assert_eq!(reg.next_n(), 4);
        reg.rebind();
        assert_eq!(reg.n(), 4);
        assert_eq!(reg.row_of(id), Some(3));
        assert_eq!(reg.churn_since_rebind(), 0);
    }

    #[test]
    fn leave_keeps_the_row_until_rebind() {
        let mut reg = WorkerRegistry::new(4);
        reg.leave(1);
        reg.leave(1); // idempotent
        assert_eq!(reg.status(1), Some(MemberStatus::Departed));
        // Still rostered this epoch (the master sees it as a dead row)…
        assert_eq!(reg.row_of(1), Some(1));
        assert_eq!(reg.departed_in_roster(), 1);
        assert_eq!(reg.churn_since_rebind(), 1);
        assert_eq!(reg.next_n(), 3);
        // …and dropped at the rebind, with rows compacted in id order.
        reg.rebind();
        assert_eq!(reg.roster(), &[0, 2, 3]);
        assert_eq!(reg.row_of(1), None);
        assert_eq!(reg.row_of(2), Some(1));
        assert_eq!(reg.row_of(3), Some(2));
        assert_eq!(reg.departed_in_roster(), 0);
    }

    #[test]
    fn confirmed_join_that_leaves_before_rebind_cancels_out() {
        let mut reg = WorkerRegistry::new(2);
        let id = reg.join();
        reg.confirm(id);
        assert_eq!(reg.churn_since_rebind(), 1);
        reg.leave(id);
        assert_eq!(reg.churn_since_rebind(), 0);
        reg.rebind();
        assert_eq!(reg.roster(), &[0, 1]);
    }

    #[test]
    fn mixed_churn_rebinds_to_the_surviving_set() {
        let mut reg = WorkerRegistry::new(5);
        reg.leave(0);
        reg.leave(3);
        let a = reg.join(); // 5
        let b = reg.join(); // 6
        reg.confirm(b);
        // a unconfirmed: waits for a later rebind.
        assert_eq!(reg.churn_since_rebind(), 3);
        assert_eq!(reg.next_n(), 4);
        reg.rebind();
        assert_eq!(reg.roster(), &[1, 2, 4, 6]);
        assert_eq!(reg.id_at(3), Some(6));
        assert_eq!(reg.row_of(a), None);
        assert_eq!(reg.status(a), Some(MemberStatus::Pending));
    }
}
