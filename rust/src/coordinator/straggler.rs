//! Per-iteration straggler sampling and the virtual-runtime accounting of
//! Eq. (2) — the substitution for a physical heterogeneous cluster
//! (DESIGN.md §4).
//!
//! [`StragglerSchedule`] generalizes the paper's stationary model to a
//! piecewise-stationary one: the cycle-time distribution may *shift* at
//! chosen iterations (machines get preempted, co-tenants arrive, networks
//! degrade). The adaptive coding engine exists to chase exactly these
//! shifts.

use crate::coding::scheme::CodingScheme;
use crate::distribution::CycleTimeDistribution;
use crate::optimizer::runtime_model::{sort_times, ProblemSpec};
use crate::util::rng::Rng;

/// A piecewise-stationary cycle-time model: phase `k` applies from its
/// start iteration until the next phase begins.
pub struct StragglerSchedule {
    /// `(start_iter, dist)`, strictly increasing starts, first at 0.
    segments: Vec<(usize, Box<dyn CycleTimeDistribution>)>,
}

impl StragglerSchedule {
    /// The paper's stationary model: one distribution for the whole run.
    pub fn stationary(dist: Box<dyn CycleTimeDistribution>) -> Self {
        Self { segments: vec![(0, dist)] }
    }

    /// Append a phase: from `start_iter` on, cycle times follow `dist`.
    /// Phases must be appended in strictly increasing start order.
    pub fn then(mut self, start_iter: usize, dist: Box<dyn CycleTimeDistribution>) -> Self {
        // Constructors seed one segment, so `last()` is always Some;
        // map_or keeps the invariant check without an unwrap.
        let last_start = self.segments.last().map_or(0, |(s, _)| *s);
        assert!(
            start_iter > last_start,
            "schedule phases must start in strictly increasing order"
        );
        self.segments.push((start_iter, dist));
        self
    }

    /// The distribution governing iteration `iter`.
    pub fn dist_at(&self, iter: usize) -> &dyn CycleTimeDistribution {
        let mut cur: &dyn CycleTimeDistribution = self.segments[0].1.as_ref();
        for (start, d) in &self.segments {
            if *start <= iter {
                cur = d.as_ref();
            } else {
                break;
            }
        }
        cur
    }

    /// Iterations at which the distribution changes (excludes 0).
    pub fn shift_points(&self) -> Vec<usize> {
        self.segments.iter().skip(1).map(|(s, _)| *s).collect()
    }

    pub fn num_phases(&self) -> usize {
        self.segments.len()
    }

    /// Human-readable phase listing for logs and reports.
    pub fn label(&self) -> String {
        self.segments
            .iter()
            .map(|(s, d)| format!("{}→{}", s, d.label()))
            .collect::<Vec<_>>()
            .join(", ")
    }
}

/// Samples each iteration's worker cycle times from a (possibly
/// non-stationary) schedule — optionally overridden per **stable
/// worker id** by a heterogeneous fleet (machines keep their speed
/// across rebinds; rows do not).
pub struct StragglerSampler {
    schedule: StragglerSchedule,
    /// Per-worker models keyed by stable id. Ids beyond the list (e.g.
    /// elastic joins) draw from the schedule's current phase.
    fleet: Option<Vec<Box<dyn CycleTimeDistribution>>>,
    rng: Rng,
}

impl StragglerSampler {
    /// Stationary convenience constructor.
    pub fn new(dist: Box<dyn CycleTimeDistribution>, seed: u64) -> Self {
        Self::from_schedule(StragglerSchedule::stationary(dist), seed)
    }

    pub fn from_schedule(schedule: StragglerSchedule, seed: u64) -> Self {
        Self { schedule, fleet: None, rng: Rng::new(seed) }
    }

    /// Give each stable worker id its own cycle-time model
    /// (`fleet[id]`); the schedule remains the fallback for ids beyond
    /// the list and the pool-level prior.
    pub fn with_fleet(mut self, fleet: Vec<Box<dyn CycleTimeDistribution>>) -> Self {
        assert!(!fleet.is_empty(), "a fleet needs at least one worker model");
        self.fleet = Some(fleet);
        self
    }

    /// Draw `T_1..T_N` for iteration `iter` (pooled: every worker from
    /// the schedule's phase — the i.i.d. case of [`Self::sample_roster`]).
    pub fn sample(&mut self, iter: usize, n: usize) -> Vec<f64> {
        self.schedule.dist_at(iter).sample_vec(n, &mut self.rng)
    }

    /// Draw one cycle time per rostered row: `times[row]` comes from
    /// worker `roster[row]`'s own model when a fleet is installed (the
    /// schedule phase otherwise / for unknown ids). Without a fleet
    /// this is exactly [`Self::sample`] — same stream, same order.
    pub fn sample_roster(&mut self, iter: usize, roster: &[usize]) -> Vec<f64> {
        match &self.fleet {
            None => self.schedule.dist_at(iter).sample_vec(roster.len(), &mut self.rng),
            Some(fleet) => roster
                .iter()
                .map(|&id| match fleet.get(id) {
                    Some(d) => d.sample(&mut self.rng),
                    None => self.schedule.dist_at(iter).sample(&mut self.rng),
                })
                .collect(),
        }
    }

    /// The distribution governing iteration `iter`.
    pub fn distribution_at(&self, iter: usize) -> &dyn CycleTimeDistribution {
        self.schedule.dist_at(iter)
    }
}

/// Eq. (2): the iteration's overall virtual runtime under the scheme —
/// when the *(N−s)*-fastest worker finishes each block, maximized over
/// blocks.
pub fn virtual_runtime(spec: &ProblemSpec, scheme: &CodingScheme, times: &[f64]) -> f64 {
    let n = spec.n;
    debug_assert_eq!(times.len(), n);
    let mut sorted = times.to_vec();
    sort_times(&mut sorted);
    let unit = spec.unit_work();
    let mut cum = 0.0;
    let mut best = 0.0f64;
    for r in scheme.ranges() {
        cum += ((r.s + 1) * r.len()) as f64;
        let v = sorted[n - 1 - r.s] * cum;
        if v > best {
            best = v;
        }
    }
    unit * best
}

/// Per-worker virtual completion stamps for every block (the stamps the
/// workers attach to their [`super::channel::BlockContribution`]s):
/// worker `w`'s block `j` completes at `unit·T_w·Σ_{l ≤ end_j}(s_l+1)`.
pub fn block_completion_stamps(
    spec: &ProblemSpec,
    scheme: &CodingScheme,
    cycle_time: f64,
) -> Vec<f64> {
    block_completion_stamps_unit(spec.unit_work(), scheme, cycle_time)
}

/// [`block_completion_stamps`] from a precomputed unit of work
/// (`(M/N)·b` cycles). The elastic pool re-dimensions `N` mid-run, so
/// workers receive the epoch's unit with each task instead of baking a
/// `ProblemSpec` in at spawn.
pub fn block_completion_stamps_unit(
    unit: f64,
    scheme: &CodingScheme,
    cycle_time: f64,
) -> Vec<f64> {
    let mut cum = 0.0;
    scheme
        .ranges()
        .iter()
        .map(|r| {
            cum += ((r.s + 1) * r.len()) as f64;
            unit * cycle_time * cum
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::shifted_exp::ShiftedExponential;
    use crate::distribution::Deterministic;
    use crate::optimizer::blocks::BlockPartition;
    use crate::optimizer::runtime_model::tau_s;

    #[test]
    fn virtual_runtime_matches_eq2() {
        let mut rng = Rng::new(1);
        let spec = ProblemSpec::new(4, 4, 4, 1.0);
        let p = BlockPartition::from_s_vector(4, &[1, 1, 2, 2]).unwrap();
        let scheme = CodingScheme::new(p, &mut rng).unwrap();
        let t = vec![0.1, 0.1, 0.25, 1.0];
        let vr = virtual_runtime(&spec, &scheme, &t);
        let eq2 = tau_s(&spec, &[1, 1, 2, 2], &t);
        assert!((vr - eq2).abs() < 1e-12);
        assert!((vr - 1.0).abs() < 1e-12); // Fig. 1(d)'s value
    }

    #[test]
    fn stamps_are_monotone_and_scale_with_cycle_time() {
        let mut rng = Rng::new(2);
        let spec = ProblemSpec::new(4, 10, 4, 1.0);
        let p = BlockPartition::new(vec![4, 3, 2, 1]);
        let scheme = CodingScheme::new(p, &mut rng).unwrap();
        let s1 = block_completion_stamps(&spec, &scheme, 1.0);
        let s2 = block_completion_stamps(&spec, &scheme, 2.0);
        assert_eq!(s1.len(), 4);
        assert!(s1.windows(2).all(|w| w[0] < w[1]));
        for (a, b) in s1.iter().zip(s2.iter()) {
            assert!((b - 2.0 * a).abs() < 1e-12);
        }
    }

    #[test]
    fn sampler_is_deterministic_per_seed() {
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut a = StragglerSampler::new(Box::new(d.clone()), 7);
        let mut b = StragglerSampler::new(Box::new(d), 7);
        assert_eq!(a.sample(0, 5), b.sample(0, 5));
    }

    #[test]
    fn schedule_switches_phases_at_boundaries() {
        let sched = StragglerSchedule::stationary(Box::new(Deterministic::new(1.0)))
            .then(10, Box::new(Deterministic::new(2.0)))
            .then(20, Box::new(Deterministic::new(3.0)));
        assert_eq!(sched.num_phases(), 3);
        assert_eq!(sched.shift_points(), vec![10, 20]);
        let mut rng = Rng::new(0);
        assert_eq!(sched.dist_at(0).sample(&mut rng), 1.0);
        assert_eq!(sched.dist_at(9).sample(&mut rng), 1.0);
        assert_eq!(sched.dist_at(10).sample(&mut rng), 2.0);
        assert_eq!(sched.dist_at(19).sample(&mut rng), 2.0);
        assert_eq!(sched.dist_at(20).sample(&mut rng), 3.0);
        assert_eq!(sched.dist_at(10_000).sample(&mut rng), 3.0);
    }

    #[test]
    fn sampler_follows_schedule() {
        let sched = StragglerSchedule::stationary(Box::new(Deterministic::new(1.0)))
            .then(5, Box::new(Deterministic::new(4.0)));
        let mut s = StragglerSampler::from_schedule(sched, 3);
        assert_eq!(s.sample(4, 3), vec![1.0, 1.0, 1.0]);
        assert_eq!(s.sample(5, 3), vec![4.0, 4.0, 4.0]);
        assert!((s.distribution_at(5).mean() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn fleet_sampler_keys_speeds_by_stable_id_not_row() {
        // Ids 0/1 fast, id 2 slow. After a rebind moves id 2 to row 0,
        // row 0's draws must be slow — the machine kept its speed.
        let fleet: Vec<Box<dyn CycleTimeDistribution>> = vec![
            Box::new(Deterministic::new(1.0)),
            Box::new(Deterministic::new(1.0)),
            Box::new(Deterministic::new(9.0)),
        ];
        let mut s = StragglerSampler::new(Box::new(Deterministic::new(5.0)), 7)
            .with_fleet(fleet);
        assert_eq!(s.sample_roster(0, &[0, 1, 2]), vec![1.0, 1.0, 9.0]);
        assert_eq!(s.sample_roster(1, &[2, 0]), vec![9.0, 1.0]);
        // Unknown ids (a later join) fall back to the schedule's phase.
        assert_eq!(s.sample_roster(2, &[0, 7]), vec![1.0, 5.0]);
    }

    #[test]
    fn pooled_sample_roster_matches_sample_stream() {
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut a = StragglerSampler::new(Box::new(d.clone()), 11);
        let mut b = StragglerSampler::new(Box::new(d), 11);
        assert_eq!(a.sample(0, 4), b.sample_roster(0, &[0, 1, 2, 3]));
        // Row→id binding is irrelevant without a fleet: only the count
        // drives the stream.
        assert_eq!(a.sample(1, 3), b.sample_roster(1, &[9, 4, 0]));
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn schedule_rejects_out_of_order_phases() {
        let _ = StragglerSchedule::stationary(Box::new(Deterministic::new(1.0)))
            .then(10, Box::new(Deterministic::new(2.0)))
            .then(10, Box::new(Deterministic::new(3.0)));
    }
}
