//! Per-iteration straggler sampling and the virtual-runtime accounting of
//! Eq. (2) — the substitution for a physical heterogeneous cluster
//! (DESIGN.md §4).

use crate::coding::scheme::CodingScheme;
use crate::distribution::CycleTimeDistribution;
use crate::optimizer::runtime_model::{sort_times, ProblemSpec};
use crate::util::rng::Rng;

/// Samples each iteration's worker cycle times.
pub struct StragglerSampler {
    dist: Box<dyn CycleTimeDistribution>,
    rng: Rng,
}

impl StragglerSampler {
    pub fn new(dist: Box<dyn CycleTimeDistribution>, seed: u64) -> Self {
        Self { dist, rng: Rng::new(seed) }
    }

    /// Draw `T_1..T_N` for one iteration.
    pub fn sample(&mut self, n: usize) -> Vec<f64> {
        self.dist.sample_vec(n, &mut self.rng)
    }

    pub fn distribution(&self) -> &dyn CycleTimeDistribution {
        self.dist.as_ref()
    }
}

/// Eq. (2): the iteration's overall virtual runtime under the scheme —
/// when the *(N−s)*-fastest worker finishes each block, maximized over
/// blocks.
pub fn virtual_runtime(spec: &ProblemSpec, scheme: &CodingScheme, times: &[f64]) -> f64 {
    let n = spec.n;
    debug_assert_eq!(times.len(), n);
    let mut sorted = times.to_vec();
    sort_times(&mut sorted);
    let unit = spec.unit_work();
    let mut cum = 0.0;
    let mut best = 0.0f64;
    for r in scheme.ranges() {
        cum += ((r.s + 1) * r.len()) as f64;
        let v = sorted[n - 1 - r.s] * cum;
        if v > best {
            best = v;
        }
    }
    unit * best
}

/// Per-worker virtual completion stamps for every block (the stamps the
/// workers attach to their [`super::channel::BlockContribution`]s):
/// worker `w`'s block `j` completes at `unit·T_w·Σ_{l ≤ end_j}(s_l+1)`.
pub fn block_completion_stamps(
    spec: &ProblemSpec,
    scheme: &CodingScheme,
    cycle_time: f64,
) -> Vec<f64> {
    let unit = spec.unit_work();
    let mut cum = 0.0;
    scheme
        .ranges()
        .iter()
        .map(|r| {
            cum += ((r.s + 1) * r.len()) as f64;
            unit * cycle_time * cum
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::distribution::shifted_exp::ShiftedExponential;
    use crate::optimizer::blocks::BlockPartition;
    use crate::optimizer::runtime_model::tau_s;

    #[test]
    fn virtual_runtime_matches_eq2() {
        let mut rng = Rng::new(1);
        let spec = ProblemSpec::new(4, 4, 4, 1.0);
        let p = BlockPartition::from_s_vector(4, &[1, 1, 2, 2]).unwrap();
        let scheme = CodingScheme::new(p, &mut rng).unwrap();
        let t = vec![0.1, 0.1, 0.25, 1.0];
        let vr = virtual_runtime(&spec, &scheme, &t);
        let eq2 = tau_s(&spec, &[1, 1, 2, 2], &t);
        assert!((vr - eq2).abs() < 1e-12);
        assert!((vr - 1.0).abs() < 1e-12); // Fig. 1(d)'s value
    }

    #[test]
    fn stamps_are_monotone_and_scale_with_cycle_time() {
        let mut rng = Rng::new(2);
        let spec = ProblemSpec::new(4, 10, 4, 1.0);
        let p = BlockPartition::new(vec![4, 3, 2, 1]);
        let scheme = CodingScheme::new(p, &mut rng).unwrap();
        let s1 = block_completion_stamps(&spec, &scheme, 1.0);
        let s2 = block_completion_stamps(&spec, &scheme, 2.0);
        assert_eq!(s1.len(), 4);
        assert!(s1.windows(2).all(|w| w[0] < w[1]));
        for (a, b) in s1.iter().zip(s2.iter()) {
            assert!((b - 2.0 * a).abs() < 1e-12);
        }
    }

    #[test]
    fn sampler_is_deterministic_per_seed() {
        let d = ShiftedExponential::new(1e-3, 50.0);
        let mut a = StragglerSampler::new(Box::new(d.clone()), 7);
        let mut b = StragglerSampler::new(Box::new(d), 7);
        assert_eq!(a.sample(5), b.sample(5));
    }
}
