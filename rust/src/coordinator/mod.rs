//! Layer-3 coordinator: the shared worker pool that executes
//! block-coordinate-gradient-coded distributed gradient descent for
//! **any number of concurrent training jobs**.
//!
//! Topology: one [`pool::WorkerPool`] owning `N` worker threads, and one
//! [`pool::JobHandle`] per submitted job. Each pool round, the
//! scheduler picks a job and runs one of its GD iterations:
//!
//! 1. The pool samples the round's worker cycle times `T_n` from the
//!    straggler model ([`straggler`]) and the job's master broadcasts
//!    `(job, iter, epoch, scheme, θ, T_n)` to every rostered worker.
//! 2. Every worker computes the partial gradients of the job's data
//!    subsets it holds (via a per-job [`crate::runtime::GradExecutor`]
//!    built lazily in-thread — PJRT artifacts in production), encodes
//!    each coordinate *block* with that block's gradient code and
//!    streams the coded blocks back, stamped with the job ([`worker`]).
//! 3. The pool routes the shared event channel by job id; the active
//!    job's master decodes each block as soon as any `N − s` workers
//!    have delivered it (cached decode vectors), assembles the exact
//!    full gradient `Σ_n g_n`, steps θ, and records both the wall clock
//!    and the model-faithful *virtual* runtime of Eq. (2) ([`master`],
//!    [`metrics`]).
//!
//! Jobs are isolated by construction: every contribution carries its
//! [`channel::JobId`], a master refuses cross-job codewords exactly like
//! stale-epoch ones, and one job's stragglers cost another job nothing
//! beyond the worker-FIFO delay its own redundancy already absorbs —
//! while the **pooled** cycle-time feed lets every job's online
//! estimator learn from every round (worker speeds are a pool property,
//! not a job property). Every observation in that feed is stamped with
//! the worker's **stable id**, so under the `[hetero]` policy each
//! machine also gets its own window and fit — the heterogeneity-aware
//! engine re-solves against the fleet of per-worker models and
//! re-shards data in proportion to fitted speed
//! ([`master::redistribute_shards_weighted`]).
//!
//! The coding scheme is an **epoch-versioned, swappable artifact** per
//! job, not an immutable `Arc` baked in at spawn: each job's adaptive
//! engine ([`adaptive`]) watches the observed cycle times through a
//! sliding window estimator ([`crate::distribution::fit`]) and, on
//! parameter drift, re-solves the partition and installs it as a new
//! epoch between iterations. Contributions encoded under a superseded
//! epoch are rejected like stale-iteration messages, so codewords from
//! two schemes never mix into one decode.
//!
//! On top of scheme epochs sit **membership epochs** ([`membership`]),
//! which are pool-level: worker identity is decoupled from code row
//! position, so `N` itself is an epoch property shared by every job.
//! Joins wait unassigned until the next epoch swap, leaves (clean
//! drains or fatal failures) are accounted as fatal stragglers for the
//! rest of the current epoch, and once churn passes a threshold the
//! pool rebinds rows **once** and every job re-solves its partition for
//! the live roster's `N'` — decoding stays exact within every (job,
//! epoch).
//!
//! ## The data plane (zero-copy tiled kernels, f32 wire, pooled buffers)
//!
//! The per-block payload path is allocation- and copy-free in steady
//! state:
//!
//! * **f32 wire, f64 accumulate.** Workers compute gradients in `f32`
//!   and encode each block with the fused tiled kernels
//!   ([`crate::linalg::kernels`]): the `s+1` shard-gradient tiles are
//!   read once each, combined in an on-stack `f64` accumulator, and
//!   rounded to `f32` exactly once for the wire — half the channel
//!   bytes of an `f64` wire with no intermediate-sum precision loss.
//!   The master decodes back in `f64` (the same kernels), so the
//!   assembled gradient is exact up to one `f32` rounding of the
//!   *inputs*, which is why the e2e exactness assertions hold unchanged
//!   on the f32 wire.
//! * **Buffer lifecycle.** Wire buffers come from one pool-wide
//!   freelist ([`crate::util::buffers::BufferPool`]): a worker `take`s
//!   a buffer per block, ownership travels with the
//!   [`channel::BlockContribution`] through the channel, and whoever
//!   disposes of the contribution — the master after a decode, any
//!   drop path (late / stale-epoch / stale-iter / cross-job /
//!   mismatched binding / abort) — `put`s it back. One owner at a
//!   time; returning is optional for correctness (a dropped buffer
//!   costs one future miss), which keeps every error path safe. After
//!   one warm-up iteration the same buffers cycle forever; pool
//!   counters are reported per job next to the decode-cache stats
//!   ([`metrics::TrainReport`]).
//! * **Decode writes in place.** The master's combine writes straight
//!   into the job's preallocated gradient slice
//!   ([`crate::coding::decoder::decode_into`]) — no intermediate
//!   decode vector, no copy — and fans large blocks out over scoped
//!   threads ([`crate::linalg::kernels::fused_combine_into_f64_auto`]).
//!
//! ## Round lifecycle under asynchronous, position-aware dispatch
//!
//! [`pool::WorkerPool::run_all_async`] generalizes step 1–3 above from
//! a decode-to-completion barrier to a **pipeline** ([`pool::AsyncConfig`]):
//!
//! 1. **Dispatch.** While fewer than `max_inflight` collects are open,
//!    the scheduler picks a ready job (not already mid-iteration) and
//!    broadcasts its next iteration immediately — job B's iteration
//!    `t+1` goes out while job A's tail blocks are still in flight.
//!    Each worker's unfinished queued work at dispatch is its
//!    **backlog**, tracked on per-worker virtual-time segment queues.
//! 2. **Backlog-priced re-solve.** Before broadcasting, each row's
//!    backlog (converted to cycles of the dispatching job's unit work)
//!    is folded into its fitted cycle-time model as an added shift —
//!    [`distribution::fit::FittedModel::delayed`](crate::distribution::fit::FittedModel::delayed)
//!    — so Eq. (2) and the subgradient solver price queue position
//!    natively; a backlog skew beyond the configured threshold installs
//!    the re-solved partition as a fresh scheme epoch.
//! 3. **Approximate / exact decode.** Each block still decodes exactly
//!    from its first `N − s` arrivals. With
//!    [`master::SemiAsyncConfig`], a block whose quorum is short *only*
//!    of deeply-backlogged rows is instead decoded **approximately**
//!    (least-squares over the arrived codewords,
//!    [`crate::coding::decoder::decode_vector_ls`]) and applied with a
//!    tracked error bound; an exact quorum landing later in the same
//!    collect silently upgrades it.
//! 4. **Reconcile.** Approximate blocks still short at finalize become
//!    pending reconciliations: when their exact quorum arrives in later
//!    rounds (stale-iteration arrivals feed them instead of being
//!    dropped), the master emits `delta = exact − approx` and the pool
//!    re-bases θ over just that block range
//!    ([`state::ModelState::correct`]); a scheme-epoch swap discards
//!    what is left, with buffers recycled and counts reported.
//!
//! A finalized round truncates its segments at the decode's virtual
//! completion and reflows the queues, so `max_inflight = 1` reproduces
//! the serialized schedule bit-for-bit; stale-iteration and stale-epoch
//! drops, buffer recycling and per-job accounting all extend to
//! overlapped iterations ([`pool`]'s module docs cover the dispatch
//! gates and accounting invariants).
//!
//! ## Round lifecycle with partial-sum streaming (rotated part quorums)
//!
//! With [`pool::JobSpec::stream_parts`]` = P ≥ 2` the three-step round
//! above changes *when* payloads move, never *what* decodes:
//!
//! 1. **Dispatch** additionally carries the job's sample slice map —
//!    [`master::redistribute_samples_weighted`] splits the dataset at
//!    sample granularity in proportion to fitted speeds (Hamilton
//!    largest-remainder, validated weights, one-sample floor), and
//!    [`master::sample_load_multipliers`] feeds the same loads back
//!    into Eq. (2) — plus the part count `P`.
//! 2. **Workers stream strides.** Each held span is cut into `P` fixed
//!    sub-spans — *data parts*, identical from every row that holds the
//!    subset. A worker visits them in rotated order: at stride `j` it
//!    computes data part `(row + j) mod P` and emits each block's coded
//!    delta for it as a [`channel::PartialBlockContribution`]. Both
//!    halves are load-bearing. Parts being data-indexed (not
//!    stride-indexed) is what makes a part quorum decodable from *any*
//!    `N − s` rows — different parts may fold from different survivor
//!    sets. The rotation is where the speed comes from: the fleet's
//!    early strides land on **different** parts, so every part quorum
//!    fills without waiting for anyone's whole round (aligned,
//!    non-rotated parts gain nothing).
//! 3. **The master folds part quorums.** Each (block, part) decodes at
//!    its own `N − s` arrivals — same cached decode vectors — and is
//!    folded into the job's gradient slice in place
//!    ([`crate::coding::decoder::decode_into_add`]); the block
//!    completes when all `P` parts have folded. A **whole-block**
//!    quorum landing first wins instead: its exact decode overwrites
//!    the slice and every buffered or folded part is discarded and
//!    recycled. Duplicate `(row, part)` deltas count as late; a part
//!    geometry that does not match the installed `P` is refused like a
//!    stale epoch; semi-async approximation skips any block that has
//!    already folded parts. The per-iteration `partial_contributions` /
//!    `partial_blocks` ledger ([`metrics`]) records which path
//!    completed each block.
//!
//! Streamed part buffers ride the same pooled-buffer ownership contract
//! as whole blocks (every drop path recycles), and `P = 1` (or the
//! default `stream_parts = 0`) reproduces the whole-block schedule
//! exactly — pinned by `tests/partial_e2e.rs` and the master's unit
//! tests.
//!
//! ## The transport boundary
//!
//! Everything above speaks **task lanes and event channels**, not
//! threads or sockets: the pool builds one [`crate::transport::Transport`]
//! from its config and asks it for a [`crate::transport::WorkerLane`]
//! per rostered worker. With the default in-process transport the lane
//! is the familiar `mpsc` pair feeding a spawned [`worker`] thread — the
//! pre-PR-9 topology, bit-for-bit. With the `tcp` feature the same lane
//! is a framed socket to a remote peer running
//! [`crate::transport::tcp::serve_worker`]: tasks and coded blocks cross
//! as length-prefixed frames (the f32 wire blocks move without copies),
//! and **liveness becomes explicit** — peers heartbeat on a fixed
//! period, the master grants each a lease, and a lease that goes silent
//! past its TTL surfaces as the *same* `Left` event a clean drain
//! produces, feeding the membership re-dimension path unchanged. The
//! master, pool and adaptive layers cannot tell the difference; that is
//! the contract. Wire-level counters (bytes/frames each way, missed
//! heartbeats, expired leases) land in [`metrics::TrainReport::wire`].
//!
//! Single-job callers keep the classic facade ([`trainer`]):
//! `train(cfg, schedule, factory)` or a driveable
//! [`trainer::TrainSession`].
//!
//! Pacing is virtual by default (timing comes from the paper's cost
//! model; numerics are real); `PacingMode::RealScaled` makes workers
//! actually sleep proportionally, so arrival order matches the model and
//! the decode-on-arrival path is exercised end-to-end.
//!
//! ## Checked invariants
//!
//! The contracts above are enforced mechanically by `bcgc-lint`
//! ([`crate::analysis`], blocking in CI). Inside `coordinator/` the
//! load-bearing rules are:
//!
//! * **`panic_hygiene`** — no `.unwrap()` / `.expect(` outside tests:
//!   every recoverable condition routes through [`crate::Result`], and
//!   the two *documented* panics ([`master::Master`]'s offer/take
//!   contract) carry inline allows naming the contract.
//! * **`buffer_ownership`** — any function here that takes a pooled
//!   buffer or counts a dropped [`channel::BlockContribution`] (late,
//!   stale-epoch, cross-job, mismatched, off-cycle) must recycle the
//!   wire buffer in the same function; this is the PR 6 data-plane
//!   ownership contract, and the rule caught a real leak on the
//!   worker's failed-send path (fixed in PR 8, regression-tested in
//!   [`worker`]).
//! * **`ledger_discipline`** — the PR 7 semi-async ledger counters
//!   (`approx_decodes`, `approx_reconciled`, `approx_discarded`,
//!   `discarded`) may only be written next to their witness calls
//!   (`take_outcome`, `take_reconciled`, `discard_pending`,
//!   `.drain(`), so the reconciliation accounting in
//!   [`metrics::TrainReport`] cannot silently drift from the decode
//!   state it describes.
//! * **`lock_order`** — mutex nesting follows the table order
//!   observation store → lease table → buffer-pool inner → socket
//!   writer → stdio (see [`adaptive::ObservationStore`],
//!   [`crate::transport::lease::LeaseTable`] and
//!   [`crate::util::buffers::BufferPool`]); unranked receivers are
//!   findings by construction.
//! * **`determinism`** — round control flow never reads wall clocks or
//!   OS entropy (virtual time only); the decode-latency *metrics* in
//!   [`master`] and [`pool`] carry inline allows because they measure
//!   without steering.
//!
//! Waivers are inline and reasoned:
//! `// lint: allow(<rule>) — <reason>`. New code that trips a rule
//! should be restructured first; an allow is for contracts the rule
//! cannot see, not for convenience.

pub mod adaptive;
pub mod channel;
pub mod master;
pub mod membership;
pub mod metrics;
pub mod pool;
pub mod state;
pub mod straggler;
pub mod trainer;
pub mod worker;

/// How worker completion times map to wall-clock behaviour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PacingMode {
    /// No sleeping: workers stream results as fast as they compute;
    /// runtimes are accounted in virtual time from the cost model.
    Virtual,
    /// Workers sleep `ns_per_unit` nanoseconds per unit of virtual time
    /// before emitting each block, so real arrival order follows the
    /// straggler model.
    RealScaled { ns_per_unit: f64 },
}
