//! Layer-3 coordinator: the master/worker runtime that executes
//! block-coordinate-gradient-coded distributed gradient descent.
//!
//! Topology: one master (the calling thread) and `N` worker threads.
//! Each GD iteration:
//!
//! 1. The master samples the workers' cycle times `T_n` from the
//!    straggler model ([`straggler`]) and broadcasts
//!    `(iter, epoch, scheme, θ, T_n)`.
//! 2. Every worker computes the partial gradients of its held data
//!    subsets (via a [`crate::runtime::GradExecutor`] — PJRT artifacts in
//!    production), encodes each coordinate *block* with that block's
//!    gradient code and streams the coded blocks back ([`worker`]).
//! 3. The master decodes each block as soon as any `N − s` workers have
//!    delivered it (cached decode vectors), assembles the exact full
//!    gradient `Σ_n g_n`, steps θ, and records both the wall clock and
//!    the model-faithful *virtual* runtime of Eq. (2) ([`master`],
//!    [`metrics`]).
//!
//! The coding scheme is an **epoch-versioned, swappable artifact**, not
//! an immutable `Arc` baked in at spawn: the adaptive engine
//! ([`adaptive`]) watches the observed cycle times through a sliding
//! window estimator ([`crate::distribution::fit`]) and, on parameter
//! drift, re-solves the partition and installs it as a new epoch between
//! iterations. Contributions encoded under a superseded epoch are
//! rejected like stale-iteration messages, so codewords from two schemes
//! never mix into one decode.
//!
//! On top of scheme epochs sit **membership epochs** ([`membership`]):
//! worker identity is decoupled from code row position, so `N` itself is
//! an epoch property. Joins wait unassigned until the next epoch swap,
//! leaves (clean drains or fatal failures) are accounted as fatal
//! stragglers for the rest of the current epoch, and once churn passes a
//! threshold the trainer re-solves the partition for the live roster's
//! `N'` and installs the re-dimensioned scheme — decoding stays exact
//! within every epoch.
//!
//! Pacing is virtual by default (timing comes from the paper's cost
//! model; numerics are real); `PacingMode::RealScaled` makes workers
//! actually sleep proportionally, so arrival order matches the model and
//! the decode-on-arrival path is exercised end-to-end.

pub mod adaptive;
pub mod channel;
pub mod master;
pub mod membership;
pub mod metrics;
pub mod state;
pub mod straggler;
pub mod trainer;
pub mod worker;

/// How worker completion times map to wall-clock behaviour.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PacingMode {
    /// No sleeping: workers stream results as fast as they compute;
    /// runtimes are accounted in virtual time from the cost model.
    Virtual,
    /// Workers sleep `ns_per_unit` nanoseconds per unit of virtual time
    /// before emitting each block, so real arrival order follows the
    /// straggler model.
    RealScaled { ns_per_unit: f64 },
}
