//! Coordinator metrics: per-iteration accounting plus the training
//! report the examples and the e2e bench print. Epoch-aware: every
//! iteration records the scheme epoch it ran under, and the report keeps
//! the full [`SchemeEpoch`] install history.

use crate::transport::WireSnapshot;
use crate::util::stats::RunningStats;

/// One GD iteration's accounting.
#[derive(Debug, Clone)]
pub struct IterMetrics {
    pub iter: usize,
    /// Scheme epoch this iteration ran under.
    pub epoch: usize,
    /// Code rows (= the epoch's `N`) in the scheme this iteration ran
    /// under — shrinks/grows as the elastic pool re-dimensions.
    pub workers: usize,
    /// Eq. (2) overall runtime under the sampled `T` (model time units).
    pub virtual_runtime: f64,
    /// Wall-clock nanoseconds spent in the iteration (compute + decode).
    pub wall_ns: u64,
    /// Wall-clock nanoseconds the master spent decoding.
    pub decode_ns: u64,
    /// Blocks decoded (= non-empty blocks of the partition).
    pub blocks_decoded: usize,
    /// Coded contributions that arrived after their block was already
    /// decoded (pure overhead under the partial-straggler model).
    pub late_contributions: usize,
    /// Contributions dropped before they could mix into a decode:
    /// encoded under a superseded scheme epoch, stamped with an id↔row
    /// binding that no longer matches the live roster, or stamped with
    /// another job's id (multi-job pools route by job, so this is a
    /// misrouted/forged-codeword backstop).
    pub stale_epoch_contributions: usize,
    /// Gradient L2 norm (diagnostic).
    pub grad_norm: f64,
    /// Blocks applied from a semi-async least-squares approximate
    /// decode this iteration (0 in fully-exact mode).
    pub approx_blocks: usize,
    /// Streamed rotation-part contributions folded into decodes this
    /// iteration (0 when partial-straggler streaming is off).
    pub partial_contributions: usize,
    /// Blocks completed part-wise — every rotation part decoded and
    /// accumulated — rather than from whole contributions.
    pub partial_blocks: usize,
    /// Queued virtual time this iteration's broadcast waited behind
    /// in-flight work from other jobs (0 when rounds are serialized):
    /// the max over rows of the backlog depth priced into dispatch.
    pub queue_wait: f64,
}

/// One installed coding scheme (the trainer hot-swaps these mid-run).
#[derive(Debug, Clone)]
pub struct SchemeEpoch {
    pub epoch: usize,
    /// Iteration before which the scheme was installed (0 for the
    /// initial scheme).
    pub installed_at_iter: usize,
    /// The partition's block sizes `x_0..x_{N-1}`.
    pub block_sizes: Vec<usize>,
    /// Estimated shifted-exp parameters that triggered the re-solve
    /// (None for the initial scheme, manual installs, and fits from a
    /// non-exponential family — see `family`).
    pub estimated_mu: Option<f64>,
    pub estimated_t0: Option<f64>,
    /// `E[T]` under the fit behind this install — defined for **every**
    /// family, unlike the shifted-exp parameter hints above.
    pub estimated_mean: Option<f64>,
    /// Straggler-model family the re-solve used (`"shifted-exp"`,
    /// `"weibull"`, `"empirical"`; None for the initial scheme and
    /// manual installs).
    pub family: Option<String>,
    /// Relative parameter drift measured at install time.
    pub drift: f64,
}

/// One membership change in an elastic run (joins, leaves, and the
/// epoch swaps that re-dimensioned the scheme around them).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MembershipRecord {
    /// Iteration before which the change was applied/observed.
    pub iter: usize,
    pub event: MembershipEvent,
}

/// What changed in the worker pool.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MembershipEvent {
    /// A worker (stable id) was registered; it waits for the next
    /// epoch rebind before receiving work.
    Join { worker: usize },
    /// A worker (stable id) left: clean drain or fatal failure.
    Leave { worker: usize },
    /// The scheme was re-dimensioned from `from_n` to `to_n` rows and
    /// installed as scheme epoch `epoch`.
    Redimension { from_n: usize, to_n: usize, epoch: usize },
}

/// Full training run report.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub iters: Vec<IterMetrics>,
    /// `(iteration, loss)` at each evaluation point.
    pub loss_curve: Vec<(usize, f32)>,
    /// Every scheme epoch installed during the run, in order.
    pub scheme_epochs: Vec<SchemeEpoch>,
    /// Worker-pool membership changes, in order (empty for static runs).
    pub membership: Vec<MembershipRecord>,
    /// Decode-vector cache statistics.
    pub decode_cache_hits: u64,
    pub decode_cache_misses: u64,
    /// Wire-buffer pool statistics (the pool-wide freelist shared by
    /// every job on the pool: a `hit` is a coded-block buffer served
    /// without allocating, a `miss` allocated a fresh one, `returned`
    /// counts buffers recycled after decode/drop). In steady state
    /// misses plateau at the in-flight high-water mark and every
    /// further block is a hit — zero per-block heap allocation.
    pub wire_pool_hits: u64,
    pub wire_pool_misses: u64,
    pub wire_pool_returned: u64,
    /// Wire-level transport counters (bytes/frames each way, missed
    /// heartbeat intervals, expired leases), snapshotted at pool
    /// finish. All zeros for the in-process transport — there is no
    /// wire — and pool-wide (the transport is shared) otherwise.
    pub wire: WireSnapshot,
    /// Semi-async decode accounting: blocks applied from a
    /// least-squares approximate decode, how many of those were later
    /// reconciled against the exact quorum, how many were discarded
    /// before it landed (epoch swap / shutdown), and the largest
    /// tracked error bound among the approximations applied.
    pub approx_decodes: usize,
    pub approx_reconciled: usize,
    pub approx_discarded: usize,
    pub max_approx_bound: f64,
    /// Blocks completed part-wise across the run (partial-straggler
    /// streaming): the run-level ledger for the per-iteration
    /// [`IterMetrics::partial_blocks`] counter, bumped beside the
    /// master's outcome handoff exactly like the approx counters.
    pub partial_decodes: usize,
    /// Workers that failed permanently during the run.
    pub failed_workers: Vec<usize>,
}

impl TrainReport {
    pub fn steps(&self) -> usize {
        self.iters.len()
    }

    /// Number of scheme epochs the run used (≥ 1 once training started).
    pub fn epochs(&self) -> usize {
        self.scheme_epochs.len().max(1)
    }

    pub fn virtual_runtime_stats(&self) -> RunningStats {
        self.virtual_runtime_stats_in(0, usize::MAX)
    }

    /// Virtual-runtime stats over iterations in `[from_iter, to_iter)` —
    /// the before/after-shift comparison the adaptive experiments report.
    pub fn virtual_runtime_stats_in(&self, from_iter: usize, to_iter: usize) -> RunningStats {
        let mut s = RunningStats::new();
        for m in &self.iters {
            if m.iter >= from_iter && m.iter < to_iter {
                s.push(m.virtual_runtime);
            }
        }
        s
    }

    pub fn wall_ns_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        for m in &self.iters {
            s.push(m.wall_ns as f64);
        }
        s
    }

    pub fn decode_ns_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        for m in &self.iters {
            s.push(m.decode_ns as f64);
        }
        s
    }

    /// Total stale-epoch contributions dropped across the run.
    pub fn stale_epoch_total(&self) -> usize {
        self.iters.iter().map(|m| m.stale_epoch_contributions).sum()
    }

    /// Total blocks applied via semi-async approximate decode.
    pub fn approx_blocks_total(&self) -> usize {
        self.iters.iter().map(|m| m.approx_blocks).sum()
    }

    /// Total blocks completed part-wise (streamed rotation parts).
    pub fn partial_blocks_total(&self) -> usize {
        self.iters.iter().map(|m| m.partial_blocks).sum()
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.loss_curve.last().map(|&(_, l)| l)
    }

    pub fn first_loss(&self) -> Option<f32> {
        self.loss_curve.first().map(|&(_, l)| l)
    }

    /// Render the loss curve as a compact text block (for EXPERIMENTS.md).
    pub fn render_loss_curve(&self) -> String {
        let mut out = String::from("iter,loss\n");
        for (it, loss) in &self.loss_curve {
            out.push_str(&format!("{it},{loss:.6}\n"));
        }
        out
    }

    /// Render the scheme-epoch history as a compact text block.
    pub fn render_epochs(&self) -> String {
        let mut out =
            String::from("epoch,installed_at,levels_used,est_mu,est_t0,est_mean,family,drift\n");
        for e in &self.scheme_epochs {
            let levels = e.block_sizes.iter().filter(|&&c| c > 0).count();
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{:.3}\n",
                e.epoch,
                e.installed_at_iter,
                levels,
                e.estimated_mu.map(|v| format!("{v:.3e}")).unwrap_or_else(|| "-".into()),
                e.estimated_t0.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
                e.estimated_mean.map(|v| format!("{v:.1}")).unwrap_or_else(|| "-".into()),
                e.family.as_deref().unwrap_or("-"),
                e.drift,
            ));
        }
        out
    }

    /// Render the membership log as a compact text block.
    pub fn render_membership(&self) -> String {
        let mut out = String::from("iter,event\n");
        for m in &self.membership {
            let ev = match &m.event {
                MembershipEvent::Join { worker } => format!("join worker {worker}"),
                MembershipEvent::Leave { worker } => format!("leave worker {worker}"),
                MembershipEvent::Redimension { from_n, to_n, epoch } => {
                    format!("redimension N {from_n}→{to_n} (epoch {epoch})")
                }
            };
            out.push_str(&format!("{},{ev}\n", m.iter));
        }
        out
    }

    /// One-line summary. The trailing wire segment (frames/bytes each
    /// way, missed heartbeat intervals, expired leases) only appears
    /// for runs that actually crossed a wire.
    pub fn summary(&self) -> String {
        let mut out = format!(
            "steps={} epochs={} E[virt]={:.1} wall/iter={} decode/iter={} loss {}→{} cache {}/{} hit pool {}/{} hit",
            self.steps(),
            self.epochs(),
            self.virtual_runtime_stats().mean(),
            crate::bench_harness::fmt_ns(self.wall_ns_stats().mean()),
            crate::bench_harness::fmt_ns(self.decode_ns_stats().mean()),
            self.first_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
            self.final_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
            self.decode_cache_hits,
            self.decode_cache_hits + self.decode_cache_misses,
            self.wire_pool_hits,
            self.wire_pool_hits + self.wire_pool_misses,
        );
        if self.partial_decodes > 0 {
            out.push_str(&format!(" partial-decodes {}", self.partial_decodes));
        }
        if self.wire != WireSnapshot::default() {
            out.push_str(&format!(
                " wire tx {}f/{}B rx {}f/{}B hb-miss {} lease-exp {}",
                self.wire.frames_sent,
                self.wire.bytes_sent,
                self.wire.frames_recv,
                self.wire.bytes_recv,
                self.wire.heartbeats_missed,
                self.wire.leases_expired,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metric(iter: usize, epoch: usize, vr: f64) -> IterMetrics {
        IterMetrics {
            iter,
            epoch,
            workers: 4,
            virtual_runtime: vr,
            wall_ns: 1000,
            decode_ns: 100,
            blocks_decoded: 2,
            late_contributions: 0,
            stale_epoch_contributions: 0,
            grad_norm: 1.0,
            approx_blocks: 0,
            partial_contributions: 0,
            partial_blocks: 0,
            queue_wait: 0.0,
        }
    }

    #[test]
    fn report_aggregates() {
        let mut r = TrainReport::default();
        for i in 0..3 {
            r.iters.push(metric(i, 0, (i + 1) as f64));
        }
        r.loss_curve.push((0, 5.0));
        r.loss_curve.push((2, 1.0));
        assert_eq!(r.steps(), 3);
        assert!((r.virtual_runtime_stats().mean() - 2.0).abs() < 1e-12);
        assert_eq!(r.final_loss(), Some(1.0));
        assert!(r.summary().contains("steps=3"));
        assert!(r.render_loss_curve().contains("2,1.000000"));
    }

    #[test]
    fn ranged_stats_slice_the_run() {
        let mut r = TrainReport::default();
        for i in 0..10 {
            let vr = if i < 5 { 1.0 } else { 3.0 };
            r.iters.push(metric(i, usize::from(i >= 5), vr));
        }
        assert!((r.virtual_runtime_stats_in(0, 5).mean() - 1.0).abs() < 1e-12);
        assert!((r.virtual_runtime_stats_in(5, 10).mean() - 3.0).abs() < 1e-12);
        assert_eq!(r.virtual_runtime_stats_in(5, 10).count(), 5);
    }

    #[test]
    fn epoch_history_renders() {
        let mut r = TrainReport::default();
        assert_eq!(r.epochs(), 1); // implicit initial epoch
        r.scheme_epochs.push(SchemeEpoch {
            epoch: 0,
            installed_at_iter: 0,
            block_sizes: vec![4, 0, 2],
            estimated_mu: None,
            estimated_t0: None,
            estimated_mean: None,
            family: None,
            drift: 0.0,
        });
        r.scheme_epochs.push(SchemeEpoch {
            epoch: 1,
            installed_at_iter: 40,
            block_sizes: vec![2, 2, 2],
            estimated_mu: Some(1e-3),
            estimated_t0: Some(49.0),
            estimated_mean: Some(1049.0),
            family: Some("shifted-exp".into()),
            drift: 0.8,
        });
        assert_eq!(r.epochs(), 2);
        let txt = r.render_epochs();
        assert!(txt.contains("1,40,3"), "{txt}");
        assert!(txt.contains("1.000e-3") || txt.contains("1.000e-03"), "{txt}");
        assert!(txt.contains("shifted-exp"), "{txt}");
        assert!(txt.contains("1049.0"), "{txt}");
    }

    #[test]
    fn membership_log_renders() {
        let mut r = TrainReport::default();
        r.membership.push(MembershipRecord {
            iter: 12,
            event: MembershipEvent::Leave { worker: 3 },
        });
        r.membership.push(MembershipRecord {
            iter: 12,
            event: MembershipEvent::Redimension { from_n: 8, to_n: 7, epoch: 2 },
        });
        r.membership.push(MembershipRecord {
            iter: 30,
            event: MembershipEvent::Join { worker: 8 },
        });
        let txt = r.render_membership();
        assert!(txt.contains("12,leave worker 3"), "{txt}");
        assert!(txt.contains("redimension N 8→7 (epoch 2)"), "{txt}");
        assert!(txt.contains("30,join worker 8"), "{txt}");
    }
}
