//! Coordinator metrics: per-iteration accounting plus the training
//! report the examples and the e2e bench print.

use crate::util::stats::RunningStats;

/// One GD iteration's accounting.
#[derive(Debug, Clone)]
pub struct IterMetrics {
    pub iter: usize,
    /// Eq. (2) overall runtime under the sampled `T` (model time units).
    pub virtual_runtime: f64,
    /// Wall-clock nanoseconds spent in the iteration (compute + decode).
    pub wall_ns: u64,
    /// Wall-clock nanoseconds the master spent decoding.
    pub decode_ns: u64,
    /// Blocks decoded (= non-empty blocks of the partition).
    pub blocks_decoded: usize,
    /// Coded contributions that arrived after their block was already
    /// decoded (pure overhead under the partial-straggler model).
    pub late_contributions: usize,
    /// Gradient L2 norm (diagnostic).
    pub grad_norm: f64,
}

/// Full training run report.
#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub iters: Vec<IterMetrics>,
    /// `(iteration, loss)` at each evaluation point.
    pub loss_curve: Vec<(usize, f32)>,
    /// Decode-vector cache statistics.
    pub decode_cache_hits: u64,
    pub decode_cache_misses: u64,
    /// Workers that failed permanently during the run.
    pub failed_workers: Vec<usize>,
}

impl TrainReport {
    pub fn steps(&self) -> usize {
        self.iters.len()
    }

    pub fn virtual_runtime_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        for m in &self.iters {
            s.push(m.virtual_runtime);
        }
        s
    }

    pub fn wall_ns_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        for m in &self.iters {
            s.push(m.wall_ns as f64);
        }
        s
    }

    pub fn decode_ns_stats(&self) -> RunningStats {
        let mut s = RunningStats::new();
        for m in &self.iters {
            s.push(m.decode_ns as f64);
        }
        s
    }

    pub fn final_loss(&self) -> Option<f32> {
        self.loss_curve.last().map(|&(_, l)| l)
    }

    pub fn first_loss(&self) -> Option<f32> {
        self.loss_curve.first().map(|&(_, l)| l)
    }

    /// Render the loss curve as a compact text block (for EXPERIMENTS.md).
    pub fn render_loss_curve(&self) -> String {
        let mut out = String::from("iter,loss\n");
        for (it, loss) in &self.loss_curve {
            out.push_str(&format!("{it},{loss:.6}\n"));
        }
        out
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "steps={} E[virt]={:.1} wall/iter={} decode/iter={} loss {}→{} cache {}/{} hit",
            self.steps(),
            self.virtual_runtime_stats().mean(),
            crate::bench_harness::fmt_ns(self.wall_ns_stats().mean()),
            crate::bench_harness::fmt_ns(self.decode_ns_stats().mean()),
            self.first_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
            self.final_loss().map(|l| format!("{l:.3}")).unwrap_or_else(|| "-".into()),
            self.decode_cache_hits,
            self.decode_cache_hits + self.decode_cache_misses,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_aggregates() {
        let mut r = TrainReport::default();
        for i in 0..3 {
            r.iters.push(IterMetrics {
                iter: i,
                virtual_runtime: (i + 1) as f64,
                wall_ns: 1000,
                decode_ns: 100,
                blocks_decoded: 2,
                late_contributions: 0,
                grad_norm: 1.0,
            });
        }
        r.loss_curve.push((0, 5.0));
        r.loss_curve.push((2, 1.0));
        assert_eq!(r.steps(), 3);
        assert!((r.virtual_runtime_stats().mean() - 2.0).abs() < 1e-12);
        assert_eq!(r.final_loss(), Some(1.0));
        assert!(r.summary().contains("steps=3"));
        assert!(r.render_loss_curve().contains("2,1.000000"));
    }
}
