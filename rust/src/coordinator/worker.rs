//! Worker thread: sequentially computes, encodes and streams coded
//! gradient blocks for each GD iteration.
//!
//! The coding scheme is **not** baked in at spawn: it arrives with every
//! [`WorkerTask::Compute`] as an epoch-versioned `Arc`, so the master can
//! install a re-optimized scheme between iterations (adaptive coding
//! engine) without respawning the thread. The per-scheme derived state
//! (held subsets, block ranges) is cached and refreshed only when the
//! epoch changes.

use std::sync::mpsc::{Receiver, Sender};

use crate::coordinator::channel::{BlockContribution, WorkerEvent, WorkerTask};
use crate::coordinator::straggler::block_completion_stamps;
use crate::coordinator::PacingMode;
use crate::optimizer::blocks::BlockRange;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::ExecutorFactory;

/// Everything a worker thread needs (moved into the thread at spawn).
pub struct WorkerContext {
    pub id: usize,
    pub spec: ProblemSpec,
    pub factory: ExecutorFactory,
    pub tasks: Receiver<WorkerTask>,
    pub events: Sender<WorkerEvent>,
    pub pacing: PacingMode,
}

/// Worker main loop. Returns when the task channel closes or a Shutdown
/// arrives; executor errors are reported to the master as
/// [`WorkerEvent::Failed`] (the coded scheme tolerates them like any
/// other straggler, up to each block's redundancy).
pub fn run(ctx: WorkerContext) {
    let WorkerContext { id, spec, factory, tasks, events, pacing } = ctx;
    let mut exec = match factory(id) {
        Ok(e) => e,
        Err(e) => {
            let _ = events.send(WorkerEvent::Failed {
                worker: id,
                iter: 0,
                reason: format!("executor init: {e}"),
                fatal: true, // the thread exits: gone for the whole run
            });
            return;
        }
    };
    // Per-scheme derived state, keyed by epoch (schemes swap rarely, so
    // recomputing only on an epoch change keeps the hot path identical to
    // the static design).
    let mut cached: Option<(usize, Vec<usize>, Vec<BlockRange>)> = None;

    while let Ok(task) = tasks.recv() {
        let (iter, epoch, scheme, theta, cycle_time) = match task {
            WorkerTask::Compute { iter, epoch, scheme, theta, cycle_time } => {
                (iter, epoch, scheme, theta, cycle_time)
            }
            WorkerTask::Shutdown => return,
        };
        if cached.as_ref().map(|(e, _, _)| *e) != Some(epoch) {
            cached = Some((epoch, scheme.worker_subsets(id).to_vec(), scheme.ranges()));
        }
        let (_, held, ranges) = cached.as_ref().unwrap();
        // Real compute: partial gradients of every held subset (batched
        // so the executor can stage θ once — §Perf opt 2). Encoding
        // consumes the f32 results directly (§Perf opt 1).
        let grads = match exec.grad_shards(&theta, held) {
            Ok(g) => g,
            Err(e) => {
                let _ = events.send(WorkerEvent::Failed {
                    worker: id,
                    iter,
                    reason: format!("grad_shards: {e}"),
                    fatal: false, // the loop continues: next task may succeed
                });
                continue;
            }
        };
        // Stream coded blocks in coordinate order (the paper's sequential
        // emission), stamping each with its virtual completion time.
        let stamps = block_completion_stamps(&spec, &scheme, cycle_time);
        let mut elapsed_virtual = 0.0f64;
        for (block_idx, r) in ranges.iter().enumerate() {
            let coded = scheme.encode_block_range_f32(id, r, &grads);
            if let PacingMode::RealScaled { ns_per_unit } = pacing {
                let wait_units = stamps[block_idx] - elapsed_virtual;
                elapsed_virtual = stamps[block_idx];
                let ns = (wait_units * ns_per_unit).max(0.0);
                if ns > 0.0 {
                    std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
                }
            }
            if events
                .send(WorkerEvent::Block(BlockContribution {
                    iter,
                    epoch,
                    worker: id,
                    block_idx,
                    virtual_time: stamps[block_idx],
                    coded,
                }))
                .is_err()
            {
                return; // master gone
            }
        }
    }
}
