//! Worker thread: sequentially computes, encodes and streams coded
//! gradient blocks for each GD iteration.
//!
//! Neither the coding scheme nor the worker's code-row position is baked
//! in at spawn: both arrive with every [`WorkerTask::Compute`] as
//! epoch-versioned state, so the master can install a re-optimized —
//! even re-**dimensioned** (different `N`) — scheme between iterations
//! without respawning the thread. The thread's stable id is only used
//! for control-plane events; all encoding is done as the task's `row`.
//! The per-scheme derived state (held subsets, block ranges, backing
//! dataset shards) is cached and refreshed only when the epoch changes.
//!
//! Lifecycle: the thread announces itself with [`WorkerEvent::Joined`]
//! once its executor is up, and acknowledges a [`WorkerTask::Drain`]
//! with [`WorkerEvent::Left`] before exiting (the elastic pool's clean
//! departure path).

use std::sync::mpsc::{Receiver, Sender};

use crate::coordinator::channel::{BlockContribution, WorkerEvent, WorkerTask};
use crate::coordinator::straggler::block_completion_stamps_unit;
use crate::coordinator::PacingMode;
use crate::optimizer::blocks::BlockRange;
use crate::runtime::ExecutorFactory;

/// Everything a worker thread needs (moved into the thread at spawn).
pub struct WorkerContext {
    /// Stable worker id (thread identity; not a code row).
    pub id: usize,
    pub factory: ExecutorFactory,
    pub tasks: Receiver<WorkerTask>,
    pub events: Sender<WorkerEvent>,
    pub pacing: PacingMode,
}

/// Per-epoch derived state, recomputed only on an epoch change.
struct EpochState {
    epoch: usize,
    row: usize,
    /// Subsets held as the epoch's `row` (nested allocation prefix).
    held: Vec<usize>,
    ranges: Vec<BlockRange>,
    /// Dataset shards backing each held subset.
    held_shards: Vec<Vec<usize>>,
}

/// Worker main loop. Returns when the task channel closes or a
/// Shutdown/Drain arrives; executor errors are reported to the master as
/// [`WorkerEvent::Failed`] (the coded scheme tolerates them like any
/// other straggler, up to each block's redundancy).
pub fn run(ctx: WorkerContext) {
    let WorkerContext { id, factory, tasks, events, pacing } = ctx;
    let mut exec = match factory(id) {
        Ok(e) => e,
        Err(e) => {
            let _ = events.send(WorkerEvent::Failed {
                worker: id,
                iter: 0,
                reason: format!("executor init: {e}"),
                fatal: true, // the thread exits: gone for the whole run
            });
            return;
        }
    };
    // Ready to be bound to a code row (joins wait for the next epoch).
    if events.send(WorkerEvent::Joined { worker: id }).is_err() {
        return; // master gone
    }
    let dim = exec.dim();
    // Schemes swap rarely, so recomputing derived state only on an epoch
    // change keeps the hot path identical to the static design.
    let mut cached: Option<EpochState> = None;

    while let Ok(task) = tasks.recv() {
        let (iter, epoch, row, scheme, shards, theta, cycle_time, unit_work) = match task {
            WorkerTask::Compute {
                iter,
                epoch,
                row,
                scheme,
                shards,
                theta,
                cycle_time,
                unit_work,
            } => (iter, epoch, row, scheme, shards, theta, cycle_time, unit_work),
            WorkerTask::Drain => {
                let _ = events.send(WorkerEvent::Left { worker: id });
                return;
            }
            WorkerTask::Shutdown => return,
        };
        if cached.as_ref().map(|c| (c.epoch, c.row)) != Some((epoch, row)) {
            let held = scheme.worker_subsets(row).to_vec();
            let held_shards: Vec<Vec<usize>> = held
                .iter()
                .map(|&k| shards.get(k).cloned().unwrap_or_default())
                .collect();
            cached = Some(EpochState {
                epoch,
                row,
                held,
                ranges: scheme.ranges(),
                held_shards,
            });
        }
        let state = cached.as_ref().unwrap();
        // Real compute: partial gradients of every dataset shard backing
        // a held subset, batched so the executor can stage θ once
        // (§Perf opt 2). Encoding consumes the f32 results directly
        // (§Perf opt 1).
        let flat: Vec<usize> =
            state.held_shards.iter().flat_map(|s| s.iter().copied()).collect();
        let flat_grads = match exec.grad_shards(&theta, &flat) {
            Ok(g) => g,
            Err(e) => {
                let _ = events.send(WorkerEvent::Failed {
                    worker: id,
                    iter,
                    reason: format!("grad_shards: {e}"),
                    fatal: false, // the loop continues: next task may succeed
                });
                continue;
            }
        };
        // Re-assemble per held subset: a subset's gradient is the sum
        // over its backing shards (after an elastic re-dimension a
        // subset can back several shards, or — when N grew past the
        // dataset's shard count — none, contributing exact zeros).
        let mut grads: Vec<Vec<f32>> = Vec::with_capacity(state.held.len());
        let mut flat_iter = flat_grads.into_iter();
        for backing in &state.held_shards {
            match backing.len() {
                0 => grads.push(vec![0.0f32; dim]),
                1 => grads.push(flat_iter.next().unwrap()),
                _ => {
                    let mut acc = flat_iter.next().unwrap();
                    for _ in 1..backing.len() {
                        let g = flat_iter.next().unwrap();
                        for (a, v) in acc.iter_mut().zip(g.iter()) {
                            *a += v;
                        }
                    }
                    grads.push(acc);
                }
            }
        }
        // Stream coded blocks in coordinate order (the paper's sequential
        // emission), stamping each with its virtual completion time.
        let stamps = block_completion_stamps_unit(unit_work, &scheme, cycle_time);
        let mut elapsed_virtual = 0.0f64;
        for (block_idx, r) in state.ranges.iter().enumerate() {
            let coded = scheme.encode_block_range_f32(row, r, &grads);
            if let PacingMode::RealScaled { ns_per_unit } = pacing {
                let wait_units = stamps[block_idx] - elapsed_virtual;
                elapsed_virtual = stamps[block_idx];
                let ns = (wait_units * ns_per_unit).max(0.0);
                if ns > 0.0 {
                    std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
                }
            }
            if events
                .send(WorkerEvent::Block(BlockContribution {
                    iter,
                    epoch,
                    worker: id,
                    row,
                    block_idx,
                    virtual_time: stamps[block_idx],
                    coded,
                }))
                .is_err()
            {
                return; // master gone
            }
        }
    }
}
