//! Worker thread: sequentially computes, encodes and streams coded
//! gradient blocks for each GD iteration.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;

use crate::coding::scheme::CodingScheme;
use crate::coordinator::channel::{BlockContribution, WorkerEvent, WorkerTask};
use crate::coordinator::straggler::block_completion_stamps;
use crate::coordinator::PacingMode;
use crate::optimizer::runtime_model::ProblemSpec;
use crate::runtime::ExecutorFactory;

/// Everything a worker thread needs (moved into the thread at spawn).
pub struct WorkerContext {
    pub id: usize,
    pub spec: ProblemSpec,
    pub scheme: Arc<CodingScheme>,
    pub factory: ExecutorFactory,
    pub tasks: Receiver<WorkerTask>,
    pub events: Sender<WorkerEvent>,
    pub pacing: PacingMode,
}

/// Worker main loop. Returns when the task channel closes or a Shutdown
/// arrives; executor errors are reported to the master as
/// [`WorkerEvent::Failed`] (the coded scheme tolerates them like any
/// other straggler, up to each block's redundancy).
pub fn run(ctx: WorkerContext) {
    let WorkerContext { id, spec, scheme, factory, tasks, events, pacing } = ctx;
    let mut exec = match factory(id) {
        Ok(e) => e,
        Err(e) => {
            let _ = events.send(WorkerEvent::Failed {
                worker: id,
                iter: 0,
                reason: format!("executor init: {e}"),
            });
            return;
        }
    };
    let held = scheme.worker_subsets(id).to_vec();
    let ranges = scheme.ranges();

    while let Ok(task) = tasks.recv() {
        let (iter, theta, cycle_time) = match task {
            WorkerTask::Compute { iter, theta, cycle_time } => (iter, theta, cycle_time),
            WorkerTask::Shutdown => return,
        };
        // Real compute: partial gradients of every held subset (batched
        // so the executor can stage θ once — §Perf opt 2). Encoding
        // consumes the f32 results directly (§Perf opt 1).
        let grads = match exec.grad_shards(&theta, &held) {
            Ok(g) => g,
            Err(e) => {
                let _ = events.send(WorkerEvent::Failed {
                    worker: id,
                    iter,
                    reason: format!("grad_shards: {e}"),
                });
                continue;
            }
        };
        // Stream coded blocks in coordinate order (the paper's sequential
        // emission), stamping each with its virtual completion time.
        let stamps = block_completion_stamps(&spec, &scheme, cycle_time);
        let mut elapsed_virtual = 0.0f64;
        for (block_idx, r) in ranges.iter().enumerate() {
            let coded = scheme.encode_block_range_f32(id, r, &grads);
            if let PacingMode::RealScaled { ns_per_unit } = pacing {
                let wait_units = stamps[block_idx] - elapsed_virtual;
                elapsed_virtual = stamps[block_idx];
                let ns = (wait_units * ns_per_unit).max(0.0);
                if ns > 0.0 {
                    std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
                }
            }
            if events
                .send(WorkerEvent::Block(BlockContribution {
                    iter,
                    worker: id,
                    block_idx,
                    virtual_time: stamps[block_idx],
                    coded,
                }))
                .is_err()
            {
                return; // master gone
            }
        }
    }
}
