//! Worker thread: sequentially computes, encodes and streams coded
//! gradient blocks — **multiplexing tasks from every job** that shares
//! the pool.
//!
//! Nothing job- or scheme-specific is baked in at spawn: every
//! [`WorkerTask::Compute`] carries its job id, its epoch-versioned
//! scheme, the worker's code-row binding for that epoch, and the
//! executor factory of the job — so one thread serves any number of
//! jobs, each with its own dataset and model. Per-job state is built
//! lazily and cached:
//!
//! * an **executor** per job, constructed from the task's factory the
//!   first time the thread sees the job. A build failure on a worker
//!   that already serves some *other* job successfully is a per-tenant
//!   problem: it is remembered and re-reported per task as a transient
//!   [`WorkerEvent::Failed`], so that job's coded redundancy absorbs
//!   the worker like any straggler while the healthy jobs keep
//!   computing. A build failure on a worker that has **never** built
//!   any executor is presumed a broken host (missing artifacts, bad
//!   runtime): the thread reports a **fatal** failure and exits, so the
//!   pool accounts it as departed and an elastic pool re-dimensions
//!   around it instead of burning a redundancy slot forever;
//! * the **per-epoch derived state** per job (held subsets, block
//!   ranges, backing dataset shards), refreshed only when the job's
//!   epoch or row binding changes.
//!
//! Tasks are processed strictly in arrival order (per-worker FIFO): the
//! pool interleaves jobs at broadcast granularity, and a worker finishes
//! one job's iteration before starting the next task.
//!
//! Lifecycle: the thread announces itself with [`WorkerEvent::Joined`]
//! right after spawn, and acknowledges a [`WorkerTask::Drain`] with
//! [`WorkerEvent::Left`] before exiting (the elastic pool's clean
//! departure path).

use std::collections::HashMap;
use std::sync::mpsc::Receiver;

use crate::coordinator::channel::{
    BlockContribution, JobId, PartialBlockContribution, WorkerEvent, WorkerTask,
};
use crate::coordinator::straggler::block_completion_stamps_unit;
use crate::coordinator::PacingMode;
use crate::optimizer::blocks::BlockRange;
use crate::runtime::GradExecutor;
use crate::transport::EventSender;
use crate::util::buffers::BufferPool;

/// Everything a worker thread needs (moved into the thread at spawn).
pub struct WorkerContext {
    /// Stable worker id (thread identity; not a code row).
    pub id: usize,
    pub tasks: Receiver<WorkerTask>,
    /// Event path back to the master — the in-process channel, or a
    /// framed socket on a remote peer ([`crate::transport`]); send
    /// semantics are identical either way.
    pub events: EventSender,
    pub pacing: PacingMode,
    /// Pool-wide freelist for coded wire buffers: the worker takes one
    /// per block before encoding, ownership travels with the
    /// [`BlockContribution`], and the master returns it after decode —
    /// zero per-block allocation once warm (see [`crate::coordinator`]'s
    /// data-plane notes).
    pub wire_pool: BufferPool,
}

/// Per-(job, epoch) derived state, recomputed only on an epoch change.
struct EpochState {
    epoch: usize,
    row: usize,
    /// Subsets held as the epoch's `row` (nested allocation prefix).
    held: Vec<usize>,
    ranges: Vec<BlockRange>,
    /// Dataset shards backing each held subset.
    held_shards: Vec<Vec<usize>>,
}

/// Per-job state a worker caches between tasks. `exec` stays `None`
/// once the job's executor failed to build (the failure is re-reported
/// per task instead of retrying an expensive broken constructor).
struct JobState {
    exec: Option<Box<dyn GradExecutor>>,
    init_attempted: bool,
    epoch: Option<EpochState>,
}

/// Worker main loop. Returns when the task channel closes or a
/// Shutdown/Drain arrives; executor errors are reported to the master as
/// [`WorkerEvent::Failed`] (the coded scheme tolerates them like any
/// other straggler, up to each block's redundancy).
pub fn run(ctx: WorkerContext) {
    let WorkerContext { id, tasks, events, pacing, wire_pool } = ctx;
    // Thread-local scratch freelist for the per-subset gradient
    // re-assembly buffers (zero-backed subsets and nothing else allocate
    // from it; executor outputs are moved in directly). Unshared, so no
    // lock contention with other workers.
    let scratch = BufferPool::new(32);
    // Ready to be bound to a code row (joins wait for the next epoch).
    if events.send(WorkerEvent::Joined { worker: id }).is_err() {
        return; // master gone
    }
    // Jobs are few and long-lived; per-job executors and per-epoch
    // derived state are cached so the hot path stays identical to the
    // single-job design.
    let mut jobs: HashMap<JobId, JobState> = HashMap::new();
    // Whether this thread has ever successfully built an executor —
    // distinguishes a per-job dependency problem (transient, the job
    // codes around this worker) from a globally broken host (fatal,
    // the thread exits and the pool drops the worker at the next
    // rebind).
    let mut ever_built = false;

    while let Ok(task) = tasks.recv() {
        let (job, iter, epoch, row, scheme, shards, theta, factory, cycle_time, unit_work, slices, parts) =
            match task {
                WorkerTask::Compute {
                    job,
                    iter,
                    epoch,
                    row,
                    scheme,
                    shards,
                    theta,
                    factory,
                    cycle_time,
                    unit_work,
                    slices,
                    parts,
                } => {
                    (job, iter, epoch, row, scheme, shards, theta, factory, cycle_time, unit_work, slices, parts)
                }
                WorkerTask::Drain => {
                    let _ = events.send(WorkerEvent::Left { worker: id });
                    return;
                }
                WorkerTask::Shutdown => return,
            };
        let state = jobs
            .entry(job)
            .or_insert_with(|| JobState { exec: None, init_attempted: false, epoch: None });
        if !state.init_attempted {
            // First task for this job: build its executor in-thread.
            state.init_attempted = true;
            match factory(id) {
                Ok(e) => {
                    ever_built = true;
                    state.exec = Some(e);
                }
                Err(e) => {
                    // No executor has ever come up on this thread: the
                    // host itself is broken — exit fatally so the pool
                    // stops binding rows to it. With at least one
                    // working executor it is a per-job problem: stay,
                    // and let that job code around us.
                    let fatal = !ever_built;
                    let _ = events.send(WorkerEvent::Failed {
                        worker: id,
                        job,
                        iter,
                        reason: format!("executor init: {e}"),
                        fatal,
                    });
                    if fatal {
                        return;
                    }
                    continue;
                }
            }
        }
        // Refresh per-epoch derived state only when the job's epoch or
        // row binding changed; `insert` hands the fresh state back, so
        // the hot path reads one binding either way (no unwrap).
        let epoch_state = match &mut state.epoch {
            Some(c) if (c.epoch, c.row) == (epoch, row) => c,
            stale => {
                let held = scheme.worker_subsets(row).to_vec();
                let held_shards: Vec<Vec<usize>> = held
                    .iter()
                    .map(|&k| shards.get(k).cloned().unwrap_or_default())
                    .collect();
                stale.insert(EpochState {
                    epoch,
                    row,
                    held,
                    ranges: scheme.ranges(),
                    held_shards,
                })
            }
        };
        let Some(exec) = state.exec.as_mut() else {
            // Executor known-broken for this job: re-report (the first
            // failure above already covered this task's iteration; later
            // tasks need their own report).
            let _ = events.send(WorkerEvent::Failed {
                worker: id,
                job,
                iter,
                reason: "executor init failed earlier for this job".into(),
                fatal: false,
            });
            continue;
        };
        let dim = exec.dim();
        // Sample-granular dispatch ([`SliceMap`] present): the held
        // subsets' gradients come from arbitrary sample spans instead of
        // dataset shards, and with `parts > 1` the spans are streamed as
        // rotated per-stride coded deltas. `slices: None` keeps the
        // shard-granular path below bit-for-bit.
        let mut span_grads: Option<Vec<Vec<f32>>> = None;
        if let Some(slice_map) = slices.as_deref() {
            if !exec.supports_spans() {
                // Transient: this job's executor is shard-only, so the
                // job codes around this worker for the iteration exactly
                // like any other straggler.
                let _ = events.send(WorkerEvent::Failed {
                    worker: id,
                    job,
                    iter,
                    reason: "sample-granular task but executor lacks span support".into(),
                    fatal: false,
                });
                continue;
            }
            let parts = parts.max(1);
            // Spans of every held subset, in held (support) order — the
            // order the encode kernel consumes gradients in. A subset
            // past the map's end contributes exact zeros (defensive:
            // the master sizes the map to the roster before dispatch).
            let spans: Vec<(usize, usize)> =
                epoch_state.held.iter().map(|&k| slice_map.get(k).copied().unwrap_or((0, 0))).collect();
            if parts > 1 {
                // Rotated partial streaming: at stride `j` this row
                // computes the **part-indexed** sub-span
                // `part = (row + j) mod parts` of every held subset,
                // encodes it per block as a coded *delta*, and emits it
                // under that part index. Indexing the data by the part
                // (not the stride) is load-bearing: every holder of a
                // subset covers the *same* samples for part `p`, so a
                // part quorum decodes exactly from ANY `N − s` rows —
                // while the rotation makes each part index complete
                // first at a different rotation of the fleet (see
                // [`PartialBlockContribution`]).
                let stamps = block_completion_stamps_unit(unit_work, &scheme, cycle_time);
                let round_virtual = stamps.last().copied().unwrap_or(0.0);
                let samples_total: usize = spans.iter().map(|&(lo, hi)| hi - lo).sum();
                let mut samples_done = 0usize;
                let mut elapsed_virtual = 0.0f64;
                'strides: for j in 0..parts {
                    // The sub-span this stride covers is indexed by the
                    // rotated part, not by `j`: rows disagree on *when*
                    // they compute part `p` but must agree on *which*
                    // samples it holds, or part-wise decode breaks.
                    let part = (row + j) % parts;
                    // Per-subset delta buffers for this stride, from the
                    // thread-local scratch freelist (zero-filled so a
                    // degenerate empty sub-span contributes exact zeros).
                    let mut deltas: Vec<Vec<f32>> = Vec::with_capacity(spans.len());
                    for &(lo, hi) in &spans {
                        let w = hi - lo;
                        let (sub_lo, sub_hi) =
                            (lo + w * part / parts, lo + w * (part + 1) / parts);
                        let mut d = scratch.take(dim);
                        d.resize(dim, 0.0);
                        if sub_lo < sub_hi {
                            if let Err(e) = exec.grad_span_into(&theta, sub_lo, sub_hi, &mut d) {
                                scratch.put(d);
                                for d in deltas {
                                    scratch.put(d);
                                }
                                let _ = events.send(WorkerEvent::Failed {
                                    worker: id,
                                    job,
                                    iter,
                                    reason: format!("grad_span_into: {e}"),
                                    fatal: false, // delivered strides stay decodable
                                });
                                break 'strides;
                            }
                        }
                        samples_done += sub_hi - sub_lo;
                        deltas.push(d);
                    }
                    for (block_idx, r) in epoch_state.ranges.iter().enumerate() {
                        let mut coded = wire_pool.take(r.len());
                        scheme.encode_block_range_f32_into(row, r, &deltas, &mut coded);
                        // One stride is a 1/parts compression of the
                        // whole-round emission schedule, offset by the
                        // `j` full strides before it.
                        let stamp = (round_virtual * j as f64 + stamps[block_idx]) / parts as f64;
                        if let PacingMode::RealScaled { ns_per_unit } = pacing {
                            let wait_units = stamp - elapsed_virtual;
                            elapsed_virtual = stamp;
                            let ns = (wait_units * ns_per_unit).max(0.0);
                            if ns > 0.0 {
                                std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
                            }
                        }
                        let sent = events.send(WorkerEvent::Partial(PartialBlockContribution {
                            job,
                            iter,
                            epoch,
                            worker: id,
                            row,
                            block_idx,
                            part,
                            parts,
                            samples_done,
                            samples_total,
                            virtual_time: stamp,
                            coded,
                        }));
                        if let Err(undelivered) = sent {
                            // Master gone mid-stream: reclaim the pooled
                            // wire buffer (and this stride's scratch)
                            // before exiting, mirroring the whole-block
                            // send-failure path below.
                            if let WorkerEvent::Partial(c) = undelivered.0 {
                                wire_pool.put(c.coded);
                            }
                            for d in deltas {
                                scratch.put(d);
                            }
                            return;
                        }
                    }
                    for d in deltas {
                        scratch.put(d);
                    }
                }
                continue;
            }
            // parts == 1: exact sample loads without streaming — the
            // whole-span gradients feed the ordinary whole-block
            // emission loop below, leaving the master's collect path
            // untouched.
            let mut grads: Vec<Vec<f32>> = Vec::with_capacity(spans.len());
            let mut span_failed = false;
            for &(lo, hi) in &spans {
                let mut g = scratch.take(dim);
                g.resize(dim, 0.0);
                if lo < hi {
                    if let Err(e) = exec.grad_span_into(&theta, lo, hi, &mut g) {
                        scratch.put(g);
                        let _ = events.send(WorkerEvent::Failed {
                            worker: id,
                            job,
                            iter,
                            reason: format!("grad_span_into: {e}"),
                            fatal: false,
                        });
                        span_failed = true;
                        break;
                    }
                }
                grads.push(g);
            }
            if span_failed {
                for g in grads {
                    scratch.put(g);
                }
                continue;
            }
            span_grads = Some(grads);
        }
        let grads: Vec<Vec<f32>> = match span_grads {
            Some(g) => g,
            None => {
                // Real compute: partial gradients of every dataset shard
                // backing a held subset, batched so the executor can
                // stage θ once (§Perf opt 2). Encoding consumes the f32
                // results directly (§Perf opt 1).
                let flat: Vec<usize> =
                    epoch_state.held_shards.iter().flat_map(|s| s.iter().copied()).collect();
                let flat_grads = match exec.grad_shards(&theta, &flat) {
                    Ok(g) => g,
                    Err(e) => {
                        let _ = events.send(WorkerEvent::Failed {
                            worker: id,
                            job,
                            iter,
                            reason: format!("grad_shards: {e}"),
                            fatal: false, // the loop continues: next task may succeed
                        });
                        continue;
                    }
                };
                // Re-assemble per held subset: a subset's gradient is the
                // sum over its backing shards (after an elastic
                // re-dimension a subset can back several shards, or —
                // when N grew past the dataset's shard count — none,
                // contributing exact zeros).
                let mut grads: Vec<Vec<f32>> = Vec::with_capacity(epoch_state.held.len());
                let mut flat_iter = flat_grads.into_iter();
                // lint: allow(panic_hygiene) — grad_shards yields one gradient per requested shard
                let mut next_grad = || flat_iter.next().expect("grad_shards shorted the request");
                for backing in &epoch_state.held_shards {
                    match backing.len() {
                        0 => {
                            // Recycled scratch buffer, zero-filled to the
                            // model dimension (take() hands it back
                            // cleared).
                            let mut z = scratch.take(dim);
                            z.resize(dim, 0.0);
                            grads.push(z);
                        }
                        1 => grads.push(next_grad()),
                        _ => {
                            let mut acc = next_grad();
                            for _ in 1..backing.len() {
                                let g = next_grad();
                                for (a, v) in acc.iter_mut().zip(g.iter()) {
                                    *a += v;
                                }
                            }
                            grads.push(acc);
                        }
                    }
                }
                grads
            }
        };
        // Stream coded blocks in coordinate order (the paper's sequential
        // emission), stamping each with its virtual completion time.
        let stamps = block_completion_stamps_unit(unit_work, &scheme, cycle_time);
        let mut elapsed_virtual = 0.0f64;
        for (block_idx, r) in epoch_state.ranges.iter().enumerate() {
            // Pooled wire buffer; the master owns it from the send on
            // and recycles it once the block decodes (or is dropped).
            let mut coded = wire_pool.take(r.len());
            scheme.encode_block_range_f32_into(row, r, &grads, &mut coded);
            if let PacingMode::RealScaled { ns_per_unit } = pacing {
                let wait_units = stamps[block_idx] - elapsed_virtual;
                elapsed_virtual = stamps[block_idx];
                let ns = (wait_units * ns_per_unit).max(0.0);
                if ns > 0.0 {
                    std::thread::sleep(std::time::Duration::from_nanos(ns as u64));
                }
            }
            let sent = events.send(WorkerEvent::Block(BlockContribution {
                job,
                iter,
                epoch,
                worker: id,
                row,
                block_idx,
                virtual_time: stamps[block_idx],
                coded,
            }));
            if let Err(undelivered) = sent {
                // Master gone mid-iteration: reclaim the pooled wire
                // buffer from the undeliverable event before exiting,
                // so a shared pool's freelist stays balanced instead of
                // leaking one buffer per worker on shutdown.
                if let WorkerEvent::Block(c) = undelivered.0 {
                    wire_pool.put(c.coded);
                }
                return;
            }
        }
        // Subset-assembly buffers go back to the thread-local scratch
        // freelist for the next iteration's zero-backed subsets.
        for g in grads {
            scratch.put(g);
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::{mpsc, Arc};

    use super::*;
    use crate::coding::scheme::CodingScheme;
    use crate::coordinator::channel::{ShardMap, SliceMap};
    use crate::data::synthetic;
    use crate::optimizer::blocks::BlockPartition;
    use crate::runtime::host::HostModel;
    use crate::runtime::host_factory;
    use crate::util::rng::Rng;

    /// Regression (found by bcgc-lint's buffer-ownership audit): when
    /// the master hangs up mid-iteration, the pooled wire buffer
    /// travelling inside the undeliverable `Block` event must flow
    /// back to the pool — previously it leaked with the dropped
    /// `SendError`, draining a shared freelist by one buffer per
    /// worker on every shutdown race.
    #[test]
    fn failed_block_send_recycles_the_wire_buffer() {
        let n = 3;
        let (dataset, theta) = synthetic::linear_regression(4, 24, n, 0.0, 7).unwrap();
        let blocks = BlockPartition::single_level(n, 0, 4);
        let mut rng = Rng::new(42);
        let scheme = Arc::new(CodingScheme::new(blocks, &mut rng).unwrap());
        let shards: Arc<ShardMap> = Arc::new((0..n).map(|k| vec![k]).collect());
        let factory = host_factory(dataset, HostModel::LinearRegression);
        let wire_pool = BufferPool::new(8);
        let (task_tx, task_rx) = mpsc::channel();
        let (event_tx, event_rx) = mpsc::channel();
        let ctx = WorkerContext {
            id: 0,
            tasks: task_rx,
            events: EventSender::InProc(event_tx),
            pacing: PacingMode::Virtual,
            wire_pool: wire_pool.clone(),
        };
        let handle = std::thread::spawn(move || run(ctx));
        match event_rx.recv().expect("worker announces itself") {
            WorkerEvent::Joined { worker } => assert_eq!(worker, 0),
            _ => panic!("expected Joined first"),
        }
        // Hang up before the worker can deliver its block, then hand
        // it one compute task: the Block send fails and the worker
        // exits — the buffer it took must already be back in the pool.
        drop(event_rx);
        task_tx
            .send(WorkerTask::Compute {
                job: 0,
                iter: 0,
                epoch: 0,
                row: 0,
                scheme,
                shards,
                theta: Arc::new(theta),
                factory,
                cycle_time: 1.0,
                unit_work: 1.0,
                slices: None,
                parts: 1,
            })
            .expect("worker is alive and waiting");
        drop(task_tx);
        handle.join().expect("worker exits cleanly");
        let stats = wire_pool.stats();
        assert_eq!(stats.returned, 1, "wire buffer not recycled on send failure");
        assert_eq!(wire_pool.free_len(), 1);
    }

    /// The streaming path's per-part coded deltas must (a) rotate the
    /// part index by the worker's row, (b) report monotone sample
    /// progress, and (c) sum to the whole-block contribution the same
    /// slice map produces without streaming — code linearity is what
    /// lets the master decode each part independently and accumulate.
    #[test]
    fn rotated_partial_deltas_sum_to_the_whole_block() {
        let n = 4;
        let (dataset, theta) = synthetic::linear_regression(4, 24, n, 0.0, 7).unwrap();
        let blocks = BlockPartition::single_level(n, 1, 4);
        let mut rng = Rng::new(9);
        let scheme = Arc::new(CodingScheme::new(blocks, &mut rng).unwrap());
        let shards: Arc<ShardMap> = Arc::new((0..n).map(|k| vec![k]).collect());
        let slices: Arc<SliceMap> = Arc::new(vec![(0, 6), (6, 12), (12, 18), (18, 24)]);
        let factory = host_factory(dataset, HostModel::LinearRegression);
        let wire_pool = BufferPool::new(8);
        let (task_tx, task_rx) = mpsc::channel();
        let (event_tx, event_rx) = mpsc::channel();
        let ctx = WorkerContext {
            id: 1,
            tasks: task_rx,
            events: EventSender::InProc(event_tx),
            pacing: PacingMode::Virtual,
            wire_pool,
        };
        let handle = std::thread::spawn(move || run(ctx));
        let theta = Arc::new(theta);
        // Same slice map twice: streamed in 3 rotation parts, then as a
        // single whole-block contribution.
        for (iter, parts) in [(0usize, 3usize), (1, 1)] {
            task_tx
                .send(WorkerTask::Compute {
                    job: 0,
                    iter,
                    epoch: 0,
                    row: 1,
                    scheme: scheme.clone(),
                    shards: shards.clone(),
                    theta: theta.clone(),
                    factory: factory.clone(),
                    cycle_time: 1.0,
                    unit_work: 1.0,
                    slices: Some(slices.clone()),
                    parts,
                })
                .expect("worker is alive and waiting");
        }
        task_tx.send(WorkerTask::Drain).expect("worker is alive");
        let mut partials = Vec::new();
        let mut whole: Option<Vec<f32>> = None;
        loop {
            match event_rx.recv().expect("worker events flow until Left") {
                WorkerEvent::Joined { worker } => assert_eq!(worker, 1),
                WorkerEvent::Partial(p) => partials.push(p),
                WorkerEvent::Block(b) => {
                    assert_eq!(b.iter, 1);
                    whole = Some(b.coded);
                }
                WorkerEvent::Left { .. } => break,
                WorkerEvent::Failed { reason, .. } => panic!("unexpected failure: {reason}"),
            }
        }
        handle.join().expect("worker exits cleanly");
        assert_eq!(partials.len(), 3, "one delta per stride for the single block");
        let whole = whole.expect("parts == 1 emits a whole BlockContribution");
        // Row 1 at (n=4, s=1) holds subsets {1, 2} → spans (6,12) and
        // (12,18): 12 samples streamed in 3 strides of 4.
        let mut last_stamp = f64::NEG_INFINITY;
        for (j, p) in partials.iter().enumerate() {
            assert_eq!(p.part, (1 + j) % 3, "part index rotates by the row");
            assert_eq!(p.parts, 3);
            assert_eq!((p.block_idx, p.row), (0, 1));
            assert_eq!(p.samples_total, 12);
            assert_eq!(p.samples_done, 4 * (j + 1), "monotone sample progress");
            assert!(p.virtual_time > last_stamp, "stamps advance stride by stride");
            last_stamp = p.virtual_time;
        }
        let mut sum = vec![0.0f64; whole.len()];
        for p in &partials {
            assert_eq!(p.coded.len(), whole.len());
            for (s, v) in sum.iter_mut().zip(p.coded.iter()) {
                *s += *v as f64;
            }
        }
        for (s, w) in sum.iter().zip(whole.iter()) {
            assert!(
                (s - *w as f64).abs() <= 1e-4 * (1.0 + w.abs() as f64),
                "per-part deltas must sum to the whole-block codeword: {s} vs {w}"
            );
        }
    }
}
