//! Message types exchanged between master and workers
//! (std `mpsc`; no async runtime is available offline, and the message
//! rates here — `N × blocks` per iteration — don't need one).

use std::sync::Arc;

/// Master → worker.
pub enum WorkerTask {
    /// Compute and stream all coded blocks for one GD iteration.
    Compute {
        iter: usize,
        /// Current model parameters (shared, read-only).
        theta: Arc<Vec<f32>>,
        /// This worker's sampled CPU cycle time `T_n` for the iteration
        /// (drives virtual completion stamps and real pacing).
        cycle_time: f64,
    },
    /// Clean shutdown.
    Shutdown,
}

/// Worker → master: one coded block.
pub struct BlockContribution {
    pub iter: usize,
    pub worker: usize,
    /// Index into the scheme's non-empty block ranges.
    pub block_idx: usize,
    /// Virtual completion time of this block at this worker:
    /// `(M/N)·b·T_n·Σ_{l ≤ block end}(s_l+1)` — Eq. (2)'s inner term.
    pub virtual_time: f64,
    /// The coded partial derivatives for the block's coordinates.
    pub coded: Vec<f64>,
}

/// Worker → master control-plane event.
pub enum WorkerEvent {
    Block(BlockContribution),
    /// The worker failed (executor error, poisoned state…); carries a
    /// description. The master treats it as a permanent straggler.
    Failed { worker: usize, iter: usize, reason: String },
}
