//! Message types exchanged between master and workers
//! (std `mpsc`; no async runtime is available offline, and the message
//! rates here — `N × blocks` per iteration — don't need one).
//!
//! The coding scheme travels *with* each compute task as an
//! epoch-versioned `Arc`, so the master can hot-swap a re-optimized
//! scheme between iterations without respawning worker threads. Workers
//! have a **stable id** for their whole lifetime but are bound to a code
//! **row position** per epoch (the elastic pool re-dimensions `N` on
//! membership change — [`crate::coordinator::membership`]), so each task
//! carries the worker's row for that epoch and every coded block is
//! stamped with both the id and the row it was encoded as. The master
//! drops contributions from superseded epochs exactly like
//! stale-iteration messages (mixing codes across epochs would corrupt
//! the decoded gradient), and drops contributions whose id↔row binding
//! no longer matches the live roster.

use std::sync::Arc;

use crate::coding::scheme::CodingScheme;

/// Dataset shards backing each code subset: `shard_map[k]` lists the
/// dataset shards whose summed gradient is subset `k`'s partial
/// gradient. Identity (`[[0], [1], …]`) while `N` matches the dataset's
/// shard count; after an elastic re-dimension the surviving subsets
/// take over the full dataset (round-robin), so the decoded gradient
/// still covers every sample exactly.
pub type ShardMap = Vec<Vec<usize>>;

/// Master → worker.
pub enum WorkerTask {
    /// Compute and stream all coded blocks for one GD iteration.
    Compute {
        iter: usize,
        /// Scheme epoch this task was issued under (monotone).
        epoch: usize,
        /// The code row this worker is bound to for `epoch`.
        row: usize,
        /// The coding scheme of that epoch.
        scheme: Arc<CodingScheme>,
        /// Subset → dataset shards mapping of that epoch.
        shards: Arc<ShardMap>,
        /// Current model parameters (shared, read-only).
        theta: Arc<Vec<f32>>,
        /// This worker's sampled CPU cycle time `T_n` for the iteration
        /// (drives virtual completion stamps and real pacing).
        cycle_time: f64,
        /// One unit of per-coordinate work, `(M/N)·b` cycles, under the
        /// epoch's `N` (workers must not bake `N` in at spawn).
        unit_work: f64,
    },
    /// Finish up and exit cleanly: acknowledge with
    /// [`WorkerEvent::Left`], then return. Used to drain a worker out
    /// of the elastic pool without killing its thread mid-encode.
    Drain,
    /// Clean shutdown (end of run; no acknowledgment expected).
    Shutdown,
}

/// Worker → master: one coded block.
pub struct BlockContribution {
    pub iter: usize,
    /// Scheme epoch the block was **encoded** under. The master only
    /// mixes contributions of its current epoch into a decode.
    pub epoch: usize,
    /// Stable id of the contributing worker.
    pub worker: usize,
    /// Code row the block was encoded as (the worker's position in
    /// `epoch`'s roster; decode survivor sets are sets of rows).
    pub row: usize,
    /// Index into the scheme's non-empty block ranges.
    pub block_idx: usize,
    /// Virtual completion time of this block at this worker:
    /// `(M/N)·b·T_n·Σ_{l ≤ block end}(s_l+1)` — Eq. (2)'s inner term.
    pub virtual_time: f64,
    /// The coded partial derivatives for the block's coordinates.
    pub coded: Vec<f64>,
}

/// Worker → master control-plane event.
pub enum WorkerEvent {
    Block(BlockContribution),
    /// The worker's executor came up: it is ready to be bound to a code
    /// row at the next epoch rebind. Sent once per thread, right after
    /// successful init (a join is not assigned work until the master
    /// has seen this and swapped in a re-dimensioned epoch).
    Joined { worker: usize },
    /// The worker drained cleanly (in response to [`WorkerTask::Drain`])
    /// and will contribute nothing more — mid-iteration this is
    /// accounted exactly like a fatal straggler.
    Left { worker: usize },
    /// The worker failed and will contribute nothing this iteration;
    /// carries a description. `fatal` distinguishes a dead worker (its
    /// thread exited — executor init failure) from a transient
    /// per-iteration error (the thread keeps serving tasks): only fatal
    /// failures remove the worker from future iterations' quorum
    /// accounting.
    Failed { worker: usize, iter: usize, reason: String, fatal: bool },
}
