//! Message types exchanged between master and workers
//! (std `mpsc`; no async runtime is available offline, and the message
//! rates here — `N × blocks` per iteration — don't need one).
//!
//! The coding scheme travels *with* each compute task as an
//! epoch-versioned `Arc`, so the master can hot-swap a re-optimized
//! scheme between iterations without respawning worker threads. Every
//! coded block is stamped with the epoch it was encoded under; the master
//! drops contributions from superseded epochs exactly like
//! stale-iteration messages (mixing codes across epochs would corrupt the
//! decoded gradient).

use std::sync::Arc;

use crate::coding::scheme::CodingScheme;

/// Master → worker.
pub enum WorkerTask {
    /// Compute and stream all coded blocks for one GD iteration.
    Compute {
        iter: usize,
        /// Scheme epoch this task was issued under (monotone).
        epoch: usize,
        /// The coding scheme of that epoch.
        scheme: Arc<CodingScheme>,
        /// Current model parameters (shared, read-only).
        theta: Arc<Vec<f32>>,
        /// This worker's sampled CPU cycle time `T_n` for the iteration
        /// (drives virtual completion stamps and real pacing).
        cycle_time: f64,
    },
    /// Clean shutdown.
    Shutdown,
}

/// Worker → master: one coded block.
pub struct BlockContribution {
    pub iter: usize,
    /// Scheme epoch the block was **encoded** under. The master only
    /// mixes contributions of its current epoch into a decode.
    pub epoch: usize,
    pub worker: usize,
    /// Index into the scheme's non-empty block ranges.
    pub block_idx: usize,
    /// Virtual completion time of this block at this worker:
    /// `(M/N)·b·T_n·Σ_{l ≤ block end}(s_l+1)` — Eq. (2)'s inner term.
    pub virtual_time: f64,
    /// The coded partial derivatives for the block's coordinates.
    pub coded: Vec<f64>,
}

/// Worker → master control-plane event.
pub enum WorkerEvent {
    Block(BlockContribution),
    /// The worker failed and will contribute nothing this iteration;
    /// carries a description. `fatal` distinguishes a dead worker (its
    /// thread exited — executor init failure) from a transient
    /// per-iteration error (the thread keeps serving tasks): only fatal
    /// failures remove the worker from future iterations' quorum
    /// accounting.
    Failed { worker: usize, iter: usize, reason: String, fatal: bool },
}
