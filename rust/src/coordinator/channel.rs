//! Message types exchanged between the worker pool and its workers.
//!
//! These types define the logical protocol; *how* they move is the
//! transport's business ([`crate::transport`]): in-process lanes carry
//! them over std `mpsc` (no async runtime is needed — the message rates
//! here, `N × blocks` per iteration per job, don't warrant one), and the
//! `tcp` transport serializes the same types into length-prefixed frames
//! ([`crate::transport::codec`]).
//!
//! A single pool of worker threads serves **multiple training jobs**
//! ([`crate::coordinator::pool::WorkerPool`]): every task and every coded
//! block is stamped with the [`JobId`] it belongs to, and the worker loop
//! multiplexes tasks from different jobs over one thread (building one
//! executor per job lazily, from the factory that travels with the task).
//!
//! The coding scheme travels *with* each compute task as an
//! epoch-versioned `Arc`, so a job can hot-swap a re-optimized scheme
//! between iterations without respawning worker threads. Workers have a
//! **stable id** for their whole lifetime but are bound to a code **row
//! position** per scheme epoch (the elastic pool re-dimensions `N` on
//! membership change — [`crate::coordinator::membership`]), so each task
//! carries the worker's row for that epoch and every coded block is
//! stamped with the job, the id and the row it was encoded as. The
//! per-job master drops contributions from superseded epochs exactly like
//! stale-iteration messages (mixing codes across epochs would corrupt the
//! decoded gradient), drops contributions whose id↔row binding no longer
//! matches the live roster, and drops contributions stamped with another
//! job's id the same way (codewords from two jobs must never mix into one
//! decode).

use std::sync::Arc;

use crate::coding::scheme::CodingScheme;
use crate::runtime::ExecutorFactory;

/// Stable identity of a training job within one [`WorkerPool`]
/// (allocated monotonically at submit, never reused).
///
/// [`WorkerPool`]: crate::coordinator::pool::WorkerPool
pub type JobId = usize;

/// Dataset shards backing each code subset: `shard_map[k]` lists the
/// dataset shards whose summed gradient is subset `k`'s partial
/// gradient. Identity (`[[0], [1], …]`) while `N` matches the dataset's
/// shard count; after an elastic re-dimension the surviving subsets
/// take over the full dataset (largest-remainder split), so the decoded
/// gradient still covers every sample exactly.
pub type ShardMap = Vec<Vec<usize>>;

/// Sample-granular refinement of [`ShardMap`]: `slices[k] = (lo, hi)`
/// assigns subset `k` the contiguous sample span `[lo, hi)` of the
/// job's dataset. The spans partition `[0, samples)` in subset order,
/// so the decoded gradient covers every sample exactly once — but the
/// cut points land on arbitrary sample indices instead of shard
/// boundaries, giving a two-speed fleet whose speed ratio is not a
/// multiple of `1/m` its exact proportional load (and a floor of one
/// sample per live subset, so no rostered row ever idles). Requires an
/// executor that can evaluate arbitrary spans
/// ([`crate::runtime::GradExecutor::grad_span_into`]).
pub type SliceMap = Vec<(usize, usize)>;

/// Master → worker.
pub enum WorkerTask {
    /// Compute and stream all coded blocks for one GD iteration of one
    /// job.
    Compute {
        /// The job this task belongs to (workers key executors and
        /// per-epoch derived state by it; contributions echo it back).
        job: JobId,
        iter: usize,
        /// Scheme epoch this task was issued under (monotone per job).
        epoch: usize,
        /// The code row this worker is bound to for `epoch`.
        row: usize,
        /// The coding scheme of that epoch.
        scheme: Arc<CodingScheme>,
        /// Subset → dataset shards mapping of that epoch.
        shards: Arc<ShardMap>,
        /// Current model parameters (shared, read-only).
        theta: Arc<Vec<f32>>,
        /// Builds this job's executor inside the worker thread the first
        /// time the worker sees the job (jobs own their dataset/model,
        /// so one thread holds one executor per job it serves).
        factory: ExecutorFactory,
        /// This worker's sampled CPU cycle time `T_n` for the iteration
        /// (drives virtual completion stamps and real pacing).
        cycle_time: f64,
        /// One unit of per-coordinate work, `(M/N)·b` cycles, under the
        /// epoch's `N` (workers must not bake `N` in at spawn).
        unit_work: f64,
        /// Sample-granular subset spans (see [`SliceMap`]); `None` keeps
        /// the shard-granular path bit-for-bit (the worker never looks
        /// at `parts` then).
        slices: Option<Arc<SliceMap>>,
        /// Rotation parts `P ≥ 1` for partial-straggler streaming: each
        /// held span is split into `P` fixed sub-spans (data parts), and
        /// at stride `j` the worker computes and emits the coded delta
        /// of data part `(row + j) mod P`. The part's samples are the
        /// same from every row — that is what lets any quorum decode a
        /// part — while the rotated *visit order* makes every part
        /// index complete first at some rotation of the fleet, so a
        /// block can decode part-wise the moment any part's quorum
        /// fills. `1` (with `slices` set) is sample-granular load
        /// without streaming.
        parts: usize,
    },
    /// Finish up and exit cleanly: acknowledge with
    /// [`WorkerEvent::Left`], then return. Used to drain a worker out
    /// of the elastic pool without killing its thread mid-encode.
    Drain,
    /// Clean shutdown (end of run; no acknowledgment expected).
    Shutdown,
}

/// Worker → master: one coded block.
pub struct BlockContribution {
    /// The job whose code this block was encoded under. A per-job
    /// master drops contributions stamped with another job's id exactly
    /// like stale-epoch messages.
    pub job: JobId,
    pub iter: usize,
    /// Scheme epoch the block was **encoded** under. The master only
    /// mixes contributions of its current epoch into a decode.
    pub epoch: usize,
    /// Stable id of the contributing worker.
    pub worker: usize,
    /// Code row the block was encoded as (the worker's position in
    /// `epoch`'s roster; decode survivor sets are sets of rows).
    pub row: usize,
    /// Index into the scheme's non-empty block ranges.
    pub block_idx: usize,
    /// Virtual completion time of this block at this worker:
    /// `(M/N)·b·T_n·Σ_{l ≤ block end}(s_l+1)` — Eq. (2)'s inner term.
    pub virtual_time: f64,
    /// The coded partial derivatives for the block's coordinates, in
    /// the **f32 wire format**: workers compute gradients in f32 and
    /// accumulate the coded combination in f64 inside the fused encode
    /// kernel, then round once to f32 for the wire — half the payload
    /// bytes of an f64 wire, with no intermediate-sum precision loss
    /// (the master decodes back in f64). The backing buffer usually
    /// comes from the pool's shared [`BufferPool`] and is recycled by
    /// the master after decode (see the data-plane notes in
    /// [`crate::coordinator`]).
    ///
    /// [`BufferPool`]: crate::util::buffers::BufferPool
    pub coded: Vec<f32>,
}

/// Worker → master: one rotation part of one coded block — the coded
/// **delta** contributed by one fixed `1/parts` sub-span (data part)
/// of every subset the row holds. A part's sub-span is the same from
/// every row, so the code's linearity lets the master decode each part
/// independently, from whichever `N − s` rows delivered it first, and
/// accumulate the results
/// ([`crate::coding::decoder::decode_into_add`]). Summing a row's
/// `parts` deltas for a block reproduces (to f32 rounding) the
/// whole-block [`BlockContribution::coded`] payload.
pub struct PartialBlockContribution {
    /// The job whose code this delta was encoded under (dropped on
    /// mismatch exactly like [`BlockContribution`]).
    pub job: JobId,
    pub iter: usize,
    /// Scheme epoch the delta was encoded under.
    pub epoch: usize,
    /// Stable id of the contributing worker.
    pub worker: usize,
    /// Code row the delta was encoded as.
    pub row: usize,
    /// Index into the scheme's non-empty block ranges.
    pub block_idx: usize,
    /// Data part index in `[0, parts)` this delta covers: sub-span
    /// `part` of each held span. This worker visited it at stride
    /// `j = (part + parts − row%parts) mod parts` of its round.
    pub part: usize,
    /// Total rotation parts `P` the round was dispatched with (the
    /// master rejects a mismatch against its collect state like a
    /// stale epoch).
    pub parts: usize,
    /// Samples of this row's total allocation finished up to and
    /// including this part (monotone within a round; diagnostics and
    /// completion-fraction tracking).
    pub samples_done: usize,
    /// This row's total sample allocation for the round.
    pub samples_total: usize,
    /// Virtual completion time of this delta at this worker.
    pub virtual_time: f64,
    /// Coded delta in the f32 wire format, full block width. Pooled
    /// and recycled under the same ownership contract as
    /// [`BlockContribution::coded`].
    pub coded: Vec<f32>,
}

/// Worker → master control-plane event.
pub enum WorkerEvent {
    Block(BlockContribution),
    /// One rotation part of a coded block (partial-straggler
    /// streaming); see [`PartialBlockContribution`].
    Partial(PartialBlockContribution),
    /// The worker thread came up: it is ready to be bound to a code
    /// row at the next epoch rebind. Sent once per thread, right after
    /// spawn (a join is not assigned work until the pool has seen this
    /// and swapped in re-dimensioned schemes).
    Joined { worker: usize },
    /// The worker drained cleanly (in response to [`WorkerTask::Drain`])
    /// and will contribute nothing more — mid-iteration this is
    /// accounted exactly like a fatal straggler, for every job.
    Left { worker: usize },
    /// The worker failed while serving `job` and contributes nothing to
    /// that job this iteration; carries a description. `fatal`
    /// distinguishes a dead worker (its thread exited — e.g. its very
    /// first executor build failed, a broken host) from a per-job,
    /// per-iteration error — an executor build or gradient failure on a
    /// thread that serves other jobs fine — after which the thread
    /// keeps serving tasks (including the same job's next iterations):
    /// only fatal failures remove the worker from every job's future
    /// quorum accounting.
    Failed { worker: usize, job: JobId, iter: usize, reason: String, fatal: bool },
}
