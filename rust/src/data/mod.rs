//! Datasets: synthetic generators and the `N`-way sample partition of the
//! paper's sample-allocation phase.

pub mod partition;
pub mod synthetic;

use std::ops::Range;

/// An in-memory supervised dataset, row-major `f32` (the dtype of the AOT
/// artifacts), pre-partitioned into `N` contiguous shards `D_1..D_N`.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Feature dimension `d`.
    pub features: usize,
    /// Target dimension (1 for regression, #classes one-hot for
    /// classification).
    pub targets: usize,
    /// `M × d` features.
    pub x: Vec<f32>,
    /// `M × targets` labels.
    pub y: Vec<f32>,
    /// Shard boundaries (length `N`, contiguous, equal size).
    pub shards: Vec<Range<usize>>,
}

impl Dataset {
    /// Total sample count `M`.
    pub fn samples(&self) -> usize {
        self.x.len() / self.features
    }

    /// Number of shards `N`.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Samples per shard `M/N`.
    pub fn shard_size(&self) -> usize {
        self.shards.first().map_or(0, |r| r.end - r.start)
    }

    /// Feature rows of one shard.
    pub fn shard_x(&self, shard: usize) -> &[f32] {
        let r = &self.shards[shard];
        &self.x[r.start * self.features..r.end * self.features]
    }

    /// Label rows of one shard.
    pub fn shard_y(&self, shard: usize) -> &[f32] {
        let r = &self.shards[shard];
        &self.y[r.start * self.targets..r.end * self.targets]
    }
}
