//! The master's dataset partition `D = D_1 ∪ … ∪ D_N` (equal sizes).

use std::ops::Range;

use crate::{Error, Result};

/// Split `samples` into `n` contiguous equal shards. Errors unless
/// `n | samples` (the paper assumes subsets of size exactly `M/N`).
pub fn equal_shards(samples: usize, n: usize) -> Result<Vec<Range<usize>>> {
    if n == 0 {
        return Err(Error::InvalidArgument("need at least one shard".into()));
    }
    if samples % n != 0 {
        return Err(Error::InvalidArgument(format!(
            "samples {samples} not divisible by N={n}"
        )));
    }
    let size = samples / n;
    Ok((0..n).map(|i| i * size..(i + 1) * size).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_tile_the_range() {
        let shards = equal_shards(12, 4).unwrap();
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[0], 0..3);
        assert_eq!(shards[3], 9..12);
        let covered: usize = shards.iter().map(|r| r.end - r.start).sum();
        assert_eq!(covered, 12);
    }

    #[test]
    fn indivisible_rejected() {
        assert!(equal_shards(10, 3).is_err());
        assert!(equal_shards(10, 0).is_err());
    }
}
