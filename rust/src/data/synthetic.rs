//! Synthetic dataset generators for the examples and the end-to-end
//! experiment (the paper trains on a generic dataset; we generate
//! well-conditioned teacher-model data so loss curves are meaningful).

use std::sync::Arc;

use crate::data::{partition, Dataset};
use crate::util::rng::Rng;
use crate::Result;

/// Linear-regression data: `y = X·θ* + ε`, `X ~ N(0, I)/√d`,
/// `ε ~ N(0, noise²)`.
pub fn linear_regression(
    features: usize,
    samples: usize,
    shards: usize,
    noise: f64,
    seed: u64,
) -> Result<(Arc<Dataset>, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    let scale = 1.0 / (features as f64).sqrt();
    let theta_true: Vec<f32> = (0..features).map(|_| rng.normal() as f32).collect();
    let mut x = vec![0.0f32; samples * features];
    let mut y = vec![0.0f32; samples];
    for m in 0..samples {
        let mut dot = 0.0f64;
        for d in 0..features {
            let v = rng.normal() * scale;
            x[m * features + d] = v as f32;
            dot += v * theta_true[d] as f64;
        }
        y[m] = (dot + rng.normal() * noise) as f32;
    }
    let ds = Dataset {
        features,
        targets: 1,
        x,
        y,
        shards: partition::equal_shards(samples, shards)?,
    };
    Ok((Arc::new(ds), theta_true))
}

/// Classification data from a random linear teacher with softmax
/// sampling-free labeling (argmax of logits + Gaussian margin noise),
/// one-hot encoded labels.
pub fn classification(
    features: usize,
    classes: usize,
    samples: usize,
    shards: usize,
    margin_noise: f64,
    seed: u64,
) -> Result<Arc<Dataset>> {
    assert!(classes >= 2);
    let mut rng = Rng::new(seed);
    let scale = 1.0 / (features as f64).sqrt();
    // Teacher weights: features × classes.
    let teacher: Vec<f64> = (0..features * classes).map(|_| rng.normal()).collect();
    let mut x = vec![0.0f32; samples * features];
    let mut y = vec![0.0f32; samples * classes];
    let mut logits = vec![0.0f64; classes];
    for m in 0..samples {
        for d in 0..features {
            x[m * features + d] = (rng.normal() * scale) as f32;
        }
        for (c, logit) in logits.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for d in 0..features {
                acc += x[m * features + d] as f64 * teacher[d * classes + c];
            }
            *logit = acc + rng.normal() * margin_noise;
        }
        let best = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(c, _)| c)
            .unwrap();
        y[m * classes + best] = 1.0;
    }
    let ds = Dataset {
        features,
        targets: classes,
        x,
        y,
        shards: partition::equal_shards(samples, shards)?,
    };
    Ok(Arc::new(ds))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linreg_shapes_and_recoverability() {
        let (ds, theta) = linear_regression(16, 64, 4, 0.0, 7).unwrap();
        assert_eq!(ds.samples(), 64);
        assert_eq!(ds.num_shards(), 4);
        assert_eq!(ds.shard_size(), 16);
        assert_eq!(theta.len(), 16);
        // Noise-free: y must equal X·θ* exactly (up to f32 rounding).
        for m in 0..ds.samples() {
            let mut dot = 0.0f64;
            for d in 0..16 {
                dot += ds.x[m * 16 + d] as f64 * theta[d] as f64;
            }
            assert!((dot - ds.y[m] as f64).abs() < 1e-5);
        }
    }

    #[test]
    fn classification_one_hot() {
        let ds = classification(8, 5, 40, 4, 0.1, 3).unwrap();
        assert_eq!(ds.targets, 5);
        for m in 0..40 {
            let row = &ds.y[m * 5..(m + 1) * 5];
            let ones = row.iter().filter(|&&v| v == 1.0).count();
            let zeros = row.iter().filter(|&&v| v == 0.0).count();
            assert_eq!(ones, 1);
            assert_eq!(zeros, 4);
        }
        // All classes appear with enough samples (teacher is random but
        // 40 samples over 5 classes nearly surely hits each; tolerate 1 miss).
        let mut seen = vec![0usize; 5];
        for m in 0..40 {
            let c = ds.y[m * 5..(m + 1) * 5].iter().position(|&v| v == 1.0).unwrap();
            seen[c] += 1;
        }
        assert!(seen.iter().filter(|&&c| c > 0).count() >= 4, "{seen:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _) = linear_regression(4, 8, 2, 0.1, 42).unwrap();
        let (b, _) = linear_regression(4, 8, 2, 0.1, 42).unwrap();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }
}
