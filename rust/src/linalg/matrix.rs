//! Row-major dense `f64` matrix with the operations the codec needs.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of `f64`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity matrix.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a row-major data vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "data length mismatch");
        Self { rows, cols, data }
    }

    /// Build from nested rows (test convenience).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Borrow row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable row access.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Extract column `j` as a vector.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Matrix–matrix product.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let dst = out.row_mut(i);
                for (d, &b) in dst.iter_mut().zip(orow.iter()) {
                    *d += a * b;
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v.iter()).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// `vᵀ · M` (left multiplication by a row vector).
    pub fn vecmat(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "vecmat shape mismatch");
        let mut out = vec![0.0; self.cols];
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            for (o, &m) in out.iter_mut().zip(self.row(i).iter()) {
                *o += vi * m;
            }
        }
        out
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Select a subset of columns into a new matrix.
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            for (c, &j) in idx.iter().enumerate() {
                out[(i, c)] = self[(i, j)];
            }
        }
        out
    }

    /// Max-abs entry (for tests / conditioning checks).
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(12) {
            write!(f, "  [")?;
            for j in 0..self.cols.min(12) {
                write!(f, "{:9.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}]", if self.cols > 12 { "…" } else { "" })?;
        }
        if self.rows > 12 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matvec_vecmat() {
        let a = Matrix::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.vecmat(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn transpose_select() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let t = a.transpose();
        assert_eq!(t.rows(), 2);
        assert_eq!(t[(0, 2)], 5.0);
        let s = a.select_rows(&[2, 0]);
        assert_eq!(s, Matrix::from_rows(&[&[5.0, 6.0], &[1.0, 2.0]]));
        let c = a.select_cols(&[1]);
        assert_eq!(c, Matrix::from_rows(&[&[2.0], &[4.0], &[6.0]]));
    }
}
