//! LU decomposition with partial pivoting, and the solves built on it.

use super::matrix::Matrix;
use crate::{Error, Result};

/// LU factorization `P·A = L·U` of a square matrix.
pub struct Lu {
    /// Combined L (unit lower, below diagonal) and U (upper) factors.
    lu: Matrix,
    /// Row permutation: `perm[i]` is the original row now in position `i`.
    perm: Vec<usize>,
    /// Sign of the permutation (for determinants).
    sign: f64,
}

impl Lu {
    /// Factor a square matrix. Fails on (numerical) singularity.
    pub fn factor(a: &Matrix) -> Result<Lu> {
        let n = a.rows();
        if a.cols() != n {
            return Err(Error::Linalg(format!("LU of non-square {}x{}", a.rows(), a.cols())));
        }
        let mut lu = a.clone();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // Partial pivot: largest |entry| in column k at/below the diagonal.
            let mut p = k;
            let mut max = lu[(k, k)].abs();
            for i in k + 1..n {
                let v = lu[(i, k)].abs();
                if v > max {
                    max = v;
                    p = i;
                }
            }
            if max < 1e-12 {
                return Err(Error::Linalg(format!("singular matrix at pivot {k} (|pivot|={max:.3e})")));
            }
            if p != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
                perm.swap(k, p);
                sign = -sign;
            }
            let pivot = lu[(k, k)];
            for i in k + 1..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m != 0.0 {
                    for j in k + 1..n {
                        let u = lu[(k, j)];
                        lu[(i, j)] -= m * u;
                    }
                }
            }
        }
        Ok(Lu { lu, perm, sign })
    }

    /// Solve `A·x = b`.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // Forward substitution with permuted b (L has unit diagonal).
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b[self.perm[i]];
            for j in 0..i {
                acc -= self.lu[(i, j)] * y[j];
            }
            y[i] = acc;
        }
        // Back substitution.
        let mut x = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in i + 1..n {
                acc -= self.lu[(i, j)] * x[j];
            }
            x[i] = acc / self.lu[(i, i)];
        }
        x
    }

    /// Solve `xᵀ·A = bᵀ`  (i.e. `Aᵀ·x = b`), used for decode-vector solves.
    pub fn solve_transposed(&self, b: &[f64]) -> Vec<f64> {
        let n = self.lu.rows();
        assert_eq!(b.len(), n);
        // Aᵀ = Uᵀ·Lᵀ·P, so solve Uᵀ·y = b, then Lᵀ·z = y, then x = Pᵀ·z.
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut acc = b[i];
            for j in 0..i {
                acc -= self.lu[(j, i)] * y[j];
            }
            y[i] = acc / self.lu[(i, i)];
        }
        let mut z = vec![0.0; n];
        for i in (0..n).rev() {
            let mut acc = y[i];
            for j in i + 1..n {
                acc -= self.lu[(j, i)] * z[j];
            }
            z[i] = acc;
        }
        let mut x = vec![0.0; n];
        for i in 0..n {
            x[self.perm[i]] = z[i];
        }
        x
    }

    /// Determinant of the factored matrix.
    pub fn det(&self) -> f64 {
        let n = self.lu.rows();
        (0..n).map(|i| self.lu[(i, i)]).product::<f64>() * self.sign
    }
}

/// Convenience: solve `A·x = b` in one call.
pub fn solve(a: &Matrix, b: &[f64]) -> Result<Vec<f64>> {
    Ok(Lu::factor(a)?.solve(b))
}

/// Convenience: inverse (used only in tests / diagnostics).
pub fn inverse(a: &Matrix) -> Result<Matrix> {
    let n = a.rows();
    let lu = Lu::factor(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = lu.solve(&e);
        e[j] = 0.0;
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn solve_known_system() {
        let a = Matrix::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = solve(&a, &[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(Lu::factor(&a).is_err());
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = Rng::new(99);
        for n in [1usize, 2, 3, 5, 8, 16, 33] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    a[(i, j)] = rng.normal();
                }
                a[(i, i)] += 3.0; // keep well-conditioned
            }
            let xtrue: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&xtrue);
            let x = solve(&a, &b).unwrap();
            for (xi, ti) in x.iter().zip(xtrue.iter()) {
                assert!((xi - ti).abs() < 1e-8, "n={n}");
            }
        }
    }

    #[test]
    fn transposed_solve_matches() {
        let mut rng = Rng::new(5);
        let n = 7;
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                a[(i, j)] = rng.normal();
            }
            a[(i, i)] += 4.0;
        }
        let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let lu = Lu::factor(&a).unwrap();
        let x = lu.solve_transposed(&b);
        // xᵀ A should equal bᵀ
        let recon = a.vecmat(&x);
        for (r, want) in recon.iter().zip(b.iter()) {
            assert!((r - want).abs() < 1e-9);
        }
    }

    #[test]
    fn det_and_inverse() {
        let a = Matrix::from_rows(&[&[4.0, 3.0], &[6.0, 3.0]]);
        let lu = Lu::factor(&a).unwrap();
        assert!((lu.det() + 6.0).abs() < 1e-12);
        let inv = inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        for i in 0..2 {
            for j in 0..2 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod[(i, j)] - want).abs() < 1e-12);
            }
        }
    }
}
