//! Tiled, fused multi-source combine kernels — the data plane's inner
//! loops.
//!
//! Both hot directions of the coded data plane are the same primitive: a
//! linear combination `out[i] = Σ_k coef_k · src_k[i]` over a handful of
//! equally-long sources (worker encode combines `s+1` shard gradients;
//! master decode combines `N−s` survivor codewords). The naive
//! implementation makes one full pass over `out` **per source** — for
//! `L` in the millions that is `s+1` read-modify-write sweeps of a
//! multi-megabyte vector per block, all memory traffic. The fused
//! kernels here instead walk the coordinates once in L1-sized tiles: per
//! tile, an on-stack `f64` accumulator is filled from every source while
//! the tile is hot, and the result is written out exactly once. Each
//! source byte is read once, each output byte written once.
//!
//! ## Numeric contract
//!
//! Accumulation is always `f64`, regardless of source/output dtype —
//! this is what lets the wire format carry `f32` (half the bytes) while
//! the decoded gradient stays exact to `f32`-rounding of the *inputs*
//! only, never of the intermediate sums. Within one coordinate, sources
//! are accumulated in slice order, identical to the naive reference, so
//! the fused kernels are bit-compatible with it (the property suite
//! pins this).
//!
//! ## Variants
//!
//! * [`fused_combine_f64`] — `f64` sources → `f64` output (the codec's
//!   generic/unit-test path).
//! * [`fused_combine_f32`] — `f32` sources → `f32` output with `f64`
//!   accumulation (worker encode → wire). Writes via `clear` + `extend`,
//!   so a recycled pool buffer needs no pre-zeroing.
//! * [`fused_combine_into_f64`] — `f32` sources → a caller-owned `f64`
//!   slice (master decode straight into the job's preallocated gradient
//!   — no intermediate vector, no copy).
//! * [`fused_combine_into_f64_auto`] — same, but combines coordinate
//!   tiles on scoped threads once the block is large enough to pay for
//!   them ([`PAR_MIN_LEN`]); small blocks stay single-threaded.
//!
//! Zero coefficients are skipped source-wise (identity and
//! fractional-repetition codes are mostly zeros); skipping only ever
//! drops exact `±0.0` addends.

/// Coordinates per tile: 1024 × 8 B of `f64` accumulator = 8 KiB, small
/// enough to stay L1-resident alongside the source tiles being streamed
/// through.
pub const TILE: usize = 1024;

/// Minimum output length before [`fused_combine_into_f64_auto`] fans the
/// tile sweep out to scoped threads; below this the spawn overhead
/// outweighs the memory-bandwidth win.
pub const PAR_MIN_LEN: usize = 1 << 18;

/// Cap on combine threads (memory-bound work stops scaling long before
/// the core count on big machines).
pub const MAX_COMBINE_THREADS: usize = 8;

/// `acc[i] += coef · src[i]`, 4-wide unrolled so the compiler keeps four
/// independent accumulator lanes in flight.
#[inline]
fn axpy_tile_f64(acc: &mut [f64], coef: f64, src: &[f64]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut a = acc.chunks_exact_mut(4);
    let mut s = src.chunks_exact(4);
    for (a4, s4) in (&mut a).zip(&mut s) {
        a4[0] += coef * s4[0];
        a4[1] += coef * s4[1];
        a4[2] += coef * s4[2];
        a4[3] += coef * s4[3];
    }
    for (o, &v) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *o += coef * v;
    }
}

/// `acc[i] += coef · f64(src[i])` for `f32` sources.
#[inline]
fn axpy_tile_f32(acc: &mut [f64], coef: f64, src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut a = acc.chunks_exact_mut(4);
    let mut s = src.chunks_exact(4);
    for (a4, s4) in (&mut a).zip(&mut s) {
        a4[0] += coef * s4[0] as f64;
        a4[1] += coef * s4[1] as f64;
        a4[2] += coef * s4[2] as f64;
        a4[3] += coef * s4[3] as f64;
    }
    for (o, &v) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *o += coef * v as f64;
    }
}

/// Fused combine, `f64` sources → `f64` output. `out` is overwritten
/// (cleared, then filled with exactly `len` values); every source must
/// be at least `len` long.
pub fn fused_combine_f64(sources: &[(f64, &[f64])], len: usize, out: &mut Vec<f64>) {
    debug_assert!(sources.iter().all(|(_, s)| s.len() >= len));
    out.clear();
    out.reserve(len);
    let mut acc = [0.0f64; TILE];
    let mut start = 0usize;
    while start < len {
        let t = TILE.min(len - start);
        let acc = &mut acc[..t];
        acc.fill(0.0);
        for &(coef, src) in sources {
            if coef == 0.0 {
                continue;
            }
            axpy_tile_f64(acc, coef, &src[start..start + t]);
        }
        out.extend_from_slice(acc);
        start += t;
    }
}

/// Fused combine, `f32` sources → `f32` output with `f64` accumulation
/// (the worker → wire encode). `out` is overwritten via `clear` +
/// `extend`, so recycled pool buffers need no pre-zeroing.
pub fn fused_combine_f32(sources: &[(f64, &[f32])], len: usize, out: &mut Vec<f32>) {
    debug_assert!(sources.iter().all(|(_, s)| s.len() >= len));
    out.clear();
    out.reserve(len);
    let mut acc = [0.0f64; TILE];
    let mut start = 0usize;
    while start < len {
        let t = TILE.min(len - start);
        let acc = &mut acc[..t];
        acc.fill(0.0);
        for &(coef, src) in sources {
            if coef == 0.0 {
                continue;
            }
            axpy_tile_f32(acc, coef, &src[start..start + t]);
        }
        out.extend(acc.iter().map(|&v| v as f32));
        start += t;
    }
}

/// Fused combine, `f32` sources → a caller-owned `f64` slice (the
/// master decode writing straight into the job's gradient). Every
/// source must be at least `out.len()` long; `out` is fully overwritten.
pub fn fused_combine_into_f64(sources: &[(f64, &[f32])], out: &mut [f64]) {
    let len = out.len();
    debug_assert!(sources.iter().all(|(_, s)| s.len() >= len));
    let mut acc = [0.0f64; TILE];
    let mut start = 0usize;
    while start < len {
        let t = TILE.min(len - start);
        let acc = &mut acc[..t];
        acc.fill(0.0);
        for &(coef, src) in sources {
            if coef == 0.0 {
                continue;
            }
            axpy_tile_f32(acc, coef, &src[start..start + t]);
        }
        out[start..start + t].copy_from_slice(acc);
        start += t;
    }
}

/// Fused combine, `f32` sources **added onto** a caller-owned `f64`
/// slice: `out[i] += Σ_k coef_k · src_k[i]`. The streaming collect path
/// folds each per-part decode into the gradient range it shares with
/// the other parts of the block, so the destination must accumulate
/// rather than overwrite. Per-tile accumulation order matches
/// [`fused_combine_into_f64`] exactly; only the final write differs
/// (`+=` instead of `copy_from_slice`).
pub fn fused_combine_into_f64_add(sources: &[(f64, &[f32])], out: &mut [f64]) {
    let len = out.len();
    debug_assert!(sources.iter().all(|(_, s)| s.len() >= len));
    let mut acc = [0.0f64; TILE];
    let mut start = 0usize;
    while start < len {
        let t = TILE.min(len - start);
        let acc = &mut acc[..t];
        acc.fill(0.0);
        for &(coef, src) in sources {
            if coef == 0.0 {
                continue;
            }
            axpy_tile_f32(acc, coef, &src[start..start + t]);
        }
        for (o, &v) in out[start..start + t].iter_mut().zip(acc.iter()) {
            *o += v;
        }
        start += t;
    }
}

/// [`fused_combine_into_f64_add`], parallelized over coordinate tiles
/// with scoped threads once the slice is at least [`PAR_MIN_LEN`] long.
/// Tile-aligned chunking keeps per-coordinate accumulation order
/// unchanged, so the result is bit-identical to the serial kernel.
pub fn fused_combine_into_f64_add_auto(sources: &[(f64, &[f32])], out: &mut [f64]) {
    let len = out.len();
    let threads = if len >= PAR_MIN_LEN {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(MAX_COMBINE_THREADS)
    } else {
        1
    };
    if threads <= 1 {
        return fused_combine_into_f64_add(sources, out);
    }
    let chunk = len.div_ceil(threads).div_ceil(TILE) * TILE;
    std::thread::scope(|scope| {
        for (i, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let off = i * chunk;
            scope.spawn(move || {
                let shifted: Vec<(f64, &[f32])> =
                    sources.iter().map(|&(c, s)| (c, &s[off..off + out_chunk.len()])).collect();
                fused_combine_into_f64_add(&shifted, out_chunk);
            });
        }
    });
}

/// [`fused_combine_into_f64`], parallelized over coordinate tiles with
/// scoped threads once the block is at least [`PAR_MIN_LEN`] long.
/// Chunk boundaries are tile-aligned and per-coordinate accumulation
/// order is unchanged, so the result is bit-identical to the serial
/// kernel.
pub fn fused_combine_into_f64_auto(sources: &[(f64, &[f32])], out: &mut [f64]) {
    let len = out.len();
    let threads = if len >= PAR_MIN_LEN {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1).min(MAX_COMBINE_THREADS)
    } else {
        1
    };
    if threads <= 1 {
        return fused_combine_into_f64(sources, out);
    }
    let chunk = len.div_ceil(threads).div_ceil(TILE) * TILE;
    std::thread::scope(|scope| {
        for (i, out_chunk) in out.chunks_mut(chunk).enumerate() {
            let off = i * chunk;
            scope.spawn(move || {
                let shifted: Vec<(f64, &[f32])> =
                    sources.iter().map(|&(c, s)| (c, &s[off..off + out_chunk.len()])).collect();
                fused_combine_into_f64(&shifted, out_chunk);
            });
        }
    });
}

/// Naive reference combine (`f64`): one full read-modify-write pass
/// over the output **per source** — the support-wise axpy the fused
/// kernels replace. Kept as the property-test oracle and the bench
/// baseline.
pub fn naive_combine_f64(sources: &[(f64, &[f64])], len: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; len];
    for &(coef, src) in sources {
        for (o, &v) in out.iter_mut().zip(src.iter()) {
            *o += coef * v;
        }
    }
    out
}

/// Naive reference combine, `f32` sources with `f64` accumulation.
pub fn naive_combine_f32_to_f64(sources: &[(f64, &[f32])], len: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; len];
    for &(coef, src) in sources {
        for (o, &v) in out.iter_mut().zip(src.iter()) {
            *o += coef * v as f64;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gen_f64(rng: &mut Rng, k: usize, len: usize) -> (Vec<f64>, Vec<Vec<f64>>) {
        let coefs: Vec<f64> =
            (0..k).map(|i| if i == 1 { 0.0 } else { rng.normal() }).collect();
        let srcs: Vec<Vec<f64>> =
            (0..k).map(|_| (0..len).map(|_| rng.normal()).collect()).collect();
        (coefs, srcs)
    }

    /// Awkward boundaries: empty, single element, one short of a tile,
    /// exact tiles, and a ragged multi-tile length.
    const LENS: [usize; 7] = [0, 1, TILE - 1, TILE, TILE + 1, 3 * TILE, 3 * TILE + 7];

    #[test]
    fn fused_f64_matches_naive_bitwise_at_tile_boundaries() {
        let mut rng = Rng::new(17);
        for &len in &LENS {
            let (coefs, srcs) = gen_f64(&mut rng, 4, len);
            let sources: Vec<(f64, &[f64])> =
                coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
            let want = naive_combine_f64(&sources, len);
            let mut got = vec![999.0; 3]; // dirty: must be fully overwritten
            fused_combine_f64(&sources, len, &mut got);
            assert_eq!(got.len(), len);
            // Same per-coordinate accumulation order ⇒ bit-compatible
            // (== also equates ±0.0 from the skipped zero coefficient).
            assert!(got.iter().zip(want.iter()).all(|(a, b)| a == b), "len={len}");
        }
    }

    #[test]
    fn fused_f32_wire_roundtrip_within_f32_rounding() {
        let mut rng = Rng::new(19);
        for &len in &LENS {
            let srcs: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
                .collect();
            let coefs = [1.0, -0.75, rng.normal()];
            let sources: Vec<(f64, &[f32])> =
                coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
            let want = naive_combine_f32_to_f64(&sources, len);
            let mut wire = vec![5.0f32; 7]; // dirty pool buffer
            fused_combine_f32(&sources, len, &mut wire);
            assert_eq!(wire.len(), len);
            for (w, v) in wire.iter().zip(want.iter()) {
                let err = (*w as f64 - v).abs() / (1.0 + v.abs());
                assert!(err < 1e-6, "len={len}: wire {w} vs {v}");
            }
        }
    }

    #[test]
    fn decode_slice_kernel_matches_naive() {
        let mut rng = Rng::new(23);
        for &len in &LENS {
            let srcs: Vec<Vec<f32>> = (0..5)
                .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
                .collect();
            let coefs: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
            let sources: Vec<(f64, &[f32])> =
                coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
            let want = naive_combine_f32_to_f64(&sources, len);
            let mut got = vec![-3.25f64; len]; // dirty gradient slice
            fused_combine_into_f64(&sources, &mut got);
            assert!(got.iter().zip(want.iter()).all(|(a, b)| a == b), "len={len}");
        }
    }

    #[test]
    fn additive_combine_accumulates_on_dirty_slice() {
        let mut rng = Rng::new(53);
        for &len in &LENS {
            let srcs: Vec<Vec<f32>> = (0..4)
                .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
                .collect();
            let coefs = [0.5, 0.0, -1.25, rng.normal()];
            let sources: Vec<(f64, &[f32])> =
                coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
            let base: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            // Reference: overwrite combine, then add the base term.
            let mut combined = vec![0.0f64; len];
            fused_combine_into_f64(&sources, &mut combined);
            let want: Vec<f64> =
                base.iter().zip(combined.iter()).map(|(b, c)| b + c).collect();
            let mut got = base.clone();
            fused_combine_into_f64_add(&sources, &mut got);
            assert!(got.iter().zip(want.iter()).all(|(a, b)| a == b), "len={len}");
        }
    }

    #[test]
    fn parallel_additive_combine_is_bit_identical_to_serial() {
        let mut rng = Rng::new(59);
        let len = PAR_MIN_LEN + 2 * TILE + 5;
        let srcs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let coefs: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
        let sources: Vec<(f64, &[f32])> =
            coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
        let base: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
        let mut serial = base.clone();
        fused_combine_into_f64_add(&sources, &mut serial);
        let mut par = base;
        fused_combine_into_f64_add_auto(&sources, &mut par);
        assert!(par.iter().zip(serial.iter()).all(|(a, b)| a == b));
    }

    #[test]
    fn parallel_combine_is_bit_identical_to_serial() {
        let mut rng = Rng::new(29);
        let len = PAR_MIN_LEN + 4 * TILE + 13;
        let srcs: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let coefs: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
        let sources: Vec<(f64, &[f32])> =
            coefs.iter().copied().zip(srcs.iter().map(|s| s.as_slice())).collect();
        let mut serial = vec![0.0f64; len];
        fused_combine_into_f64(&sources, &mut serial);
        let mut par = vec![7.0f64; len];
        fused_combine_into_f64_auto(&sources, &mut par);
        assert!(par.iter().zip(serial.iter()).all(|(a, b)| a == b));
    }
}
