//! Small dense linear-algebra substrate (no external crates).
//!
//! The gradient-coding codec needs exact construction and inversion of the
//! encoding matrix blocks (Tandon et al.'s Algorithm 1 solves an `s×s`
//! system per row; decoding solves an `(N−s)`-sized system per survivor
//! set), so we implement a row-major [`Matrix`] with LU-based solves.

pub mod kernels;
pub mod lu;
pub mod matrix;

pub use lu::Lu;
pub use matrix::Matrix;
