//! Criterion-lite: a small benchmarking harness (the offline environment
//! has no `criterion`). Provides warmup, repeated sampling, robust
//! summary statistics and paper-style table printing. Every
//! `rust/benches/*.rs` target is a `harness = false` binary built on this.

use std::time::Instant;

use crate::util::stats::{mean, quantile};

/// Timing summary of one benchmark case.
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl Sample {
    pub fn median_ns(&self) -> f64 {
        quantile(&self.samples_ns, 0.5)
    }

    pub fn p10_ns(&self) -> f64 {
        quantile(&self.samples_ns, 0.1)
    }

    pub fn p90_ns(&self) -> f64 {
        quantile(&self.samples_ns, 0.9)
    }

    pub fn mean_ns(&self) -> f64 {
        mean(&self.samples_ns)
    }
}

/// Benchmark runner with warmup and sample-count control.
pub struct Bencher {
    warmup_iters: usize,
    samples: usize,
    min_iters_per_sample: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self { warmup_iters: 3, samples: 20, min_iters_per_sample: 1 }
    }
}

impl Bencher {
    pub fn new(warmup_iters: usize, samples: usize) -> Self {
        Self { warmup_iters, samples, min_iters_per_sample: 1 }
    }

    /// Time `f`, returning per-call nanoseconds over `samples` samples.
    /// `f` must return something observable to defeat dead-code elimination
    /// (use [`black_box`]).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Sample {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples_ns = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let start = Instant::now();
            for _ in 0..self.min_iters_per_sample {
                black_box(f());
            }
            let ns = start.elapsed().as_nanos() as f64 / self.min_iters_per_sample as f64;
            samples_ns.push(ns);
        }
        Sample { name: name.to_string(), samples_ns }
    }
}

/// Opaque value sink (stable `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Fixed-width table printer for paper-style result rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncol {
                line.push_str(&format!("{:<w$} ", cells[i], w = widths[i]));
                line.push_str("| ");
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let sep: String = format!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Standard bench banner so every bench output is self-describing.
pub fn banner(name: &str, what: &str) {
    println!("\n=== {name} ===");
    println!("{what}\n");
}

/// The commit under benchmark: `$GITHUB_SHA` in CI, `git rev-parse HEAD`
/// locally, `"unknown"` outside a checkout.
pub fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.is_empty() {
            return sha;
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .map(|o| String::from_utf8_lossy(&o.stdout).trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".into())
}

/// Stamp a hand-rolled `BENCH_*.json` artifact with `{git_sha, seed,
/// config}` trajectory metadata, injected as a `"meta"` key right after
/// the opening brace. Every bench JSON in this crate is rendered as
/// `"{\n  ..."`; anything else is returned unchanged.
pub fn stamp_bench_meta(json: &str, seed: u64, config: &str) -> String {
    let Some(pos) = json.find('\n') else { return json.to_string() };
    if !json.starts_with('{') {
        return json.to_string();
    }
    let meta = format!(
        "  \"meta\": {{\"git_sha\": \"{}\", \"seed\": {seed}, \"config\": \"{}\"}},\n",
        git_sha().replace('"', ""),
        config.replace('\\', "\\\\").replace('"', "\\\"")
    );
    format!("{}{}{}", &json[..pos + 1], meta, &json[pos + 1..])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_produces_samples() {
        let b = Bencher::new(1, 5);
        let s = b.run("add", || 1 + 1);
        assert_eq!(s.samples_ns.len(), 5);
        assert!(s.median_ns() >= 0.0);
        assert!(s.p10_ns() <= s.p90_ns());
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["scheme", "runtime"]);
        t.row(&["x^(t)".into(), "123".into()]);
        t.row(&["single-BCGC".into(), "456789".into()]);
        let r = t.render();
        assert!(r.contains("scheme"));
        assert!(r.lines().count() == 4);
        // All lines same width.
        let widths: Vec<usize> = r.lines().map(|l| l.len()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{r}");
    }

    #[test]
    fn stamp_bench_meta_injects_trajectory_metadata() {
        let json = "{\n  \"bench\": \"x\",\n  \"v\": 1\n}\n";
        let stamped = stamp_bench_meta(json, 2021, "N=20 pool=weibull");
        assert!(stamped.starts_with("{\n  \"meta\": {\"git_sha\": \""), "{stamped}");
        assert!(stamped.contains("\"seed\": 2021"));
        assert!(stamped.contains("\"config\": \"N=20 pool=weibull\""));
        assert!(stamped.contains("\"bench\": \"x\""));
        assert_eq!(stamped.matches('{').count(), stamped.matches('}').count());
        // Quotes in the config string stay escaped JSON.
        let q = stamp_bench_meta(json, 1, "say \"hi\"");
        assert!(q.contains("\\\"hi\\\""));
        // Non-object payloads pass through untouched.
        assert_eq!(stamp_bench_meta("[1, 2]", 0, "c"), "[1, 2]");
    }

    #[test]
    fn fmt_ns_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(2500.0), "2.50 µs");
        assert_eq!(fmt_ns(3.6e6), "3.60 ms");
        assert_eq!(fmt_ns(2.5e9), "2.500 s");
    }
}
