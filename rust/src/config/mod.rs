//! Experiment configuration substrate: a TOML-subset parser plus typed
//! configs (no `serde`/`toml` crates in the offline environment).
//!
//! Supported syntax: `[section]` headers, `key = value` with string,
//! integer, float, boolean and flat-array values, `#` comments.

pub mod experiment;
pub mod toml_lite;

pub use experiment::{
    AdaptiveSettings, DistConfig, DriftPhase, ElasticSettings, ExperimentConfig, HeteroSettings,
    JobsSettings, PoolSettings,
};
pub use toml_lite::{TomlValue, TomlDoc};
