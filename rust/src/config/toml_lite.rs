//! A small TOML-subset parser sufficient for experiment configs.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    String(String),
    Integer(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Integer(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Integer(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: `section.key → value` (top-level keys live under "").
#[derive(Debug, Clone, Default)]
pub struct TomlDoc {
    map: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    /// Parse a TOML-subset string.
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut map = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(Error::Config(format!("line {}: bad section header", lineno + 1)));
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| {
                Error::Config(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = line[..eq].trim();
            let val = line[eq + 1..].trim();
            if key.is_empty() {
                return Err(Error::Config(format!("line {}: empty key", lineno + 1)));
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            map.insert(full_key, parse_value(val, lineno + 1)?);
        }
        Ok(TomlDoc { map })
    }

    /// Load from a file.
    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.map.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(|v| v.as_str())
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(|v| v.as_i64())
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.as_f64())
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(|v| v.as_bool())
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.map.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    let s = s.trim();
    if s.is_empty() {
        return Err(Error::Config(format!("line {lineno}: empty value")));
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(Error::Config(format!("line {lineno}: unterminated string")));
        }
        return Ok(TomlValue::String(s[1..s.len() - 1].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(Error::Config(format!("line {lineno}: unterminated array")));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, lineno)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Integer(i));
    }
    if let Ok(f) = s.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(Error::Config(format!("line {lineno}: cannot parse value {s:?}")))
}

/// Split on commas that are not nested inside strings or brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = TomlDoc::parse(
            r#"
            # top comment
            name = "fig4a"
            seed = 42
            [sweep]
            mu = 1e-3          # rate
            enabled = true
            ns = [10, 20, 30]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("fig4a"));
        assert_eq!(doc.get_i64("seed"), Some(42));
        assert_eq!(doc.get_f64("sweep.mu"), Some(1e-3));
        assert_eq!(doc.get_bool("sweep.enabled"), Some(true));
        let arr = doc.get("sweep.ns").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_i64(), Some(20));
    }

    #[test]
    fn integer_reads_as_float_too() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get_str("tag"), Some("a#b"));
    }

    #[test]
    fn errors_are_reported() {
        assert!(TomlDoc::parse("key").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("[sec").is_err());
        assert!(TomlDoc::parse("k = \"unterminated").is_err());
        assert!(TomlDoc::parse("k = [1, 2").is_err());
        assert!(TomlDoc::parse("k = nope").is_err());
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("m = [[1, 2], [3, 4]]").unwrap();
        let outer = doc.get("m").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        let row1 = outer[1].as_array().unwrap();
        assert_eq!(row1[0].as_i64(), Some(3));
    }
}
