//! Typed experiment configuration, loadable from a TOML-subset file
//! (see `configs/` for the shipped experiment definitions).
//!
//! Beyond the paper's stationary setting, a config may declare a
//! `[drift]` phase (the straggler distribution shifts mid-run) and an
//! `[adaptive]` policy (the coordinator re-estimates parameters online
//! and re-optimizes the coding scheme) — the inputs to the adaptive
//! coding engine.

use std::path::Path;

use crate::config::toml_lite::TomlDoc;
use crate::coordinator::adaptive::{AdaptiveConfig, HeteroConfig, ResolveStrategy};
use crate::coordinator::pool::ScheduleMode;
use crate::coordinator::straggler::StragglerSchedule;
use crate::coordinator::trainer::ElasticConfig;
use crate::sim::ChurnSchedule;
use crate::distribution::fit::{FamilyPolicy, FitMethod};
use crate::distribution::{
    gamma::Gamma, lognormal::LogNormal, pareto::Pareto, shifted_exp::ShiftedExponential,
    weibull::Weibull, CycleTimeDistribution, Deterministic, TwoPoint,
};
use crate::optimizer::runtime_model::ProblemSpec;
use crate::{Error, Result};

/// A fully-specified experiment: problem dimensions, straggler model,
/// Monte-Carlo budget and seed, plus optional drift/adaptive settings.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub workers: usize,
    pub coords: usize,
    pub samples: usize,
    pub cycles_per_coord: f64,
    pub trials: usize,
    pub seed: u64,
    pub distribution: DistConfig,
    /// Optional mid-run distribution shift (`[drift]` section).
    pub drift: Option<DriftPhase>,
    /// Optional adaptive re-optimization policy (`[adaptive]` section).
    pub adaptive: Option<AdaptiveSettings>,
    /// Optional heterogeneity-aware sensing/actuation (`[hetero]`
    /// section; attaches to the adaptive policy).
    pub hetero: Option<HeteroSettings>,
    /// Optional elastic worker-pool policy (`[elastic]` section).
    pub elastic: Option<ElasticSettings>,
    /// Optional shared-pool settings (`[pool]` section — multi-job runs).
    pub pool: Option<PoolSettings>,
    /// Optional multi-job settings (`[jobs]` section).
    pub jobs: Option<JobsSettings>,
}

/// Straggler-model choice (mirrors `distribution::*`).
#[derive(Debug, Clone)]
pub enum DistConfig {
    ShiftedExp { mu: f64, t0: f64 },
    Weibull { shape: f64, scale: f64, shift: f64 },
    Pareto { alpha: f64, xm: f64 },
    TwoPoint { fast: f64, slow: f64, p_slow: f64 },
    Deterministic { value: f64 },
    LogNormal { mu: f64, sigma: f64, shift: f64 },
    Gamma { shape: f64, scale: f64, shift: f64 },
}

impl DistConfig {
    /// Instantiate the distribution object.
    pub fn build(&self) -> Box<dyn CycleTimeDistribution> {
        match *self {
            DistConfig::ShiftedExp { mu, t0 } => Box::new(ShiftedExponential::new(mu, t0)),
            DistConfig::Weibull { shape, scale, shift } => {
                Box::new(Weibull::new(shape, scale, shift))
            }
            DistConfig::Pareto { alpha, xm } => Box::new(Pareto::new(alpha, xm)),
            DistConfig::TwoPoint { fast, slow, p_slow } => {
                Box::new(TwoPoint::new(fast, slow, p_slow))
            }
            DistConfig::Deterministic { value } => Box::new(Deterministic::new(value)),
            DistConfig::LogNormal { mu, sigma, shift } => {
                Box::new(LogNormal::new(mu, sigma, shift))
            }
            DistConfig::Gamma { shape, scale, shift } => Box::new(Gamma::new(shape, scale, shift)),
        }
    }

    /// Parse a distribution from `{section}.kind` + parameters. Returns
    /// `Ok(None)` when the section declares no `kind`.
    pub fn from_doc_section(doc: &TomlDoc, section: &str) -> Result<Option<Self>> {
        let key = |k: &str| format!("{section}.{k}");
        let need = |k: &str| {
            doc.get_f64(&key(k))
                .ok_or_else(|| Error::Config(format!("[{section}] needs {k}")))
        };
        let Some(kind) = doc.get_str(&key("kind")) else {
            return Ok(None);
        };
        let dist = match kind {
            "shifted_exp" => DistConfig::ShiftedExp {
                mu: need("mu")?,
                t0: doc.get_f64(&key("t0")).unwrap_or(50.0),
            },
            "weibull" => DistConfig::Weibull {
                shape: need("shape")?,
                scale: need("scale")?,
                shift: doc.get_f64(&key("shift")).unwrap_or(0.0),
            },
            "pareto" => DistConfig::Pareto { alpha: need("alpha")?, xm: need("xm")? },
            "two_point" => DistConfig::TwoPoint {
                fast: need("fast")?,
                slow: need("slow")?,
                p_slow: doc.get_f64(&key("p_slow")).unwrap_or(0.5),
            },
            "lognormal" => DistConfig::LogNormal {
                mu: need("mu")?,
                sigma: need("sigma")?,
                shift: doc.get_f64(&key("shift")).unwrap_or(0.0),
            },
            "gamma" => DistConfig::Gamma {
                shape: need("shape")?,
                scale: need("scale")?,
                shift: doc.get_f64(&key("shift")).unwrap_or(0.0),
            },
            "deterministic" => DistConfig::Deterministic { value: need("value")? },
            other => {
                return Err(Error::Config(format!("unknown distribution kind {other:?}")))
            }
        };
        Ok(Some(dist))
    }
}

/// A mid-run distribution shift: from `at_iter` on, cycle times follow
/// `distribution`.
#[derive(Debug, Clone)]
pub struct DriftPhase {
    pub at_iter: usize,
    pub distribution: DistConfig,
}

/// `[adaptive]` section: plain data, buildable into an
/// [`AdaptiveConfig`].
#[derive(Debug, Clone)]
pub struct AdaptiveSettings {
    pub window: usize,
    pub check_every: usize,
    pub cooldown: usize,
    pub min_samples: usize,
    pub drift_threshold: f64,
    /// `"mle"` or `"moments"`.
    pub estimator: String,
    /// `"auto"`, `"shifted-exp"`, `"weibull"` or `"empirical"` — the
    /// straggler-model family the window is fitted to (`auto` = KS-gated
    /// selection with an empirical fallback).
    pub family: String,
    /// `"closed_form"` or `"subgradient"`.
    pub resolve: String,
}

impl AdaptiveSettings {
    pub fn build(&self) -> Result<AdaptiveConfig> {
        if self.window < 2 {
            return Err(Error::Config("adaptive.window must be ≥ 2".into()));
        }
        if self.min_samples < 2 {
            return Err(Error::Config("adaptive.min_samples must be ≥ 2".into()));
        }
        if self.check_every == 0 {
            return Err(Error::Config("adaptive.check_every must be ≥ 1".into()));
        }
        if self.drift_threshold <= 0.0 || !self.drift_threshold.is_finite() {
            return Err(Error::Config("adaptive.drift_threshold must be a positive number".into()));
        }
        let method = match self.estimator.as_str() {
            "mle" => FitMethod::Mle,
            "moments" => FitMethod::Moments,
            other => return Err(Error::Config(format!("unknown estimator {other:?}"))),
        };
        let family = FamilyPolicy::parse(&self.family).ok_or_else(|| {
            Error::Config(format!(
                "unknown straggler family {:?} (auto|shifted-exp|weibull|empirical)",
                self.family
            ))
        })?;
        let strategy = match self.resolve.as_str() {
            "closed_form" => ResolveStrategy::ClosedFormFreq,
            "subgradient" => {
                ResolveStrategy::Subgradient { iters: 1500, playoff_trials: 800 }
            }
            other => return Err(Error::Config(format!("unknown resolve strategy {other:?}"))),
        };
        Ok(AdaptiveConfig {
            window: self.window,
            check_every: self.check_every,
            cooldown: self.cooldown,
            min_samples: self.min_samples,
            drift_threshold: self.drift_threshold,
            method,
            family,
            strategy,
            hetero: None,
        })
    }
}

/// `[hetero]` section: heterogeneity-aware sensing/actuation, attached
/// to the `[adaptive]` policy at build time.
///
/// ```toml
/// [hetero]
/// enabled = true
/// per_worker_window = 128
/// min_worker_samples = 24
/// speed_weighted_shards = true
/// ```
#[derive(Debug, Clone)]
pub struct HeteroSettings {
    pub per_worker_window: usize,
    pub min_worker_samples: usize,
    pub speed_weighted_shards: bool,
}

impl HeteroSettings {
    fn parse(doc: &TomlDoc) -> Result<Option<Self>> {
        if !doc.get_bool("hetero.enabled").unwrap_or(false) {
            return Ok(None);
        }
        let d = HeteroConfig::default();
        let get = |key: &str, default: usize| -> Result<usize> {
            match doc.get_i64(key) {
                None => Ok(default),
                Some(v) if v >= 2 => Ok(v as usize),
                Some(_) => Err(Error::Config(format!("{key} must be ≥ 2"))),
            }
        };
        Ok(Some(Self {
            per_worker_window: get("hetero.per_worker_window", d.per_worker_window)?,
            min_worker_samples: get("hetero.min_worker_samples", d.min_worker_samples)?,
            speed_weighted_shards: doc
                .get_bool("hetero.speed_weighted_shards")
                .unwrap_or(d.speed_weighted_shards),
        }))
    }

    /// The controller's hetero knobs.
    pub fn build(&self) -> HeteroConfig {
        HeteroConfig {
            per_worker_window: self.per_worker_window,
            min_worker_samples: self.min_worker_samples,
            speed_weighted_shards: self.speed_weighted_shards,
        }
    }
}

/// `[elastic]` section: plain data, buildable into the trainer's
/// [`ElasticConfig`] or a simulator [`ChurnSchedule`].
///
/// ```toml
/// [elastic]
/// enabled = true
/// churn_threshold = 1
/// depart_at = [100, 150]   # drain one worker before each iteration
/// arrive_at = [220]        # spawn one worker before the iteration
/// ```
#[derive(Debug, Clone)]
pub struct ElasticSettings {
    /// Membership changes since the last rebind that trigger a
    /// re-dimension.
    pub churn_threshold: usize,
    /// One departure scheduled before each listed iteration.
    pub depart_at: Vec<usize>,
    /// One arrival scheduled before each listed iteration.
    pub arrive_at: Vec<usize>,
}

impl ElasticSettings {
    fn parse(doc: &TomlDoc) -> Result<Option<Self>> {
        if !doc.get_bool("elastic.enabled").unwrap_or(false) {
            return Ok(None);
        }
        let iters_list = |key: &str| -> Result<Vec<usize>> {
            let Some(v) = doc.get(key) else { return Ok(Vec::new()) };
            let arr = v
                .as_array()
                .ok_or_else(|| Error::Config(format!("{key} must be an array")))?;
            let mut out = Vec::with_capacity(arr.len());
            for item in arr {
                let it = item
                    .as_i64()
                    .filter(|&i| i >= 1)
                    .ok_or_else(|| Error::Config(format!("{key} entries must be ≥ 1")))?;
                out.push(it as usize);
            }
            if out.windows(2).any(|w| w[0] > w[1]) {
                return Err(Error::Config(format!("{key} must be in ascending order")));
            }
            Ok(out)
        };
        let threshold = match doc.get_i64("elastic.churn_threshold") {
            None => 1,
            Some(v) if v >= 1 => v as usize,
            Some(_) => {
                return Err(Error::Config("elastic.churn_threshold must be ≥ 1".into()))
            }
        };
        Ok(Some(Self {
            churn_threshold: threshold,
            depart_at: iters_list("elastic.depart_at")?,
            arrive_at: iters_list("elastic.arrive_at")?,
        }))
    }

    /// The threaded trainer's elastic policy.
    pub fn build(&self) -> ElasticConfig {
        ElasticConfig {
            churn_threshold: self.churn_threshold.max(1),
            departures: self.depart_at.iter().map(|&at| (at, 1)).collect(),
            arrivals: self.arrive_at.iter().map(|&at| (at, 1)).collect(),
        }
    }

    /// The virtual-time simulator's churn schedule (events merged in
    /// iteration order).
    pub fn churn_schedule(&self) -> ChurnSchedule {
        let mut events: Vec<(usize, bool)> = self
            .depart_at
            .iter()
            .map(|&at| (at, true))
            .chain(self.arrive_at.iter().map(|&at| (at, false)))
            .collect();
        events.sort_by_key(|&(at, _)| at);
        let mut sched = ChurnSchedule::none();
        for (at, depart) in events {
            sched = if depart { sched.then_depart(at, 1) } else { sched.then_arrive(at, 1) };
        }
        sched
    }
}

/// `[pool]` section: the shared worker fleet a multi-job run submits
/// its jobs to.
///
/// ```toml
/// [pool]
/// workers = 8
/// schedule = "weighted"   # or "round_robin"
/// ```
#[derive(Debug, Clone)]
pub struct PoolSettings {
    /// Worker count (None = the CLI/default decides).
    pub workers: Option<usize>,
    /// Scheduler spelling (validated at parse time).
    pub schedule: String,
}

impl PoolSettings {
    fn parse(doc: &TomlDoc) -> Result<Option<Self>> {
        let workers = match doc.get_i64("pool.workers") {
            None => None,
            Some(v) if v >= 1 => Some(v as usize),
            Some(_) => return Err(Error::Config("pool.workers must be ≥ 1".into())),
        };
        let schedule = doc.get_str("pool.schedule").map(str::to_string);
        if workers.is_none() && schedule.is_none() {
            return Ok(None);
        }
        let schedule = schedule.unwrap_or_else(|| "round_robin".into());
        if ScheduleMode::parse(&schedule).is_none() {
            return Err(Error::Config(format!(
                "pool.schedule {schedule:?}: expected round_robin|weighted"
            )));
        }
        Ok(Some(Self { workers, schedule }))
    }

    /// The parsed scheduler mode (validated at load).
    pub fn schedule_mode(&self) -> ScheduleMode {
        ScheduleMode::parse(&self.schedule).expect("validated at parse time")
    }
}

/// `[jobs]` section: how many concurrent jobs a multi-job run submits
/// and how many steps each runs.
///
/// ```toml
/// [jobs]
/// count = 2
/// steps = [150, 50]   # or a scalar applied to every job
/// ```
#[derive(Debug, Clone)]
pub struct JobsSettings {
    pub count: usize,
    /// Per-job step counts; a scalar in the file is replicated. May be
    /// shorter than `count` (consumers fall back to their default).
    pub steps: Vec<usize>,
}

impl JobsSettings {
    fn parse(doc: &TomlDoc) -> Result<Option<Self>> {
        let Some(count) = doc.get_i64("jobs.count") else {
            if doc.get("jobs.steps").is_some() {
                return Err(Error::Config("[jobs] declares steps but no count".into()));
            }
            return Ok(None);
        };
        let count = usize::try_from(count)
            .ok()
            .filter(|&c| c >= 1)
            .ok_or_else(|| Error::Config("jobs.count must be ≥ 1".into()))?;
        let steps = match doc.get("jobs.steps") {
            None => Vec::new(),
            Some(v) => {
                if let Some(one) = v.as_i64() {
                    if one < 1 {
                        return Err(Error::Config("jobs.steps must be ≥ 1".into()));
                    }
                    vec![one as usize; count]
                } else if let Some(arr) = v.as_array() {
                    let mut out = Vec::with_capacity(arr.len());
                    for item in arr {
                        let s = item
                            .as_i64()
                            .filter(|&s| s >= 1)
                            .ok_or_else(|| {
                                Error::Config("jobs.steps entries must be ≥ 1".into())
                            })?;
                        out.push(s as usize);
                    }
                    if out.len() > count {
                        return Err(Error::Config(format!(
                            "jobs.steps lists {} entries for {count} jobs",
                            out.len()
                        )));
                    }
                    out
                } else {
                    return Err(Error::Config(
                        "jobs.steps must be an integer or an integer array".into(),
                    ));
                }
            }
        };
        Ok(Some(Self { count, steps }))
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            workers: 20,
            coords: 20_000,
            samples: 50,
            cycles_per_coord: 1.0,
            trials: 2000,
            seed: 2021,
            distribution: DistConfig::ShiftedExp { mu: 1e-3, t0: 50.0 },
            drift: None,
            adaptive: None,
            hetero: None,
            elastic: None,
            pool: None,
            jobs: None,
        }
    }
}

impl ExperimentConfig {
    /// Parse from a TOML-subset document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = doc.get_str("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get_i64("workers") {
            cfg.workers = usize::try_from(v)
                .map_err(|_| Error::Config("workers must be positive".into()))?;
        }
        if let Some(v) = doc.get_i64("coords") {
            cfg.coords =
                usize::try_from(v).map_err(|_| Error::Config("coords must be positive".into()))?;
        }
        if let Some(v) = doc.get_i64("samples") {
            cfg.samples = usize::try_from(v)
                .map_err(|_| Error::Config("samples must be positive".into()))?;
        }
        if let Some(v) = doc.get_f64("cycles_per_coord") {
            cfg.cycles_per_coord = v;
        }
        if let Some(v) = doc.get_i64("trials") {
            cfg.trials =
                usize::try_from(v).map_err(|_| Error::Config("trials must be positive".into()))?;
        }
        if let Some(v) = doc.get_i64("seed") {
            cfg.seed = v as u64;
        }
        if let Some(d) = DistConfig::from_doc_section(doc, "distribution")? {
            cfg.distribution = d;
        }
        cfg.drift = match (doc.get_i64("drift.at_iter"), DistConfig::from_doc_section(doc, "drift")?)
        {
            (None, None) => None,
            (Some(at), Some(distribution)) => {
                let at_iter = usize::try_from(at)
                    .ok()
                    .filter(|&v| v >= 1)
                    .ok_or_else(|| Error::Config("drift.at_iter must be ≥ 1".into()))?;
                Some(DriftPhase { at_iter, distribution })
            }
            (Some(_), None) => return Err(Error::Config("[drift] needs a kind".into())),
            (None, Some(_)) => {
                return Err(Error::Config(
                    "[drift] declares a distribution but no at_iter".into(),
                ))
            }
        };
        if doc.get_bool("adaptive.enabled").unwrap_or(false) {
            let d = AdaptiveConfig::default();
            let get_usize = |key: &str, default: usize| -> Result<usize> {
                match doc.get_i64(key) {
                    None => Ok(default),
                    Some(v) => usize::try_from(v)
                        .map_err(|_| Error::Config(format!("{key} must be nonnegative"))),
                }
            };
            let settings = AdaptiveSettings {
                window: get_usize("adaptive.window", d.window)?,
                check_every: get_usize("adaptive.check_every", d.check_every)?,
                cooldown: get_usize("adaptive.cooldown", d.cooldown)?,
                min_samples: get_usize("adaptive.min_samples", d.min_samples)?,
                drift_threshold: doc
                    .get_f64("adaptive.drift_threshold")
                    .unwrap_or(d.drift_threshold),
                estimator: doc.get_str("adaptive.estimator").unwrap_or("mle").to_string(),
                family: doc.get_str("adaptive.family").unwrap_or("auto").to_string(),
                resolve: doc.get_str("adaptive.resolve").unwrap_or("closed_form").to_string(),
            };
            settings.build()?; // validate eagerly so load-time errors are loud
            cfg.adaptive = Some(settings);
        }
        cfg.hetero = HeteroSettings::parse(doc)?;
        if cfg.hetero.is_some() && cfg.adaptive.is_none() {
            return Err(Error::Config(
                "[hetero] requires an enabled [adaptive] section (it is a sensing/actuation \
                 extension of the adaptive policy)"
                    .into(),
            ));
        }
        cfg.elastic = ElasticSettings::parse(doc)?;
        cfg.pool = PoolSettings::parse(doc)?;
        cfg.jobs = JobsSettings::parse(doc)?;
        if cfg.workers == 0 || cfg.coords == 0 || cfg.samples == 0 {
            return Err(Error::Config("workers/coords/samples must be ≥ 1".into()));
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_doc(&TomlDoc::load(path)?)
    }

    /// The [`ProblemSpec`] these dimensions define.
    pub fn spec(&self) -> ProblemSpec {
        ProblemSpec::new(self.workers, self.coords, self.samples, self.cycles_per_coord)
    }

    /// The fully-assembled adaptive policy: `[adaptive]` with the
    /// `[hetero]` extension attached when declared.
    pub fn adaptive_config(&self) -> Result<Option<AdaptiveConfig>> {
        match &self.adaptive {
            None => Ok(None),
            Some(a) => {
                let mut cfg = a.build()?;
                cfg.hetero = self.hetero.as_ref().map(HeteroSettings::build);
                Ok(Some(cfg))
            }
        }
    }

    /// The straggler schedule: stationary, or two-phase when `[drift]`
    /// is declared.
    pub fn schedule(&self) -> StragglerSchedule {
        let base = StragglerSchedule::stationary(self.distribution.build());
        match &self.drift {
            Some(p) => base.then(p.at_iter, p.distribution.build()),
            None => base,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let cfg = ExperimentConfig::default();
        let spec = cfg.spec();
        assert_eq!(spec.n, 20);
        assert_eq!(spec.coords, 20_000);
        assert!(cfg.drift.is_none());
        assert!(cfg.adaptive.is_none());
    }

    #[test]
    fn parse_full_config() {
        let doc = TomlDoc::parse(
            r#"
            name = "fig4a"
            workers = 30
            coords = 20000
            samples = 50
            trials = 1000
            seed = 7
            [distribution]
            kind = "shifted_exp"
            mu = 1e-3
            t0 = 50
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.workers, 30);
        assert_eq!(cfg.seed, 7);
        let d = cfg.distribution.build();
        assert!((d.mean() - 1050.0).abs() < 1e-9);
    }

    #[test]
    fn parse_drift_and_adaptive_sections() {
        let doc = TomlDoc::parse(
            r#"
            workers = 16
            [distribution]
            kind = "shifted_exp"
            mu = 1e-2
            [drift]
            at_iter = 150
            kind = "shifted_exp"
            mu = 1e-3
            t0 = 80
            [adaptive]
            enabled = true
            window = 320
            drift_threshold = 0.25
            estimator = "moments"
            family = "weibull"
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let drift = cfg.drift.as_ref().expect("drift parsed");
        assert_eq!(drift.at_iter, 150);
        assert!((drift.distribution.build().mean() - 1080.0).abs() < 1e-9);
        let ad = cfg.adaptive.as_ref().expect("adaptive parsed");
        assert_eq!(ad.window, 320);
        assert_eq!(ad.estimator, "moments");
        assert_eq!(ad.family, "weibull");
        let built = ad.build().unwrap();
        assert!((built.drift_threshold - 0.25).abs() < 1e-12);
        assert_eq!(built.family, FamilyPolicy::Weibull);
        // Defaults fill unset knobs.
        assert_eq!(built.check_every, AdaptiveConfig::default().check_every);
        // The schedule shifts at the declared iteration.
        let sched = cfg.schedule();
        assert_eq!(sched.shift_points(), vec![150]);
        assert!((sched.dist_at(0).mean() - 150.0).abs() < 1e-9);
        assert!((sched.dist_at(150).mean() - 1080.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_disabled_by_default_and_bad_values_rejected() {
        let doc = TomlDoc::parse("[adaptive]\nwindow = 100").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(cfg.adaptive.is_none(), "adaptive requires enabled = true");

        let doc = TomlDoc::parse("[adaptive]\nenabled = true\nestimator = \"magic\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());

        // Out-of-range numeric knobs fail at load time, not at spawn.
        for bad in [
            "[adaptive]\nenabled = true\nwindow = 0",
            "[adaptive]\nenabled = true\nwindow = -1",
            "[adaptive]\nenabled = true\nmin_samples = 1",
            "[adaptive]\nenabled = true\ncheck_every = 0",
            "[adaptive]\nenabled = true\ndrift_threshold = 0.0",
            "[adaptive]\nenabled = true\nfamily = \"cauchy\"",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ExperimentConfig::from_doc(&doc).is_err(), "{bad}");
        }

        let doc = TomlDoc::parse("[drift]\nat_iter = 0\nkind = \"deterministic\"\nvalue = 1")
            .unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());

        let doc = TomlDoc::parse("[drift]\nat_iter = 10").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err(), "[drift] without kind");

        // The inverse omission must be just as loud: a drift distribution
        // without at_iter must not silently run stationary.
        let doc = TomlDoc::parse("[drift]\nkind = \"deterministic\"\nvalue = 1").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err(), "[drift] without at_iter");
    }

    #[test]
    fn parse_hetero_section() {
        let doc = TomlDoc::parse(
            r#"
            workers = 8
            [adaptive]
            enabled = true
            [hetero]
            enabled = true
            per_worker_window = 96
            min_worker_samples = 12
            speed_weighted_shards = false
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let h = cfg.hetero.as_ref().expect("hetero parsed");
        assert_eq!(h.per_worker_window, 96);
        assert_eq!(h.min_worker_samples, 12);
        assert!(!h.speed_weighted_shards);
        let built = cfg.adaptive_config().unwrap().expect("adaptive policy assembled");
        let hc = built.hetero.expect("hetero attached to the adaptive policy");
        assert_eq!(hc.per_worker_window, 96);
        assert_eq!(hc.min_worker_samples, 12);
        assert!(!hc.speed_weighted_shards);

        // Defaults fill unset knobs; shards weighting defaults on.
        let doc = TomlDoc::parse("[adaptive]\nenabled = true\n[hetero]\nenabled = true").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let h = cfg.hetero.unwrap();
        let d = HeteroConfig::default();
        assert_eq!(h.per_worker_window, d.per_worker_window);
        assert_eq!(h.min_worker_samples, d.min_worker_samples);
        assert!(h.speed_weighted_shards);
    }

    #[test]
    fn hetero_section_rejects_bad_values_and_requires_adaptive() {
        for bad in [
            "[adaptive]\nenabled = true\n[hetero]\nenabled = true\nper_worker_window = 1",
            "[adaptive]\nenabled = true\n[hetero]\nenabled = true\nmin_worker_samples = 0",
            // [hetero] without an adaptive policy has nothing to attach to.
            "[hetero]\nenabled = true",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ExperimentConfig::from_doc(&doc).is_err(), "{bad}");
        }
        // Disabled by default; an adaptive-only config carries no hetero.
        let doc = TomlDoc::parse("[adaptive]\nenabled = true\n[hetero]\nper_worker_window = 9")
            .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(cfg.hetero.is_none(), "hetero requires enabled = true");
        assert!(cfg.adaptive_config().unwrap().unwrap().hetero.is_none());
    }

    #[test]
    fn parse_elastic_section() {
        let doc = TomlDoc::parse(
            r#"
            workers = 10
            [elastic]
            enabled = true
            churn_threshold = 2
            depart_at = [100, 150]
            arrive_at = [220]
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let el = cfg.elastic.as_ref().expect("elastic parsed");
        assert_eq!(el.churn_threshold, 2);
        assert_eq!(el.depart_at, vec![100, 150]);
        assert_eq!(el.arrive_at, vec![220]);
        let built = el.build();
        assert_eq!(built.departures, vec![(100, 1), (150, 1)]);
        assert_eq!(built.arrivals, vec![(220, 1)]);
        let churn = el.churn_schedule();
        assert_eq!(churn.first_change(), Some(100));
        assert_eq!(churn.n_at(160, 10), 8);
        assert_eq!(churn.n_at(220, 10), 9);
    }

    #[test]
    fn elastic_disabled_by_default_and_bad_values_rejected() {
        let doc = TomlDoc::parse("[elastic]\ndepart_at = [10]").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(cfg.elastic.is_none(), "elastic requires enabled = true");
        for bad in [
            "[elastic]\nenabled = true\nchurn_threshold = 0",
            "[elastic]\nenabled = true\ndepart_at = [0]",
            "[elastic]\nenabled = true\ndepart_at = 7",
            "[elastic]\nenabled = true\narrive_at = [30, 10]",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ExperimentConfig::from_doc(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn parse_pool_and_jobs_sections() {
        let doc = TomlDoc::parse(
            r#"
            workers = 8
            [pool]
            workers = 8
            schedule = "weighted"
            [jobs]
            count = 2
            steps = [150, 50]
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        let pool = cfg.pool.as_ref().expect("pool parsed");
        assert_eq!(pool.workers, Some(8));
        assert_eq!(pool.schedule_mode(), ScheduleMode::WeightedUnitWork);
        let jobs = cfg.jobs.as_ref().expect("jobs parsed");
        assert_eq!(jobs.count, 2);
        assert_eq!(jobs.steps, vec![150, 50]);

        // Scalar steps replicate; schedule defaults to round_robin.
        let doc = TomlDoc::parse("[pool]\nworkers = 4\n[jobs]\ncount = 3\nsteps = 40").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.pool.as_ref().unwrap().schedule_mode(), ScheduleMode::RoundRobin);
        assert_eq!(cfg.jobs.as_ref().unwrap().steps, vec![40, 40, 40]);
    }

    #[test]
    fn pool_and_jobs_sections_reject_bad_values() {
        for bad in [
            "[pool]\nworkers = 0",
            "[pool]\nschedule = \"lottery\"",
            "[jobs]\ncount = 0",
            "[jobs]\nsteps = [10]",
            "[jobs]\ncount = 1\nsteps = [10, 20]",
            "[jobs]\ncount = 2\nsteps = 0",
            "[jobs]\ncount = 2\nsteps = \"many\"",
        ] {
            let doc = TomlDoc::parse(bad).unwrap();
            assert!(ExperimentConfig::from_doc(&doc).is_err(), "{bad}");
        }
        // Absent sections parse to None.
        let cfg = ExperimentConfig::from_doc(&TomlDoc::parse("workers = 4").unwrap()).unwrap();
        assert!(cfg.pool.is_none() && cfg.jobs.is_none());
    }

    #[test]
    fn unknown_distribution_rejected() {
        let doc = TomlDoc::parse("[distribution]\nkind = \"cauchy\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn all_dist_kinds_build() {
        for (kind, extra) in [
            ("shifted_exp", "mu = 0.001"),
            ("weibull", "shape = 1.2\nscale = 5\nshift = 1"),
            ("pareto", "alpha = 2.0\nxm = 1.0"),
            ("two_point", "fast = 1\nslow = 6"),
            ("deterministic", "value = 2"),
            ("lognormal", "mu = 3\nsigma = 0.5\nshift = 10"),
            ("gamma", "shape = 2\nscale = 100\nshift = 25"),
        ] {
            let text = format!("[distribution]\nkind = \"{kind}\"\n{extra}");
            let cfg = ExperimentConfig::from_doc(&TomlDoc::parse(&text).unwrap()).unwrap();
            let _ = cfg.distribution.build();
        }
    }
}
