//! Typed experiment configuration, loadable from a TOML-subset file
//! (see `configs/` for the shipped experiment definitions).

use std::path::Path;

use crate::config::toml_lite::TomlDoc;
use crate::distribution::{
    gamma::Gamma, lognormal::LogNormal, pareto::Pareto, shifted_exp::ShiftedExponential,
    weibull::Weibull, CycleTimeDistribution, Deterministic, TwoPoint,
};
use crate::optimizer::runtime_model::ProblemSpec;
use crate::{Error, Result};

/// A fully-specified experiment: problem dimensions, straggler model,
/// Monte-Carlo budget and seed.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub name: String,
    pub workers: usize,
    pub coords: usize,
    pub samples: usize,
    pub cycles_per_coord: f64,
    pub trials: usize,
    pub seed: u64,
    pub distribution: DistConfig,
}

/// Straggler-model choice (mirrors `distribution::*`).
#[derive(Debug, Clone)]
pub enum DistConfig {
    ShiftedExp { mu: f64, t0: f64 },
    Weibull { shape: f64, scale: f64, shift: f64 },
    Pareto { alpha: f64, xm: f64 },
    TwoPoint { fast: f64, slow: f64, p_slow: f64 },
    Deterministic { value: f64 },
    LogNormal { mu: f64, sigma: f64, shift: f64 },
    Gamma { shape: f64, scale: f64, shift: f64 },
}

impl DistConfig {
    /// Instantiate the distribution object.
    pub fn build(&self) -> Box<dyn CycleTimeDistribution> {
        match *self {
            DistConfig::ShiftedExp { mu, t0 } => Box::new(ShiftedExponential::new(mu, t0)),
            DistConfig::Weibull { shape, scale, shift } => {
                Box::new(Weibull::new(shape, scale, shift))
            }
            DistConfig::Pareto { alpha, xm } => Box::new(Pareto::new(alpha, xm)),
            DistConfig::TwoPoint { fast, slow, p_slow } => {
                Box::new(TwoPoint::new(fast, slow, p_slow))
            }
            DistConfig::Deterministic { value } => Box::new(Deterministic::new(value)),
            DistConfig::LogNormal { mu, sigma, shift } => {
                Box::new(LogNormal::new(mu, sigma, shift))
            }
            DistConfig::Gamma { shape, scale, shift } => Box::new(Gamma::new(shape, scale, shift)),
        }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            name: "default".into(),
            workers: 20,
            coords: 20_000,
            samples: 50,
            cycles_per_coord: 1.0,
            trials: 2000,
            seed: 2021,
            distribution: DistConfig::ShiftedExp { mu: 1e-3, t0: 50.0 },
        }
    }
}

impl ExperimentConfig {
    /// Parse from a TOML-subset document.
    pub fn from_doc(doc: &TomlDoc) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        if let Some(v) = doc.get_str("name") {
            cfg.name = v.to_string();
        }
        if let Some(v) = doc.get_i64("workers") {
            cfg.workers = usize::try_from(v)
                .map_err(|_| Error::Config("workers must be positive".into()))?;
        }
        if let Some(v) = doc.get_i64("coords") {
            cfg.coords =
                usize::try_from(v).map_err(|_| Error::Config("coords must be positive".into()))?;
        }
        if let Some(v) = doc.get_i64("samples") {
            cfg.samples = usize::try_from(v)
                .map_err(|_| Error::Config("samples must be positive".into()))?;
        }
        if let Some(v) = doc.get_f64("cycles_per_coord") {
            cfg.cycles_per_coord = v;
        }
        if let Some(v) = doc.get_i64("trials") {
            cfg.trials =
                usize::try_from(v).map_err(|_| Error::Config("trials must be positive".into()))?;
        }
        if let Some(v) = doc.get_i64("seed") {
            cfg.seed = v as u64;
        }
        if let Some(kind) = doc.get_str("distribution.kind") {
            cfg.distribution = match kind {
                "shifted_exp" => DistConfig::ShiftedExp {
                    mu: doc
                        .get_f64("distribution.mu")
                        .ok_or_else(|| Error::Config("shifted_exp needs mu".into()))?,
                    t0: doc.get_f64("distribution.t0").unwrap_or(50.0),
                },
                "weibull" => DistConfig::Weibull {
                    shape: doc
                        .get_f64("distribution.shape")
                        .ok_or_else(|| Error::Config("weibull needs shape".into()))?,
                    scale: doc
                        .get_f64("distribution.scale")
                        .ok_or_else(|| Error::Config("weibull needs scale".into()))?,
                    shift: doc.get_f64("distribution.shift").unwrap_or(0.0),
                },
                "pareto" => DistConfig::Pareto {
                    alpha: doc
                        .get_f64("distribution.alpha")
                        .ok_or_else(|| Error::Config("pareto needs alpha".into()))?,
                    xm: doc
                        .get_f64("distribution.xm")
                        .ok_or_else(|| Error::Config("pareto needs xm".into()))?,
                },
                "two_point" => DistConfig::TwoPoint {
                    fast: doc
                        .get_f64("distribution.fast")
                        .ok_or_else(|| Error::Config("two_point needs fast".into()))?,
                    slow: doc
                        .get_f64("distribution.slow")
                        .ok_or_else(|| Error::Config("two_point needs slow".into()))?,
                    p_slow: doc.get_f64("distribution.p_slow").unwrap_or(0.5),
                },
                "lognormal" => DistConfig::LogNormal {
                    mu: doc
                        .get_f64("distribution.mu")
                        .ok_or_else(|| Error::Config("lognormal needs mu".into()))?,
                    sigma: doc
                        .get_f64("distribution.sigma")
                        .ok_or_else(|| Error::Config("lognormal needs sigma".into()))?,
                    shift: doc.get_f64("distribution.shift").unwrap_or(0.0),
                },
                "gamma" => DistConfig::Gamma {
                    shape: doc
                        .get_f64("distribution.shape")
                        .ok_or_else(|| Error::Config("gamma needs shape".into()))?,
                    scale: doc
                        .get_f64("distribution.scale")
                        .ok_or_else(|| Error::Config("gamma needs scale".into()))?,
                    shift: doc.get_f64("distribution.shift").unwrap_or(0.0),
                },
                "deterministic" => DistConfig::Deterministic {
                    value: doc
                        .get_f64("distribution.value")
                        .ok_or_else(|| Error::Config("deterministic needs value".into()))?,
                },
                other => {
                    return Err(Error::Config(format!("unknown distribution kind {other:?}")))
                }
            };
        }
        if cfg.workers == 0 || cfg.coords == 0 || cfg.samples == 0 {
            return Err(Error::Config("workers/coords/samples must be ≥ 1".into()));
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_doc(&TomlDoc::load(path)?)
    }

    /// The [`ProblemSpec`] these dimensions define.
    pub fn spec(&self) -> ProblemSpec {
        ProblemSpec::new(self.workers, self.coords, self.samples, self.cycles_per_coord)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_roundtrip() {
        let cfg = ExperimentConfig::default();
        let spec = cfg.spec();
        assert_eq!(spec.n, 20);
        assert_eq!(spec.coords, 20_000);
    }

    #[test]
    fn parse_full_config() {
        let doc = TomlDoc::parse(
            r#"
            name = "fig4a"
            workers = 30
            coords = 20000
            samples = 50
            trials = 1000
            seed = 7
            [distribution]
            kind = "shifted_exp"
            mu = 1e-3
            t0 = 50
            "#,
        )
        .unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.workers, 30);
        assert_eq!(cfg.seed, 7);
        let d = cfg.distribution.build();
        assert!((d.mean() - 1050.0).abs() < 1e-9);
    }

    #[test]
    fn unknown_distribution_rejected() {
        let doc = TomlDoc::parse("[distribution]\nkind = \"cauchy\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }

    #[test]
    fn all_dist_kinds_build() {
        for (kind, extra) in [
            ("shifted_exp", "mu = 0.001"),
            ("weibull", "shape = 1.2\nscale = 5\nshift = 1"),
            ("pareto", "alpha = 2.0\nxm = 1.0"),
            ("two_point", "fast = 1\nslow = 6"),
            ("deterministic", "value = 2"),
            ("lognormal", "mu = 3\nsigma = 0.5\nshift = 10"),
            ("gamma", "shape = 2\nscale = 100\nshift = 25"),
        ] {
            let text = format!("[distribution]\nkind = \"{kind}\"\n{extra}");
            let cfg = ExperimentConfig::from_doc(&TomlDoc::parse(&text).unwrap()).unwrap();
            let _ = cfg.distribution.build();
        }
    }
}
