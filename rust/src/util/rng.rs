//! Deterministic pseudo-random number generation.
//!
//! The build environment has no `rand` crate, so we implement the generators
//! we need: SplitMix64 for seeding and xoshiro256++ as the workhorse, plus
//! the sampling transforms used by the straggler models (uniform,
//! exponential via inverse CDF, normal via Box–Muller variant, etc.).
//!
//! Everything is deterministic given a seed; experiments record their seeds
//! so every figure is exactly reproducible.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 256-bit state general-purpose PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from the last Box–Muller draw.
    cached_normal: Option<f64>,
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, cached_normal: None }
    }

    /// Deterministically derive an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mix = self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(mix)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = rotl(self.s[0].wrapping_add(self.s[3]), 23).wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = rotl(self.s[3], 45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `(0, 1]` — safe to feed into `ln`.
    #[inline]
    pub fn uniform_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`, unbiased via Lemire rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// `Exp(rate)` via inverse CDF.
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform_open().ln() / rate
    }

    /// Standard normal via Box–Muller (polar-free version, caches the pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Fill a slice with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            let v = r.uniform_open();
            assert!(v > 0.0 && v <= 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            sum += u;
            sumsq += u * u;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let rate = 2.5;
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 1e-2, "mean={mean}");
        assert!((var - 1.0).abs() < 2e-2, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(19);
        for _ in 0..100 {
            let k = r.sample_indices(20, 8);
            assert_eq!(k.len(), 8);
            let mut s = k.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
