//! Foundational utilities built from scratch for the offline environment:
//! deterministic RNG, special functions, statistics and a tiny logger.

pub mod buffers;
pub mod logging;
pub mod rng;
pub mod special;
pub mod stats;
